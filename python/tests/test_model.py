"""Train/eval step tests: loss decreases, gates behave, specs line up."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, models


def _make_args(name, nc, B, seed=0, intensity=1.0, lam=0.0, rho_gate=0.0,
               noise_gate=1.0):
    params = models.init_params(jax.random.PRNGKey(0), name, nc)
    rho = models.init_rho_raw(name, nc)
    zeros = lambda: [jnp.zeros_like(p) for p in params]
    zr = jnp.zeros_like(rho)
    x = jax.random.uniform(jax.random.PRNGKey(1), (B, 32, 32, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (B,), 0, nc)
    return (
        params
        + [rho]
        + zeros()
        + zeros()
        + [zr, zr]
        + [
            jnp.zeros((1,)),
            x,
            y,
            jnp.array([seed], jnp.int32),
            jnp.array([intensity]),
            jnp.array([lam]),
            jnp.array([rho_gate]),
            jnp.array([noise_gate]),
        ]
    )


class TestTrainStep:
    def test_loss_decreases_mlp(self):
        """A few steps of the jitted train step must reduce the loss."""
        name, nc, B = "mlp", 10, 16
        step_fn, _ = model.make_train_step(name, nc, B)
        jstep = jax.jit(step_fn)
        args = _make_args(name, nc, B, noise_gate=0.0)
        n_params = 2 * models.num_param_layers(name, nc)
        losses = []
        for t in range(8):
            out = jstep(*args)
            losses.append(float(out[-3][0]))
            # thread state: params, rho, m, v, m_rho, v_rho / bump step
            state = list(out[: 3 * n_params + 3])
            params = state[:n_params]
            rho = state[n_params]
            m = state[n_params + 1 : 2 * n_params + 1]
            v = state[2 * n_params + 1 : 3 * n_params + 1]
            m_rho, v_rho = state[-2], state[-1]
            args = (
                params
                + [rho]
                + m
                + v
                + [m_rho, v_rho]
                + [jnp.array([float(t + 1)])]
                + args[3 * n_params + 4 :]
            )
        assert losses[-1] < losses[0]

    def test_rho_gate_freezes_rho(self):
        name, nc, B = "mlp", 10, 8
        step_fn, _ = model.make_train_step(name, nc, B)
        n_params = 2 * models.num_param_layers(name, nc)
        out = jax.jit(step_fn)(*_make_args(name, nc, B, rho_gate=0.0, lam=0.1))
        rho_new = out[n_params]
        rho_old = models.init_rho_raw(name, nc)
        np.testing.assert_allclose(rho_new, rho_old, atol=1e-7)

    def test_rho_moves_with_energy_reg(self):
        """Technique B: with lam > 0 and the gate open, rho must move."""
        name, nc, B = "mlp", 10, 8
        step_fn, _ = model.make_train_step(name, nc, B)
        n_params = 2 * models.num_param_layers(name, nc)
        out = jax.jit(step_fn)(*_make_args(name, nc, B, rho_gate=1.0, lam=1.0))
        rho_new = np.asarray(out[n_params])
        rho_old = np.asarray(models.init_rho_raw(name, nc))
        assert np.abs(rho_new - rho_old).max() > 1e-6

    def test_energy_reg_pushes_rho_down(self):
        """Gradient of the energy term alone must decrease rho (Fig 7)."""
        name, nc, B = "mlp", 10, 8
        step_fn, _ = model.make_train_step(name, nc, B)
        n_params = 2 * models.num_param_layers(name, nc)
        # huge lambda so the energy term dominates CE
        out = jax.jit(step_fn)(*_make_args(name, nc, B, rho_gate=1.0, lam=1e4))
        rho_new = np.asarray(out[n_params])
        rho_old = np.asarray(models.init_rho_raw(name, nc))
        assert (rho_new < rho_old).all()

    def test_noise_gate_deterministic(self):
        name, nc, B = "mlp", 10, 8
        step_fn, _ = model.make_train_step(name, nc, B)
        o1 = jax.jit(step_fn)(*_make_args(name, nc, B, seed=1, noise_gate=0.0))
        o2 = jax.jit(step_fn)(*_make_args(name, nc, B, seed=2, noise_gate=0.0))
        np.testing.assert_allclose(o1[-3], o2[-3], rtol=1e-6)


class TestEvalStep:
    def test_counts_bounded(self):
        name, nc, B = "mlp", 10, 32
        eval_fn, _ = model.make_eval_step(name, nc, B)
        params = models.init_params(jax.random.PRNGKey(0), name, nc)
        rho = models.init_rho_raw(name, nc)
        x = jax.random.uniform(jax.random.PRNGKey(1), (B, 32, 32, 3))
        y = jax.random.randint(jax.random.PRNGKey(2), (B,), 0, nc)
        top1, top5, loss_sum, energy = jax.jit(eval_fn)(
            *params, rho, x, y,
            jnp.array([0], jnp.int32), jnp.array([1.0]), jnp.array([1.0]),
        )
        assert 0 <= float(top1[0]) <= B
        assert float(top1[0]) <= float(top5[0]) <= B
        assert float(energy[0]) > 0

    def test_decomp_energy_lower(self):
        """A+B+C eval reports less analog energy than single-read eval."""
        name, nc, B = "mlp", 10, 32
        params = models.init_params(jax.random.PRNGKey(0), name, nc)
        rho = models.init_rho_raw(name, nc)
        x = jax.random.uniform(jax.random.PRNGKey(1), (B, 32, 32, 3))
        y = jax.random.randint(jax.random.PRNGKey(2), (B,), 0, nc)
        common = (
            *params, rho, x, y,
            jnp.array([0], jnp.int32), jnp.array([1.0]), jnp.array([1.0]),
        )
        e_ori = jax.jit(model.make_eval_step(name, nc, B)[0])(*common)[3]
        e_new = jax.jit(model.make_eval_step(name, nc, B, decomposed=True)[0])(
            *common
        )[3]
        assert float(e_new[0]) < float(e_ori[0])


class TestSpecs:
    @pytest.mark.parametrize("name", ["mlp", "tiny_resnet"])
    def test_train_spec_counts(self, name):
        nc, B = 10, 4
        step_fn, specs = model.make_train_step(name, nc, B)
        n_params = 2 * models.num_param_layers(name, nc)
        assert len(specs) == 3 * n_params + 3 + 8
        out = jax.eval_shape(step_fn, *model.abstract_inputs(specs))
        assert len(out) == 3 * n_params + 3 + 3

    def test_eval_spec_counts(self):
        eval_fn, specs = model.make_eval_step("mlp", 10, 4)
        out = jax.eval_shape(eval_fn, *model.abstract_inputs(specs))
        assert len(out) == 4

    def test_init_artifact_matches_params(self):
        from compile import aot

        init_fn, specs = aot.make_init("mlp", 10)
        outs = init_fn(jnp.array([0], jnp.int32))
        params = models.init_params(jax.random.PRNGKey(0), "mlp", 10)
        assert len(outs) == len(params) + 1
        for o, p in zip(outs, params):
            np.testing.assert_allclose(o, p, rtol=1e-6)
