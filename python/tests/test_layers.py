"""Noisy layer tests: exact-vs-CLT equivalence, gradient flow, decomposition."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers, models


def _cfg(intensity=1.0, noise_gate=1.0, act_bits=4, weight_bits=8):
    return {
        "act_bits": act_bits,
        "weight_bits": weight_bits,
        "intensity": intensity,
        "noise_gate": noise_gate,
    }


class TestNoisyDense:
    def test_noiseless_when_gated(self):
        x = jax.random.uniform(jax.random.PRNGKey(0), (8, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
        b = jnp.zeros((16,))
        y, st = layers.noisy_dense(
            jax.random.PRNGKey(2), x, w, b, 4.0, _cfg(noise_gate=0.0)
        )
        # only quantisation error remains
        xq, _, _ = __import__("compile.quant", fromlist=["quant_act"]).quant_act(x, 4)
        wq, _ = __import__("compile.quant", fromlist=["quant_weight"]).quant_weight(w, 8)
        np.testing.assert_allclose(y, xq @ wq, rtol=1e-4, atol=1e-4)

    def test_noise_decreases_with_rho(self):
        """Paper Fig 2(b): higher energy coefficient -> tighter outputs."""
        x = jax.random.uniform(jax.random.PRNGKey(0), (16, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
        b = jnp.zeros((8,))

        def spread(rho):
            outs = [
                layers.noisy_dense(jax.random.PRNGKey(t), x, w, b, rho, _cfg())[0]
                for t in range(24)
            ]
            return float(jnp.std(jnp.stack(outs), axis=0).mean())

        assert spread(16.0) < spread(1.0) < spread(0.1)

    def test_exact_and_clt_same_variance(self):
        """Force both paths on the same layer; fluctuation std must agree."""
        x = jax.random.uniform(jax.random.PRNGKey(0), (8, 128))
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 32))
        b = jnp.zeros((32,))
        budget = layers.EXACT_BUDGET

        def spread():
            outs = [
                layers.noisy_dense(jax.random.PRNGKey(t), x, w, b, 1.0, _cfg())[0]
                for t in range(64)
            ]
            return float(jnp.std(jnp.stack(outs), axis=0).mean())

        s_exact = spread()
        try:
            layers.EXACT_BUDGET = 0  # force CLT
            s_clt = spread()
        finally:
            layers.EXACT_BUDGET = budget
        assert s_clt == pytest.approx(s_exact, rel=0.2)

    def test_gradients_finite(self):
        x = jax.random.uniform(jax.random.PRNGKey(0), (4, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        b = jnp.zeros((8,))

        def f(w, rho):
            y, _ = layers.noisy_dense(jax.random.PRNGKey(2), x, w, b, rho, _cfg())
            return jnp.sum(y * y)

        gw, grho = jax.grad(f, argnums=(0, 1))(w, 2.0)
        assert np.all(np.isfinite(np.asarray(gw)))
        assert np.isfinite(float(grho))

    def test_rho_gradient_nonzero(self):
        """Technique B depends on dL/drho flowing through the noise."""
        x = jax.random.uniform(jax.random.PRNGKey(0), (4, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        b = jnp.zeros((8,))

        def f(rho):
            y, _ = layers.noisy_dense(jax.random.PRNGKey(2), x, w, b, rho, _cfg())
            return jnp.sum(y * y)

        assert abs(float(jax.grad(f)(2.0))) > 0.0


class TestDecomposedDense:
    def test_matches_plain_when_noiseless(self):
        x = jax.random.uniform(jax.random.PRNGKey(0), (8, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
        b = jax.random.normal(jax.random.PRNGKey(2), (16,))
        y0, _ = layers.noisy_dense(
            jax.random.PRNGKey(3), x, w, b, 4.0, _cfg(noise_gate=0.0)
        )
        y1, _ = layers.noisy_dense_decomp(
            jax.random.PRNGKey(3), x, w, b, 4.0, _cfg(noise_gate=0.0)
        )
        np.testing.assert_allclose(y0, y1, rtol=1e-3, atol=1e-3)

    def test_lower_fluctuation_than_plain(self):
        """Technique C headline claim (eq. 18) at the layer level."""
        x = jax.random.uniform(jax.random.PRNGKey(0), (16, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
        b = jnp.zeros((8,))

        def spread(fn):
            outs = [
                fn(jax.random.PRNGKey(t), x, w, b, 0.5, _cfg())[0]
                for t in range(48)
            ]
            return float(jnp.std(jnp.stack(outs), axis=0).mean())

        assert spread(layers.noisy_dense_decomp) < spread(layers.noisy_dense)

    def test_lower_energy_than_plain(self):
        """Technique C energy claim (eq. 20) from the layer stats."""
        x = jax.random.uniform(jax.random.PRNGKey(0), (16, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
        b = jnp.zeros((8,))
        _, st_ori = layers.noisy_dense(jax.random.PRNGKey(2), x, w, b, 1.0, _cfg())
        _, st_new = layers.noisy_dense_decomp(
            jax.random.PRNGKey(2), x, w, b, 1.0, _cfg()
        )
        assert float(st_new["energy"]) < float(st_ori["energy"])


class TestNoisyConv:
    def test_noiseless_gate(self):
        x = jax.random.uniform(jax.random.PRNGKey(0), (2, 8, 8, 3))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 8)) * 0.2
        b = jnp.zeros((8,))
        y1, _ = layers.noisy_conv(
            jax.random.PRNGKey(2), x, w, b, 1.0, _cfg(noise_gate=0.0)
        )
        y2, _ = layers.noisy_conv(
            jax.random.PRNGKey(3), x, w, b, 1.0, _cfg(noise_gate=0.0)
        )
        np.testing.assert_allclose(y1, y2, rtol=1e-6)

    def test_depthwise_shapes(self):
        x = jax.random.uniform(jax.random.PRNGKey(0), (2, 8, 8, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 1, 16)) * 0.2
        b = jnp.zeros((16,))
        y, st = layers.noisy_conv(
            jax.random.PRNGKey(2), x, w, b, 1.0, _cfg(), stride=2, groups=16
        )
        assert y.shape == (2, 4, 4, 16)

    def test_alpha_is_output_area(self):
        x = jax.random.uniform(jax.random.PRNGKey(0), (2, 8, 8, 3))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 8)) * 0.2
        b = jnp.zeros((8,))
        _, st = layers.noisy_conv(jax.random.PRNGKey(2), x, w, b, 1.0, _cfg())
        assert st["alpha"] == 64.0


class TestModelForward:
    @pytest.mark.parametrize("name", models.MODEL_NAMES)
    def test_shapes_and_finite(self, name):
        params = models.init_params(jax.random.PRNGKey(0), name, 10)
        rho = models.init_rho_raw(name, 10)
        x = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))
        spec = models.model_spec(name, 10)
        logits, stats = models.forward(
            params, rho, x, jax.random.PRNGKey(2), _cfg(), spec
        )
        assert logits.shape == (2, 10)
        assert np.all(np.isfinite(np.asarray(logits)))
        assert len(stats) == models.num_param_layers(name, 10)

    @pytest.mark.parametrize("name", ["mlp", "tiny_resnet"])
    def test_decomposed_forward(self, name):
        params = models.init_params(jax.random.PRNGKey(0), name, 10)
        rho = models.init_rho_raw(name, 10)
        x = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))
        spec = models.model_spec(name, 10)
        logits, _ = models.forward(
            params, rho, x, jax.random.PRNGKey(2), _cfg(), spec, decomposed=True
        )
        assert logits.shape == (2, 10)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_layer_meta_matches_params(self):
        for name in models.MODEL_NAMES:
            metas = models.layer_meta(name, 10)
            params = models.init_params(jax.random.PRNGKey(0), name, 10)
            assert len(metas) == len(params) // 2
            for meta, w in zip(metas, params[0::2]):
                assert meta["cells"] == w.size
