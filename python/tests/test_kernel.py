"""Pallas kernel vs pure-jnp oracle — the CORE L1 correctness signal.

Hypothesis sweeps shapes; fixed seeds keep the suite deterministic.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import bitserial_matmul, emt_matmul
from compile.kernels.ref import bitserial_matmul_ref, clt_noise_std, emt_matmul_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape).astype(
        jnp.float32
    )


class TestEmtMatmul:
    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 48),
        k=st.integers(1, 96),
        n=st.integers(1, 160),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, b, k, n, seed):
        x = jax.random.uniform(jax.random.PRNGKey(seed), (b, k))
        w = _rand(seed + 1, k, n)
        d = _rand(seed + 2, b, k, n, scale=0.05)
        bias = _rand(seed + 3, n)
        got = emt_matmul(x, w, d, bias)
        want = emt_matmul_ref(x, w, d, bias)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_zero_delta_is_clean_matmul(self):
        x = jax.random.uniform(jax.random.PRNGKey(0), (8, 32))
        w = _rand(1, 32, 16)
        got = emt_matmul(x, w, jnp.zeros((8, 32, 16)), jnp.zeros((16,)))
        np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-5)

    def test_no_bias_default(self):
        x = jax.random.uniform(jax.random.PRNGKey(0), (4, 8))
        w = _rand(1, 8, 4)
        d = _rand(2, 4, 8, 4, scale=0.01)
        np.testing.assert_allclose(
            emt_matmul(x, w, d), emt_matmul_ref(x, w, d), rtol=1e-5, atol=1e-5
        )

    def test_tile_boundaries(self):
        """Shapes straddling the (32, 128) default tiles."""
        for b, n in [(31, 127), (32, 128), (33, 129), (65, 257)]:
            x = jax.random.uniform(jax.random.PRNGKey(b), (b, 24))
            w = _rand(n, 24, n)
            d = _rand(b + n, b, 24, n, scale=0.02)
            np.testing.assert_allclose(
                emt_matmul(x, w, d),
                emt_matmul_ref(x, w, d),
                rtol=2e-4,
                atol=2e-4,
            )


class TestBitserialMatmul:
    @settings(max_examples=15, deadline=None)
    @given(
        p=st.integers(1, 6),
        b=st.integers(1, 24),
        k=st.integers(1, 48),
        n=st.integers(1, 96),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, p, b, k, n, seed):
        key = jax.random.PRNGKey(seed)
        bits = (jax.random.uniform(key, (p, b, k)) > 0.5).astype(jnp.float32)
        w = _rand(seed + 1, k, n)
        d = _rand(seed + 2, p, b, k, n, scale=0.05)
        bias = _rand(seed + 3, n)
        got = bitserial_matmul(bits, w, d, bias)
        want = bitserial_matmul_ref(bits, w, d, bias)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_recomposes_integer_matmul(self):
        """sum_p 2^p bits_p == x  =>  bit-serial(clean) == x @ w."""
        key = jax.random.PRNGKey(7)
        levels = jax.random.randint(key, (8, 16), 0, 16).astype(jnp.float32)
        bits = jnp.stack(
            [jnp.mod(jnp.floor(levels / 2.0**p), 2.0) for p in range(4)]
        )
        w = _rand(1, 16, 12)
        got = bitserial_matmul(bits, w, jnp.zeros((4, 8, 16, 12)), jnp.zeros((12,)))
        np.testing.assert_allclose(got, levels @ w, rtol=1e-4, atol=1e-4)

    def test_fluctuation_reduction_sqrt_law(self):
        """eq (16)-(18): decomposed read noise std < original read std."""
        trials, b, k, n, p = 64, 4, 64, 8, 4
        key = jax.random.PRNGKey(0)
        levels = jax.random.randint(key, (b, k), 0, 2**p).astype(jnp.float32)
        bits = jnp.stack(
            [jnp.mod(jnp.floor(levels / 2.0**q), 2.0) for q in range(p)]
        )
        w = _rand(1, k, n)
        sigma = 0.1
        outs_ori, outs_new = [], []
        for t in range(trials):
            d1 = sigma * jax.random.normal(jax.random.PRNGKey(2 * t), (b, k, n))
            d4 = sigma * jax.random.normal(
                jax.random.PRNGKey(2 * t + 1), (p, b, k, n)
            )
            outs_ori.append(emt_matmul_ref(levels, w, d1))
            outs_new.append(bitserial_matmul_ref(bits, w, d4))
        std_ori = float(jnp.std(jnp.stack(outs_ori), axis=0).mean())
        std_new = float(jnp.std(jnp.stack(outs_new), axis=0).mean())
        assert std_new < std_ori


class TestCltSurrogate:
    def test_variance_matches_exact_sampling(self):
        """The conv-path CLT noise has the same variance as explicit
        per-read sampling (validates the DESIGN.md §2 substitution)."""
        from compile import layers

        b, k, n = 8, 256, 16
        x = jax.random.uniform(jax.random.PRNGKey(0), (b, k))
        sigma = 0.05
        trials = 200
        noise = []
        for t in range(trials):
            d = layers.sample_delta(jax.random.PRNGKey(t), (b, k, n), sigma)
            noise.append(jnp.einsum("bk,bkn->bn", x, d))
        emp_std = jnp.std(jnp.stack(noise), axis=0)  # (b, n)
        pred_std = clt_noise_std(x, sigma)  # (b, 1)
        np.testing.assert_allclose(
            emp_std.mean(axis=1), pred_std[:, 0], rtol=0.15
        )
