"""Quantiser unit tests: round-trips, ranges, STE gradients, bit-planes."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quant


class TestWeightQuant:
    def test_range_preserved(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        wq, s = quant.quant_weight(w, 8)
        assert float(jnp.max(jnp.abs(wq))) <= float(s) + 1e-6

    def test_levels_are_discrete(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (128,))
        wq, s = quant.quant_weight(w, 4)
        levels = wq / s * 7.0
        np.testing.assert_allclose(levels, jnp.round(levels), atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(bits=st.integers(2, 8), seed=st.integers(0, 1000))
    def test_error_bounded_by_half_step(self, bits, seed):
        w = jax.random.normal(jax.random.PRNGKey(seed), (256,))
        wq, s = quant.quant_weight(w, bits)
        step = s / (2.0 ** (bits - 1) - 1.0)
        assert float(jnp.max(jnp.abs(wq - w))) <= float(step) / 2 + 1e-6

    def test_ste_gradient_is_identity(self):
        w = jnp.array([0.3, -0.7, 0.1])
        g = jax.grad(lambda w: jnp.sum(quant.quant_weight(w, 8)[0]))(w)
        # away from the clip boundary, d(quant)/dw ~= 1 via STE (the max-|w|
        # element also sees a small gradient through the dynamic scale)
        np.testing.assert_allclose(g, jnp.ones_like(w), atol=1e-2)


class TestActQuant:
    def test_levels_in_range(self):
        x = jax.random.uniform(jax.random.PRNGKey(0), (32, 16)) * 3.0
        _, levels, _ = quant.quant_act(x, 4)
        assert float(levels.min()) >= 0.0
        assert float(levels.max()) <= 15.0

    def test_dequant_close(self):
        x = jax.random.uniform(jax.random.PRNGKey(1), (64,))
        xd, levels, s = quant.quant_act(x, 8)
        np.testing.assert_allclose(xd, levels * s, rtol=1e-6)
        assert float(jnp.max(jnp.abs(xd - x))) <= float(s) / 2 + 1e-6

    def test_non_negative_input_assumption(self):
        x = jnp.array([0.0, 0.5, 1.0])
        xd, levels, s = quant.quant_act(x, 2)
        assert float(levels.max()) == 3.0


class TestBitPlanes:
    @settings(max_examples=20, deadline=None)
    @given(bits=st.integers(1, 8), seed=st.integers(0, 1000))
    def test_exact_recomposition(self, bits, seed):
        levels = jax.random.randint(
            jax.random.PRNGKey(seed), (16, 8), 0, 2**bits
        ).astype(jnp.float32)
        planes = quant.bit_planes(levels, bits)
        recomposed = sum(planes[p] * 2.0**p for p in range(bits))
        np.testing.assert_allclose(recomposed, levels, atol=1e-4)

    def test_planes_binary(self):
        levels = jnp.arange(16.0).reshape(4, 4)
        planes = quant.bit_planes(levels, 4)
        vals = np.unique(np.asarray(planes))
        assert set(np.round(vals, 5)).issubset({0.0, 1.0})

    def test_lsb_first(self):
        planes = quant.bit_planes(jnp.array([[1.0]]), 4)
        np.testing.assert_allclose(planes[:, 0, 0], [1, 0, 0, 0], atol=1e-5)

    def test_gradient_flows(self):
        def f(x):
            _, levels, s = quant.quant_act(x, 4)
            planes = quant.bit_planes(levels, 4)
            return jnp.sum(sum(planes[p] * 2.0**p for p in range(4)) * s)

        g = jax.grad(f)(jnp.array([0.2, 0.8, 0.5]))
        assert np.all(np.isfinite(np.asarray(g)))
        assert float(jnp.abs(g).sum()) > 0.0
