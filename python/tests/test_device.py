"""Unit tests for the canonical device model."""

import numpy as np
import pytest

from compile import device


class TestStateOffsets:
    def test_zero_mean_unit_var(self):
        for m in (2, 3, 4, 8, 16):
            c = device.state_offsets(m)
            assert abs(float(c.mean())) < 1e-6
            assert abs(float(c.std()) - 1.0) < 1e-5

    def test_single_state_noiseless(self):
        c = device.state_offsets(1)
        assert c.shape == (1,) and c[0] == 0.0

    def test_symmetric(self):
        c = device.state_offsets(4)
        np.testing.assert_allclose(np.sort(c), -np.sort(-c)[::-1], atol=1e-6)

    def test_invalid(self):
        with pytest.raises(ValueError):
            device.state_offsets(0)


class TestSigma:
    def test_sigma_decreases_with_rho(self):
        """Higher energy coefficient -> lower fluctuation (Fig 2b)."""
        s = [float(device.sigma_rel(r)) for r in (0.25, 1.0, 4.0, 16.0)]
        assert all(a > b for a, b in zip(s, s[1:]))

    def test_sqrt_law(self):
        assert float(device.sigma_rel(4.0)) == pytest.approx(
            float(device.sigma_rel(1.0)) / 2.0, rel=1e-6
        )

    def test_intensity_scaling(self):
        w = float(device.sigma_rel(1.0, device.INTENSITY["weak"]))
        n = float(device.sigma_rel(1.0, device.INTENSITY["normal"]))
        s = float(device.sigma_rel(1.0, device.INTENSITY["strong"]))
        assert w < n < s
        assert s == pytest.approx(4 * w, rel=1e-6)

    def test_sigma_abs_scales_with_wscale(self):
        assert float(device.sigma_abs(1.0, 1.0, 2.0)) == pytest.approx(
            2 * float(device.sigma_abs(1.0, 1.0, 1.0)), rel=1e-6
        )


class TestEnergy:
    def test_energy_linear_in_rho(self):
        """E proportional to rho (Fig 2a / eq 19)."""
        assert float(device.read_energy(2.0, 0.5, 3.0)) == pytest.approx(
            2 * float(device.read_energy(1.0, 0.5, 3.0))
        )

    def test_energy_linear_in_weight(self):
        assert float(device.read_energy(1.0, 1.0, 3.0)) == pytest.approx(
            2 * float(device.read_energy(1.0, 0.5, 3.0))
        )

    def test_decomposed_cheaper(self):
        """eq (19)-(20): rho * sum(bits) < rho * level for any level >= 2."""
        for level in range(2, 16):
            bits = bin(level).count("1")
            e_ori = float(device.read_energy(1.0, 1.0, level))
            e_new = float(device.read_energy(1.0, 1.0, bits))
            assert e_new < e_ori
