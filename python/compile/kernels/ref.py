"""Pure-jnp oracles for the L1 Pallas kernels.

These are the ground truth the Pallas kernels (and, transitively, the Rust
crossbar simulator) are validated against.  They implement eq. (11) and
eq. (15) of the paper directly.
"""

from __future__ import annotations

import jax.numpy as jnp


def emt_matmul_ref(x, w, delta, bias=None):
    """Noisy crossbar MAC, eq. (11):  y[b,n] = sum_k x[b,k] * (w[k,n] + delta[b,k,n]).

    ``delta`` carries a fresh fluctuation sample per (sample, cell) read —
    the ``r(w, rho) ∘ S`` term with the deterministic part already folded in.

    Shapes: x (B, K), w (K, N), delta (B, K, N) -> (B, N).
    """
    y = x @ w + jnp.einsum("bk,bkn->bn", x, delta)
    if bias is not None:
        y = y + bias
    return y


def bitserial_matmul_ref(bits, w, delta, bias=None):
    """Low-fluctuation decomposed MAC, eq. (15):
        y[b,n] = sum_p 2^p * sum_k bits[p,b,k] * (w[k,n] + delta[p,b,k,n]).

    Each bit-plane is an independent crossbar read, so it gets an
    independent fluctuation sample ``delta[p]`` — this is what averages the
    fluctuation down (eq. 16-18).

    Shapes: bits (P, B, K) in {0,1}, w (K, N), delta (P, B, K, N) -> (B, N).
    """
    p = bits.shape[0]
    scales = 2.0 ** jnp.arange(p, dtype=w.dtype)
    per_plane = jnp.einsum("pbk,kn->pbn", bits, w) + jnp.einsum(
        "pbk,pbkn->pbn", bits, delta
    )
    y = jnp.einsum("p,pbn->bn", scales, per_plane)
    if bias is not None:
        y = y + bias
    return y


def clt_noise_std(x, sigma_abs):
    """Std of the output noise of a noisy MAC under the CLT surrogate.

    For y[b,n] = sum_k x[b,k] * (w[k,n] + d[b,k,n]) with i.i.d. zero-mean
    d of std ``sigma_abs``:  std(y[b,n] - (x@w)[b,n]) = sigma_abs *
    sqrt(sum_k x[b,k]^2), independent of n.
    """
    return sigma_abs * jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
