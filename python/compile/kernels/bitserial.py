"""L1 Pallas kernel: low-fluctuation decomposed MAC (technique C, eq. 15).

Computes  y[b,n] = sum_p 2^p * sum_k bits[p,b,k] * (w[k,n] + delta[p,b,k,n]).

The bit-plane loop is the innermost grid dimension, so the weight tile
(K, bn) is loaded into VMEM once per (i, j) output tile and reused across
all P bit-plane reads — the analog-crossbar analogue of keeping the array
programmed while the DAC streams input bits.  The accumulator lives in the
output VMEM block across the P grid steps (initialised at p == 0).

Each bit-plane consumes a *fresh* fluctuation sample delta[p] — independent
reads are exactly what gives the sqrt-law fluctuation reduction of
eq. (16)-(18).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 32
DEFAULT_BN = 128

_VMEM_BUDGET_F32 = 3 * 1024 * 1024


def _pick_tiles(b: int, k: int, n: int):
    bm = min(DEFAULT_BM, b)
    bn = min(DEFAULT_BN, n)
    while bm > 1 and bm * k * bn > _VMEM_BUDGET_F32:
        bm //= 2
    return bm, bn


def _kernel(bits_ref, w_ref, d_ref, b_ref, o_ref):
    p = pl.program_id(2)
    bits = bits_ref[0]  # (bm, K)
    w = w_ref[...]  # (K, bn)
    d = d_ref[0]  # (bm, K, bn)
    scale = jnp.exp2(p.astype(jnp.float32))
    plane = jnp.dot(bits, w, preferred_element_type=jnp.float32)
    plane = plane + jnp.einsum("bk,bkn->bn", bits, d)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = b_ref[...] + scale * plane

    @pl.when(p != 0)
    def _acc():
        o_ref[...] += scale * plane


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitserial_matmul(bits, w, delta, bias=None, *, interpret=True):
    """Decomposed noisy crossbar MAC.

    Args:
      bits: (P, B, K) binary activation bit-planes (LSB first), float 0/1.
      w: (K, N) programmed weights.
      delta: (P, B, K, N) fresh fluctuation sample per bit-plane read.
      bias: optional (N,).
    Returns:
      (B, N) float32.
    """
    p, b, k = bits.shape
    k2, n = w.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    assert delta.shape == (p, b, k, n), f"bad delta shape {delta.shape}"
    if bias is None:
        bias = jnp.zeros((n,), w.dtype)
    bm, bn = _pick_tiles(b, k, n)
    grid = (pl.cdiv(b, bm), pl.cdiv(n, bn), p)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, k), lambda i, j, q: (q, i, 0)),
            pl.BlockSpec((k, bn), lambda i, j, q: (0, j)),
            pl.BlockSpec((1, bm, k, bn), lambda i, j, q: (q, i, 0, j)),
            pl.BlockSpec((bn,), lambda i, j, q: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, q: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(bits, w, delta, bias)
