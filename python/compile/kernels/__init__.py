"""L1 Pallas kernels for EMT in-memory deep learning."""

from .bitserial import bitserial_matmul
from .emt_matmul import emt_matmul

__all__ = ["emt_matmul", "bitserial_matmul"]
