"""L1 Pallas kernel: noisy EMT crossbar MAC (eq. 11).

Computes  y[b,n] = sum_k x[b,k] * (w[k,n] + delta[b,k,n])  (+ bias).

Crossbar mapping (DESIGN.md §Hardware-Adaptation): one Pallas block is one
crossbar tile.  The weight tile (K, bn) stays resident in VMEM while batch
tiles of activations stream through — the BlockSpec index maps below encode
exactly that HBM↔VMEM schedule.  The inner op is a dense (bm, K) @ (K, bn)
matmul (MXU-shaped) plus the per-read fluctuation contraction.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated against ``ref.emt_matmul_ref`` and
real-TPU performance is estimated analytically in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  bm*K + K*bn + bm*K*bn floats must fit VMEM (~16 MiB);
# with bm=32, bn=128, K<=1024: 32K + 128K + 4M floats ≈ 17 MB — we halve bm
# for the worst case via _pick_bm.
DEFAULT_BM = 32
DEFAULT_BN = 128

_VMEM_BUDGET_F32 = 3 * 1024 * 1024  # floats, conservative


def _pick_tiles(b: int, k: int, n: int):
    bm = min(DEFAULT_BM, b)
    bn = min(DEFAULT_BN, n)
    # Shrink the batch tile until the delta tile fits the VMEM budget.
    while bm > 1 and bm * k * bn > _VMEM_BUDGET_F32:
        bm //= 2
    return bm, bn


def _kernel(x_ref, w_ref, d_ref, b_ref, o_ref):
    x = x_ref[...]  # (bm, K)
    w = w_ref[...]  # (K, bn)
    d = d_ref[...]  # (bm, K, bn)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + jnp.einsum("bk,bkn->bn", x, d)
    o_ref[...] = acc + b_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def emt_matmul(x, w, delta, bias=None, *, interpret=True):
    """Noisy crossbar MAC.

    Args:
      x: (B, K) activations (already DAC-quantised, float).
      w: (K, N) programmed weights (dequantised levels).
      delta: (B, K, N) per-read fluctuation sample (state offset * sigma).
      bias: optional (N,) bias.
    Returns:
      (B, N) float32.
    """
    b, k = x.shape
    k2, n = w.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    assert delta.shape == (b, k, n), f"bad delta shape {delta.shape}"
    if bias is None:
        bias = jnp.zeros((n,), x.dtype)
    bm, bn = _pick_tiles(b, k, n)
    grid = (pl.cdiv(b, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, k, bn), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(x, w, delta, bias)
