"""Quantisers with straight-through estimators (STE).

Weights: signed symmetric ``B_w``-bit quantisation (the integer level is
what gets programmed into the analog cell's conductance; one cell per
weight, bipolar conductance).

Activations: unsigned ``B_a``-bit quantisation after ReLU.  The integer
level is what the DAC drives onto the crossbar row — and what the
low-fluctuation decomposition (technique C) splits into bit-planes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import device


@jax.custom_jvp
def _round_ste(x):
    return jnp.round(x)


@_round_ste.defjvp
def _round_ste_jvp(primals, tangents):
    (x,), (dx,) = primals, tangents
    return jnp.round(x), dx  # straight-through


def weight_scale(w):
    """Per-tensor full-scale of a weight tensor (max |w|, floored)."""
    return jnp.maximum(jnp.max(jnp.abs(w)), 1e-6)


def quant_weight(w, bits: int = device.DEFAULT_WEIGHT_BITS):
    """Fake-quantise weights symmetrically to ``bits`` signed bits.

    Returns (w_q_dequantised, w_scale).  Gradients flow via STE.
    """
    levels = 2.0 ** (bits - 1) - 1.0
    s = weight_scale(w)
    q = _round_ste(jnp.clip(w / s, -1.0, 1.0) * levels) / levels
    return q * s, s


def quant_act(x, bits: int = device.DEFAULT_ACT_BITS):
    """Fake-quantise non-negative activations to ``bits`` unsigned bits.

    Returns (x_deq, levels_int, scale): ``x_deq = levels_int * scale`` and
    ``levels_int`` in [0, 2^bits - 1] (float-typed integers). Gradients via
    STE through the rounding, and through the dynamic scale.
    """
    n = 2.0**bits - 1.0
    s = jnp.maximum(jnp.max(x), 1e-6) / n
    levels = jnp.clip(_round_ste(x / s), 0.0, n)
    return levels * s, levels, s


def bit_planes(levels, bits: int = device.DEFAULT_ACT_BITS):
    """Decompose integer activation levels into binary bit-planes.

    ``levels``: float tensor of integer values in [0, 2^bits - 1].
    Returns tensor of shape (bits, *levels.shape) with entries in {0., 1.},
    least-significant plane first, so ``levels == sum_p planes[p] * 2^p``.
    Gradients: each plane uses an STE-style pass-through scaled by 2^-bits
    so that the recomposition's gradient matches the identity.
    """
    lv = jax.lax.stop_gradient(levels)
    planes = []
    for p in range(bits):
        planes.append(jnp.mod(jnp.floor(lv / 2.0**p), 2.0))
    out = jnp.stack(planes, axis=0)
    # Attach a straight-through path: recompose(out) == levels exactly, so
    # route the gradient of `levels` evenly through the planes.
    recompose = sum(out[p] * 2.0**p for p in range(bits))
    correction = (levels - jax.lax.stop_gradient(recompose)) / float(
        sum(2.0**p for p in range(bits))
    )
    return out + correction[None]
