"""L2 noisy layer primitives.

Each parameterised layer models an analog EMT crossbar read:

  * weights are fake-quantised (`quant.quant_weight`) — the programmed
    conductance levels;
  * activations are fake-quantised (`quant.quant_act`) — the DAC levels;
  * every read draws a fresh RTN state per cell (eq. 7);
  * technique C replaces the single analog read by B_a bit-plane reads
    (eq. 15) with independent fluctuation per plane.

Noise realisation strategy (DESIGN.md §2):

  * **exact path** — sample the m-state one-hot S explicitly and contract
    via the Pallas kernels.  Memory is O(B*K*N), so it is used whenever
    that fits `EXACT_BUDGET`; the last dense layer of every model always
    takes this path, keeping the L1 kernels in every lowered artifact.
  * **CLT path** — for large convolutions the per-read noise sum
    `sum_k x_k d_k` is replaced by a Gaussian with the exactly matched
    variance `sigma^2 * sum_k x_k^2` (validated against the exact path in
    python/tests/test_layers.py).  This is a variance-exact surrogate, not
    a simplification of the math: for K >= 64 the CLT error is far below
    the quantisation floor.

The Pallas kernels are wrapped in `jax.custom_vjp` so the train step can
differentiate through them; the rho-gradient flows through `delta` by
reparameterisation (delta = sigma(rho) * c with c ~ states).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import device, quant
from .kernels.bitserial import bitserial_matmul as _bitserial_kernel
from .kernels.emt_matmul import emt_matmul as _emt_kernel

#: max number of f32 elements we allow an explicit per-read noise tensor.
EXACT_BUDGET = 2**22

_OFFSETS = jnp.asarray(device.state_offsets())


def sample_delta(key, shape, sigma):
    """Sample per-read fluctuation offsets: delta = sigma * c_l, l ~ U{m}.

    `sigma` may be a traced scalar (so rho-gradients flow through it).
    """
    states = jax.random.randint(key, shape, 0, _OFFSETS.shape[0])
    return sigma * _OFFSETS[states]


# ---------------------------------------------------------------------------
# differentiable wrappers around the pallas kernels
# ---------------------------------------------------------------------------


@jax.custom_vjp
def emt_matmul_vjp(x, w, delta, bias):
    return _emt_kernel(x, w, delta, bias)


def _emt_fwd(x, w, delta, bias):
    return emt_matmul_vjp(x, w, delta, bias), (x, w, delta)


def _emt_bwd(res, g):
    x, w, delta = res
    dx = g @ w.T + jnp.einsum("bn,bkn->bk", g, delta)
    dw = x.T @ g
    dd = x[:, :, None] * g[:, None, :]
    db = g.sum(axis=0)
    return dx, dw, dd, db


emt_matmul_vjp.defvjp(_emt_fwd, _emt_bwd)


@jax.custom_vjp
def bitserial_matmul_vjp(bits, w, delta, bias):
    return _bitserial_kernel(bits, w, delta, bias)


def _bs_fwd(bits, w, delta, bias):
    return bitserial_matmul_vjp(bits, w, delta, bias), (bits, w, delta)


def _bs_bwd(res, g):
    bits, w, delta = res
    p = bits.shape[0]
    scales = 2.0 ** jnp.arange(p, dtype=w.dtype)
    dbits = scales[:, None, None] * (
        jnp.einsum("bn,kn->bk", g, w)[None] + jnp.einsum("bn,pbkn->pbk", g, delta)
    )
    dw = jnp.einsum("p,pbk,bn->kn", scales, bits, g)
    dd = scales[:, None, None, None] * (bits[:, :, :, None] * g[None, :, None, :])
    db = g.sum(axis=0)
    return dbits, dw, dd, db


bitserial_matmul_vjp.defvjp(_bs_fwd, _bs_bwd)


# ---------------------------------------------------------------------------
# noisy dense
# ---------------------------------------------------------------------------


def noisy_dense(key, x, w, b, rho, cfg):
    """One noisy crossbar dense layer in original (single-read) mode.

    x: (B, K) non-negative dequantised activations.
    Returns (y, stats) where stats carries the energy bookkeeping terms.
    """
    x_deq, levels, s = quant.quant_act(x, cfg["act_bits"])
    w_deq, w_scale = quant.quant_weight(w, cfg["weight_bits"])
    sigma = device.sigma_abs(rho, cfg["intensity"], w_scale) * cfg["noise_gate"]
    bsz, k = x_deq.shape
    n = w_deq.shape[1]
    if bsz * k * n <= EXACT_BUDGET:
        delta = sample_delta(key, (bsz, k, n), sigma)
        y = emt_matmul_vjp(x_deq, w_deq, delta, b)
    else:
        clean = x_deq @ w_deq + b
        eps = jax.random.normal(key, clean.shape)
        y = clean + sigma * jnp.sqrt(
            jnp.sum(x_deq * x_deq, axis=-1, keepdims=True) + 1e-12
        ) * eps
    stats = _layer_stats(w_deq, w_scale, levels, rho, alpha=1.0, cfg=cfg)
    return y, stats


def noisy_dense_decomp(key, x, w, b, rho, cfg):
    """Noisy dense layer in low-fluctuation decomposed (bit-serial) mode."""
    bits_n = cfg["act_bits"]
    _, levels, s = quant.quant_act(x, bits_n)
    w_deq, w_scale = quant.quant_weight(w, cfg["weight_bits"])
    sigma = device.sigma_abs(rho, cfg["intensity"], w_scale) * cfg["noise_gate"]
    planes = quant.bit_planes(levels, bits_n)  # (P, B, K)
    p, bsz, k = planes.shape
    n = w_deq.shape[1]
    if p * bsz * k * n <= EXACT_BUDGET:
        delta = sample_delta(key, (p, bsz, k, n), sigma)
        y_lv = bitserial_matmul_vjp(planes, w_deq, delta, jnp.zeros((n,), w.dtype))
    else:
        scales = 2.0 ** jnp.arange(p, dtype=w.dtype)
        clean = jnp.einsum("p,pbk,kn->bn", scales, planes, w_deq)
        eps = jax.random.normal(key, clean.shape)
        var = jnp.einsum("p,pbk->b", scales**2, planes)  # bits^2 == bits
        y_lv = clean + sigma * jnp.sqrt(var + 1e-12)[:, None] * eps
    y = y_lv * s + b
    stats = _layer_stats(
        w_deq, w_scale, levels, rho, alpha=1.0, cfg=cfg, planes=planes
    )
    return y, stats


# ---------------------------------------------------------------------------
# noisy conv (CLT path; exact path is exercised by dense layers + tests)
# ---------------------------------------------------------------------------

_DN = ("NHWC", "HWIO", "NHWC")


def _conv(x, w, stride, groups=1):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=_DN,
        feature_group_count=groups,
    )


def noisy_conv(key, x, w, b, rho, cfg, stride=1, groups=1):
    """Noisy crossbar conv layer (im2col-equivalent CLT noise), original mode.

    x: (B, H, W, Cin) non-negative; w: (kh, kw, Cin/groups, Cout).
    """
    x_deq, levels, s = quant.quant_act(x, cfg["act_bits"])
    w_deq, w_scale = quant.quant_weight(w, cfg["weight_bits"])
    sigma = device.sigma_abs(rho, cfg["intensity"], w_scale) * cfg["noise_gate"]
    clean = _conv(x_deq, w_deq, stride, groups) + b
    # per-output-pixel read-noise variance: sigma^2 * sum_patch x^2
    ones = jnp.ones(w.shape[:3] + (1,), x.dtype)
    if groups == 1:
        sq = _conv(x_deq * x_deq, ones, stride)  # (B,H,W,1)
    else:  # depthwise: each output channel sees only its own input channel
        ones_dw = jnp.ones(w.shape[:2] + (1, 1), x.dtype)
        sq = _conv(
            x_deq * x_deq,
            jnp.broadcast_to(ones_dw, w.shape[:2] + (1, groups)),
            stride,
            groups,
        )
    eps = jax.random.normal(key, clean.shape)
    y = clean + sigma * jnp.sqrt(sq + 1e-12) * eps
    out_hw = clean.shape[1] * clean.shape[2]
    stats = _layer_stats(w_deq, w_scale, levels, rho, alpha=float(out_hw), cfg=cfg)
    return y, stats


def noisy_conv_decomp(key, x, w, b, rho, cfg, stride=1, groups=1):
    """Noisy conv in decomposed mode: one conv per bit-plane, fresh noise."""
    bits_n = cfg["act_bits"]
    _, levels, s = quant.quant_act(x, bits_n)
    w_deq, w_scale = quant.quant_weight(w, cfg["weight_bits"])
    sigma = device.sigma_abs(rho, cfg["intensity"], w_scale) * cfg["noise_gate"]
    planes = quant.bit_planes(levels, bits_n)  # (P,B,H,W,C)
    ones = jnp.ones(w.shape[:3] + (1,), x.dtype)

    def plane_read(p, key_p):
        bits = planes[p]
        clean = _conv(bits, w_deq, stride, groups)
        if groups == 1:
            sq = _conv(bits, ones, stride)  # bits^2 == bits
        else:
            ones_dw = jnp.broadcast_to(
                jnp.ones(w.shape[:2] + (1, 1), x.dtype), w.shape[:2] + (1, groups)
            )
            sq = _conv(bits, ones_dw, stride, groups)
        eps = jax.random.normal(key_p, clean.shape)
        return clean + sigma * jnp.sqrt(sq + 1e-12) * eps

    keys = jax.random.split(key, bits_n)
    y_lv = sum(2.0**p * plane_read(p, keys[p]) for p in range(bits_n))
    y = y_lv * s + b
    out_hw = y.shape[1] * y.shape[2]
    stats = _layer_stats(
        w_deq, w_scale, levels, rho, alpha=float(out_hw), cfg=cfg, planes=planes
    )
    return y, stats


# ---------------------------------------------------------------------------
# energy bookkeeping
# ---------------------------------------------------------------------------


def _layer_stats(w_deq, w_scale, levels, rho, alpha, cfg, planes=None):
    """Per-layer energy terms.

    reg_term  — the paper's regulariser `alpha * rho * sum_t |w_t|`
                (weights normalised to full-scale, eq. 13).
    energy    — estimated analog read energy of this layer for this batch,
                normalised device units (eq. 19): rho * |w|_norm * levels
                summed over reads; decomposed mode uses sum of set bits.
    """
    w_norm_sum = jnp.sum(jnp.abs(w_deq)) / w_scale
    reg_term = alpha * rho * w_norm_sum
    w_norm_mean = jnp.mean(jnp.abs(w_deq)) / w_scale
    if planes is None:
        duty = jnp.mean(levels)  # mean integer DAC level per read
    else:
        duty = jnp.mean(jnp.sum(planes, axis=0))  # mean set bits per read
    n_cells = float(w_deq.size)
    energy = device.E0 * rho * w_norm_mean * duty * n_cells * alpha
    return {"reg": reg_term, "energy": energy, "cells": n_cells, "alpha": alpha}
