"""Canonical EMT device model (Python side).

This module is the single source of truth for the device math used by the
L1 Pallas kernels and the L2 JAX model.  The Rust substrate
(``rust/src/device/``) mirrors these definitions exactly; the integration
tests cross-check the two implementations through the AOT artifacts.

Model
-----
An analog EMT cell storing weight ``w`` (normalised to the layer full-scale
``w_scale``) fluctuates between ``m`` discrete RTN states.  When read at
state ``l`` it returns

    r_l(w, rho) = w + sigma_abs(rho, intensity, w_scale) * c_l

where ``c_l`` are zero-mean, unit-variance, evenly spaced state offsets and

    sigma_abs = K_F * intensity / sqrt(rho) * w_scale .

``rho`` is the (trainable) energy coefficient: larger rho means a stronger
programming/read current, hence lower relative fluctuation but higher read
energy (Ielmini et al. [25], resistance-dependent RTN).

Energy of one analog read with integer activation level ``a`` (0..2^Ba-1):

    E_read = E0 * rho * (|w| / w_scale) * a            (original mode)
    E_read = E0 * rho * (|w| / w_scale) * sum(delta_p) (decomposed mode)

matching eq. (19) of the paper.  ``E0`` is a device constant; the Rust
energy model owns the absolute calibration to uJ.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Constants (mirrored in rust/src/device/mod.rs — keep in sync!)
# ---------------------------------------------------------------------------

#: Default number of RTN states of a cell.
DEFAULT_NUM_STATES = 4

#: Fluctuation constant: relative sigma at rho == 1.0, intensity == 1.0.
K_F = 0.04

#: Fluctuation intensity levels (paper §5.2: weak / normal / strong).
INTENSITY = {"weak": 0.5, "normal": 1.0, "strong": 2.0}

#: Device energy unit for one full-scale, full-duty analog read (normalised).
E0 = 1.0

#: Default activation bits (B_a) — number of bit-planes in decomposed mode.
#: B_a = 5 matches the paper's 5x decomposed-mode delay (Table 1: 14/2.8 us).
DEFAULT_ACT_BITS = 5

#: Default weight bits (signed, symmetric).
DEFAULT_WEIGHT_BITS = 8


def state_offsets(m: int = DEFAULT_NUM_STATES) -> np.ndarray:
    """Zero-mean, unit-variance, evenly spaced RTN state offsets ``c_l``.

    For m == 1 the cell is noiseless (offset 0).
    """
    if m < 1:
        raise ValueError(f"need at least one state, got {m}")
    if m == 1:
        return np.zeros((1,), dtype=np.float32)
    raw = np.linspace(-1.0, 1.0, m)
    raw = raw - raw.mean()
    return (raw / raw.std()).astype(np.float32)


def sigma_rel(rho, intensity=1.0):
    """Relative fluctuation amplitude (fraction of w_scale)."""
    return K_F * intensity / jnp.sqrt(rho)


def sigma_abs(rho, intensity, w_scale):
    """Absolute fluctuation amplitude in weight units."""
    return sigma_rel(rho, intensity) * w_scale


def read_energy(rho, w_abs_norm, act_level):
    """Energy of one analog read (normalised units). ``w_abs_norm`` in [0,1],
    ``act_level`` is the integer activation level (or bit-count in
    decomposed mode)."""
    return E0 * rho * w_abs_norm * act_level
