"""L2 trainable model zoo.

Scaled-down analogues of the paper's evaluation models (DESIGN.md §2):

  * ``mlp``            — sanity model (flatten + 3 dense)
  * ``tiny_vgg``       — VGG-16 stand-in: stacked 3x3 convs + dense head
  * ``tiny_resnet``    — ResNet-18 stand-in: residual blocks [1,1,1]
  * ``tiny_resnet34``  — ResNet-34 stand-in: residual blocks [2,2,2]
  * ``tiny_mobilenet`` — MobileNet stand-in: depthwise-separable blocks

Models are specs interpreted by ``forward``; every parameterised layer is
an analog crossbar read (see layers.py).  The spec also yields the layer
metadata (cells, fan-in, reads-per-inference alpha) consumed by the Rust
energy/latency model via the artifact manifest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import layers

# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------
# entries:
#   ("conv",  cin, cout, k, stride)
#   ("dwconv", c, k, stride)            depthwise
#   ("dense", d_in, d_out)
#   ("pool", k)                          max-pool k x k
#   ("gap",)                             global average pool
#   ("flatten",)
# ReLU + activation quantisation is applied at the input of every
# parameterised layer (inputs are already in [0, 1]).


def model_spec(name: str, num_classes: int = 10):
    if name == "mlp":
        return [
            ("flatten",),
            ("dense", 3072, 256),
            ("dense", 256, 128),
            ("dense", 128, num_classes),
        ]
    if name == "tiny_vgg":
        return [
            ("conv", 3, 32, 3, 1),
            ("conv", 32, 32, 3, 1),
            ("pool", 2),
            ("conv", 32, 64, 3, 1),
            ("conv", 64, 64, 3, 1),
            ("pool", 2),
            ("flatten",),
            ("dense", 64 * 8 * 8, 128),
            ("dense", 128, num_classes),
        ]
    if name in ("tiny_resnet", "tiny_resnet34"):
        reps = 1 if name == "tiny_resnet" else 2
        spec = [("conv", 3, 16, 3, 1)]
        cin = 16
        for cout, stride in ((16, 1), (32, 2), (64, 2)):
            for r in range(reps):
                spec.append(("res", cin, cout, stride if r == 0 else 1))
                cin = cout
        spec += [("gap",), ("dense", 64, num_classes)]
        return spec
    if name == "tiny_mobilenet":
        return [
            ("conv", 3, 16, 3, 1),
            ("dwconv", 16, 3, 1),
            ("conv", 16, 32, 1, 1),
            ("dwconv", 32, 3, 2),
            ("conv", 32, 64, 1, 1),
            ("dwconv", 64, 3, 2),
            ("conv", 64, 128, 1, 1),
            ("gap",),
            ("dense", 128, num_classes),
        ]
    raise ValueError(f"unknown model {name!r}")


MODEL_NAMES = ["mlp", "tiny_vgg", "tiny_resnet", "tiny_resnet34", "tiny_mobilenet"]


def _param_layers(spec):
    """Expand spec into the flat list of parameterised (crossbar) layers."""
    out = []
    for entry in spec:
        kind = entry[0]
        if kind == "conv":
            _, cin, cout, k, stride = entry
            out.append(("conv", (k, k, cin, cout)))
        elif kind == "dwconv":
            _, c, k, stride = entry
            out.append(("dwconv", (k, k, 1, c)))
        elif kind == "dense":
            _, din, dout = entry
            out.append(("dense", (din, dout)))
        elif kind == "res":
            _, cin, cout, stride = entry
            out.append(("conv", (3, 3, cin, cout)))
            out.append(("conv", (3, 3, cout, cout)))
            if stride != 1 or cin != cout:
                out.append(("conv", (1, 1, cin, cout)))  # projection skip
    return out


def num_param_layers(name, num_classes=10):
    return len(_param_layers(model_spec(name, num_classes)))


def init_params(key, name, num_classes=10):
    """He-init parameters: flat list [w0, b0, w1, b1, ...]."""
    plist = _param_layers(model_spec(name, num_classes))
    params = []
    for i, (kind, shape) in enumerate(plist):
        key, sub = jax.random.split(key)
        if kind == "dense":
            fan_in = shape[0]
            bshape = (shape[1],)
        else:
            fan_in = shape[0] * shape[1] * shape[2]
            bshape = (shape[3],)
        w = jax.random.normal(sub, shape) * np.sqrt(2.0 / fan_in)
        params.append(w.astype(jnp.float32))
        params.append(jnp.zeros(bshape, jnp.float32))
    return params


def init_rho_raw(name, num_classes=10, rho0=4.0):
    """Per-layer raw energy coefficients; softplus(rho_raw) == rho0."""
    n = num_param_layers(name, num_classes)
    raw = np.log(np.expm1(rho0)).astype(np.float32)
    return jnp.full((n,), raw, jnp.float32)


def rho_of(rho_raw):
    """Positive, bounded energy coefficients."""
    return jnp.clip(jax.nn.softplus(rho_raw), 0.05, 100.0)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def forward(params, rho_raw, x, key, cfg, spec, decomposed=False):
    """Run the model; returns (logits, stats_list).

    cfg: dict(act_bits, weight_bits, intensity, noise_gate) — intensity and
    noise_gate may be traced scalars.
    """
    rho = rho_of(rho_raw)
    dense = layers.noisy_dense_decomp if decomposed else layers.noisy_dense
    conv = layers.noisy_conv_decomp if decomposed else layers.noisy_conv
    idx = 0  # param-layer index
    stats = []

    def take():
        nonlocal idx
        w, b = params[2 * idx], params[2 * idx + 1]
        r = rho[idx]
        idx += 1
        return w, b, r

    def crossbar_conv(x, key, stride, groups=1):
        w, b, r = take()
        return conv(key, x, w, b, r, cfg, stride=stride, groups=groups)

    for entry in spec:
        kind = entry[0]
        key, sub = jax.random.split(key)
        if kind == "conv":
            _, cin, cout, k, stride = entry
            x = jax.nn.relu(x)
            x, st = crossbar_conv(x, sub, stride)
            stats.append(st)
        elif kind == "dwconv":
            _, c, k, stride = entry
            x = jax.nn.relu(x)
            x, st = crossbar_conv(x, sub, stride, groups=c)
            stats.append(st)
        elif kind == "res":
            _, cin, cout, stride = entry
            x_in = jax.nn.relu(x)
            key, k1, k2, k3 = jax.random.split(key, 4)
            h, st1 = crossbar_conv(x_in, k1, stride)
            h = jax.nn.relu(h)
            h, st2 = crossbar_conv(h, k2, 1)
            stats += [st1, st2]
            if stride != 1 or cin != cout:
                skip, st3 = crossbar_conv(x_in, k3, stride)
                stats.append(st3)
            else:
                skip = x_in
            x = h + skip
        elif kind == "dense":
            x = jax.nn.relu(x)
            w, b, r = take()
            x, st = dense(sub, x, w, b, r, cfg)
            stats.append(st)
        elif kind == "pool":
            k = entry[1]
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID"
            )
        elif kind == "gap":
            x = jnp.mean(x, axis=(1, 2))
        elif kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        else:
            raise ValueError(f"unknown spec entry {entry}")
    return x, stats


def layer_meta(name, num_classes=10, hw=32):
    """Static per-layer metadata for the Rust energy/latency model.

    Returns a list of dicts: kind, cells (= #weights), fan_in (crossbar rows
    per read), alpha (reads per weight per inference), out_features.
    Spatial sizes assume hw x hw inputs and 'SAME' padding.
    """
    spec = model_spec(name, num_classes)
    metas = []
    cur = hw

    def conv_meta(k, cin, cout, stride, groups=1):
        nonlocal cur
        out = int(np.ceil(cur / stride))
        meta = {
            "kind": "dwconv" if groups > 1 else "conv",
            "cells": k * k * (cin // groups) * cout,
            "fan_in": k * k * (cin // groups),
            "alpha": out * out,
            "out_features": cout,
        }
        cur = out
        return meta

    for entry in spec:
        kind = entry[0]
        if kind == "conv":
            _, cin, cout, k, stride = entry
            metas.append(conv_meta(k, cin, cout, stride))
        elif kind == "dwconv":
            _, c, k, stride = entry
            metas.append(conv_meta(k, c, c, stride, groups=c))
        elif kind == "res":
            _, cin, cout, stride = entry
            metas.append(conv_meta(3, cin, cout, stride))
            metas.append(conv_meta(3, cout, cout, 1))
            if stride != 1 or cin != cout:
                # projection operates on the pre-stride grid
                saved = cur
                cur = int(np.ceil(saved * stride / stride))  # same as post
                metas.append(
                    {
                        "kind": "conv",
                        "cells": cin * cout,
                        "fan_in": cin,
                        "alpha": cur * cur,
                        "out_features": cout,
                    }
                )
        elif kind == "dense":
            _, din, dout = entry
            metas.append(
                {
                    "kind": "dense",
                    "cells": din * dout,
                    "fan_in": din,
                    "alpha": 1,
                    "out_features": dout,
                }
            )
        elif kind == "pool":
            cur //= entry[1]
        elif kind == "gap":
            cur = 1
    return metas
