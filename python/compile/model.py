"""L2 train / eval / predict step builders.

Everything here is lowered ONCE by aot.py to HLO text and executed from the
Rust coordinator through PJRT — python never runs on the request path.

A single `train_step` artifact serves solutions `trad`, `A` and `A+B` via
scalar gate inputs (noise_gate, lam, rho_gate); `A+B+C` uses the
structurally different `train_step_decomp` artifact (bit-serial forward).

Flat argument convention (mirrored by rust/src/runtime/session.rs):

  train:   [w0,b0,...,wL,bL, rho_raw,
            m0..mL(b), v0..vL(b), m_rho, v_rho,
            step(1,), x(B,H,W,C), y(B,)i32, seed(1,)i32,
            intensity(1,), lam(1,), rho_gate(1,), noise_gate(1,)]
        -> (params'..., rho_raw', m'..., v'..., m_rho', v_rho',
            loss(1,), acc(1,), energy(1,))

  eval:    [params..., rho_raw, x, y(B,)i32, seed(1,)i32,
            intensity(1,), noise_gate(1,)]
        -> (top1(1,), top5(1,), loss_sum(1,), energy(1,))

  predict: [params..., rho_raw, x, seed(1,)i32, intensity(1,),
            noise_gate(1,)] -> (logits(B,C),)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import device, models

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
LR = 1e-3
RHO_LR_SCALE = 10.0  # rho moves on a coarser scale than weights


def _cfg(intensity, noise_gate, act_bits, weight_bits):
    return {
        "act_bits": act_bits,
        "weight_bits": weight_bits,
        "intensity": intensity,
        "noise_gate": noise_gate,
    }


def _loss_fn(params, rho_raw, x, y, key, spec, decomposed, intensity, lam,
             noise_gate, act_bits, weight_bits, num_classes):
    cfg = _cfg(intensity, noise_gate, act_bits, weight_bits)
    logits, stats = models.forward(
        params, rho_raw, x, key, cfg, spec, decomposed=decomposed
    )
    labels = jax.nn.one_hot(y, num_classes)
    ce = -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), axis=-1))
    # Paper eq. (13): lam * sum_t alpha_t * rho * |w_t| — normalised by the
    # total number of cell reads so lam is scale-free across models.
    total_reads = sum(s["alpha"] * s["cells"] for s in stats)
    reg = sum(s["reg"] for s in stats) / total_reads
    energy = sum(s["energy"] for s in stats)
    loss = ce + lam * reg
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return loss, (ce, acc, energy, logits)


def make_train_step(name, num_classes, batch, hw=32, decomposed=False,
                    act_bits=device.DEFAULT_ACT_BITS,
                    weight_bits=device.DEFAULT_WEIGHT_BITS):
    """Build the flat-signature Adam train step for one model."""
    spec = models.model_spec(name, num_classes)
    n_layers = models.num_param_layers(name, num_classes)
    n_params = 2 * n_layers

    def step_fn(*args):
        i = 0

        def take(k):
            nonlocal i
            out = args[i : i + k]
            i += k
            return list(out)

        params = take(n_params)
        (rho_raw,) = take(1)
        m = take(n_params)
        v = take(n_params)
        (m_rho,) = take(1)
        (v_rho,) = take(1)
        step, x, y, seed, intensity, lam, rho_gate, noise_gate = take(8)

        step = step[0]
        key = jax.random.fold_in(jax.random.PRNGKey(seed[0]), step.astype(jnp.int32))
        inten = intensity[0]
        lam_s = lam[0]
        rho_g = rho_gate[0]
        noise_g = noise_gate[0]

        grad_fn = jax.value_and_grad(_loss_fn, argnums=(0, 1), has_aux=True)
        (loss, (ce, acc, energy, _)), (gp, g_rho) = grad_fn(
            params, rho_raw, x, y, key, spec, decomposed, inten, lam_s,
            noise_g, act_bits, weight_bits, num_classes,
        )
        g_rho = g_rho * rho_g

        t = step + 1.0
        bc1 = 1.0 - ADAM_B1**t
        bc2 = 1.0 - ADAM_B2**t

        def adam(p, g, m_, v_, lr):
            m_n = ADAM_B1 * m_ + (1 - ADAM_B1) * g
            v_n = ADAM_B2 * v_ + (1 - ADAM_B2) * (g * g)
            p_n = p - lr * (m_n / bc1) / (jnp.sqrt(v_n / bc2) + ADAM_EPS)
            return p_n, m_n, v_n

        new_p, new_m, new_v = [], [], []
        for p, g, m_, v_ in zip(params, gp, m, v):
            pn, mn, vn = adam(p, g, m_, v_, LR)
            new_p.append(pn)
            new_m.append(mn)
            new_v.append(vn)
        rho_n, m_rho_n, v_rho_n = adam(rho_raw, g_rho, m_rho, v_rho, LR * RHO_LR_SCALE)

        out = (
            new_p
            + [rho_n]
            + new_m
            + new_v
            + [m_rho_n, v_rho_n]
            + [loss[None], acc[None], energy[None]]
        )
        return tuple(out)

    return step_fn, train_input_specs(name, num_classes, batch, hw)


def make_eval_step(name, num_classes, batch, hw=32, decomposed=False,
                   act_bits=device.DEFAULT_ACT_BITS,
                   weight_bits=device.DEFAULT_WEIGHT_BITS):
    spec = models.model_spec(name, num_classes)
    n_params = 2 * models.num_param_layers(name, num_classes)

    def eval_fn(*args):
        params = list(args[:n_params])
        rho_raw, x, y, seed, intensity, noise_gate = args[n_params : n_params + 6]
        key = jax.random.PRNGKey(seed[0])
        cfg = _cfg(intensity[0], noise_gate[0], act_bits, weight_bits)
        logits, stats = models.forward(
            params, rho_raw, x, key, cfg, spec, decomposed=decomposed
        )
        labels = jax.nn.one_hot(y, num_classes)
        loss_sum = -jnp.sum(labels * jax.nn.log_softmax(logits))
        top1 = jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        # top-5 via label-logit rank (lax.top_k lowers to a `topk` HLO
        # attribute that xla_extension 0.5.1's text parser rejects)
        k5 = min(5, num_classes)
        label_logit = jnp.take_along_axis(logits, y[:, None], axis=1)
        rank = jnp.sum((logits > label_logit).astype(jnp.int32), axis=1)
        top5 = jnp.sum((rank < k5).astype(jnp.float32))
        energy = sum(s["energy"] for s in stats)
        return (top1[None], top5[None], loss_sum[None], jnp.asarray(energy)[None])

    return eval_fn, eval_input_specs(name, num_classes, batch, hw)


def make_predict(name, num_classes, batch, hw=32, decomposed=False,
                 act_bits=device.DEFAULT_ACT_BITS,
                 weight_bits=device.DEFAULT_WEIGHT_BITS):
    spec = models.model_spec(name, num_classes)
    n_params = 2 * models.num_param_layers(name, num_classes)

    def predict_fn(*args):
        params = list(args[:n_params])
        rho_raw, x, seed, intensity, noise_gate = args[n_params : n_params + 5]
        key = jax.random.PRNGKey(seed[0])
        cfg = _cfg(intensity[0], noise_gate[0], act_bits, weight_bits)
        logits, _ = models.forward(
            params, rho_raw, x, key, cfg, spec, decomposed=decomposed
        )
        return (logits,)

    return predict_fn, predict_input_specs(name, num_classes, batch, hw)


# ---------------------------------------------------------------------------
# input specs (shape/dtype manifests)
# ---------------------------------------------------------------------------


def _param_specs(name, num_classes):
    plist = models._param_layers(models.model_spec(name, num_classes))
    specs = []
    for kind, shape in plist:
        bshape = (shape[1],) if kind == "dense" else (shape[3],)
        specs.append(("w", shape, "f32"))
        specs.append(("b", bshape, "f32"))
    return specs


def train_input_specs(name, num_classes, batch, hw=32):
    ps = _param_specs(name, num_classes)
    n_layers = len(ps) // 2
    specs = [(f"param{i}", s, d) for i, (_, s, d) in enumerate(ps)]
    specs += [("rho_raw", (n_layers,), "f32")]
    specs += [(f"m{i}", s, d) for i, (_, s, d) in enumerate(ps)]
    specs += [(f"v{i}", s, d) for i, (_, s, d) in enumerate(ps)]
    specs += [("m_rho", (n_layers,), "f32"), ("v_rho", (n_layers,), "f32")]
    specs += [
        ("step", (1,), "f32"),
        ("x", (batch, hw, hw, 3), "f32"),
        ("y", (batch,), "i32"),
        ("seed", (1,), "i32"),
        ("intensity", (1,), "f32"),
        ("lam", (1,), "f32"),
        ("rho_gate", (1,), "f32"),
        ("noise_gate", (1,), "f32"),
    ]
    return specs


def eval_input_specs(name, num_classes, batch, hw=32):
    ps = _param_specs(name, num_classes)
    n_layers = len(ps) // 2
    specs = [(f"param{i}", s, d) for i, (_, s, d) in enumerate(ps)]
    specs += [
        ("rho_raw", (n_layers,), "f32"),
        ("x", (batch, hw, hw, 3), "f32"),
        ("y", (batch,), "i32"),
        ("seed", (1,), "i32"),
        ("intensity", (1,), "f32"),
        ("noise_gate", (1,), "f32"),
    ]
    return specs


def predict_input_specs(name, num_classes, batch, hw=32):
    ps = _param_specs(name, num_classes)
    n_layers = len(ps) // 2
    specs = [(f"param{i}", s, d) for i, (_, s, d) in enumerate(ps)]
    specs += [
        ("rho_raw", (n_layers,), "f32"),
        ("x", (batch, hw, hw, 3), "f32"),
        ("seed", (1,), "i32"),
        ("intensity", (1,), "f32"),
        ("noise_gate", (1,), "f32"),
    ]
    return specs


def abstract_inputs(specs):
    dt = {"f32": jnp.float32, "i32": jnp.int32}
    return [jax.ShapeDtypeStruct(shape, dt[d]) for _, shape, d in specs]
