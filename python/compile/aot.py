"""AOT compiler: lower every L2 step function to HLO text + manifest.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via `make artifacts`; outputs:

  artifacts/<model>_<nc>_<kind>.hlo.txt     one per (model, step kind)
  artifacts/manifest.json                   input/output specs + model and
                                            device metadata for the Rust
                                            runtime (serde-parsed)
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import device, model, models

TRAIN_BATCH = 64
EVAL_BATCH = 256
PREDICT_BATCH = 16

#: (model, num_classes) pairs. nc=10 is the synthetic-CIFAR suite; nc=20 is
#: the synthetic-ImageNet stand-in suite (paper: ResNet-18/34 on ImageNet).
SUITES = [
    ("mlp", 10),
    ("tiny_vgg", 10),
    ("tiny_resnet", 10),
    ("tiny_mobilenet", 10),
    ("tiny_resnet", 20),
    ("tiny_resnet34", 20),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(specs):
    return [
        {"name": n, "shape": list(s), "dtype": d} for n, s, d in specs
    ]


def _out_specs(fn, in_specs):
    outs = jax.eval_shape(fn, *model.abstract_inputs(in_specs))
    dt = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}
    return [
        {"name": f"out{i}", "shape": list(o.shape), "dtype": dt[o.dtype]}
        for i, o in enumerate(outs)
    ]


def make_init(name, num_classes):
    """Init artifact: (seed,) -> (params..., rho_raw). Keeps He-init
    identical between Python tests and the Rust driver."""

    def init_fn(seed):
        params = models.init_params(jax.random.PRNGKey(seed[0]), name, num_classes)
        return tuple(params + [models.init_rho_raw(name, num_classes)])

    return init_fn, [("seed", (1,), "i32")]


def artifact_set(name, nc):
    return [
        ("init", *make_init(name, nc)),
        ("train", *model.make_train_step(name, nc, TRAIN_BATCH)),
        ("train_decomp", *model.make_train_step(name, nc, TRAIN_BATCH, decomposed=True)),
        ("eval", *model.make_eval_step(name, nc, EVAL_BATCH)),
        ("eval_decomp", *model.make_eval_step(name, nc, EVAL_BATCH, decomposed=True)),
        ("predict", *model.make_predict(name, nc, PREDICT_BATCH)),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma list: model:nc pairs")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    suites = SUITES
    if args.only:
        keep = set(args.only.split(","))
        suites = [(m, n) for m, n in SUITES if f"{m}:{n}" in keep]

    manifest = {
        "device": {
            "num_states": device.DEFAULT_NUM_STATES,
            "k_f": device.K_F,
            "intensity": device.INTENSITY,
            "act_bits": device.DEFAULT_ACT_BITS,
            "weight_bits": device.DEFAULT_WEIGHT_BITS,
            "e0": device.E0,
        },
        "batches": {
            "train": TRAIN_BATCH,
            "eval": EVAL_BATCH,
            "predict": PREDICT_BATCH,
        },
        "models": {},
        "artifacts": [],
    }

    for name, nc in suites:
        key = f"{name}_{nc}"
        manifest["models"][key] = {
            "model": name,
            "num_classes": nc,
            "n_layers": models.num_param_layers(name, nc),
            "layer_meta": models.layer_meta(name, nc),
        }
        for kind, fn, in_specs in artifact_set(name, nc):
            fname = f"{key}_{kind}.hlo.txt"
            t0 = time.time()
            lowered = jax.jit(fn).lower(*model.abstract_inputs(in_specs))
            text = to_hlo_text(lowered)
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": f"{key}_{kind}",
                    "model": key,
                    "kind": kind,
                    "file": fname,
                    "inputs": _spec_json(in_specs),
                    "outputs": _out_specs(fn, in_specs),
                }
            )
            print(f"  {fname}: {len(text)/1e6:.1f} MB in {time.time()-t0:.1f}s")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json")


if __name__ == "__main__":
    main()
