//! Stamps the build-info triple (`trace::build_info`): rustc version and
//! git sha, falling back to "unknown" when either is unavailable (e.g. a
//! source tarball).  No dependencies; runs the local toolchain/git only.

use std::process::Command;

fn capture(cmd: &str, args: &[&str]) -> Option<String> {
    let out = Command::new(cmd).args(args).output().ok()?;
    if !out.status.success() {
        return None;
    }
    let s = String::from_utf8(out.stdout).ok()?;
    let s = s.trim();
    if s.is_empty() {
        None
    } else {
        Some(s.to_string())
    }
}

fn main() {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let rustc_version = capture(&rustc, &["--version"])
        .map(|v| v.trim_start_matches("rustc ").to_string())
        .unwrap_or_else(|| "unknown".to_string());
    let git_sha = capture("git", &["rev-parse", "--short=12", "HEAD"])
        .unwrap_or_else(|| "unknown".to_string());
    println!("cargo:rustc-env=EMTOPT_RUSTC={rustc_version}");
    println!("cargo:rustc-env=EMTOPT_GIT_SHA={git_sha}");
    // re-stamp when HEAD moves (harmless no-op outside a git checkout)
    println!("cargo:rerun-if-changed=../.git/HEAD");
    println!("cargo:rerun-if-changed=build.rs");
}
