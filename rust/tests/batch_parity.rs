//! Batched-vs-sequential parity of the shared-state execution engine
//! (ISSUE 1 acceptance): `NoisyModel::forward_batch` must produce
//! bit-identical logits AND bit-identical `ReadCounters` to a
//! sample-by-sample loop under the fixed per-sample RNG streams
//! `Rng::stream(seed, i)` — at 1, 2, and N threads, in both read modes.

use emtopt::crossbar::ReadCounters;
use emtopt::device::DeviceConfig;
use emtopt::energy::{EnergyPlan, LayerPlan, PlanSource, ReadMode};
use emtopt::inference::{NoisyModel, Scratch, SlabPool};
use emtopt::rng::{hash2, Rng};

const DIMS: [(usize, usize); 3] = [(24, 20), (20, 12), (12, 6)];

fn mk_model(cfg: &DeviceConfig, seed: u64) -> NoisyModel {
    let mut rng = Rng::new(seed);
    let data: Vec<(Vec<f32>, Vec<f32>)> = DIMS
        .iter()
        .map(|&(i, o)| {
            let w: Vec<f32> = (0..i * o).map(|_| rng.normal() * 0.3).collect();
            let b: Vec<f32> = (0..o).map(|_| rng.normal() * 0.05).collect();
            (w, b)
        })
        .collect();
    let specs: Vec<(&[f32], &[f32], usize, usize)> = data
        .iter()
        .zip(DIMS.iter())
        .map(|((w, b), &(i, o))| (w.as_slice(), b.as_slice(), i, o))
        .collect();
    NoisyModel::new(&specs, cfg).unwrap()
}

fn batch_input(d_in: usize, batch: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..batch * d_in).map(|_| rng.next_f32()).collect()
}

#[test]
fn batched_matches_sequential_at_1_2_and_n_threads() {
    let cfg = DeviceConfig::default();
    let model = mk_model(&cfg, 1);
    let batch = 8usize;
    let xs = batch_input(model.d_in(), batch, 2);
    let seed = 42u64;
    let n = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4)
        .max(3);

    for mode in [ReadMode::Original, ReadMode::Decomposed] {
        let plan = model.uniform_plan(mode);
        let mut c_seq = ReadCounters::default();
        let seq = model.forward_batch_seq(&xs, &plan, &cfg, seed, &mut c_seq);
        assert_eq!(seq.len(), batch * model.d_out());

        for threads in [1usize, 2, n] {
            let pool = rayon::ThreadPoolBuilder::new()
                .num_threads(threads)
                .build()
                .unwrap();
            let (par, c_par) = pool.install(|| {
                let mut c = ReadCounters::default();
                let y = model.forward_batch(&xs, &plan, &cfg, seed, &mut c);
                (y, c)
            });
            assert_eq!(
                seq, par,
                "{mode:?}: logits must be bit-identical at {threads} threads"
            );
            assert_eq!(
                c_seq, c_par,
                "{mode:?}: counters must be bit-identical at {threads} threads"
            );
        }
    }
}

#[test]
fn per_sample_streams_are_independent_of_batch_layout() {
    // sample i of a batch must equal a lone forward with Rng::stream(seed, i):
    // the stream discipline is the public contract that makes request-level
    // results independent of how the router packs batches across workers.
    let cfg = DeviceConfig::default();
    let model = mk_model(&cfg, 3);
    let batch = 5usize;
    let xs = batch_input(model.d_in(), batch, 4);
    let seed = 7u64;
    let d_in = model.d_in();
    let d_out = model.d_out();

    let plan = model.uniform_plan(ReadMode::Original);
    let mut c_batch = ReadCounters::default();
    let logits = model.forward_batch(&xs, &plan, &cfg, seed, &mut c_batch);

    let mut scratch = Scratch::for_model(&model);
    let mut c_solo_total = ReadCounters::default();
    for i in 0..batch {
        let mut rng = Rng::stream(seed, i as u64);
        let mut c = ReadCounters::default();
        let y = model
            .forward_into(
                &xs[i * d_in..(i + 1) * d_in],
                &mut scratch,
                &plan,
                &cfg,
                &mut rng,
                &mut c,
            )
            .to_vec();
        assert_eq!(
            &logits[i * d_out..(i + 1) * d_out],
            y.as_slice(),
            "sample {i} must not depend on its batch neighbours"
        );
        c_solo_total.merge(&c);
    }
    assert_eq!(c_batch, c_solo_total);
}

#[test]
fn counters_merge_in_sample_order_regardless_of_pool() {
    // run the same batch in two pools with different thread counts and a
    // third time on the global pool: every f64 in the counters must match
    // exactly (merge order is index order, not completion order)
    let cfg = DeviceConfig::default();
    let model = mk_model(&cfg, 9);
    let plan = model.uniform_plan(ReadMode::Decomposed);
    let xs = batch_input(model.d_in(), 16, 10);
    let run_in = |threads: usize| {
        let pool = rayon::ThreadPoolBuilder::new()
            .num_threads(threads)
            .build()
            .unwrap();
        pool.install(|| {
            let mut c = ReadCounters::default();
            model.forward_batch(&xs, &plan, &cfg, 5, &mut c);
            c
        })
    };
    let a = run_in(1);
    let b = run_in(4);
    let mut c_global = ReadCounters::default();
    model.forward_batch(&xs, &plan, &cfg, 5, &mut c_global);
    assert_eq!(a, b);
    assert_eq!(a, c_global);
    assert!(a.cell_pj > 0.0 && a.cycles > 0);
}

/// A deliberately lopsided plan: every layer at a different rho, the
/// middle layer additionally bit-serial.  Exercises the per-layer plan
/// path end to end (ISSUE 4: technique B shaping in the native engine).
fn non_uniform_plan() -> EnergyPlan {
    EnergyPlan::new(
        vec![
            LayerPlan::new(1.5, ReadMode::Original),
            LayerPlan::new(6.0, ReadMode::Decomposed),
            LayerPlan::new(0.5, ReadMode::Original),
        ],
        PlanSource::Trained,
    )
}

#[test]
fn non_uniform_plan_parity_at_1_2_and_n_threads() {
    // ISSUE 4 acceptance: forward_batch_seeds under a non-uniform plan
    // stays bit-identical (logits AND counters) at any thread count.
    let cfg = DeviceConfig::default();
    let model = mk_model(&cfg, 21);
    let plan = non_uniform_plan();
    let batch = 7usize;
    let xs = batch_input(model.d_in(), batch, 22);
    let seeds: Vec<u64> = (0..batch).map(|i| 0xBEEF + i as u64 * 101).collect();
    let n = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4)
        .max(3);

    let mut c_ref = ReadCounters::default();
    let reference = model.forward_batch_seeds(&xs, &plan, &cfg, &seeds, &mut c_ref);
    assert_eq!(reference.len(), batch * model.d_out());
    for threads in [1usize, 2, n] {
        let pool = rayon::ThreadPoolBuilder::new()
            .num_threads(threads)
            .build()
            .unwrap();
        let (par, c_par) = pool.install(|| {
            let mut c = ReadCounters::default();
            let y = model.forward_batch_seeds(&xs, &plan, &cfg, &seeds, &mut c);
            (y, c)
        });
        assert_eq!(
            reference, par,
            "non-uniform plan: logits must be bit-identical at {threads} threads"
        );
        assert_eq!(
            c_ref, c_par,
            "non-uniform plan: counters must be bit-identical at {threads} threads"
        );
    }
    // and the seeded batch still equals per-sample solo forwards
    for i in 0..batch {
        let mut c = ReadCounters::default();
        let solo = model.forward_batch_seeds(
            &xs[i * model.d_in()..(i + 1) * model.d_in()],
            &plan,
            &cfg,
            &seeds[i..i + 1],
            &mut c,
        );
        assert_eq!(
            solo.as_slice(),
            &reference[i * model.d_out()..(i + 1) * model.d_out()],
            "sample {i} must not depend on batch packing under a non-uniform plan"
        );
    }
}

#[test]
fn non_uniform_plan_changes_energy_and_noise() {
    // the plan must actually reach the device: per-layer rho shapes the
    // energy accounting, and a different plan draws different noise
    let cfg = DeviceConfig::default();
    let model = mk_model(&cfg, 23);
    let xs = batch_input(model.d_in(), 4, 24);
    let seeds: Vec<u64> = (0..4u64).map(|i| 7 + i).collect();
    let run = |plan: &EnergyPlan| {
        let mut c = ReadCounters::default();
        let y = model.forward_batch_seeds(&xs, plan, &cfg, &seeds, &mut c);
        (y, c)
    };
    let (y_uniform, c_uniform) = run(&model.uniform_plan(ReadMode::Original));
    let (y_plan, c_plan) = run(&non_uniform_plan());
    assert_ne!(y_uniform, y_plan, "plan rho must reach the noise draw");
    assert_ne!(c_uniform.cell_pj, c_plan.cell_pj, "plan rho must reach the energy accounting");
    // decomposed middle layer pays extra cycles vs the all-original plan
    assert!(c_plan.cycles > c_uniform.cycles);
}

// ---------------------------------------------------------------------------
// Layer-major engine parity (ISSUE 10): `forward_batch_seeds` now runs
// layer-major tile-blocked, but its bit-identity contract is unchanged —
// the sample-major oracle, the sequential loop, tracing, and the pooled
// slab path must all agree exactly.
// ---------------------------------------------------------------------------

#[test]
fn layer_major_matches_seq_and_sample_major_across_batches_and_threads() {
    // `forward_batch_seq(seed)` gives sample i the stream
    // `Rng::stream(seed, i) == Rng::new(hash2(seed, i))`, so feeding the
    // seeded engines `hash2(seed, i)` pins all three execution orders to
    // one set of per-sample streams.
    let cfg = DeviceConfig::default();
    let model = mk_model(&cfg, 31);
    let seed = 33u64;
    let n = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4)
        .max(3);

    for plan in [model.uniform_plan(ReadMode::Original), non_uniform_plan()] {
        for batch in [1usize, 2, 7, 16] {
            let xs = batch_input(model.d_in(), batch, 32 + batch as u64);
            let seeds: Vec<u64> = (0..batch as u64).map(|i| hash2(seed, i)).collect();

            let mut c_seq = ReadCounters::default();
            let seq = model.forward_batch_seq(&xs, &plan, &cfg, seed, &mut c_seq);
            let mut c_sm = ReadCounters::default();
            let sm =
                model.forward_batch_seeds_sample_major(&xs, &plan, &cfg, &seeds, &mut c_sm);
            assert_eq!(seq, sm, "sample-major oracle diverged from seq at b={batch}");
            assert_eq!(c_seq, c_sm);

            for threads in [1usize, 2, n] {
                let pool = rayon::ThreadPoolBuilder::new()
                    .num_threads(threads)
                    .build()
                    .unwrap();
                let (lm, c_lm) = pool.install(|| {
                    let mut c = ReadCounters::default();
                    let y = model.forward_batch_seeds(&xs, &plan, &cfg, &seeds, &mut c);
                    (y, c)
                });
                assert_eq!(
                    seq, lm,
                    "layer-major logits diverged at b={batch}, {threads} threads"
                );
                assert_eq!(
                    c_seq, c_lm,
                    "layer-major counters diverged at b={batch}, {threads} threads"
                );
            }
        }
    }
}

#[test]
fn layer_major_tracing_is_exact_and_reconciles_energy() {
    // Tracing must not perturb the computation (bit-identical logits and
    // merged counters), and each sample's per-layer uJ spans must sum to
    // that sample's own counter total — the per-request attribution the
    // serving stack reports.
    let cfg = DeviceConfig::default();
    let model = mk_model(&cfg, 41);
    let plan = non_uniform_plan();
    let batch = 7usize;
    let xs = batch_input(model.d_in(), batch, 42);
    let seeds: Vec<u64> = (0..batch as u64).map(|i| 0xACE + i * 17).collect();

    let mut c_plain = ReadCounters::default();
    let plain = model.forward_batch_seeds(&xs, &plan, &cfg, &seeds, &mut c_plain);

    for threads in [1usize, 2] {
        let pool = rayon::ThreadPoolBuilder::new()
            .num_threads(threads)
            .build()
            .unwrap();
        let (traced, traces, c_traced) = pool.install(|| {
            let mut c = ReadCounters::default();
            let (y, t) = model.forward_batch_seeds_traced(&xs, &plan, &cfg, &seeds, &mut c);
            (y, t, c)
        });
        assert_eq!(plain, traced, "tracing must not perturb logits");
        assert_eq!(c_plain, c_traced, "tracing must not perturb counters");
        assert_eq!(traces.len(), batch);

        let mut merged = ReadCounters::default();
        for t in &traces {
            assert_eq!(t.layers.n, DIMS.len());
            // per-layer uJ spans reconcile with the sample's counters
            let layer_uj: f64 = t.layers.uj[..t.layers.n].iter().map(|&u| u as f64).sum();
            let sample_uj = t.counters.total_pj() * 1e-6;
            assert!(
                (layer_uj - sample_uj).abs() < 1e-6 * sample_uj.max(1e-12) + 1e-9,
                "per-layer uJ {layer_uj} != sample uJ {sample_uj}"
            );
            assert!(t.counters.cycles > 0);
            merged.merge(&t.counters);
        }
        // ...and the per-sample counters sum back to the batch total
        assert_eq!(merged, c_traced);
    }
}

#[test]
fn pooled_slab_paths_are_bit_identical_and_recycle() {
    // The SlabPool variants are the scheduler's steady-state path: same
    // bits as the fresh-allocation engines, with arenas parked between
    // dispatches instead of dropped.
    let cfg = DeviceConfig::default();
    let model = mk_model(&cfg, 51);
    let plan = model.uniform_plan(ReadMode::Decomposed);
    let batch = 9usize;
    let xs = batch_input(model.d_in(), batch, 52);
    let seeds: Vec<u64> = (0..batch as u64).map(|i| hash2(99, i)).collect();

    let mut c_ref = ReadCounters::default();
    let reference = model.forward_batch_seeds(&xs, &plan, &cfg, &seeds, &mut c_ref);
    let (traced_ref, traces_ref) = {
        let mut c = ReadCounters::default();
        model.forward_batch_seeds_traced(&xs, &plan, &cfg, &seeds, &mut c)
    };

    let pool = SlabPool::new();
    assert_eq!(pool.idle(), 0);
    for round in 0..3 {
        let mut c = ReadCounters::default();
        let y = model.forward_batch_seeds_pooled(&xs, &plan, &cfg, &seeds, &mut c, &pool);
        assert_eq!(reference, y, "pooled logits diverged on round {round}");
        assert_eq!(c_ref, c, "pooled counters diverged on round {round}");
        // the dispatch's slab is parked, and steady state reuses it
        // rather than growing the pool
        assert!(pool.idle() >= 1, "round {round} returned no slab");
    }
    let idle_after_plain = pool.idle();

    let mut c = ReadCounters::default();
    let (y, traces) =
        model.forward_batch_seeds_traced_pooled(&xs, &plan, &cfg, &seeds, &mut c, &pool);
    assert_eq!(traced_ref, y);
    assert_eq!(c_ref, c);
    assert_eq!(traces.len(), traces_ref.len());
    for (a, b) in traces.iter().zip(traces_ref.iter()) {
        assert_eq!(a.counters, b.counters, "pooled tracing must be exact");
    }
    assert!(pool.idle() >= idle_after_plain, "traced dispatch lost a slab");
}
