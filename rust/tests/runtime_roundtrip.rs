//! Runtime integration tests: artifacts -> PJRT -> numbers.
//!
//! These require `make artifacts` to have run; they are skipped (with a
//! note) when artifacts/ is missing so `cargo test` works pre-build.

use emtopt::data::{Dataset, Split, Suite};
use emtopt::runtime::{execute, scalar_i32, to_vec_f32, Artifacts, Evaluator, Predictor, Trainer};
use emtopt::runtime::session::TrainKnobs;

fn arts() -> Option<Artifacts> {
    match Artifacts::open_default() {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("skipping runtime test (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn manifest_consistent_with_files() {
    let Some(arts) = arts() else { return };
    assert!(!arts.manifest.artifacts.is_empty());
    for a in &arts.manifest.artifacts {
        assert!(
            arts.dir.join(&a.file).exists(),
            "artifact file missing: {}",
            a.file
        );
    }
    // every model has its six artifact kinds
    for key in arts.manifest.model_keys() {
        for kind in ["init", "train", "train_decomp", "eval", "eval_decomp", "predict"] {
            assert!(
                arts.manifest.artifact(&format!("{key}_{kind}")).is_ok(),
                "{key} missing {kind}"
            );
        }
    }
}

#[test]
fn init_artifact_shapes_match_manifest() {
    let Some(arts) = arts() else { return };
    let info = arts.manifest.artifact("mlp_10_init").unwrap();
    let exe = arts.runtime.load_hlo(&arts.dir.join(&info.file)).unwrap();
    let outs = execute(&exe, &[scalar_i32(0)]).unwrap();
    // params... + rho_raw
    let train = arts.manifest.artifact("mlp_10_train").unwrap();
    let n_params = arts.manifest.model("mlp_10").unwrap().n_layers * 2;
    assert_eq!(outs.len(), n_params + 1);
    for (lit, spec) in outs.iter().zip(train.inputs.iter()) {
        assert_eq!(lit.element_count(), spec.numel(), "spec {}", spec.name);
    }
}

#[test]
fn init_is_deterministic_in_seed() {
    let Some(arts) = arts() else { return };
    let info = arts.manifest.artifact("mlp_10_init").unwrap();
    let exe = arts.runtime.load_hlo(&arts.dir.join(&info.file)).unwrap();
    let a = execute(&exe, &[scalar_i32(5)]).unwrap();
    let b = execute(&exe, &[scalar_i32(5)]).unwrap();
    let c = execute(&exe, &[scalar_i32(6)]).unwrap();
    assert_eq!(to_vec_f32(&a[0]).unwrap(), to_vec_f32(&b[0]).unwrap());
    assert_ne!(to_vec_f32(&a[0]).unwrap(), to_vec_f32(&c[0]).unwrap());
}

#[test]
fn train_step_reduces_loss_through_pjrt() {
    let Some(arts) = arts() else { return };
    let mut trainer = Trainer::new(&arts, "mlp_10", false, 1).unwrap();
    let ds = Dataset::new(Suite::Cifar, 1);
    let knobs = TrainKnobs::traditional();
    let mut losses = Vec::new();
    for s in 0..10 {
        let (x, y) = ds.batch(Split::Train, s * trainer.batch as u64, trainer.batch);
        let out = trainer.step(&x, &y, &knobs).unwrap();
        assert!(out.loss.is_finite());
        losses.push(out.loss);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss must fall: {losses:?}"
    );
}

#[test]
fn noise_gate_and_intensity_affect_eval() {
    let Some(arts) = arts() else { return };
    let mut trainer = Trainer::new(&arts, "mlp_10", false, 2).unwrap();
    let ds = Dataset::new(Suite::Cifar, 2);
    let knobs = TrainKnobs::traditional();
    for s in 0..6 {
        let (x, y) = ds.batch(Split::Train, s * trainer.batch as u64, trainer.batch);
        trainer.step(&x, &y, &knobs).unwrap();
    }
    let evaluator = Evaluator::new(&arts, "mlp_10", false).unwrap();
    let (x, y) = ds.batch(Split::Test, 0, evaluator.batch);
    let params = trainer.params();
    let rho = trainer.rho_raw();
    // noiseless eval is deterministic across seeds
    let a = evaluator.eval_batch(params, rho, &x, &y, 1, 1.0, 0.0).unwrap();
    let b = evaluator.eval_batch(params, rho, &x, &y, 2, 1.0, 0.0).unwrap();
    assert_eq!(a.top1, b.top1);
    // strong noise must not beat the noiseless accuracy (statistically;
    // use a very strong intensity for a clear margin)
    let noisy = evaluator.eval_batch(params, rho, &x, &y, 3, 8.0, 1.0).unwrap();
    assert!(
        noisy.top1 <= a.top1,
        "strong noise should not help: {} vs {}",
        noisy.top1,
        a.top1
    );
}

#[test]
fn decomposed_eval_runs_and_reports_lower_energy() {
    let Some(arts) = arts() else { return };
    let trainer = Trainer::new(&arts, "mlp_10", false, 3).unwrap();
    let ds = Dataset::new(Suite::Cifar, 3);
    let e_plain = Evaluator::new(&arts, "mlp_10", false).unwrap();
    let e_dec = Evaluator::new(&arts, "mlp_10", true).unwrap();
    let (x, y) = ds.batch(Split::Test, 0, e_plain.batch);
    let a = e_plain
        .eval_batch(trainer.params(), trainer.rho_raw(), &x, &y, 1, 1.0, 1.0)
        .unwrap();
    let b = e_dec
        .eval_batch(trainer.params(), trainer.rho_raw(), &x, &y, 1, 1.0, 1.0)
        .unwrap();
    assert!(b.energy < a.energy, "eq. 20: {} vs {}", b.energy, a.energy);
}

#[test]
fn predictor_shapes() {
    let Some(arts) = arts() else { return };
    let trainer = Trainer::new(&arts, "mlp_10", false, 4).unwrap();
    let p = Predictor::new(&arts, "mlp_10").unwrap();
    let ds = Dataset::new(Suite::Cifar, 4);
    let (x, _) = ds.batch(Split::Test, 0, p.batch);
    let logits = p
        .predict(trainer.params(), trainer.rho_raw(), &x, 1, 1.0)
        .unwrap();
    assert_eq!(logits.len(), p.batch * p.num_classes);
    assert!(logits.iter().all(|v| v.is_finite()));
}
