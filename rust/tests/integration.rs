//! Cross-module integration tests: the native crossbar substrate vs the
//! analytical models, the solution/experiment plumbing, and the
//! store round-trip through real training state shapes.

use emtopt::baselines::{hardware_cost, Method};
use emtopt::coordinator::{experiments, Solution, TrainedModel};
use emtopt::crossbar::{CrossbarArray, ReadCounters};
use emtopt::device::{DeviceConfig, Intensity};
use emtopt::energy::{EnergyModel, ReadMode};
use emtopt::inference::NoisyModel;
use emtopt::models::paper_scale::{resnet, vgg16, Resolution};
use emtopt::rng::Rng;
use emtopt::timing::TimingModel;

#[test]
fn native_sim_energy_matches_analytical_shape() {
    // the crossbar counters and the analytical EnergyModel must agree on
    // the rho-linearity and the decomposed-vs-original ordering.
    let (k, n) = (128usize, 32usize);
    let mut rng = Rng::new(1);
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.3).collect();
    let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();

    let run = |rho: f32, mode: ReadMode, rng: &mut Rng| {
        let cfg = DeviceConfig {
            rho,
            ..DeviceConfig::default()
        };
        let arr = CrossbarArray::program(&w, k, n, &cfg);
        let mut out = vec![0.0f32; n];
        let mut counters = ReadCounters::default();
        arr.mac(&x, &mut out, arr.read_plan(mode), 5, 1.0, rng, &mut counters);
        counters.cell_pj
    };
    let e1 = run(1.0, ReadMode::Original, &mut rng);
    let e2 = run(2.0, ReadMode::Original, &mut rng);
    assert!((e2 / e1 - 2.0).abs() < 1e-6, "rho-linearity: {}", e2 / e1);
    let ed = run(1.0, ReadMode::Decomposed, &mut rng);
    assert!(ed < e1, "decomposed cell energy lower: {ed} vs {e1}");
}

#[test]
fn native_mlp_accuracy_degrades_with_intensity() {
    // end-to-end on the native substrate: a fixed random MLP classifies a
    // linearly-separable toy task better at weak than at strong intensity.
    let mut rng = Rng::new(7);
    let dims = [(32usize, 24usize), (24, 8)];
    let data: Vec<(Vec<f32>, Vec<f32>)> = dims
        .iter()
        .map(|&(i, o)| {
            let w: Vec<f32> = (0..i * o).map(|_| rng.normal() * 0.4).collect();
            (w, vec![0.0f32; o])
        })
        .collect();
    let specs: Vec<(&[f32], &[f32], usize, usize)> = data
        .iter()
        .zip(dims.iter())
        .map(|((w, b), &(i, o))| (w.as_slice(), b.as_slice(), i, o))
        .collect();

    let agreement = |intensity: Intensity, rng: &mut Rng| {
        let cfg = DeviceConfig {
            intensity,
            rho: 0.2, // noisy regime
            ..DeviceConfig::default()
        };
        let model = NoisyModel::new(&specs, &cfg).unwrap();
        let mut counters = ReadCounters::default();
        let mut same = 0;
        let trials = 60;
        for t in 0..trials {
            let mut r2 = Rng::new(100 + t);
            let x: Vec<f32> = (0..32).map(|_| r2.next_f32()).collect();
            let clean = model.forward_clean(&x, &cfg);
            let argmax = |v: &[f32]| {
                v.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0
            };
            let noisy = model.forward_single(
                &x,
                &model.uniform_plan(ReadMode::Original),
                &cfg,
                rng,
                &mut counters,
            );
            if argmax(&clean) == argmax(&noisy) {
                same += 1;
            }
        }
        same
    };
    let weak = agreement(Intensity::Weak, &mut rng);
    let strong = agreement(Intensity::Strong, &mut rng);
    assert!(
        weak > strong,
        "weak intensity must preserve more decisions: {weak} vs {strong}"
    );
}

#[test]
fn table_shapes_hold_analytically() {
    // The Table 1/2 hardware columns that don't need training: cells and
    // delay ratios between methods, straight from the models.
    let em = EnergyModel::new(5);
    let tm = TimingModel::new(5);
    for model in [vgg16(Resolution::Cifar), resnet(18, Resolution::Cifar)] {
        let ours = hardware_cost(Method::OursAB, &model, 1.0, 1.0, &em, &tm);
        let ours_c = hardware_cost(Method::OursABC, &model, 1.0, 1.0, &em, &tm);
        let bin = hardware_cost(Method::BinarizedEncoding, &model, 1.0, 1.0, &em, &tm);
        let comp =
            hardware_cost(Method::FluctuationCompensation, &model, 1.0, 1.0, &em, &tm);
        // paper: binarized 5x cells; compensation 5x delay; ours-C 5x delay
        assert!((bin.cells / ours.cells - 5.0).abs() < 1e-9);
        assert!((comp.delay_us / ours.delay_us - 5.0).abs() < 1e-9);
        assert!((ours_c.delay_us / ours.delay_us - 5.0).abs() < 1e-9);
        // ours-C saves analog energy vs ours at the same rho
        assert!(ours_c.energy_uj < ours.energy_uj);
    }
}

#[test]
fn solution_method_mapping_consistent() {
    for sol in Solution::ALL {
        let m = sol.method();
        assert_eq!(sol.decomposed(), m.read_mode() == ReadMode::Decomposed);
        if sol != Solution::Traditional {
            assert!(m.noise_aware());
        }
    }
}

#[test]
fn store_roundtrip_runtime_shapes() {
    let trained = TrainedModel {
        model_key: "tiny_resnet_10".into(),
        solution: Solution::ABC,
        params: vec![
            (vec![3, 3, 3, 16], vec![0.5; 3 * 3 * 3 * 16]),
            (vec![16], vec![0.0; 16]),
        ],
        rho_raw: vec![4.0; 1],
        loss_trace: vec![2.3, 1.0],
    };
    let dir = std::env::temp_dir().join("emtopt_integration_store");
    let path = dir.join("t.emtm");
    emtopt::coordinator::store::save(&trained, &path).unwrap();
    let back = emtopt::coordinator::store::load(&path).unwrap();
    assert_eq!(back.params, trained.params);
    assert_eq!(back.solution, Solution::ABC);
    // scaled rho raw round-trips through the softplus parameterisation
    let scaled = back.scaled_rho_raw(2.0);
    let rho0 = emtopt::runtime::rho_of_raw(back.rho_raw[0]);
    let rho1 = emtopt::runtime::rho_of_raw(scaled[0]);
    assert!((rho1 / rho0 - 2.0).abs() < 1e-3);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn paper_scale_energy_ordering() {
    // sanity of the Fig 9 energy axis: decomposed < original cell energy,
    // imagenet models cost more than cifar ones (paper §5.3 observation)
    let em = EnergyModel::new(5);
    let r18c = resnet(18, Resolution::Cifar);
    let r18i = resnet(18, Resolution::ImageNet);
    let e_c = em.model_uj_uniform(&r18c, 1.0, ReadMode::Original);
    let e_i = em.model_uj_uniform(&r18i, 1.0, ReadMode::Original);
    assert!(
        e_i > 2.0 * e_c,
        "imagenet inference must cost more: {e_i} vs {e_c}"
    );
}

#[test]
fn experiments_helpers() {
    assert!(experiments::paper_model_for("tiny_vgg_10").is_some());
    let grid = experiments::default_rho_grid();
    assert!(grid.len() >= 8);
    let cfg = experiments::schedule_for("mlp_10");
    assert!(cfg.pretrain_steps > 0 && cfg.finetune_steps > 0);
}
