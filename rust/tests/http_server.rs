//! Integration tests for the HTTP serving stack: boot the real server on
//! an ephemeral port and drive it over raw `TcpStream`s — happy path,
//! malformed input -> 400, overload -> 503, and `/metrics` accounting.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use emtopt::coordinator::router::NativeServerConfig;
use emtopt::device::DeviceConfig;
use emtopt::inference::NoisyModel;
use emtopt::rng::Rng;
use emtopt::server::http::HttpConn;
use emtopt::server::{serve_http, HttpServerConfig, ServerHandle};
use emtopt::util::json::Json;

/// A small random dense stack programmed on the crossbar substrate.
fn model(dims: &[(usize, usize)], seed: u64, dev: &DeviceConfig) -> Arc<NoisyModel> {
    let mut rng = Rng::new(seed);
    let data: Vec<(Vec<f32>, Vec<f32>)> = dims
        .iter()
        .map(|&(i, o)| {
            let w: Vec<f32> = (0..i * o).map(|_| rng.normal() * 0.3).collect();
            let b = vec![0.0f32; o];
            (w, b)
        })
        .collect();
    let specs: Vec<(&[f32], &[f32], usize, usize)> = data
        .iter()
        .zip(dims.iter())
        .map(|((w, b), &(i, o))| (w.as_slice(), b.as_slice(), i, o))
        .collect();
    Arc::new(NoisyModel::new(&specs, dev).unwrap())
}

fn boot(engine: NativeServerConfig) -> ServerHandle {
    let dev = engine.device.clone();
    let m = model(&[(8, 3)], 3, &dev);
    serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            engine,
            ..Default::default()
        },
    )
    .unwrap()
}

fn connect(handle: &ServerHandle) -> HttpConn<TcpStream> {
    let stream = TcpStream::connect(handle.addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    HttpConn::new(stream)
}

fn post(conn: &mut HttpConn<TcpStream>, path: &str, body: &str) -> (u16, Json) {
    conn.write_request("POST", path, body.as_bytes()).unwrap();
    let (status, body) = conn.read_response(1 << 20).unwrap();
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    (status, v)
}

fn get(conn: &mut HttpConn<TcpStream>, path: &str) -> (u16, Vec<u8>) {
    conn.write_request("GET", path, b"").unwrap();
    conn.read_response(1 << 20).unwrap()
}

/// Render one pixel row as a JSON array literal.
fn image_json(row: &[f32]) -> String {
    let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
    format!("[{}]", cells.join(","))
}

#[test]
fn happy_path_infer_classify_tiers() {
    let handle = boot(NativeServerConfig {
        batch: 4,
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let mut conn = connect(&handle);

    // healthz reports the deployed shape and the batch cap
    let (status, body) = get(&mut conn, "/healthz");
    assert_eq!(status, 200);
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(v.get("input_len").unwrap().as_usize().unwrap(), 8);
    assert_eq!(v.get("num_classes").unwrap().as_usize().unwrap(), 3);
    assert_eq!(v.get("max_batch").unwrap().as_usize().unwrap(), 64);

    // infer: logits + echo of the tier plan
    let img = "[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]";
    let (status, v) = post(&mut conn, "/v1/infer", &format!("{{\"image\":{img}}}"));
    assert_eq!(status, 200);
    assert_eq!(v.get("logits").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(v.get("tier").unwrap().as_str().unwrap(), "normal");
    assert_eq!(v.get("mode").unwrap().as_str().unwrap(), "original");

    // classify adds the argmax, and tiers select different lanes
    let (status, v) = post(
        &mut conn,
        "/v1/classify",
        &format!("{{\"image\":{img},\"tier\":\"low\"}}"),
    );
    assert_eq!(status, 200);
    assert!(v.get("class").unwrap().as_usize().unwrap() < 3);
    assert_eq!(v.get("tier").unwrap().as_str().unwrap(), "low");
    assert_eq!(v.get("mode").unwrap().as_str().unwrap(), "decomposed");
    let rho_low = v.get("rho").unwrap().as_f64().unwrap();

    let (status, v) = post(
        &mut conn,
        "/v1/infer",
        &format!("{{\"image\":{img},\"tier\":\"high\"}}"),
    );
    assert_eq!(status, 200);
    let rho_high = v.get("rho").unwrap().as_f64().unwrap();
    assert!(
        rho_high > rho_low,
        "high tier must buy a larger rho ({rho_high} vs {rho_low})"
    );

    handle.shutdown().unwrap();
}

#[test]
fn bad_requests_get_4xx() {
    let handle = boot(NativeServerConfig {
        batch: 2,
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let mut conn = connect(&handle);

    // malformed JSON
    let (status, v) = post(&mut conn, "/v1/infer", "this is not json");
    assert_eq!(status, 400);
    assert!(v.get("error").is_ok());

    // wrong image length
    let (status, _) = post(&mut conn, "/v1/infer", "{\"image\":[1,2]}");
    assert_eq!(status, 400);

    // unknown tier
    let (status, _) = post(
        &mut conn,
        "/v1/infer",
        "{\"image\":[0,0,0,0,0,0,0,0],\"tier\":\"turbo\"}",
    );
    assert_eq!(status, 400);

    // unknown route / wrong method (keep-alive survives error responses)
    let (status, _) = post(&mut conn, "/v1/nope", "{}");
    assert_eq!(status, 404);
    let (status, _) = get(&mut conn, "/v1/infer");
    assert_eq!(status, 405);

    handle.shutdown().unwrap();
}

#[test]
fn batch_body_bit_identical_to_sequential_singles() {
    // Acceptance contract of the batch path: the same model + seed behind
    // two servers with different per-lane worker counts; per-image logits
    // of one multi-image body must be bit-identical to the same images as
    // sequential single requests, on either server (content-derived noise
    // seeds make results independent of batch packing and thread count).
    let mk = |workers: usize| {
        boot(NativeServerConfig {
            batch: 4,
            workers,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        })
    };
    let a = mk(1);
    let b = mk(3);
    let n = 5usize;
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut r = Rng::stream(4242, i as u64);
            (0..8).map(|_| r.next_f32()).collect()
        })
        .collect();
    let rows_json: Vec<String> = rows.iter().map(|r| image_json(r)).collect();
    let body = format!("{{\"images\":[{}],\"tier\":\"high\"}}", rows_json.join(","));

    let batch_logits = |handle: &ServerHandle| -> Vec<Vec<f32>> {
        let mut conn = connect(handle);
        let (status, v) = post(&mut conn, "/v1/infer", &body);
        assert_eq!(status, 200);
        assert_eq!(v.get("count").unwrap().as_usize().unwrap(), n);
        assert_eq!(v.get("tier").unwrap().as_str().unwrap(), "high");
        v.get("logits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|row| row.as_f32s().unwrap())
            .collect()
    };
    let la = batch_logits(&a);
    let lb = batch_logits(&b);
    assert_eq!(la.len(), n);
    assert_eq!(la, lb, "batch logits must not depend on worker count");

    // sequential singles (server b) reproduce every batch row bit-exactly
    let mut conn = connect(&b);
    for (i, rj) in rows_json.iter().enumerate() {
        let (status, v) = post(
            &mut conn,
            "/v1/infer",
            &format!("{{\"image\":{rj},\"tier\":\"high\"}}"),
        );
        assert_eq!(status, 200);
        assert_eq!(
            v.get("logits").unwrap().as_f32s().unwrap(),
            la[i],
            "image {i}: single-request logits diverged from the batch row"
        );
    }
    a.shutdown().unwrap();
    b.shutdown().unwrap();
}

#[test]
fn batch_parse_and_admission_errors() {
    let dev = DeviceConfig::default();
    let m = model(&[(8, 3)], 3, &dev);
    let handle = serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            engine: NativeServerConfig {
                batch: 4,
                workers: 1,
                max_wait: Duration::from_millis(1),
                max_client_batch: 2,
                device: dev,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let mut conn = connect(&handle);
    let img = "[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]";

    // ragged rows
    let (status, v) = post(
        &mut conn,
        "/v1/infer",
        &format!("{{\"images\":[{img},[1,2]]}}"),
    );
    assert_eq!(status, 400);
    assert!(v.get("error").is_ok());
    // empty batch
    let (status, _) = post(&mut conn, "/v1/infer", "{\"images\":[]}");
    assert_eq!(status, 400);
    // both body forms at once
    let (status, _) = post(
        &mut conn,
        "/v1/infer",
        &format!("{{\"image\":{img},\"images\":[{img}]}}"),
    );
    assert_eq!(status, 400);
    // non-finite pixel in a row
    let (status, _) = post(&mut conn, "/v1/infer", "{\"images\":[[1e39,0,0,0,0,0,0,0]]}");
    assert_eq!(status, 400);
    // 3 images over the max_client_batch=2 cap -> typed 413
    let (status, v) = post(
        &mut conn,
        "/v1/infer",
        &format!("{{\"images\":[{img},{img},{img}]}}"),
    );
    assert_eq!(status, 413);
    assert!(v.get("error").unwrap().as_str().unwrap().contains("limit"));

    // within the cap: classify returns per-image classes
    let (status, v) = post(
        &mut conn,
        "/v1/classify",
        &format!("{{\"images\":[{img},{img}]}}"),
    );
    assert_eq!(status, 200);
    let classes = v.get("classes").unwrap().as_arr().unwrap();
    assert_eq!(classes.len(), 2);
    // identical pixels + content-derived seeds -> identical predictions
    assert_eq!(
        classes[0].as_usize().unwrap(),
        classes[1].as_usize().unwrap()
    );

    // engine accounting: one multi-image request, two images, on /metrics
    let (status, body) = get(&mut conn, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text
        .lines()
        .any(|l| l == "emtopt_client_batch_requests_total{tier=\"normal\"} 1"));
    assert!(text
        .lines()
        .any(|l| l == "emtopt_images_total{tier=\"normal\"} 2"));
    handle.shutdown().unwrap();
}

#[test]
fn concurrent_load_matches_metrics() {
    let handle = boot(NativeServerConfig {
        batch: 4,
        workers: 2,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let clients = 4usize;
    let per_client = 16u64;
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let mut conn = connect(&handle);
            std::thread::spawn(move || {
                let tiers = ["low", "normal", "high"];
                let mut ok = 0u64;
                for i in 0..per_client {
                    let mut r = Rng::stream(77 + c as u64, i);
                    let img: Vec<String> =
                        (0..8).map(|_| format!("{}", r.next_f32())).collect();
                    let body = format!(
                        "{{\"image\":[{}],\"tier\":\"{}\"}}",
                        img.join(","),
                        tiers[(i % 3) as usize]
                    );
                    let (status, v) = post(&mut conn, "/v1/classify", &body);
                    assert_eq!(status, 200);
                    assert!(v.get("class").unwrap().as_usize().unwrap() < 3);
                    ok += 1;
                }
                ok
            })
        })
        .collect();
    let ok: u64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
    let sent = clients as u64 * per_client;
    assert_eq!(ok, sent);

    // scrape /metrics and reconcile with what we sent
    let mut conn = connect(&handle);
    let (status, body) = get(&mut conn, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();

    let series_sum = |name: &str| -> u64 {
        text.lines()
            .filter(|l| l.starts_with(name) && !l.starts_with('#'))
            .map(|l| {
                l.rsplit_once(' ')
                    .map(|(_, v)| v.parse::<f64>().unwrap_or(0.0))
                    .unwrap_or(0.0) as u64
            })
            .sum()
    };
    // every 200 we saw is a 200 the server recorded (no other clients);
    // the scrape itself responds after rendering, so it is not counted
    assert_eq!(series_sum("emtopt_http_requests_total{code=\"200\"}"), sent);
    // the engine saw exactly the classify requests, spread over tiers
    assert_eq!(series_sum("emtopt_requests_total{"), sent);
    // tail-latency histogram observed every engine request
    assert_eq!(series_sum("emtopt_request_latency_us_count{"), sent);
    for tier in ["low", "normal", "high"] {
        let line = format!("emtopt_requests_total{{tier=\"{tier}\"}}");
        assert!(
            series_sum(&line) > 0,
            "tier {tier} lane must have served traffic"
        );
    }

    handle.shutdown().unwrap();
}

#[test]
fn overload_sheds_with_503() {
    // one slow lane: queue_depth 1, one worker, batch 1, and a model big
    // enough (2x 192x192 noisy layers) that a burst of concurrent
    // requests cannot drain before admission control kicks in.
    let dev = DeviceConfig::default();
    let m = model(&[(192, 192), (192, 192)], 9, &dev);
    let handle = serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            conn_threads: 24,
            engine: NativeServerConfig {
                batch: 1,
                workers: 1,
                queue_depth: 1,
                max_wait: Duration::from_millis(1),
                device: dev,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();

    let burst = 16usize;
    let threads: Vec<_> = (0..burst)
        .map(|c| {
            let mut conn = connect(&handle);
            std::thread::spawn(move || {
                let mut r = Rng::stream(900 + c as u64, 0);
                let img: Vec<String> =
                    (0..192).map(|_| format!("{}", r.next_f32())).collect();
                let body = format!("{{\"image\":[{}]}}", img.join(","));
                let (status, _) = post(&mut conn, "/v1/infer", &body);
                status
            })
        })
        .collect();
    let statuses: Vec<u16> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    let ok = statuses.iter().filter(|&&s| s == 200).count();
    let shed = statuses.iter().filter(|&&s| s == 503).count();
    assert_eq!(ok + shed, burst, "only 200/503 expected, got {statuses:?}");
    assert!(ok >= 1, "at least one request must be admitted");
    assert!(shed >= 1, "burst of {burst} at queue_depth 1 must shed load");

    handle.shutdown().unwrap();
}

#[test]
fn graceful_shutdown_via_admin_endpoint() {
    let handle = boot(NativeServerConfig {
        batch: 2,
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    assert!(!handle.shutdown_requested());
    let mut conn = connect(&handle);
    let (status, v) = post(&mut conn, "/admin/shutdown", "");
    assert_eq!(status, 200);
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "shutting down");
    assert!(handle.shutdown_requested());
    // full drain: every thread joins
    handle.shutdown().unwrap();
}
