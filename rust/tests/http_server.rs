//! Integration tests for the HTTP serving stack: boot the real server on
//! an ephemeral port and drive it over raw `TcpStream`s — happy path,
//! malformed input -> 400, overload -> 503, and `/metrics` accounting.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use emtopt::coordinator::router::NativeServerConfig;
use emtopt::coordinator::{store, Solution, TrainedModel};
use emtopt::device::DeviceConfig;
use emtopt::energy::EnergyModel;
use emtopt::inference::NoisyModel;
use emtopt::rng::Rng;
use emtopt::runtime::raw_of_rho;
use emtopt::server::http::HttpConn;
use emtopt::server::{model_desc, serve_http, HttpServerConfig, ServerHandle};
use emtopt::util::json::Json;

/// A small random dense stack programmed on the crossbar substrate.
fn model(dims: &[(usize, usize)], seed: u64, dev: &DeviceConfig) -> Arc<NoisyModel> {
    let mut rng = Rng::new(seed);
    let data: Vec<(Vec<f32>, Vec<f32>)> = dims
        .iter()
        .map(|&(i, o)| {
            let w: Vec<f32> = (0..i * o).map(|_| rng.normal() * 0.3).collect();
            let b = vec![0.0f32; o];
            (w, b)
        })
        .collect();
    let specs: Vec<(&[f32], &[f32], usize, usize)> = data
        .iter()
        .zip(dims.iter())
        .map(|((w, b), &(i, o))| (w.as_slice(), b.as_slice(), i, o))
        .collect();
    Arc::new(NoisyModel::new(&specs, dev).unwrap())
}

fn boot(engine: NativeServerConfig) -> ServerHandle {
    let dev = engine.device.clone();
    let m = model(&[(8, 3)], 3, &dev);
    serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            engine,
            ..Default::default()
        },
    )
    .unwrap()
}

fn connect(handle: &ServerHandle) -> HttpConn<TcpStream> {
    let stream = TcpStream::connect(handle.addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    HttpConn::new(stream)
}

fn post(conn: &mut HttpConn<TcpStream>, path: &str, body: &str) -> (u16, Json) {
    conn.write_request("POST", path, body.as_bytes()).unwrap();
    let (status, body) = conn.read_response(1 << 20).unwrap();
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    (status, v)
}

fn get(conn: &mut HttpConn<TcpStream>, path: &str) -> (u16, Vec<u8>) {
    conn.write_request("GET", path, b"").unwrap();
    conn.read_response(1 << 20).unwrap()
}

/// POST returning status, headers and parsed body.
fn post_parts(
    conn: &mut HttpConn<TcpStream>,
    path: &str,
    body: &str,
) -> (u16, Vec<(String, String)>, Json) {
    conn.write_request("POST", path, body.as_bytes()).unwrap();
    let (status, headers, body) = conn.read_response_parts(1 << 20).unwrap();
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    (status, headers, v)
}

fn header_value<'h>(headers: &'h [(String, String)], name: &str) -> Option<&'h str> {
    headers
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.as_str())
}

/// Render one pixel row as a JSON array literal.
fn image_json(row: &[f32]) -> String {
    let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
    format!("[{}]", cells.join(","))
}

#[test]
fn happy_path_infer_classify_tiers() {
    let handle = boot(NativeServerConfig {
        batch: 4,
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let mut conn = connect(&handle);

    // healthz reports the deployed shape and the batch cap
    let (status, body) = get(&mut conn, "/healthz");
    assert_eq!(status, 200);
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(v.get("input_len").unwrap().as_usize().unwrap(), 8);
    assert_eq!(v.get("num_classes").unwrap().as_usize().unwrap(), 3);
    assert_eq!(v.get("max_batch").unwrap().as_usize().unwrap(), 64);
    // the energy-plan subsystem advertises its provenance + per-tier rho
    assert_eq!(v.get("plan_source").unwrap().as_str().unwrap(), "analytic");
    let tiers = v.get("tiers").unwrap().as_arr().unwrap();
    assert_eq!(tiers.len(), 3);
    for t in tiers {
        assert_eq!(t.get("source").unwrap().as_str().unwrap(), "analytic");
        assert_eq!(t.get("rho").unwrap().as_f32s().unwrap().len(), 1);
        assert!(t.get("planned_uj").unwrap().as_f64().unwrap() > 0.0);
    }

    // infer: logits + echo of the tier plan
    let img = "[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]";
    let (status, v) = post(&mut conn, "/v1/infer", &format!("{{\"image\":{img}}}"));
    assert_eq!(status, 200);
    assert_eq!(v.get("logits").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(v.get("tier").unwrap().as_str().unwrap(), "normal");
    assert_eq!(v.get("mode").unwrap().as_str().unwrap(), "original");
    assert_eq!(v.get("plan_source").unwrap().as_str().unwrap(), "analytic");
    assert_eq!(v.get("rho_per_layer").unwrap().as_f32s().unwrap().len(), 1);

    // classify adds the argmax, and tiers select different lanes
    let (status, v) = post(
        &mut conn,
        "/v1/classify",
        &format!("{{\"image\":{img},\"tier\":\"low\"}}"),
    );
    assert_eq!(status, 200);
    assert!(v.get("class").unwrap().as_usize().unwrap() < 3);
    assert_eq!(v.get("tier").unwrap().as_str().unwrap(), "low");
    assert_eq!(v.get("mode").unwrap().as_str().unwrap(), "decomposed");
    let rho_low = v.get("rho").unwrap().as_f64().unwrap();

    let (status, v) = post(
        &mut conn,
        "/v1/infer",
        &format!("{{\"image\":{img},\"tier\":\"high\"}}"),
    );
    assert_eq!(status, 200);
    let rho_high = v.get("rho").unwrap().as_f64().unwrap();
    assert!(
        rho_high > rho_low,
        "high tier must buy a larger rho ({rho_high} vs {rho_low})"
    );

    handle.shutdown().unwrap();
}

#[test]
fn bad_requests_get_4xx() {
    let handle = boot(NativeServerConfig {
        batch: 2,
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let mut conn = connect(&handle);

    // malformed JSON
    let (status, v) = post(&mut conn, "/v1/infer", "this is not json");
    assert_eq!(status, 400);
    assert!(v.get("error").is_ok());

    // wrong image length
    let (status, _) = post(&mut conn, "/v1/infer", "{\"image\":[1,2]}");
    assert_eq!(status, 400);

    // unknown tier
    let (status, _) = post(
        &mut conn,
        "/v1/infer",
        "{\"image\":[0,0,0,0,0,0,0,0],\"tier\":\"turbo\"}",
    );
    assert_eq!(status, 400);

    // unknown route / wrong method (keep-alive survives error responses)
    let (status, _) = post(&mut conn, "/v1/nope", "{}");
    assert_eq!(status, 404);
    let (status, _) = get(&mut conn, "/v1/infer");
    assert_eq!(status, 405);

    handle.shutdown().unwrap();
}

#[test]
fn batch_body_bit_identical_to_sequential_singles() {
    // Acceptance contract of the batch path: the same model + seed behind
    // two servers with different per-lane worker counts; per-image logits
    // of one multi-image body must be bit-identical to the same images as
    // sequential single requests, on either server (content-derived noise
    // seeds make results independent of batch packing and thread count).
    let mk = |workers: usize| {
        boot(NativeServerConfig {
            batch: 4,
            workers,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        })
    };
    let a = mk(1);
    let b = mk(3);
    let n = 5usize;
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut r = Rng::stream(4242, i as u64);
            (0..8).map(|_| r.next_f32()).collect()
        })
        .collect();
    let rows_json: Vec<String> = rows.iter().map(|r| image_json(r)).collect();
    let body = format!("{{\"images\":[{}],\"tier\":\"high\"}}", rows_json.join(","));

    let batch_logits = |handle: &ServerHandle| -> Vec<Vec<f32>> {
        let mut conn = connect(handle);
        let (status, v) = post(&mut conn, "/v1/infer", &body);
        assert_eq!(status, 200);
        assert_eq!(v.get("count").unwrap().as_usize().unwrap(), n);
        assert_eq!(v.get("tier").unwrap().as_str().unwrap(), "high");
        v.get("logits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|row| row.as_f32s().unwrap())
            .collect()
    };
    let la = batch_logits(&a);
    let lb = batch_logits(&b);
    assert_eq!(la.len(), n);
    assert_eq!(la, lb, "batch logits must not depend on worker count");

    // sequential singles (server b) reproduce every batch row bit-exactly
    let mut conn = connect(&b);
    for (i, rj) in rows_json.iter().enumerate() {
        let (status, v) = post(
            &mut conn,
            "/v1/infer",
            &format!("{{\"image\":{rj},\"tier\":\"high\"}}"),
        );
        assert_eq!(status, 200);
        assert_eq!(
            v.get("logits").unwrap().as_f32s().unwrap(),
            la[i],
            "image {i}: single-request logits diverged from the batch row"
        );
    }
    a.shutdown().unwrap();
    b.shutdown().unwrap();
}

#[test]
fn batch_parse_and_admission_errors() {
    let dev = DeviceConfig::default();
    let m = model(&[(8, 3)], 3, &dev);
    let handle = serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            engine: NativeServerConfig {
                batch: 4,
                workers: 1,
                max_wait: Duration::from_millis(1),
                max_client_batch: 2,
                device: dev,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let mut conn = connect(&handle);
    let img = "[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]";

    // ragged rows
    let (status, v) = post(
        &mut conn,
        "/v1/infer",
        &format!("{{\"images\":[{img},[1,2]]}}"),
    );
    assert_eq!(status, 400);
    assert!(v.get("error").is_ok());
    // empty batch
    let (status, _) = post(&mut conn, "/v1/infer", "{\"images\":[]}");
    assert_eq!(status, 400);
    // both body forms at once
    let (status, _) = post(
        &mut conn,
        "/v1/infer",
        &format!("{{\"image\":{img},\"images\":[{img}]}}"),
    );
    assert_eq!(status, 400);
    // non-finite pixel in a row
    let (status, _) = post(&mut conn, "/v1/infer", "{\"images\":[[1e39,0,0,0,0,0,0,0]]}");
    assert_eq!(status, 400);
    // 3 images over the max_client_batch=2 cap -> typed 413
    let (status, v) = post(
        &mut conn,
        "/v1/infer",
        &format!("{{\"images\":[{img},{img},{img}]}}"),
    );
    assert_eq!(status, 413);
    assert!(v.get("error").unwrap().as_str().unwrap().contains("limit"));

    // within the cap: classify returns per-image classes
    let (status, v) = post(
        &mut conn,
        "/v1/classify",
        &format!("{{\"images\":[{img},{img}]}}"),
    );
    assert_eq!(status, 200);
    let classes = v.get("classes").unwrap().as_arr().unwrap();
    assert_eq!(classes.len(), 2);
    // identical pixels + content-derived seeds -> identical predictions
    assert_eq!(
        classes[0].as_usize().unwrap(),
        classes[1].as_usize().unwrap()
    );

    // engine accounting: one multi-image request, two images, on /metrics
    let (status, body) = get(&mut conn, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text
        .lines()
        .any(|l| l == "emtopt_client_batch_requests_total{tier=\"normal\"} 1"));
    assert!(text
        .lines()
        .any(|l| l == "emtopt_images_total{tier=\"normal\"} 2"));
    handle.shutdown().unwrap();
}

#[test]
fn concurrent_load_matches_metrics() {
    let handle = boot(NativeServerConfig {
        batch: 4,
        workers: 2,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let clients = 4usize;
    let per_client = 16u64;
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let mut conn = connect(&handle);
            std::thread::spawn(move || {
                let tiers = ["low", "normal", "high"];
                let mut ok = 0u64;
                for i in 0..per_client {
                    let mut r = Rng::stream(77 + c as u64, i);
                    let img: Vec<String> =
                        (0..8).map(|_| format!("{}", r.next_f32())).collect();
                    let body = format!(
                        "{{\"image\":[{}],\"tier\":\"{}\"}}",
                        img.join(","),
                        tiers[(i % 3) as usize]
                    );
                    let (status, v) = post(&mut conn, "/v1/classify", &body);
                    assert_eq!(status, 200);
                    assert!(v.get("class").unwrap().as_usize().unwrap() < 3);
                    ok += 1;
                }
                ok
            })
        })
        .collect();
    let ok: u64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
    let sent = clients as u64 * per_client;
    assert_eq!(ok, sent);

    // scrape /metrics and reconcile with what we sent
    let mut conn = connect(&handle);
    let (status, body) = get(&mut conn, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();

    let series_sum = |name: &str| -> u64 {
        text.lines()
            .filter(|l| l.starts_with(name) && !l.starts_with('#'))
            .map(|l| {
                l.rsplit_once(' ')
                    .map(|(_, v)| v.parse::<f64>().unwrap_or(0.0))
                    .unwrap_or(0.0) as u64
            })
            .sum()
    };
    // every 200 we saw is a 200 the server recorded (no other clients);
    // the scrape itself responds after rendering, so it is not counted
    assert_eq!(series_sum("emtopt_http_requests_total{code=\"200\"}"), sent);
    // the engine saw exactly the classify requests, spread over tiers
    assert_eq!(series_sum("emtopt_requests_total{"), sent);
    // tail-latency histogram observed every engine request
    assert_eq!(series_sum("emtopt_request_latency_us_count{"), sent);
    for tier in ["low", "normal", "high"] {
        let line = format!("emtopt_requests_total{{tier=\"{tier}\"}}");
        assert!(
            series_sum(&line) > 0,
            "tier {tier} lane must have served traffic"
        );
    }

    handle.shutdown().unwrap();
}

#[test]
fn overload_sheds_with_503() {
    // one slow lane: queue_depth 1, one worker, batch 1, and a model big
    // enough (2x 192x192 noisy layers) that a burst of concurrent
    // requests cannot drain before admission control kicks in.
    let dev = DeviceConfig::default();
    let m = model(&[(192, 192), (192, 192)], 9, &dev);
    let handle = serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            engine: NativeServerConfig {
                batch: 1,
                workers: 1,
                queue_depth: 1,
                max_wait: Duration::from_millis(1),
                device: dev,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();

    let burst = 16usize;
    let threads: Vec<_> = (0..burst)
        .map(|c| {
            let mut conn = connect(&handle);
            std::thread::spawn(move || {
                let mut r = Rng::stream(900 + c as u64, 0);
                let img: Vec<String> =
                    (0..192).map(|_| format!("{}", r.next_f32())).collect();
                let body = format!("{{\"image\":[{}]}}", img.join(","));
                let (status, headers, _) = post_parts(&mut conn, "/v1/infer", &body);
                let retry_after = header_value(&headers, "retry-after")
                    .map(|v| v.parse::<u64>().expect("retry-after must be an integer"));
                (status, retry_after)
            })
        })
        .collect();
    let statuses: Vec<(u16, Option<u64>)> =
        threads.into_iter().map(|t| t.join().unwrap()).collect();
    let ok = statuses.iter().filter(|&&(s, _)| s == 200).count();
    let shed = statuses.iter().filter(|&&(s, _)| s == 503).count();
    assert_eq!(ok + shed, burst, "only 200/503 expected, got {statuses:?}");
    assert!(ok >= 1, "at least one request must be admitted");
    assert!(shed >= 1, "burst of {burst} at queue_depth 1 must shed load");
    // every 503 carries an honest, bounded Retry-After back-off hint
    for (status, retry_after) in &statuses {
        if *status == 503 {
            let ra = retry_after.expect("503 must carry retry-after");
            assert!((1..=30).contains(&ra), "retry-after {ra} out of range");
        }
    }

    handle.shutdown().unwrap();
}

#[test]
fn energy_governor_sheds_low_tiers_with_503() {
    // ISSUE 5: fleet energy budget as admission control.  A budget far
    // below one inference's device energy means the first served request
    // exhausts it; afterwards low/normal shed with 503 + Retry-After
    // while the high tier keeps serving, and the governor's counters and
    // budget gauges appear on /metrics.
    let dev = DeviceConfig::default();
    let m = model(&[(8, 3)], 3, &dev);
    let handle = serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            engine: NativeServerConfig {
                batch: 4,
                workers: 1,
                max_wait: Duration::from_millis(1),
                energy_budget_uj_s: Some(1e-8),
                device: dev,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let mut conn = connect(&handle);
    let img = "[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]";

    // healthz advertises the armed budget
    let (status, body) = get(&mut conn, "/healthz");
    assert_eq!(status, 200);
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let budget = v.get("energy_budget_uj_s").unwrap().as_f64().unwrap();
    assert!((budget - 1e-8).abs() < 1e-14, "advertised budget {budget}");

    // A high-tier request (never shed) burns energy, pushing the rolling
    // rate far over the budget, so the immediately following low/normal
    // requests shed.  The recorded energy falls out of the 2 s governor
    // window, so on a badly stalled runner a later request could sneak
    // back in — the bounded retry refreshes the window and keeps the
    // test deterministic in practice.
    let mut observed = None;
    for _attempt in 0..5 {
        let (status, _) = post(
            &mut conn,
            "/v1/infer",
            &format!("{{\"image\":{img},\"tier\":\"high\"}}"),
        );
        assert_eq!(status, 200, "the high tier is never energy-shed");
        let (low_status, headers, v) = post_parts(
            &mut conn,
            "/v1/infer",
            &format!("{{\"image\":{img},\"tier\":\"low\"}}"),
        );
        let (normal_status, _, _) = post_parts(
            &mut conn,
            "/v1/infer",
            &format!("{{\"image\":{img},\"tier\":\"normal\"}}"),
        );
        if low_status == 503 && normal_status == 503 {
            observed = Some((headers, v));
            break;
        }
    }
    let (headers, v) = observed.expect("low/normal must shed while the budget is exhausted");
    assert!(
        v.get("error").unwrap().as_str().unwrap().contains("energy budget"),
        "shed error must name the budget: {v:?}"
    );
    let ra: u64 = header_value(&headers, "retry-after")
        .expect("energy shed must carry retry-after")
        .parse()
        .unwrap();
    assert!((1..=30).contains(&ra), "retry-after {ra} out of range");
    // the highest tier keeps the serving contract throughout
    let (status, _) = post(
        &mut conn,
        "/v1/infer",
        &format!("{{\"image\":{img},\"tier\":\"high\"}}"),
    );
    assert_eq!(status, 200);

    // shed counters + budget gauges on /metrics (>= 1: the retry loop
    // above may have shed more than once)
    let (status, body) = get(&mut conn, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    let shed_count = |tier: &str| -> u64 {
        let prefix = format!("emtopt_governor_shed_total{{tier=\"{tier}\"}} ");
        text.lines()
            .find_map(|l| l.strip_prefix(prefix.as_str()))
            .expect("shed counter series must render")
            .parse()
            .unwrap()
    };
    assert!(shed_count("low") >= 1);
    assert!(shed_count("normal") >= 1);
    assert_eq!(shed_count("high"), 0, "the high tier is never shed");
    assert!(text.lines().any(|l| l.starts_with("emtopt_energy_rate_uj_s ")));
    assert!(text
        .lines()
        .any(|l| l.starts_with("emtopt_energy_budget_uj_s ")));
    let headroom = text
        .lines()
        .find(|l| l.starts_with("emtopt_energy_budget_headroom_uj_s "))
        .and_then(|l| l.rsplit_once(' '))
        .map(|(_, v)| v.parse::<f64>().unwrap())
        .expect("headroom gauge must render when the governor is armed");
    assert!(headroom < 0.0, "exhausted budget must show negative headroom");
    // true per-tier queue length gauge: everything drained by now
    for tier in ["low", "normal", "high"] {
        let line = format!("emtopt_tier_queue_len{{tier=\"{tier}\"}} 0");
        assert!(text.lines().any(|l| l == line), "missing {line}");
    }

    handle.shutdown().unwrap();
}

#[test]
fn trace_echo_reconciles_with_flight_recorder_and_metrics() {
    // PR 7 acceptance: the span tracer is always-on and observable three
    // ways — the inline `"trace": true` echo, the `/admin/trace` flight
    // recorder (Chrome trace-event JSON), and the per-stage histograms
    // on /metrics — and the three views reconcile with each other.
    let handle = boot(NativeServerConfig {
        batch: 4,
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let mut conn = connect(&handle);

    // healthz carries the build-provenance triple
    let (status, body) = get(&mut conn, "/healthz");
    assert_eq!(status, 200);
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    for key in ["version", "rustc", "git_sha"] {
        let s = v.get(key).unwrap().as_str().unwrap();
        assert!(!s.is_empty(), "healthz {key} must be non-empty");
    }

    let img_a = "[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]";
    let img_b = "[0.9,0.8,0.7,0.6,0.5,0.4,0.3,0.2]";

    // tracing must not perturb the noise: traced and untraced logits of
    // the same pixels are bit-identical (content-derived seeds, and the
    // tracer only reads clocks/counters, never the RNG)
    let (status, plain) = post(&mut conn, "/v1/infer", &format!("{{\"image\":{img_a}}}"));
    assert_eq!(status, 200);
    assert!(plain.opt("trace").is_none(), "untraced responses must not echo spans");
    let body_a = format!("{{\"image\":{img_a},\"trace\":true}}");
    let (status, traced) = post(&mut conn, "/v1/infer", &body_a);
    assert_eq!(status, 200);
    assert_eq!(
        traced.get("logits").unwrap().as_f32s().unwrap(),
        plain.get("logits").unwrap().as_f32s().unwrap(),
        "tracing changed the logits"
    );

    // the inline echo: identity, placement, stage spans, energy, layers
    let t = traced.get("trace").unwrap();
    let id_a = t.get("trace_id").unwrap().as_str().unwrap().to_string();
    assert!(
        id_a.starts_with("0x") && id_a.len() == 18,
        "trace_id must be a full-width hex string: {id_a}"
    );
    assert_eq!(t.get("tier").unwrap().as_str().unwrap(), "normal");
    assert_eq!(t.get("batch_images").unwrap().as_usize().unwrap(), 1);
    assert!(t.get("energy_uj").unwrap().as_f64().unwrap() > 0.0);
    let layers = t.get("layers").unwrap().as_arr().unwrap();
    assert_eq!(layers.len(), 1, "single-layer model -> one layer span");
    assert!(layers[0].get("uj").unwrap().as_f64().unwrap() > 0.0);
    // the echo omits write/total (bytes are formed before the write)
    assert!(t.opt("write_us").is_none());
    assert!(t.opt("total_us").is_none());

    // identical pixels -> identical content-derived trace id
    let (status, again) = post(&mut conn, "/v1/infer", &body_a);
    assert_eq!(status, 200);
    assert_eq!(
        again.get("trace").unwrap().get("trace_id").unwrap().as_str().unwrap(),
        id_a,
        "trace id must be deterministic in the pixels"
    );

    // a unique image whose 4 spans we can isolate in the dump
    let (status, traced_b) = post(
        &mut conn,
        "/v1/infer",
        &format!("{{\"image\":{img_b},\"trace\":true}}"),
    );
    assert_eq!(status, 200);
    let tb = traced_b.get("trace").unwrap();
    let id_b = tb.get("trace_id").unwrap().as_str().unwrap().to_string();
    assert_ne!(id_a, id_b, "different pixels -> different trace ids");
    let echo_compute = tb.get("compute_us").unwrap().as_u64().unwrap();
    let echo_queue = tb.get("queue_wait_us").unwrap().as_u64().unwrap();
    let echo_batch = tb.get("batch_wait_us").unwrap().as_u64().unwrap();

    // a traced multi-image body reports the formed device batch
    let (status, traced_batch) = post(
        &mut conn,
        "/v1/infer",
        &format!("{{\"images\":[{img_a},{img_b}],\"trace\":true}}"),
    );
    assert_eq!(status, 200);
    assert_eq!(traced_batch.get("count").unwrap().as_usize().unwrap(), 2);
    assert_eq!(
        traced_batch
            .get("trace")
            .unwrap()
            .get("batch_images")
            .unwrap()
            .as_usize()
            .unwrap(),
        2
    );

    // the flight recorder replays the same requests as Chrome trace JSON
    let (status, body) = get(&mut conn, "/admin/trace");
    assert_eq!(status, 200);
    let dump = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let events = dump.get("traceEvents").unwrap().as_arr().unwrap();
    let ph_of = |e: &Json| e.get("ph").ok().and_then(|p| p.as_str().ok()).map(str::to_string);
    let spans: Vec<&Json> = events
        .iter()
        .filter(|e| ph_of(e).as_deref() == Some("X"))
        .collect();
    assert!(!spans.is_empty(), "flight recorder must hold spans");
    assert!(
        events.iter().any(|e| ph_of(e).as_deref() == Some("M")),
        "process_name metadata must be present"
    );
    // the unique request appears exactly once: four spans, one per stage
    let mine: Vec<&Json> = spans
        .iter()
        .copied()
        .filter(|e| {
            e.get("args")
                .ok()
                .and_then(|a| a.get("trace_id").ok())
                .and_then(|i| i.as_str().ok())
                == Some(id_b.as_str())
        })
        .collect();
    assert_eq!(mine.len(), 4, "one complete span per stage");
    fn stage_span<'a>(spans: &[&'a Json], name: &str) -> &'a Json {
        spans
            .iter()
            .copied()
            .find(|e| e.get("name").unwrap().as_str().unwrap() == name)
            .unwrap_or_else(|| panic!("missing {name} span"))
    }
    let dur_of = |name: &str| stage_span(&mine, name).get("dur").unwrap().as_u64().unwrap();
    // stages are laid end-to-end in request order
    let ts_of = |name: &str| stage_span(&mine, name).get("ts").unwrap().as_u64().unwrap();
    assert!(ts_of("queue_wait") <= ts_of("batch_wait"));
    assert!(ts_of("batch_wait") <= ts_of("compute"));
    assert!(ts_of("compute") <= ts_of("write"));
    // the dump and the inline echo describe the same measurement
    assert_eq!(dur_of("queue_wait"), echo_queue);
    assert_eq!(dur_of("batch_wait"), echo_batch);
    assert_eq!(dur_of("compute"), echo_compute);
    // stage-sum <= end-to-end total (the remainder is parse/reply hop)
    let compute_args = stage_span(&mine, "compute").get("args").unwrap();
    let total_us = compute_args.get("total_us").unwrap().as_u64().unwrap();
    let stage_sum =
        dur_of("queue_wait") + dur_of("batch_wait") + dur_of("compute") + dur_of("write");
    assert!(stage_sum <= total_us, "stage sum {stage_sum} exceeds e2e total {total_us}");
    assert!(compute_args.get("energy_uj").unwrap().as_f64().unwrap() > 0.0);

    // /metrics: the stage histograms observed every engine request
    // (5 requests: plain A, traced A x2, traced B, traced batch)
    let (status, body) = get(&mut conn, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    for stage in ["queue_wait", "batch_wait", "compute", "write"] {
        let line = format!("emtopt_stage_latency_us_count{{tier=\"normal\",stage=\"{stage}\"}} 5");
        assert!(text.lines().any(|l| l == line), "missing {line}");
    }
    assert!(
        text.lines().any(|l| l.starts_with("emtopt_build_info{")),
        "build-info gauge must render"
    );

    handle.shutdown().unwrap();
}

#[test]
fn graceful_shutdown_via_admin_endpoint() {
    let handle = boot(NativeServerConfig {
        batch: 2,
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    assert!(!handle.shutdown_requested());
    let mut conn = connect(&handle);
    let (status, v) = post(&mut conn, "/admin/shutdown", "");
    assert_eq!(status, 200);
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "shutting down");
    assert!(handle.shutdown_requested());
    // full drain: every thread joins
    handle.shutdown().unwrap();
}

#[test]
fn per_peer_connection_cap_rejects_with_429() {
    // a tight cap: 2 live connections per peer IP
    let dev = DeviceConfig::default();
    let m = model(&[(8, 3)], 3, &dev);
    let handle = serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            max_conns_per_peer: 2,
            ..Default::default()
        },
    )
    .unwrap();

    // two connections get served; make sure both are past the acceptor
    let img = "[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]";
    let mut c1 = connect(&handle);
    let mut c2 = connect(&handle);
    let (status, _) = post(&mut c1, "/v1/infer", &format!("{{\"image\":{img}}}"));
    assert_eq!(status, 200);
    let (status, _) = post(&mut c2, "/v1/infer", &format!("{{\"image\":{img}}}"));
    assert_eq!(status, 200);

    // the third connection from the same IP is rejected outright with a
    // typed 429 + back-off hint (no request ever sent)
    let mut c3 = connect(&handle);
    let (status, headers, body) = c3.read_response_parts(1 << 20).unwrap();
    assert_eq!(status, 429);
    assert!(header_value(&headers, "retry-after").is_some());
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(v.get("error").unwrap().as_str().unwrap().contains("cap 2"));

    // closing a connection frees the slot: a fresh connection serves
    // again once the handler notices the close (read-timeout bounded)
    drop(c1);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let served = loop {
        let mut c = connect(&handle);
        let wrote = c.write_request("GET", "/healthz", b"").is_ok();
        match c.read_response(1 << 20) {
            Ok((200, _)) if wrote => break true,
            _ if std::time::Instant::now() > deadline => break false,
            _ => std::thread::sleep(Duration::from_millis(50)),
        }
    };
    assert!(served, "slot must free up after the peer closes a connection");

    // the rejection is visible on /metrics (reuse the live keep-alive
    // connection: a fresh one could race the slot just freed above)
    let (status, metrics) = get(&mut c2, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(metrics).unwrap();
    let rejected: f64 = text
        .lines()
        .find(|l| l.starts_with("emtopt_http_peer_rejected_total"))
        .and_then(|l| l.rsplit_once(' '))
        .map(|(_, v)| v.parse().unwrap())
        .unwrap();
    assert!(rejected >= 1.0, "peer rejection counter must tick: {rejected}");
    assert!(text
        .lines()
        .any(|l| l.starts_with("emtopt_http_requests_total{code=\"429\"}")));

    drop(c2);
    handle.shutdown().unwrap();
}

/// Store fixture for the trained-plan end-to-end tests: a 2-layer model
/// with trained rho (2.0, 8.0) — a deliberately lopsided 1:4 allocation.
fn trained_fixture(dir: &std::path::Path) -> std::path::PathBuf {
    let trained = TrainedModel {
        model_key: "fixture_8_6_3".into(),
        solution: Solution::AB,
        params: vec![
            (vec![8, 6], vec![0.1; 48]),
            (vec![6], vec![0.0; 6]),
            (vec![6, 3], vec![0.1; 18]),
            (vec![3], vec![0.0; 3]),
        ],
        rho_raw: vec![raw_of_rho(2.0), raw_of_rho(8.0)],
        loss_trace: vec![1.0, 0.5],
    };
    let path = dir.join("fixture.emtm");
    store::save(&trained, &path).unwrap();
    path
}

#[test]
fn trained_store_plan_flows_store_to_http() {
    // ISSUE 4 acceptance: a non-uniform EnergyPlan flows
    // store -> tier plans -> inference -> HTTP.  With a fixture store
    // model, /v1/infer returns per-layer rho matching the stored rho_raw
    // rescaled to the tier budget, and batch logits stay bit-identical
    // across worker counts under that plan.
    let dir = std::env::temp_dir().join("emtopt_http_trained_fixture");
    std::fs::create_dir_all(&dir).unwrap();
    let path = trained_fixture(&dir);
    let trained_rho = emtopt::server::load_trained_rho(&path).unwrap();
    assert_eq!(trained_rho.len(), 2);

    let dev = DeviceConfig::default();
    let mk = |workers: usize| {
        let m = model(&[(8, 6), (6, 3)], 11, &dev);
        serve_http(
            m,
            HttpServerConfig {
                addr: "127.0.0.1:0".into(),
                trained_rho: Some(trained_rho.clone()),
                engine: NativeServerConfig {
                    batch: 4,
                    workers,
                    max_wait: Duration::from_millis(1),
                    device: dev.clone(),
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap()
    };
    let a = mk(1);
    let b = mk(3);
    let mut conn = connect(&a);

    // healthz advertises the trained source
    let (status, body) = get(&mut conn, "/healthz");
    assert_eq!(status, 200);
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(v.get("plan_source").unwrap().as_str().unwrap(), "trained");

    // every tier: rho_per_layer preserves the stored 1:4 allocation,
    // rescaled to the tier budget (checked against the analytic model)
    let img = "[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]";
    let desc = {
        let m = model(&[(8, 6), (6, 3)], 11, &dev);
        model_desc(&m)
    };
    let em = EnergyModel::new(dev.act_bits);
    let reference_uj =
        em.model_uj_uniform(&desc, dev.rho as f64, emtopt::energy::ReadMode::Original);
    let mut per_tier_rho: Vec<Vec<f32>> = Vec::new();
    for (tier, scale) in [("low", 0.5), ("normal", 1.0), ("high", 2.0)] {
        let (status, v) = post(
            &mut conn,
            "/v1/infer",
            &format!("{{\"image\":{img},\"tier\":\"{tier}\"}}"),
        );
        assert_eq!(status, 200);
        assert_eq!(v.get("plan_source").unwrap().as_str().unwrap(), "trained");
        let rho = v.get("rho_per_layer").unwrap().as_f32s().unwrap();
        assert_eq!(rho.len(), 2);
        assert!(
            (rho[1] / rho[0] - 4.0).abs() < 1e-3,
            "tier {tier}: stored 1:4 rho allocation lost, got {rho:?}"
        );
        // rescaled to the tier budget: the plan's analytic energy equals
        // the tier's target (no clamping at these magnitudes)
        let plan = emtopt::energy::EnergyPlan::new(
            rho.iter()
                .map(|&r| {
                    emtopt::energy::LayerPlan::new(
                        r,
                        if tier == "low" {
                            emtopt::energy::ReadMode::Decomposed
                        } else {
                            emtopt::energy::ReadMode::Original
                        },
                    )
                })
                .collect(),
            emtopt::energy::PlanSource::Trained,
        );
        let planned = em.plan_uj(&desc, &plan);
        let target = reference_uj * scale;
        assert!(
            (planned - target).abs() / target < 1e-3,
            "tier {tier}: plan energy {planned} must hit the tier budget {target}"
        );
        per_tier_rho.push(rho);
    }
    // a larger budget at the same read mode buys elementwise-larger rho
    // (low reads decomposed — cheaper cells — so it is not comparable)
    for l in 0..2 {
        assert!(per_tier_rho[2][l] > per_tier_rho[1][l]);
    }

    // batch-parity under the trained plan across worker counts
    let n = 5usize;
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut r = Rng::stream(777, i as u64);
            (0..8).map(|_| r.next_f32()).collect()
        })
        .collect();
    let rows_json: Vec<String> = rows.iter().map(|r| image_json(r)).collect();
    let body = format!("{{\"images\":[{}],\"tier\":\"normal\"}}", rows_json.join(","));
    let batch_logits = |handle: &ServerHandle| -> Vec<Vec<f32>> {
        let mut conn = connect(handle);
        let (status, v) = post(&mut conn, "/v1/infer", &body);
        assert_eq!(status, 200);
        v.get("logits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|row| row.as_f32s().unwrap())
            .collect()
    };
    let la = batch_logits(&a);
    let lb = batch_logits(&b);
    assert_eq!(la, lb, "trained-plan batch logits must not depend on worker count");
    // and singles reproduce the batch rows bit-exactly
    let mut conn_b = connect(&b);
    for (i, rj) in rows_json.iter().enumerate() {
        let (status, v) = post(
            &mut conn_b,
            "/v1/infer",
            &format!("{{\"image\":{rj},\"tier\":\"normal\"}}"),
        );
        assert_eq!(status, 200);
        assert_eq!(v.get("logits").unwrap().as_f32s().unwrap(), la[i]);
    }

    a.shutdown().unwrap();
    b.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trained_store_layer_mismatch_is_rejected_at_boot() {
    // a 2-layer trained vector cannot serve a 1-layer model: serve_http
    // must fail fast with a typed error, not silently fall back
    let dir = std::env::temp_dir().join("emtopt_http_trained_mismatch");
    std::fs::create_dir_all(&dir).unwrap();
    let path = trained_fixture(&dir);
    let trained_rho = emtopt::server::load_trained_rho(&path).unwrap();
    let dev = DeviceConfig::default();
    let m = model(&[(8, 3)], 3, &dev);
    let err = serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            trained_rho: Some(trained_rho),
            ..Default::default()
        },
    )
    .err()
    .expect("layer-count mismatch must refuse to boot");
    assert!(err.to_string().contains("layers"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn global_conn_cap_rejects_with_503_and_tracks_gauges() {
    // a tight global cap; the per-peer cap stays loose so the 503 path
    // (not the 429 one) is what fires
    let dev = DeviceConfig::default();
    let m = model(&[(8, 3)], 3, &dev);
    let handle = serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            max_conns: 2,
            max_conns_per_peer: 64,
            ..Default::default()
        },
    )
    .unwrap();

    // fill the cap and make sure both connections are past the acceptor
    let mut c1 = connect(&handle);
    let mut c2 = connect(&handle);
    let (status, _) = get(&mut c1, "/healthz");
    assert_eq!(status, 200);
    let (status, _) = get(&mut c2, "/healthz");
    assert_eq!(status, 200);

    // one over the cap: typed 503 + Retry-After, no request ever sent
    let mut c3 = connect(&handle);
    let (status, headers, body) = c3.read_response_parts(1 << 20).unwrap();
    assert_eq!(status, 503);
    assert!(header_value(&headers, "retry-after").is_some());
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(v
        .get("error")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("capacity (2)"));

    // gauges: the two held connections, and a peak that saw the
    // momentary third before its rejection flushed
    let (status, metrics) = get(&mut c1, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(metrics).unwrap();
    let gauge = |name: &str| -> f64 {
        text.lines()
            .find(|l| l.strip_prefix(name).map_or(false, |r| r.starts_with(' ')))
            .and_then(|l| l.rsplit_once(' '))
            .map(|(_, v)| v.parse().unwrap())
            .unwrap()
    };
    assert!(gauge("emtopt_http_open_conns") >= 2.0);
    assert!(gauge("emtopt_http_open_conns_peak") >= 3.0);
    assert!(text
        .lines()
        .any(|l| l.starts_with("emtopt_http_requests_total{code=\"503\"}")));

    // closing a held connection frees global capacity
    drop(c2);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let served = loop {
        let mut c = connect(&handle);
        let wrote = c.write_request("GET", "/healthz", b"").is_ok();
        match c.read_response(1 << 20) {
            Ok((200, _)) if wrote => break true,
            _ if std::time::Instant::now() > deadline => break false,
            _ => std::thread::sleep(Duration::from_millis(50)),
        }
    };
    assert!(served, "capacity must free up after a connection closes");

    drop(c1);
    handle.shutdown().unwrap();
}

#[test]
fn slowloris_partial_heads_swept_with_400_blocking_no_workers() {
    use std::io::{Read as _, Write as _};

    // short slowloris deadline, single compute worker
    let dev = DeviceConfig::default();
    let m = model(&[(8, 3)], 3, &dev);
    let handle = serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            request_timeout: Duration::from_millis(300),
            engine: NativeServerConfig {
                batch: 1,
                workers: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();

    // eight sockets trickle a partial request head, then stall forever
    let mut slow: Vec<TcpStream> = (0..8)
        .map(|_| {
            let mut s = TcpStream::connect(handle.addr()).unwrap();
            s.write_all(b"POST /v1/infer HTTP/1.1\r\nhost: slow\r\n")
                .unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            s
        })
        .collect();

    // the single worker is untouched: a well-formed request on a fresh
    // connection serves immediately while all eight heads are stalled
    let img = "[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]";
    let mut conn = connect(&handle);
    let (status, _) = post(&mut conn, "/v1/infer", &format!("{{\"image\":{img}}}"));
    assert_eq!(status, 200, "stalled request heads must not occupy a worker");

    // past request_timeout the sweep answers each straggler with 400
    // and closes the connection
    for s in &mut slow {
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(
            text.starts_with("HTTP/1.1 400"),
            "expected the slowloris sweep's 400, got: {text}"
        );
    }

    drop(conn);
    handle.shutdown().unwrap();
}

#[test]
fn stopped_reader_is_swept_without_blocking_workers() {
    use std::io::{Read as _, Write as _};

    // short stalled-connection deadline, single compute worker
    let dev = DeviceConfig::default();
    let m = model(&[(8, 3)], 3, &dev);
    let handle = serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            idle_timeout: Duration::from_millis(400),
            engine: NativeServerConfig {
                batch: 1,
                workers: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();

    // the rude client: sends one request, then never reads the response
    let img = "[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]";
    let body = format!("{{\"image\":{img}}}");
    let mut rude = TcpStream::connect(handle.addr()).unwrap();
    rude.write_all(
        format!(
            "POST /v1/classify HTTP/1.1\r\nhost: rude\r\n\
             content-type: application/json\r\ncontent-length: {}\r\n\
             connection: keep-alive\r\n\r\n{}",
            body.len(),
            body
        )
        .as_bytes(),
    )
    .unwrap();

    // the single worker keeps serving everyone else meanwhile
    let mut conn = connect(&handle);
    for _ in 0..3 {
        let (status, _) = post(&mut conn, "/v1/classify", &body);
        assert_eq!(status, 200);
    }

    // after idle_timeout the sweep drops the stalled connection: the
    // rude client finds its (kernel-buffered) response followed by EOF
    rude.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = Vec::new();
    rude.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8_lossy(&buf);
    assert!(text.starts_with("HTTP/1.1 200"), "{text}");

    drop(conn);
    handle.shutdown().unwrap();
}

#[test]
fn open_conns_gauge_tracks_closes() {
    let handle = boot(NativeServerConfig::default());
    let mut c1 = connect(&handle);
    let mut c2 = connect(&handle);
    let mut c3 = connect(&handle);
    for c in [&mut c1, &mut c2, &mut c3] {
        let (status, _) = get(c, "/healthz");
        assert_eq!(status, 200);
    }

    let gauge = |text: &str, name: &str| -> Option<f64> {
        text.lines()
            .find(|l| l.strip_prefix(name).map_or(false, |r| r.starts_with(' ')))
            .and_then(|l| l.rsplit_once(' '))
            .and_then(|(_, v)| v.parse().ok())
    };
    let (status, metrics) = get(&mut c1, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(metrics).unwrap();
    assert!(gauge(&text, "emtopt_http_open_conns").unwrap() >= 3.0);
    assert!(gauge(&text, "emtopt_http_open_conns_peak").unwrap() >= 3.0);

    // closing two connections shows on the live gauge; the peak holds
    drop(c2);
    drop(c3);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let (status, metrics) = get(&mut c1, "/metrics");
        assert_eq!(status, 200);
        let text = String::from_utf8(metrics).unwrap();
        let open = gauge(&text, "emtopt_http_open_conns").unwrap();
        if open <= 1.0 {
            assert!(gauge(&text, "emtopt_http_open_conns_peak").unwrap() >= 3.0);
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "open-conns gauge must drop after closes: {open}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    drop(c1);
    handle.shutdown().unwrap();
}

/// Exact value of the metric line starting with `name ` (pass labels in
/// `name` for labelled families: `foo{tier="normal"}`).
fn metric(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(name).and_then(|r| r.strip_prefix(' ')))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or_else(|| panic!("metric {name} missing from /metrics"))
}

fn post_raw(conn: &mut HttpConn<TcpStream>, path: &str, body: &str) -> (u16, Vec<u8>) {
    conn.write_request("POST", path, body.as_bytes()).unwrap();
    conn.read_response(1 << 20).unwrap()
}

fn boot_cached(entries: usize, bytes: usize) -> ServerHandle {
    let dev = DeviceConfig::default();
    let m = model(&[(8, 3)], 3, &dev);
    serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            cache_entries: entries,
            cache_bytes: bytes,
            engine: NativeServerConfig {
                batch: 4,
                workers: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn result_cache_hit_is_byte_identical_and_skips_compute() {
    // PR 9 acceptance: an armed exact result cache serves repeat content
    // byte-identically, without scheduler admission, device reads or
    // energy — and the stage histograms record a write sample but NO
    // queue_wait/batch_wait/compute samples for the hit (the zero-stage
    // invariant, the counterpart of the stage-sum <= total invariant
    // pinned in trace_echo_reconciles_with_flight_recorder_and_metrics).
    let handle = boot_cached(64, 1 << 20);
    let mut conn = connect(&handle);

    let img = "[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]";
    let body = format!("{{\"image\":{img}}}");
    let (status, cold) = post_raw(&mut conn, "/v1/infer", &body);
    assert_eq!(status, 200);

    let (_, m1) = get(&mut conn, "/metrics");
    let m1 = String::from_utf8(m1).unwrap();
    assert_eq!(metric(&m1, "emtopt_cache_misses_total"), 1.0);
    assert_eq!(metric(&m1, "emtopt_cache_hits_total"), 0.0);
    assert_eq!(metric(&m1, "emtopt_cache_entries"), 1.0);
    assert!(metric(&m1, "emtopt_cache_bytes") > 0.0);

    // the repeat: byte-identical to the cold miss
    let (status, hit) = post_raw(&mut conn, "/v1/infer", &body);
    assert_eq!(status, 200);
    assert_eq!(hit, cold, "cache hit must be byte-identical to the miss");

    let (_, m2) = get(&mut conn, "/metrics");
    let m2 = String::from_utf8(m2).unwrap();
    assert_eq!(metric(&m2, "emtopt_cache_hits_total"), 1.0);
    assert_eq!(metric(&m2, "emtopt_cache_misses_total"), 1.0);
    assert!(
        metric(&m2, "emtopt_cache_saved_uj_total") > 0.0,
        "a hit must credit the energy its entry recorded"
    );
    // zero device-side delta across the hit: no reads, no energy, no
    // engine admission
    for family in [
        "emtopt_read_cycles_total{tier=\"normal\"}",
        "emtopt_energy_cell_pj_total{tier=\"normal\"}",
        "emtopt_energy_peripheral_pj_total{tier=\"normal\"}",
        "emtopt_requests_total{tier=\"normal\"}",
    ] {
        assert_eq!(
            metric(&m2, family),
            metric(&m1, family),
            "cache hit changed {family}"
        );
    }
    // zero-stage invariant: the hit added one write sample and nothing
    // to the compute-side stages
    for stage in ["queue_wait", "batch_wait", "compute"] {
        let name =
            format!("emtopt_stage_latency_us_count{{tier=\"normal\",stage=\"{stage}\"}}");
        assert_eq!(metric(&m2, &name), 1.0, "hit recorded a {stage} sample");
    }
    assert_eq!(
        metric(
            &m2,
            "emtopt_stage_latency_us_count{tier=\"normal\",stage=\"write\"}"
        ),
        2.0,
        "hit must still record its write stage"
    );

    // different pixels on the same tier: a genuine miss, computed
    let (status, _) = post_raw(
        &mut conn,
        "/v1/infer",
        "{\"image\":[0.9,0.8,0.7,0.6,0.5,0.4,0.3,0.2]}",
    );
    assert_eq!(status, 200);
    // same pixels on a different tier: a different plan, so a different
    // key — also a miss
    let (status, _) = post_raw(
        &mut conn,
        "/v1/infer",
        &format!("{{\"image\":{img},\"tier\":\"low\"}}"),
    );
    assert_eq!(status, 200);
    let (_, m3) = get(&mut conn, "/metrics");
    let m3 = String::from_utf8(m3).unwrap();
    assert_eq!(metric(&m3, "emtopt_cache_misses_total"), 3.0);
    assert_eq!(metric(&m3, "emtopt_cache_entries"), 3.0);

    // a traced hit carries the bypass marker with zero compute stages
    let traced_body = format!("{{\"image\":{img},\"trace\":true}}");
    let (status, first) = post(&mut conn, "/v1/infer", &traced_body);
    assert_eq!(status, 200);
    assert_eq!(
        *first.get("trace").unwrap().get("cache_hit").unwrap(),
        Json::Bool(true),
        "the traced repeat of cached pixels must be served from cache"
    );
    let t = first.get("trace").unwrap();
    for stage in ["queue_wait_us", "batch_wait_us", "compute_us"] {
        assert_eq!(t.get(stage).unwrap().as_u64().unwrap(), 0, "{stage} on a hit");
    }
    assert_eq!(t.get("energy_uj").unwrap().as_f64().unwrap(), 0.0);

    drop(conn);
    handle.shutdown().unwrap();
}

#[test]
fn cache_off_default_is_byte_compatible_and_renders_zero_families() {
    // Default config keeps the cache off: repeats recompute, the
    // emtopt_cache_* families render as zeros, and the response bytes
    // match an armed server's bit-for-bit (the cache is pure memoization
    // of a deterministic function — arming it must not change a byte).
    let plain = boot(NativeServerConfig {
        batch: 4,
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let cached = boot_cached(64, 1 << 20);
    let mut pc = connect(&plain);
    let mut cc = connect(&cached);

    let body = "{\"image\":[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8],\"tier\":\"high\"}";
    let (ps, pb) = post_raw(&mut pc, "/v1/classify", body);
    let (cs, cb) = post_raw(&mut cc, "/v1/classify", body);
    assert_eq!((ps, cs), (200, 200));
    assert_eq!(pb, cb, "arming the cache changed a cold response");
    // the armed server's hit serves the same bytes again
    let (_, cb2) = post_raw(&mut cc, "/v1/classify", body);
    assert_eq!(cb, cb2);

    // the plain server recomputed both times and its cache stayed inert
    let (_, pb2) = post_raw(&mut pc, "/v1/classify", body);
    assert_eq!(pb, pb2, "deterministic recompute must match itself");
    let (_, mtext) = get(&mut pc, "/metrics");
    let mtext = String::from_utf8(mtext).unwrap();
    for family in [
        "emtopt_cache_hits_total",
        "emtopt_cache_misses_total",
        "emtopt_cache_evictions_total",
        "emtopt_cache_entries",
        "emtopt_cache_bytes",
        "emtopt_cache_saved_uj_total",
    ] {
        assert_eq!(metric(&mtext, family), 0.0, "{family} on a cache-off server");
    }
    assert_eq!(metric(&mtext, "emtopt_requests_total{tier=\"high\"}"), 2.0);

    drop(pc);
    drop(cc);
    plain.shutdown().unwrap();
    cached.shutdown().unwrap();
}

#[test]
fn expect_continue_gets_interim_before_body() {
    use std::io::{Read as _, Write as _};

    let handle = boot(NativeServerConfig {
        batch: 2,
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });

    // the polite client: head with `Expect: 100-continue`, then WAIT for
    // the interim response before shipping a single body byte
    let body = "{\"image\":[0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8]}";
    let mut s = TcpStream::connect(handle.addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(
        format!(
            "POST /v1/infer HTTP/1.1\r\nhost: t\r\ncontent-type: application/json\r\n\
             content-length: {}\r\nexpect: 100-continue\r\nconnection: close\r\n\r\n",
            body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    let interim = b"HTTP/1.1 100 Continue\r\n\r\n";
    let mut got = vec![0u8; interim.len()];
    s.read_exact(&mut got).unwrap();
    assert_eq!(got, interim, "server must invite the body before it arrives");
    // now ship the body; connection: close frames the final response
    s.write_all(body.as_bytes()).unwrap();
    let mut rest = Vec::new();
    s.read_to_end(&mut rest).unwrap();
    let text = String::from_utf8_lossy(&rest);
    assert!(text.starts_with("HTTP/1.1 200"), "{text}");
    assert!(text.contains("logits"), "{text}");

    handle.shutdown().unwrap();
}

#[test]
fn expect_continue_over_cap_is_413_before_the_body() {
    use std::io::{Read as _, Write as _};

    // a tiny body cap: the declared length is rejected at head time
    let dev = DeviceConfig::default();
    let m = model(&[(8, 3)], 3, &dev);
    let handle = serve_http(
        m,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            max_body_bytes: 256,
            ..Default::default()
        },
    )
    .unwrap();

    let mut s = TcpStream::connect(handle.addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(
        b"POST /v1/infer HTTP/1.1\r\nhost: t\r\ncontent-type: application/json\r\n\
          content-length: 1000000\r\nexpect: 100-continue\r\n\r\n",
    )
    .unwrap();
    // the server answers the typed 413 and closes — no interim, and the
    // megabyte body never has to move
    let mut resp = Vec::new();
    s.read_to_end(&mut resp).unwrap();
    let text = String::from_utf8_lossy(&resp);
    assert!(text.starts_with("HTTP/1.1 413"), "{text}");
    assert!(
        !text.contains("100 Continue"),
        "an over-cap head must never be invited to continue: {text}"
    );

    handle.shutdown().unwrap();
}
