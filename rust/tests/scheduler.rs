//! Unified-scheduler integration tests (ISSUE 5 acceptance): work
//! stealing across tier lanes must preserve batch/sequential bit-parity
//! at 1, 2 and N workers, and the rebalancer must shift effective
//! capacity onto a saturated tier within one (manually stepped,
//! deterministic) rebalance interval.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use emtopt::coordinator::router::{serve_native, NativeServerConfig};
use emtopt::device::DeviceConfig;
use emtopt::inference::NoisyModel;
use emtopt::rng::Rng;
use emtopt::server::{tier_plans, EnergyTier, TieredEngine};

/// A small random dense stack programmed on the crossbar substrate.
fn model(dims: &[(usize, usize)], seed: u64, dev: &DeviceConfig) -> Arc<NoisyModel> {
    let mut rng = Rng::new(seed);
    let data: Vec<(Vec<f32>, Vec<f32>)> = dims
        .iter()
        .map(|&(i, o)| {
            let w: Vec<f32> = (0..i * o).map(|_| rng.normal() * 0.3).collect();
            let b = vec![0.0f32; o];
            (w, b)
        })
        .collect();
    let specs: Vec<(&[f32], &[f32], usize, usize)> = data
        .iter()
        .zip(dims.iter())
        .map(|((w, b), &(i, o))| (w.as_slice(), b.as_slice(), i, o))
        .collect();
    Arc::new(NoisyModel::new(&specs, dev).unwrap())
}

#[test]
fn parity_under_active_stealing_at_1_2_and_n_workers() {
    // The same 5 images through the high tier — as one multi-image batch
    // and as sequential singles — while background threads keep the low
    // tier saturated, so high-tier work is routinely served by stolen /
    // rebalanced workers.  All logits must be bit-identical to each
    // other AND across engines with 1, 2 and N shared workers:
    // content-derived noise seeds make results independent of which
    // worker ran what (DESIGN.md §10).
    let dev = DeviceConfig::default();
    let n_threads = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4)
        .max(3);
    let (d_in, d_out) = (8usize, 3usize);
    let n = 5usize;
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut r = Rng::stream(4242, i as u64);
            (0..d_in).map(|_| r.next_f32()).collect()
        })
        .collect();
    let flat: Vec<f32> = rows.concat();

    let mut reference: Option<Vec<f32>> = None;
    for workers in [1usize, 2, n_threads] {
        let m = model(&[(8, 6), (6, 3)], 17, &dev);
        let base = NativeServerConfig {
            batch: 4,
            workers,
            max_wait: Duration::from_millis(1),
            // fast rebalancing: homes churn while the probe runs
            rebalance_interval: Duration::from_millis(5),
            device: dev.clone(),
            ..Default::default()
        };
        let (engine, handles) = TieredEngine::start(m, &base, None).unwrap();
        let engine = Arc::new(engine);

        let stop = Arc::new(AtomicBool::new(false));
        let noise: Vec<_> = (0..2u64)
            .map(|t| {
                let engine = engine.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut i = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let mut r = Rng::stream(9_000 + t, i);
                        let img: Vec<f32> = (0..8).map(|_| r.next_f32()).collect();
                        // shed results are fine — the point is pressure
                        let _ = engine.try_infer(EnergyTier::Low, img);
                        i += 1;
                    }
                })
            })
            .collect();

        let batch_logits = engine.infer_batch(EnergyTier::High, flat.clone()).unwrap();
        assert_eq!(batch_logits.len(), n * d_out);
        for (i, row) in rows.iter().enumerate() {
            let single = engine.infer(EnergyTier::High, row.clone()).unwrap();
            assert_eq!(
                single.as_slice(),
                &batch_logits[i * d_out..(i + 1) * d_out],
                "workers {workers}, image {i}: singles must match the batch row under stealing"
            );
        }
        match &reference {
            None => reference = Some(batch_logits),
            Some(r) => assert_eq!(
                r, &batch_logits,
                "worker count {workers} changed the logits"
            ),
        }

        stop.store(true, Ordering::Relaxed);
        for h in noise {
            h.join().unwrap();
        }
        drop(engine);
        for h in handles {
            h.join().unwrap();
        }
    }
}

#[test]
fn rebalancer_moves_workers_to_a_saturated_tier() {
    // A deliberately slow model keeps the high-tier queue deep while the
    // low/normal tiers sit idle.  The background loop is disabled
    // (rebalance_interval zero); ONE manual rebalance_once() step — the
    // deterministic-clock equivalent of one interval — must move every
    // worker's home onto the saturated tier.
    let dev = DeviceConfig::default();
    let m = model(&[(192, 192), (192, 192)], 7, &dev);
    let base = NativeServerConfig {
        batch: 1,
        workers: 3,
        max_wait: Duration::from_millis(1),
        queue_depth: 256,
        rebalance_interval: Duration::ZERO, // manual stepping only
        device: dev.clone(),
        ..Default::default()
    };
    let (engine, handles) = TieredEngine::start(m, &base, None).unwrap();
    let engine = Arc::new(engine);

    // initial static split: one home per tier
    let snap = engine.snapshot();
    assert_eq!(
        snap.lanes
            .iter()
            .map(|l| l.effective_workers)
            .collect::<Vec<_>>(),
        vec![1, 1, 1]
    );

    // saturate high while low/normal stay idle
    let burst = 24usize;
    let waiters: Vec<_> = (0..burst)
        .map(|i| {
            let engine = engine.clone();
            std::thread::spawn(move || {
                let mut r = Rng::stream(100 + i as u64, 0);
                let img: Vec<f32> = (0..192).map(|_| r.next_f32()).collect();
                engine.infer(EnergyTier::High, img).unwrap()
            })
        })
        .collect();
    // wait until a deep backlog is visible on the high queue (the model
    // is slow enough that it cannot drain between here and the step)
    let deadline = Instant::now() + Duration::from_secs(30);
    while engine.snapshot().lanes[EnergyTier::High.index()].queue_len < 16 {
        assert!(
            Instant::now() < deadline,
            "high queue never built a backlog: {:?}",
            engine.snapshot()
        );
        std::thread::yield_now();
    }

    let moves = engine.rebalance_once();
    assert!(moves >= 2, "one step must re-home the idle lanes' workers, moved {moves}");
    let snap = engine.snapshot();
    assert_eq!(
        snap.lanes[EnergyTier::High.index()].effective_workers,
        3,
        "all effective capacity must sit on the saturated tier: {snap:?}"
    );
    assert_eq!(snap.lanes[EnergyTier::Low.index()].effective_workers, 0);
    assert_eq!(snap.rebalance_moves, moves as u64);

    for w in waiters {
        let logits = w.join().unwrap();
        assert_eq!(logits.len(), 192);
    }
    drop(engine);
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn adaptive_pool_beats_fixed_split_on_a_saturated_tier() {
    // ISSUE 5 acceptance: with a saturated `high` tier and idle
    // `low`/`normal` tiers, the adaptive shared pool (all 3 workers
    // converge on the hot queue) drains the burst measurably faster
    // than the fixed per-tier split it replaced, at equal total
    // workers.  The baseline is emulated exactly: under the old static
    // 3x-lane layout, the high tier owned 1 of 3 workers — i.e. a
    // single-lane engine with 1 worker running the same high-tier plan.
    let cores = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1);
    if cores < 2 {
        return; // one core serialises both configurations identically
    }
    let dev = DeviceConfig::default();
    let burst = 24usize;
    let drain_time = |infer: &(dyn Fn(Vec<f32>) -> emtopt::Result<Vec<f32>> + Sync)| {
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for i in 0..burst {
                let mut r = Rng::stream(3_000 + i as u64, 0);
                let img: Vec<f32> = (0..192).map(|_| r.next_f32()).collect();
                scope.spawn(move || {
                    assert_eq!(infer(img).unwrap().len(), 192);
                });
            }
        });
        t0.elapsed()
    };

    // adaptive: one shared 3-worker pool behind the tiered engine
    let m = model(&[(192, 192), (192, 192)], 7, &dev);
    let base = NativeServerConfig {
        batch: 1,
        workers: 3,
        max_wait: Duration::from_millis(1),
        device: dev.clone(),
        ..Default::default()
    };
    let (engine, handles) = TieredEngine::start(m, &base, None).unwrap();
    let adaptive = drain_time(&|img| engine.infer(EnergyTier::High, img));
    drop(engine);
    for h in handles {
        h.join().unwrap();
    }

    // fixed split: the high tier's old static share (1 worker), same
    // model, same per-layer plan, same lane seed
    let m = model(&[(192, 192), (192, 192)], 7, &dev);
    let high_plan = tier_plans(&m, &dev, None).unwrap()[EnergyTier::High.index()]
        .plan
        .clone();
    let cfg = NativeServerConfig {
        batch: 1,
        workers: 1,
        max_wait: Duration::from_millis(1),
        plan: Some(high_plan),
        seed: base.seed.wrapping_add(EnergyTier::High.index() as u64),
        device: dev,
        ..Default::default()
    };
    let (client, _stats, handles) = serve_native(m, cfg).unwrap();
    let fixed = drain_time(&|img| client.infer(img));
    drop(client);
    for h in handles {
        h.join().unwrap();
    }

    let speedup = fixed.as_secs_f64() / adaptive.as_secs_f64().max(1e-9);
    assert!(
        speedup > 1.2,
        "adaptive scheduler must beat the fixed split at equal total \
         workers: fixed {fixed:?} vs adaptive {adaptive:?} ({speedup:.2}x)"
    );
}

#[test]
fn governor_budget_sheds_low_first_and_keeps_high_serving() {
    // A tiny budget: the first (high-tier) request's energy already blows
    // it, so low and normal shed with the typed error while high keeps
    // serving — the energy-SLO contract end to end on the engine API.
    let dev = DeviceConfig::default();
    let m = model(&[(8, 3)], 3, &dev);
    let base = NativeServerConfig {
        batch: 2,
        workers: 1,
        max_wait: Duration::from_millis(1),
        rebalance_interval: Duration::ZERO,
        // orders of magnitude below one inference's device energy: the
        // first served request exhausts it for the whole 2 s window
        energy_budget_uj_s: Some(1e-8),
        device: dev.clone(),
        ..Default::default()
    };
    let (engine, handles) = TieredEngine::start(m, &base, None).unwrap();
    assert_eq!(engine.energy_budget_uj_s(), Some(1e-8));

    let img = |s: u64| -> Vec<f32> {
        let mut r = Rng::stream(s, 0);
        (0..8).map(|_| r.next_f32()).collect()
    };
    // within budget (no energy observed yet): everything serves
    assert!(engine.try_infer(EnergyTier::Low, img(1)).is_ok());
    // that request's energy pushes the rolling rate far over the budget
    let err = engine.try_infer(EnergyTier::Low, img(2)).unwrap_err();
    assert!(
        err.is::<emtopt::scheduler::EnergyShed>(),
        "expected a typed EnergyShed, got {err:?}"
    );
    assert!(engine.try_infer(EnergyTier::Normal, img(3)).is_err());
    assert!(
        engine.try_infer(EnergyTier::High, img(4)).is_ok(),
        "the top tier must keep serving under an exhausted budget"
    );
    let snap = engine.snapshot();
    assert_eq!(snap.lanes[EnergyTier::Low.index()].governor_shed, 1);
    assert_eq!(snap.lanes[EnergyTier::Normal.index()].governor_shed, 1);
    assert_eq!(snap.lanes[EnergyTier::High.index()].governor_shed, 0);
    let (rate, budget) = snap.energy.expect("governor armed");
    assert!(rate > budget, "rate {rate} must exceed budget {budget}");

    drop(engine);
    for h in handles {
        h.join().unwrap();
    }
}
