//! Property-based tests over the simulation substrate (seeded random
//! sweeps; proptest is unavailable offline — see Cargo.toml note — so we
//! drive the same shrink-free random-case pattern with the crate RNG).

use emtopt::crossbar::{CrossbarArray, ReadCounters};
use emtopt::data::{Dataset, Split};
use emtopt::device::{state_offsets, DeviceConfig};
use emtopt::energy::{EnergyModel, ReadMode};
use emtopt::quant;
use emtopt::rng::Rng;

/// Run `f` over `cases` random seeds (our mini-proptest driver).
fn for_cases(cases: u64, mut f: impl FnMut(u64, &mut Rng)) {
    for case in 0..cases {
        let mut rng = Rng::new(0xB0B + case * 7919);
        f(case, &mut rng);
    }
}

#[test]
fn prop_quant_weight_roundtrip_bounded() {
    for_cases(50, |case, rng| {
        let n = 1 + (rng.next_u64() % 512) as usize;
        let bits = 2 + (case % 7) as u32;
        let w: Vec<f32> = (0..n).map(|_| rng.normal() * (1.0 + case as f32)).collect();
        let (q, s) = quant::quant_weight(&w, bits);
        let deq = quant::dequant_weight(&q, s, bits);
        let step = s / ((1i32 << (bits - 1)) - 1) as f32;
        for (a, b) in w.iter().zip(deq.iter()) {
            assert!((a - b).abs() <= step / 2.0 + 1e-5, "case {case}");
        }
    });
}

#[test]
fn prop_quant_act_monotone() {
    // quantisation must preserve ordering up to one step
    for_cases(30, |case, rng| {
        let n = 2 + (rng.next_u64() % 256) as usize;
        let bits = 2 + (case % 6) as u32;
        let x: Vec<f32> = (0..n).map(|_| rng.next_f32() * 3.0).collect();
        let (q, _) = quant::quant_act(&x, bits);
        for i in 0..n {
            for j in 0..n {
                if x[i] > x[j] {
                    assert!(q[i] + 1 >= q[j], "ordering violated at case {case}");
                }
            }
        }
    });
}

#[test]
fn prop_bit_planes_recompose_any_level() {
    for_cases(20, |_case, rng| {
        let bits = 1 + (rng.next_u64() % 8) as u32;
        let level = (rng.next_u64() % (1 << bits)) as u32;
        let recomposed: u32 = (0..bits).map(|p| quant::bit_plane(level, p) << p).sum();
        assert_eq!(recomposed, level);
        assert!(quant::popcount(level) <= bits);
    });
}

#[test]
fn prop_state_offsets_zero_mean_unit_var() {
    for m in 2..32 {
        let c = state_offsets(m);
        let mean: f64 = c.iter().map(|&v| v as f64).sum::<f64>() / m as f64;
        let var: f64 = c.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / m as f64;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-4);
    }
}

#[test]
fn prop_crossbar_clean_mac_linear() {
    // MAC(a*x) == a * MAC(x) for the noiseless path (up to requantisation:
    // identical levels because the dynamic scale absorbs `a`)
    for_cases(10, |case, rng| {
        let k = 4 + (rng.next_u64() % 64) as usize;
        let n = 1 + (rng.next_u64() % 32) as usize;
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let cfg = DeviceConfig::default();
        let arr = CrossbarArray::program(&w, k, n, &cfg);
        let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let x2: Vec<f32> = x.iter().map(|&v| v * 3.0).collect();
        let mut o1 = vec![0.0f32; n];
        let mut o2 = vec![0.0f32; n];
        arr.mac_clean(&x, &mut o1, 5);
        arr.mac_clean(&x2, &mut o2, 5);
        for (a, b) in o1.iter().zip(o2.iter()) {
            assert!(
                (3.0 * a - b).abs() <= 1e-3 * (b.abs() + 1.0),
                "case {case}: {a} vs {b}"
            );
        }
    });
}

#[test]
fn prop_crossbar_energy_counters_monotone() {
    // more reads never decrease counters; energy scales with rho
    for_cases(10, |case, rng| {
        let k = 8 + (rng.next_u64() % 64) as usize;
        let n = 4 + (rng.next_u64() % 16) as usize;
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.4).collect();
        let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let mut out = vec![0.0f32; n];
        let mut cfg = DeviceConfig::default();
        cfg.rho = 1.0 + (case % 5) as f32;
        let arr = CrossbarArray::program(&w, k, n, &cfg);
        let mut counters = ReadCounters::default();
        let mut last = 0.0;
        for _ in 0..4 {
            arr.mac(&x, &mut out, arr.read_plan(ReadMode::Original), 5, 1.0, rng, &mut counters);
            assert!(counters.cell_pj >= last);
            last = counters.cell_pj;
        }
    });
}

#[test]
fn prop_forward_batch_deterministic_per_seed() {
    // same (model, inputs, seed) -> bit-identical logits and counters;
    // different seeds -> different noise draws
    use emtopt::inference::NoisyModel;
    for_cases(5, |case, rng| {
        let d_in = 4 + (rng.next_u64() % 24) as usize;
        let d_out = 2 + (rng.next_u64() % 8) as usize;
        let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() * 0.4).collect();
        let b: Vec<f32> = (0..d_out).map(|_| rng.normal() * 0.05).collect();
        let cfg = DeviceConfig::default();
        let model =
            NoisyModel::new(&[(w.as_slice(), b.as_slice(), d_in, d_out)], &cfg).unwrap();
        let batch = 1 + (rng.next_u64() % 6) as usize;
        let xs: Vec<f32> = (0..batch * d_in).map(|_| rng.next_f32()).collect();
        let mut c1 = ReadCounters::default();
        let mut c2 = ReadCounters::default();
        let plan = model.uniform_plan(ReadMode::Original);
        let y1 = model.forward_batch(&xs, &plan, &cfg, case, &mut c1);
        let y2 = model.forward_batch(&xs, &plan, &cfg, case, &mut c2);
        assert_eq!(y1, y2, "case {case}: same seed must reproduce");
        assert_eq!(c1, c2);
        let mut c3 = ReadCounters::default();
        let y3 = model.forward_batch(&xs, &plan, &cfg, case + 1000, &mut c3);
        assert_ne!(y1, y3, "case {case}: different seed must resample noise");
    });
}

#[test]
fn prop_energy_model_additive_over_layers() {
    use emtopt::models::{LayerMeta, ModelDesc};
    for_cases(20, |_case, rng| {
        let em = EnergyModel::new(5);
        let l1 = LayerMeta::conv(3, 1 + (rng.next_u64() % 64) as u64, 8, 16);
        let l2 = LayerMeta::dense(1 + (rng.next_u64() % 512) as u64, 10);
        let m12 = ModelDesc {
            name: "m".into(),
            layers: vec![l1.clone(), l2.clone()],
        };
        let e12 = em.model_uj_uniform(&m12, 2.0, ReadMode::Original);
        let e1 = em.model_uj_uniform(
            &ModelDesc {
                name: "a".into(),
                layers: vec![l1],
            },
            2.0,
            ReadMode::Original,
        );
        let e2 = em.model_uj_uniform(
            &ModelDesc {
                name: "b".into(),
                layers: vec![l2],
            },
            2.0,
            ReadMode::Original,
        );
        assert!((e12 - e1 - e2).abs() < 1e-12);
    });
}

#[test]
fn prop_dataset_total_determinism() {
    // any (seed, split, index) triple regenerates the identical sample
    for_cases(10, |case, rng| {
        let ds = Dataset::with_params(2 + (case % 10) as usize, 0.5, rng.next_u64());
        let idx = rng.next_u64() % 1000;
        let mut a = vec![0.0f32; emtopt::data::IMG_LEN];
        let mut b = vec![0.0f32; emtopt::data::IMG_LEN];
        let la = ds.sample_into(Split::Train, idx, &mut a);
        let lb = ds.sample_into(Split::Train, idx, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b);
    });
}

#[test]
fn prop_router_stats_invariants() {
    use emtopt::coordinator::router::ServerStats;
    use std::sync::atomic::Ordering;
    for_cases(20, |_case, rng| {
        let s = ServerStats::default();
        let batches = 1 + rng.next_u64() % 50;
        let batch_size = 1 + (rng.next_u64() % 64) as usize;
        let padded = rng.next_u64() % (batches * batch_size as u64);
        s.batches.store(batches, Ordering::Relaxed);
        s.padded_slots.store(padded, Ordering::Relaxed);
        let fill = s.mean_batch_fill(batch_size);
        assert!((0.0..=1.0).contains(&fill));
    });
}
