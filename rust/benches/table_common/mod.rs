//! Shared helpers for the table/figure benches (included via #[path]).

use emtopt::baselines::{hardware_cost, Method};
use emtopt::coordinator::{self, store, Solution};
use emtopt::data::Suite;
use emtopt::device::Intensity;
use emtopt::energy::EnergyModel;
use emtopt::metrics::{fmt_cells, fmt_delay_us, fmt_energy_uj, fmt_pct, Table};
use emtopt::runtime::{Artifacts, Evaluator};
use emtopt::timing::TimingModel;

/// The evaluation matrix row: method + the solution whose training it uses.
/// Quick mode drops the A+B+C row on conv models: xla_extension 0.5.1
/// needs >10 min to compile their decomposed eval graphs per process
/// (fig9/table1 cover A+B+C end-to-end on the fast-compiling mlp;
/// EMTOPT_BENCH_FULL=1 restores the row here).
pub fn method_rows(include_abc: bool) -> Vec<(Method, Solution)> {
    let mut rows = vec![
        (Method::BinarizedEncoding, Solution::Traditional),
        (Method::WeightScaling, Solution::Traditional),
        (Method::FluctuationCompensation, Solution::Traditional),
        (Method::OursAB, Solution::AB),
    ];
    if include_abc {
        rows.push((Method::OursABC, Solution::ABC));
    }
    rows
}

/// A+B+C rows run when fully requested or on the fast-compiling mlp.
pub fn abc_enabled(model_key: &str) -> bool {
    std::env::var("EMTOPT_BENCH_FULL").is_ok() || model_key.starts_with("mlp")
}

/// Holistic table (paper Tables 1–2): per method, min energy / cells /
/// delay at 0% / 1% / 2% top-1 accuracy drop vs the noiseless baseline.
pub fn holistic_table(
    arts: &Artifacts,
    model_key: &str,
    suite: Suite,
    intensity: Intensity,
) -> emtopt::Result<Table> {
    let em = EnergyModel::new(arts.manifest.device.act_bits);
    let tm = TimingModel::new(arts.manifest.device.act_bits);
    let paper = coordinator::experiments::paper_model_for(model_key).unwrap();
    let mut cfg = coordinator::experiments::schedule_for(model_key);
    cfg.intensity = intensity;
    let setup = coordinator::EvalSetup {
        suite,
        intensity,
        batches: 1,
        ..Default::default()
    };
    let grid = coordinator::experiments::default_rho_grid();

    // compile each eval executable once per model (slow 0.5.1 compiles)
    let eval_plain = Evaluator::new(arts, model_key, false)?;
    let abc = abc_enabled(model_key);
    let eval_dec = if abc { Some(Evaluator::new(arts, model_key, true)?) } else { None };
    // noiseless baseline accuracy from the AB-trained model (the paper's
    // dashed "GPU baseline")
    let ab = store::train_cached(arts, model_key, suite, Solution::AB, &cfg)?;
    let baseline =
        coordinator::experiments::eval_baseline(&eval_plain, &ab, &setup)?.top1_acc();

    let mut table = Table::new(
        format!(
            "{} [{model_key}] baseline top-1 {} @ {} fluctuation",
            paper.name,
            fmt_pct(baseline),
            intensity.name()
        ),
        &[
            "method",
            "E@0% (uJ)",
            "E@1% (uJ)",
            "E@2% (uJ)",
            "#cells",
            "delay (us)",
        ],
    );

    for (method, sol) in method_rows(abc) {
        let mut mcfg = cfg;
        if sol == Solution::Traditional {
            // trad training never sees noise: share one cache entry
            mcfg.intensity = Intensity::Normal;
        }
        let trained = store::train_cached(arts, model_key, suite, sol, &mcfg)?;
        let evaluator = if sol.decomposed() { eval_dec.as_ref().unwrap() } else { &eval_plain };
        let pts = coordinator::sweep_accuracy_vs_energy(
            evaluator, &trained, &setup, &paper, method, &em, &grid,
        )?;
        let mut cells = String::from("-");
        let mut delay = String::from("-");
        let mut energies = Vec::new();
        for drop in [0.0, 0.01, 0.02] {
            match coordinator::experiments::find_energy_at_drop(&pts, baseline, drop) {
                Some(p) => {
                    energies.push(fmt_energy_uj(p.energy_uj));
                    let cost = hardware_cost(
                        method,
                        &paper,
                        p.mean_rho,
                        intensity.factor() as f64,
                        &em,
                        &tm,
                    );
                    cells = fmt_cells(cost.cells);
                    delay = fmt_delay_us(cost.delay_us);
                }
                None => {
                    // paper marks unreachable 0%-drop cells in red; we
                    // report best achievable accuracy instead
                    let best = coordinator::experiments::best_accuracy_point(&pts);
                    energies.push(match best {
                        Some(b) => format!(
                            "{} ({:+.1}%)",
                            fmt_energy_uj(b.energy_uj),
                            (b.top1 - baseline) * 100.0
                        ),
                        None => "-".into(),
                    });
                }
            }
        }
        table.row(vec![
            method.name().into(),
            energies[0].clone(),
            energies[1].clone(),
            energies[2].clone(),
            cells,
            delay,
        ]);
    }
    Ok(table)
}
