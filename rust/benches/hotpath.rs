//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf).
//!
//! L3 native crossbar simulator: MAC-simulations/s in both read modes,
//! tile current-sum throughput, dataset generation, and the PJRT
//! dispatch overhead of one predict batch.

use emtopt::crossbar::CrossbarArray;
use emtopt::data::{Dataset, Split, Suite};
use emtopt::device::DeviceConfig;
use emtopt::energy::ReadMode;
use emtopt::rng::Rng;
use emtopt::util::bench::report;

fn main() -> emtopt::Result<()> {
    println!("=== hotpath: native crossbar simulator ===");
    let cfg = DeviceConfig::default();
    let (k, n) = (256usize, 256usize);
    let mut rng = Rng::new(1);
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.3).collect();
    let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
    let mut out = vec![0.0f32; n];

    let mut arr = CrossbarArray::program(&w, k, n, &cfg);
    let macs = (k * n) as f64;

    let r = report("crossbar 256x256 original read", 3, 50, || {
        arr.mac(&x, &mut out, ReadMode::Original, 5, 1.0, &mut rng);
    });
    println!(
        "  -> {:.1} M MAC-sim/s",
        r.throughput(macs) / 1e6
    );

    let r = report("crossbar 256x256 decomposed read (5 planes)", 3, 20, || {
        arr.mac(&x, &mut out, ReadMode::Decomposed, 5, 1.0, &mut rng);
    });
    println!("  -> {:.1} M MAC-sim/s", r.throughput(5.0 * macs) / 1e6);

    let r = report("crossbar 256x256 clean reference read", 3, 100, || {
        arr.mac_clean(&x, &mut out, 5);
    });
    println!("  -> {:.1} M MAC/s", r.throughput(macs) / 1e6);

    println!("\n=== hotpath: dataset generation ===");
    let ds = Dataset::new(Suite::Cifar, 1);
    let mut idx = 0u64;
    let r = report("dataset batch of 64 (NHWC 32x32x3)", 2, 30, || {
        let (_x, _y) = ds.batch(Split::Train, idx, 64);
        idx += 64;
    });
    println!(
        "  -> {:.2} M px/s",
        r.throughput(64.0 * 3072.0) / 1e6
    );

    println!("\n=== hotpath: PJRT predict dispatch ===");
    match emtopt::runtime::Artifacts::open_default() {
        Ok(arts) => {
            let predictor = emtopt::runtime::Predictor::new(&arts, "mlp_10")?;
            let init = arts.manifest.artifact("mlp_10_init")?;
            let init_exe = arts.runtime.load_hlo(&arts.dir.join(&init.file))?;
            let mut outs =
                emtopt::runtime::execute(&init_exe, &[emtopt::runtime::scalar_i32(0)])?;
            let rho = emtopt::runtime::to_vec_f32(&outs.pop().unwrap())?;
            let params = outs;
            let (x, _) = ds.batch(Split::Test, 0, predictor.batch);
            let mut seed = 0i32;
            let r = report("predict batch=16 (mlp_10, noisy)", 3, 30, || {
                seed += 1;
                predictor.predict(&params, &rho, &x, seed, 1.0).unwrap();
            });
            println!(
                "  -> {:.0} img/s through the full noisy model",
                r.throughput(predictor.batch as f64)
            );
        }
        Err(e) => println!("(skipping PJRT bench: {e})"),
    }
    Ok(())
}
