//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf).
//!
//! L3 native crossbar simulator: MAC-simulations/s in both read modes,
//! the fused tile read kernel vs its checked-in scalar reference and the
//! pre-PR-6 per-cell kernel (the `kernel_vs_scalar_ratio` field is the
//! CI perf-regression gate input — see `hotpath_gate.json`),
//! tile current-sum throughput, the batched execution engine
//! (`NoisyModel::forward_batch` vs the sequential single-sample loop),
//! the layer-major vs sample-major batch engines on an L2-overflowing
//! MLP (the `layer_major_speedup` field is the second CI gate input),
//! dataset generation, and — with `--features aot` — the PJRT dispatch
//! overhead of one predict batch.
//!
//! Emits a machine-readable `BENCH_hotpath.json` throughput record in the
//! working directory so successive PRs accumulate a perf trajectory.

use emtopt::crossbar::{CrossbarArray, MacScratch, ReadCounters, Tile};
use emtopt::data::{Dataset, Split, Suite};
use emtopt::device::{state_offsets, DeviceConfig};
use emtopt::energy::ReadMode;
use emtopt::inference::NoisyModel;
use emtopt::rng::Rng;
use emtopt::util::bench::report;

fn main() -> emtopt::Result<()> {
    println!("=== hotpath: native crossbar simulator ===");
    let cfg = DeviceConfig::default();
    let (k, n) = (256usize, 256usize);
    let mut rng = Rng::new(1);
    // bulk Box–Muller: both halves of every pair are used (PR 6)
    let mut w = vec![0.0f32; k * n];
    rng.fill_normal(&mut w);
    for v in &mut w {
        *v *= 0.3;
    }
    let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
    let mut out = vec![0.0f32; n];

    let arr = CrossbarArray::program(&w, k, n, &cfg);
    let mut counters = ReadCounters::default();
    let mut scratch = MacScratch::default();
    let macs = (k * n) as f64;

    let r = report("crossbar 256x256 original read", 3, 50, || {
        arr.mac_scratch(
            &x,
            &mut out,
            arr.read_plan(ReadMode::Original),
            5,
            1.0,
            &mut rng,
            &mut counters,
            &mut scratch,
        );
    });
    let mac_original = r.throughput(macs);
    println!("  -> {:.1} M MAC-sim/s", mac_original / 1e6);

    let r = report("crossbar 256x256 decomposed read (5 planes)", 3, 20, || {
        arr.mac_scratch(
            &x,
            &mut out,
            arr.read_plan(ReadMode::Decomposed),
            5,
            1.0,
            &mut rng,
            &mut counters,
            &mut scratch,
        );
    });
    let mac_decomposed = r.throughput(5.0 * macs);
    println!("  -> {:.1} M MAC-sim/s", mac_decomposed / 1e6);

    let r = report("crossbar 256x256 clean reference read", 3, 100, || {
        arr.mac_clean(&x, &mut out, 5);
    });
    let mac_clean = r.throughput(macs);
    println!("  -> {:.1} M MAC/s", mac_clean / 1e6);

    println!("\n=== hotpath: tile read kernel (fused vs scalar reference) ===");
    // One full noisy tile read: every row active, default 4-state device,
    // representative sigma.  The fused/scalar-ref ratio is measured in
    // the SAME process on the SAME tile, so machine speed cancels out of
    // it — that ratio is what the CI perf gate pins (hotpath_gate.json).
    let m = cfg.num_states;
    let sigma = 0.2f32;
    let tile = Tile::new(w.clone(), k, n, m);
    let levels: Vec<u32> = (0..k as u32).map(|r| 1 + (r % 15)).collect();

    let r = report("tile 256x256 fused kernel", 3, 60, || {
        out.fill(0.0);
        let e = tile.current_sum_scaled(&levels, &mut out, 1.0, sigma, &mut rng);
        std::hint::black_box(e);
    });
    let kernel_fused = r.throughput(macs);
    println!("  -> {:.1} M MAC-sim/s", kernel_fused / 1e6);

    let r = report("tile 256x256 scalar reference", 3, 30, || {
        out.fill(0.0);
        let e = tile.current_sum_scaled_ref(&levels, &mut out, 1.0, sigma, &mut rng);
        std::hint::black_box(e);
    });
    let kernel_scalar_ref = r.throughput(macs);
    println!("  -> {:.1} M MAC-sim/s", kernel_scalar_ref / 1e6);
    let kernel_ratio = kernel_fused / kernel_scalar_ref;
    println!("  fused / scalar-ref ratio: {kernel_ratio:.2}x (CI gate input)");

    // The pre-PR-6 kernel — one Lemire `below(m)` rejection sample and
    // one energy accumulate per CELL — reimplemented here so the record
    // keeps carrying the speedup evidence after the library dropped it.
    let offsets = state_offsets(m);
    let tile_w = tile.w_norm();
    let r = report("tile 256x256 legacy per-cell kernel", 3, 15, || {
        out.fill(0.0);
        let mut energy = 0.0f64;
        for row in 0..k {
            let lv = levels[row] as f32;
            let wrow = &tile_w[row * n..(row + 1) * n];
            let mut row_abs = 0.0f32;
            for (c, &wv) in wrow.iter().enumerate() {
                let state = rng.below(m as u32) as usize;
                out[c] += lv * (wv + sigma * offsets[state]);
                row_abs += wv.abs();
            }
            energy += (row_abs * lv) as f64;
        }
        std::hint::black_box(energy);
    });
    let kernel_legacy = r.throughput(macs);
    let kernel_speedup = kernel_fused / kernel_legacy;
    println!(
        "  -> {:.1} M MAC-sim/s legacy — fused is {kernel_speedup:.2}x faster",
        kernel_legacy / 1e6
    );

    println!("\n=== hotpath: programmed-weight plane cache ===");
    // Decomposed bit-plane reads off the plane cache (multiply-free,
    // pre-scaled planes) vs the same reads through the scaled multiply
    // kernel.  Binary row levels — one activation bit-plane, the shape
    // every decomposed-mode read has.  Both kernels run in the same
    // process on the same tile, so the ratio is machine-independent.
    let plane_bits = 4u32;
    let cached = Tile::with_plane_cache(w.clone(), k, n, m, plane_bits);
    let bits: Vec<u32> = (0..k as u32).map(|r| r & 1).collect();
    let mut plane = 0u32;
    let r = report("tile 256x256 plane-cache read", 3, 60, || {
        out.fill(0.0);
        let e = cached.current_sum_plane(&bits, &mut out, plane % plane_bits, sigma, &mut rng);
        plane += 1;
        std::hint::black_box(e);
    });
    let plane_cached = r.throughput(macs);
    println!("  -> {:.1} M MAC-sim/s", plane_cached / 1e6);

    let mut plane = 0u32;
    let r = report("tile 256x256 scaled multiply read", 3, 60, || {
        out.fill(0.0);
        let scale = (1u64 << (plane % plane_bits)) as f32;
        let e = cached.current_sum_scaled(&bits, &mut out, scale, sigma, &mut rng);
        plane += 1;
        std::hint::black_box(e);
    });
    let plane_scaled = r.throughput(macs);
    let weight_plane_speedup = plane_cached / plane_scaled;
    println!(
        "  -> {:.1} M MAC-sim/s multiply — plane cache is {weight_plane_speedup:.2}x",
        plane_scaled / 1e6
    );

    // parity spot-check: a cached-plane read must be bit-identical to
    // the multiply kernel on the same RNG stream, energy included
    for p in 0..plane_bits {
        let mut ra = Rng::new(99);
        let mut rb = Rng::new(99);
        let mut oa = vec![0.0f32; n];
        let mut ob = vec![0.0f32; n];
        let ea = cached.current_sum_plane(&bits, &mut oa, p, sigma, &mut ra);
        let eb = cached.current_sum_scaled(&bits, &mut ob, (1u64 << p) as f32, sigma, &mut rb);
        assert_eq!(oa, ob, "plane-cache parity violated at plane {p}");
        assert_eq!(ea, eb, "plane-cache energy parity violated at plane {p}");
    }
    println!("  parity: cached planes bit-identical to the multiply kernel");

    println!("\n=== hotpath: batched execution engine ===");
    // MLP sized like the tiny-zoo mlp head: 256 -> 256 -> 128 -> 10
    let dims = [(256usize, 256usize), (256, 128), (128, 10)];
    let layer_data: Vec<(Vec<f32>, Vec<f32>)> = dims
        .iter()
        .map(|&(i, o)| {
            let mut lw = vec![0.0f32; i * o];
            rng.fill_normal(&mut lw);
            for v in &mut lw {
                *v *= 0.2;
            }
            let mut lb = vec![0.0f32; o];
            rng.fill_normal(&mut lb);
            for v in &mut lb {
                *v *= 0.02;
            }
            (lw, lb)
        })
        .collect();
    let specs: Vec<(&[f32], &[f32], usize, usize)> = layer_data
        .iter()
        .zip(dims.iter())
        .map(|((lw, lb), &(i, o))| (lw.as_slice(), lb.as_slice(), i, o))
        .collect();
    let model = NoisyModel::new(&specs, &cfg)?;
    // the serving plan the engine sections run under (uniform analytic;
    // its source is recorded in BENCH_hotpath.json so perf points are
    // attributable to the plan that produced them)
    let plan = model.uniform_plan(ReadMode::Original);
    let plan_source = plan.source.name();
    let batch = 32usize;
    let xs: Vec<f32> = (0..batch * model.d_in()).map(|_| rng.next_f32()).collect();
    let threads = rayon::current_num_threads();

    let mut c_seq = ReadCounters::default();
    let r = report("forward_batch_seq  mlp(256-256-128-10) b=32", 2, 10, || {
        let _ = model.forward_batch_seq(&xs, &plan, &cfg, 7, &mut c_seq);
    });
    let seq_sps = r.throughput(batch as f64);
    println!("  -> {seq_sps:.0} samples/s (single-sample loop)");

    let mut c_par = ReadCounters::default();
    let r = report("forward_batch      mlp(256-256-128-10) b=32", 2, 10, || {
        let _ = model.forward_batch(&xs, &plan, &cfg, 7, &mut c_par);
    });
    let par_sps = r.throughput(batch as f64);
    let speedup = par_sps / seq_sps;
    println!("  -> {par_sps:.0} samples/s on {threads} rayon threads ({speedup:.2}x)");

    // parity spot-check: the parallel engine must be bit-identical
    let mut ca = ReadCounters::default();
    let mut cb = ReadCounters::default();
    let ya = model.forward_batch_seq(&xs, &plan, &cfg, 7, &mut ca);
    let yb = model.forward_batch(&xs, &plan, &cfg, 7, &mut cb);
    assert_eq!(ya, yb, "batched engine parity violated");
    assert_eq!(ca, cb, "batched engine counter parity violated");
    println!("  parity: logits + counters bit-identical across engines");

    println!("\n=== hotpath: layer-major batch engine ===");
    // Wide MLP whose weight planes overflow a typical L2 (1024-1024-512-10
    // is ~6.3 MB of f32 weights): the regime where visiting each layer's
    // tiles once per batch (layer-major, the serving default) beats
    // re-streaming the whole model per image (sample-major).  Both
    // engines run in the same process on the same model with the same
    // per-image seeds, so `layer_major_speedup` is machine-independent —
    // that ratio at b=16 is what the CI perf gate pins
    // (hotpath_gate.json `layer_major_baseline`).
    let lm_dims = [(1024usize, 1024usize), (1024, 512), (512, 10)];
    let lm_data: Vec<(Vec<f32>, Vec<f32>)> = lm_dims
        .iter()
        .map(|&(i, o)| {
            let mut lw = vec![0.0f32; i * o];
            rng.fill_normal(&mut lw);
            for v in &mut lw {
                *v *= 0.05;
            }
            (lw, vec![0.0f32; o])
        })
        .collect();
    let lm_specs: Vec<(&[f32], &[f32], usize, usize)> = lm_data
        .iter()
        .zip(lm_dims.iter())
        .map(|((lw, lb), &(i, o))| (lw.as_slice(), lb.as_slice(), i, o))
        .collect();
    let lm_model = NoisyModel::new(&lm_specs, &cfg)?;
    let lm_plan = lm_model.uniform_plan(ReadMode::Original);
    let lm_macs: f64 = lm_dims.iter().map(|&(i, o)| (i * o) as f64).sum();
    let mut layer_major_speedups = [0.0f64; 3];
    let mut batch_major_mac_per_s = 0.0f64;
    for (bi, &b) in [1usize, 4, 16].iter().enumerate() {
        let bxs: Vec<f32> = (0..b * lm_model.d_in()).map(|_| rng.next_f32()).collect();
        let seeds: Vec<u64> = (0..b as u64)
            .map(|i| 0x5eed_0000u64 ^ i.wrapping_mul(0x9e37_79b9_7f4a_7c15))
            .collect();
        let iters = if b >= 16 { 3 } else { 6 };
        let mut c_sm = ReadCounters::default();
        let r = report(
            &format!("sample-major mlp(1024-1024-512-10) b={b}"),
            1,
            iters,
            || {
                let _ = lm_model
                    .forward_batch_seeds_sample_major(&bxs, &lm_plan, &cfg, &seeds, &mut c_sm);
            },
        );
        let sm = r.throughput(b as f64 * lm_macs);
        let mut c_lm = ReadCounters::default();
        let r = report(
            &format!("layer-major  mlp(1024-1024-512-10) b={b}"),
            1,
            iters,
            || {
                let _ = lm_model.forward_batch_seeds(&bxs, &lm_plan, &cfg, &seeds, &mut c_lm);
            },
        );
        let lm = r.throughput(b as f64 * lm_macs);
        layer_major_speedups[bi] = lm / sm;
        if b == 16 {
            batch_major_mac_per_s = lm;
        }
        println!(
            "  b={b}: {:.1} M MAC-sim/s layer-major vs {:.1} M sample-major ({:.2}x)",
            lm / 1e6,
            sm / 1e6,
            layer_major_speedups[bi]
        );
        // parity spot-check at every batch size: layer-major must be
        // bit-identical to the sample-major oracle, counters included
        let mut pa = ReadCounters::default();
        let mut pb = ReadCounters::default();
        let la = lm_model.forward_batch_seeds(&bxs, &lm_plan, &cfg, &seeds, &mut pa);
        let lb = lm_model.forward_batch_seeds_sample_major(&bxs, &lm_plan, &cfg, &seeds, &mut pb);
        assert_eq!(la, lb, "layer-major parity violated at b={b}");
        assert_eq!(pa, pb, "layer-major counter parity violated at b={b}");
    }
    let layer_major_speedup = layer_major_speedups[2];
    println!(
        "  parity: layer-major bit-identical to sample-major at b=1/4/16; \
         b=16 speedup {layer_major_speedup:.2}x (CI gate input)"
    );

    println!("\n=== hotpath: dataset generation ===");
    let ds = Dataset::new(Suite::Cifar, 1);
    let mut idx = 0u64;
    let r = report("dataset batch of 64 (NHWC 32x32x3)", 2, 30, || {
        let (_x, _y) = ds.batch(Split::Train, idx, 64);
        idx += 64;
    });
    let dataset_px_s = r.throughput(64.0 * 3072.0);
    println!("  -> {:.2} M px/s", dataset_px_s / 1e6);

    #[cfg(feature = "aot")]
    {
        println!("\n=== hotpath: PJRT predict dispatch ===");
        match emtopt::runtime::Artifacts::open_default() {
            Ok(arts) => {
                let predictor = emtopt::runtime::Predictor::new(&arts, "mlp_10")?;
                let init = arts.manifest.artifact("mlp_10_init")?;
                let init_exe = arts.runtime.load_hlo(&arts.dir.join(&init.file))?;
                let mut outs =
                    emtopt::runtime::execute(&init_exe, &[emtopt::runtime::scalar_i32(0)])?;
                let rho = emtopt::runtime::to_vec_f32(&outs.pop().unwrap())?;
                let params = outs;
                let (px, _) = ds.batch(Split::Test, 0, predictor.batch);
                let mut seed = 0i32;
                let r = report("predict batch=16 (mlp_10, noisy)", 3, 30, || {
                    seed += 1;
                    predictor.predict(&params, &rho, &px, seed, 1.0).unwrap();
                });
                println!(
                    "  -> {:.0} img/s through the full noisy model",
                    r.throughput(predictor.batch as f64)
                );
            }
            Err(e) => println!("(skipping PJRT bench: {e})"),
        }
    }
    #[cfg(not(feature = "aot"))]
    println!("\n(PJRT dispatch bench skipped: built without --features aot)");

    // machine-readable throughput record for the perf trajectory
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let json = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"unix_time\": {unix_time},\n  \
         \"plan_source\": \"{plan_source}\",\n  \
         \"rayon_threads\": {threads},\n  \
         \"mac_sim_per_s_original\": {mac_original:.1},\n  \
         \"mac_sim_per_s_decomposed\": {mac_decomposed:.1},\n  \
         \"mac_per_s_clean\": {mac_clean:.1},\n  \
         \"kernel_mac_per_s_fused\": {kernel_fused:.1},\n  \
         \"kernel_mac_per_s_scalar_ref\": {kernel_scalar_ref:.1},\n  \
         \"kernel_vs_scalar_ratio\": {kernel_ratio:.4},\n  \
         \"kernel_mac_per_s_percell_legacy\": {kernel_legacy:.1},\n  \
         \"speedup_vs_percell\": {kernel_speedup:.3},\n  \
         \"plane_cache_mac_per_s\": {plane_cached:.1},\n  \
         \"plane_multiply_mac_per_s\": {plane_scaled:.1},\n  \
         \"weight_plane_speedup\": {weight_plane_speedup:.3},\n  \
         \"batch32_seq_samples_per_s\": {seq_sps:.1},\n  \
         \"batch32_par_samples_per_s\": {par_sps:.1},\n  \
         \"batch_speedup\": {speedup:.3},\n  \
         \"batch_major_mac_per_s\": {batch_major_mac_per_s:.1},\n  \
         \"layer_major_speedup_b1\": {:.3},\n  \
         \"layer_major_speedup_b4\": {:.3},\n  \
         \"layer_major_speedup\": {layer_major_speedup:.3},\n  \
         \"dataset_px_per_s\": {dataset_px_s:.1}\n}}\n",
        layer_major_speedups[0], layer_major_speedups[1]
    );
    std::fs::write("BENCH_hotpath.json", json)?;
    println!("\nwrote BENCH_hotpath.json");
    Ok(())
}
