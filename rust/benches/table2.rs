//! Table 2 reproduction: ImageNet(-stand-in) holistic comparison for
//! ResNet-18 and ResNet-34.
//!
//! Paper shape: the SOTA methods cannot reach 0% accuracy drop (their
//! best-accuracy row is annotated with the residual drop), while ours
//! recover the baseline; ours (A+B) / (A+B+C) stay 1-2 orders of
//! magnitude below the SOTA energy.

#[path = "table_common/mod.rs"]
mod table_common;

use emtopt::data::Suite;
use emtopt::device::Intensity;
use emtopt::runtime::Artifacts;

fn main() -> emtopt::Result<()> {
    let arts = Artifacts::open_default()?;
    let full = std::env::var("EMTOPT_BENCH_FULL").is_ok();
    let models: &[&str] = if full {
        &["tiny_resnet_20", "tiny_resnet34_20"]
    } else {
        &["tiny_resnet_20"]
    };
    println!("=== Table 2: synthetic-ImageNet holistic comparison ===");
    for model_key in models {
        let t0 = std::time::Instant::now();
        let table = table_common::holistic_table(
            &arts,
            model_key,
            Suite::ImageNet,
            Intensity::Normal,
        )?;
        table.print();
        println!("# {model_key}: {:.1}s\n", t0.elapsed().as_secs_f64());
    }
    Ok(())
}
