//! Fig 11 reproduction: top-1 / top-5 accuracy of ours vs the SOTA on the
//! synthetic-ImageNet ResNet models at a fixed (normal) fluctuation
//! intensity, each method at its best operating point.
//!
//! Paper shape: ours (A+B+C) matches the noiseless baseline top-1/top-5;
//! ours (A+B) is slightly below; every SOTA method leaves a visible gap.

#[path = "table_common/mod.rs"]
mod table_common;

use emtopt::coordinator::{self, store, Solution};
use emtopt::data::Suite;
use emtopt::device::Intensity;
use emtopt::energy::EnergyModel;
use emtopt::metrics::{fmt_pct, Table};
use emtopt::runtime::{Artifacts, Evaluator};

fn main() -> emtopt::Result<()> {
    let arts = Artifacts::open_default()?;
    let full = std::env::var("EMTOPT_BENCH_FULL").is_ok();
    let models: &[&str] = if full {
        &["tiny_resnet_20", "tiny_resnet34_20"]
    } else {
        &["tiny_resnet_20"]
    };
    let em = EnergyModel::new(arts.manifest.device.act_bits);
    let grid = coordinator::experiments::default_rho_grid();
    let intensity = Intensity::Normal;

    for model_key in models {
        let paper = coordinator::experiments::paper_model_for(model_key).unwrap();
        let cfg = coordinator::experiments::schedule_for(model_key);
        let setup = coordinator::EvalSetup {
            suite: Suite::ImageNet,
            intensity,
            batches: 1,
            ..Default::default()
        };
        // compile once per model (slow 0.5.1 decomposed-graph compiles)
        let eval_plain = Evaluator::new(&arts, model_key, false)?;
        let abc = table_common::abc_enabled(model_key);
        let eval_dec = if abc { Some(Evaluator::new(&arts, model_key, true)?) } else { None };
        // noiseless "GPU" baseline (dashed line of the figure)
        let ab = store::train_cached(&arts, model_key, Suite::ImageNet, Solution::AB, &cfg)?;
        let base = coordinator::experiments::eval_baseline(&eval_plain, &ab, &setup)?;

        let mut table = Table::new(
            format!(
                "Fig 11 [{model_key} -> {}] baseline top-1 {} top-5 {}",
                paper.name,
                fmt_pct(base.top1_acc()),
                fmt_pct(base.top5_acc())
            ),
            &["method", "top-1", "top-5"],
        );
        for (method, sol) in table_common::method_rows(abc) {
            let trained = store::train_cached(&arts, model_key, Suite::ImageNet, sol, &cfg)?;
            let evaluator = if sol.decomposed() { eval_dec.as_ref().unwrap() } else { &eval_plain };
            let pts = coordinator::sweep_accuracy_vs_energy(
                evaluator, &trained, &setup, &paper, method, &em, &grid,
            )?;
            if let Some(best) = coordinator::experiments::best_accuracy_point(&pts) {
                table.row(vec![
                    method.name().into(),
                    fmt_pct(best.top1),
                    fmt_pct(best.top5),
                ]);
            }
        }
        table.print();
    }
    Ok(())
}
