//! Fig 9 reproduction: CIFAR-10 ablation — accuracy vs energy budget for
//! the traditional optimizer and solutions A / A+B / A+B+C.
//!
//! Paper shape to reproduce: the traditional optimizer collapses as the
//! budget shrinks; A < A+B <= A+B+C at a fixed budget; A+B+C stays near
//! the noiseless baseline across the whole budget range.
//!
//! Quick mode trains the short schedules of `schedule_for`; set
//! EMTOPT_BENCH_FULL=1 for the 8x schedules.  Trained models are cached
//! under runs/cache, so re-runs only pay the evaluation sweeps.

use emtopt::coordinator::{self, store, Solution};
use emtopt::data::Suite;
use emtopt::energy::EnergyModel;
use emtopt::metrics::{fmt_energy_uj, fmt_pct, Table};
use emtopt::runtime::{Artifacts, Evaluator};

fn main() -> emtopt::Result<()> {
    let arts = Artifacts::open_default()?;
    let full = std::env::var("EMTOPT_BENCH_FULL").is_ok();
    // quick mode: mlp only — xla_extension 0.5.1 takes ~8 min to compile
    // each conv model's decomposed train graph (fig10/11 + table2 cover
    // the conv models; EMTOPT_BENCH_FULL=1 runs the full matrix here too)
    let models: &[&str] = if full {
        &["tiny_vgg_10", "tiny_resnet_10", "tiny_mobilenet_10", "mlp_10"]
    } else {
        &["mlp_10"]
    };
    let em = EnergyModel::new(arts.manifest.device.act_bits);
    let grid = coordinator::experiments::default_rho_grid();

    for model_key in models {
        let cfg = coordinator::experiments::schedule_for(model_key);
        let paper = coordinator::experiments::paper_model_for(model_key).unwrap();
        let setup = coordinator::EvalSetup {
            suite: Suite::Cifar,
            batches: 1,
            ..Default::default()
        };
        let mut table = Table::new(
            format!("Fig 9 [{model_key} -> {} energy axis]", paper.name),
            &["solution", "energy (uJ)", "top-1", "top-5"],
        );
        let mut baseline = None;
        // compile once per model (slow 0.5.1 decomposed-graph compiles)
        let eval_plain = Evaluator::new(&arts, model_key, false)?;
        let eval_dec = Evaluator::new(&arts, model_key, true)?;
        for sol in Solution::ALL {
            let t0 = std::time::Instant::now();
            let trained = store::train_cached(&arts, model_key, Suite::Cifar, sol, &cfg)?;
            let evaluator = if sol.decomposed() { &eval_dec } else { &eval_plain };
            if baseline.is_none() {
                let b =
                    coordinator::experiments::eval_baseline(evaluator, &trained, &setup)?;
                baseline = Some(b.top1_acc());
                println!(
                    "# {model_key}: noiseless baseline top-1 = {}",
                    fmt_pct(b.top1_acc())
                );
            }
            let pts = coordinator::sweep_accuracy_vs_energy(
                evaluator,
                &trained,
                &setup,
                &paper,
                sol.method(),
                &em,
                &grid,
            )?;
            for p in &pts {
                table.row(vec![
                    sol.name().into(),
                    fmt_energy_uj(p.energy_uj),
                    fmt_pct(p.top1),
                    fmt_pct(p.top5),
                ]);
            }
            println!(
                "# {model_key} {}: trained+swept in {:.1}s",
                sol.name(),
                t0.elapsed().as_secs_f64()
            );
        }
        table.print();
    }
    Ok(())
}
