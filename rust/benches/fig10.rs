//! Fig 10 reproduction: energy at maximum accuracy under weak / normal /
//! strong fluctuation intensity, ours vs the three SOTA families, on the
//! synthetic-ImageNet ResNet models.
//!
//! Paper shape: every method prefers a larger rho (more energy) as the
//! intensity grows, but ours (A+B) stays ~1 order and ours (A+B+C) ~2
//! orders of magnitude below the SOTA at every intensity.

#[path = "table_common/mod.rs"]
mod table_common;

use emtopt::coordinator::{self, store, Solution};
use emtopt::data::Suite;
use emtopt::device::Intensity;
use emtopt::energy::EnergyModel;
use emtopt::metrics::{fmt_energy_uj, fmt_pct, Table};
use emtopt::runtime::{Artifacts, Evaluator};

fn main() -> emtopt::Result<()> {
    let arts = Artifacts::open_default()?;
    let full = std::env::var("EMTOPT_BENCH_FULL").is_ok();
    let models: &[&str] = if full {
        &["tiny_resnet_20", "tiny_resnet34_20"]
    } else {
        &["tiny_resnet_20"]
    };
    let em = EnergyModel::new(arts.manifest.device.act_bits);
    let grid = coordinator::experiments::default_rho_grid();

    for model_key in models {
        let paper = coordinator::experiments::paper_model_for(model_key).unwrap();
        let mut table = Table::new(
            format!("Fig 10 [{model_key} -> {}]", paper.name),
            &["intensity", "method", "top-1 @ max", "energy (uJ)"],
        );
        // compile each eval executable ONCE per model (xla_extension 0.5.1
        // compiles the decomposed graphs very slowly)
        let eval_plain = Evaluator::new(&arts, model_key, false)?;
        let abc = table_common::abc_enabled(model_key);
        let eval_dec = if abc { Some(Evaluator::new(&arts, model_key, true)?) } else { None };
        for intensity in Intensity::ALL {
            let mut cfg = coordinator::experiments::schedule_for(model_key);
            cfg.intensity = intensity;
            let setup = coordinator::EvalSetup {
                suite: Suite::ImageNet,
                intensity,
                batches: 1,
                ..Default::default()
            };
            for (method, sol) in table_common::method_rows(abc) {
                let mut mcfg = cfg;
                if sol == Solution::Traditional {
                    mcfg.intensity = Intensity::Normal; // trad never sees noise
                }
                let trained =
                    store::train_cached(&arts, model_key, Suite::ImageNet, sol, &mcfg)?;
                let evaluator = if sol.decomposed() { eval_dec.as_ref().unwrap() } else { &eval_plain };
                let pts = coordinator::sweep_accuracy_vs_energy(
                    evaluator, &trained, &setup, &paper, method, &em, &grid,
                )?;
                if let Some(best) = coordinator::experiments::best_accuracy_point(&pts) {
                    table.row(vec![
                        intensity.name().into(),
                        method.name().into(),
                        fmt_pct(best.top1),
                        fmt_energy_uj(best.energy_uj),
                    ]);
                }
            }
        }
        table.print();
    }
    Ok(())
}
