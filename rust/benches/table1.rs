//! Table 1 reproduction: CIFAR-10 holistic comparison — minimum energy,
//! cell count and delay at 0% / 1% / 2% accuracy drop for VGG-16,
//! ResNet-18 and MobileNet (stand-ins), ours vs the three SOTA families.
//!
//! Paper shape: ours (A+B) is ~1 order of magnitude below the best SOTA
//! energy at every drop level, ours (A+B+C) ~2 orders; A+B+C pays ~5x
//! delay; binarized encoding pays ~5x cells.

#[path = "table_common/mod.rs"]
mod table_common;

use emtopt::data::Suite;
use emtopt::device::Intensity;
use emtopt::runtime::Artifacts;

fn main() -> emtopt::Result<()> {
    let arts = Artifacts::open_default()?;
    let full = std::env::var("EMTOPT_BENCH_FULL").is_ok();
    // quick mode: mlp (VGG-16 energy axis) only — see fig9.rs note on the
    // 0.5.1 decomposed-graph compile times; full mode runs all three.
    let models: &[&str] = if full {
        &["tiny_vgg_10", "tiny_resnet_10", "tiny_mobilenet_10"]
    } else {
        &["mlp_10"]
    };
    println!("=== Table 1: synthetic-CIFAR holistic comparison ===");
    for model_key in models {
        let t0 = std::time::Instant::now();
        let table = table_common::holistic_table(
            &arts,
            model_key,
            Suite::Cifar,
            Intensity::Normal,
        )?;
        table.print();
        println!("# {model_key}: {:.1}s\n", t0.elapsed().as_secs_f64());
    }
    Ok(())
}
