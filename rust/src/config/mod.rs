//! Experiment configuration (TOML subset via `util::toml_lite`).
//!
//! Every CLI command and bench reads an [`ExperimentConfig`]; defaults are
//! tuned so `emtopt train` works out of the box on the artifacts built by
//! `make artifacts`.

use std::path::Path;

use crate::util::toml_lite::TomlDoc;
use crate::Result;

#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    /// Artifact directory (manifest.json + *.hlo.txt).
    pub artifacts: String,
    /// Tiny-zoo model key, e.g. "tiny_resnet_10".
    pub model: String,
    /// trad | a | ab | abc
    pub solution: String,
    /// weak | normal | strong
    pub intensity: String,
    pub train: TrainSection,
    pub eval: EvalSection,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TrainSection {
    pub pretrain_steps: u32,
    pub finetune_steps: u32,
    /// Energy-regularization weight (lambda, eq. 13).
    pub lam: f32,
    pub seed: i32,
    pub log_every: u32,
}

#[derive(Clone, Debug, PartialEq)]
pub struct EvalSection {
    /// Number of eval batches (x 256 samples).
    pub batches: u32,
    pub seed: i32,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            artifacts: "artifacts".into(),
            model: "tiny_resnet_10".into(),
            solution: "ab".into(),
            intensity: "normal".into(),
            train: TrainSection::default(),
            eval: EvalSection::default(),
        }
    }
}

impl Default for TrainSection {
    fn default() -> Self {
        TrainSection {
            pretrain_steps: 120,
            finetune_steps: 120,
            lam: 0.3,
            seed: 7,
            log_every: 20,
        }
    }
}

impl Default for EvalSection {
    fn default() -> Self {
        EvalSection {
            batches: 2,
            seed: 1234,
        }
    }
}

impl ExperimentConfig {
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let d = ExperimentConfig::default();
        Ok(ExperimentConfig {
            artifacts: doc.str_or("", "artifacts", &d.artifacts),
            model: doc.str_or("", "model", &d.model),
            solution: doc.str_or("", "solution", &d.solution),
            intensity: doc.str_or("", "intensity", &d.intensity),
            train: TrainSection {
                pretrain_steps: doc.parse_or("train", "pretrain_steps", d.train.pretrain_steps)?,
                finetune_steps: doc.parse_or("train", "finetune_steps", d.train.finetune_steps)?,
                lam: doc.parse_or("train", "lam", d.train.lam)?,
                seed: doc.parse_or("train", "seed", d.train.seed)?,
                log_every: doc.parse_or("train", "log_every", d.train.log_every)?,
            },
            eval: EvalSection {
                batches: doc.parse_or("eval", "batches", d.eval.batches)?,
                seed: doc.parse_or("eval", "seed", d.eval.seed)?,
            },
        })
    }

    pub fn to_toml(&self) -> String {
        let mut doc = TomlDoc::default();
        doc.set("", "artifacts", &self.artifacts);
        doc.set("", "model", &self.model);
        doc.set("", "solution", &self.solution);
        doc.set("", "intensity", &self.intensity);
        doc.set("train", "pretrain_steps", self.train.pretrain_steps.to_string());
        doc.set("train", "finetune_steps", self.train.finetune_steps.to_string());
        doc.set("train", "lam", self.train.lam.to_string());
        doc.set("train", "seed", self.train.seed.to_string());
        doc.set("train", "log_every", self.train.log_every.to_string());
        doc.set("eval", "batches", self.eval.batches.to_string());
        doc.set("eval", "seed", self.eval.seed.to_string());
        doc.render()
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_toml(&text)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_toml())?;
        Ok(())
    }

    pub fn suite(&self) -> crate::data::Suite {
        if self.model.ends_with("_20") {
            crate::data::Suite::ImageNet
        } else {
            crate::data::Suite::Cifar
        }
    }

    pub fn solution_parsed(&self) -> Result<crate::coordinator::Solution> {
        self.solution
            .parse()
            .map_err(|e: String| anyhow::anyhow!(e))
    }

    pub fn intensity_parsed(&self) -> Result<crate::device::Intensity> {
        self.intensity
            .parse()
            .map_err(|e: String| anyhow::anyhow!(e))
    }

    pub fn train_config(&self) -> Result<crate::coordinator::TrainConfig> {
        Ok(crate::coordinator::TrainConfig {
            pretrain_steps: self.train.pretrain_steps,
            finetune_steps: self.train.finetune_steps,
            lam: self.train.lam,
            intensity: self.intensity_parsed()?,
            seed: self.train.seed,
            log_every: self.train.log_every,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip() {
        let cfg = ExperimentConfig::default();
        let back = ExperimentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn partial_toml_uses_defaults() {
        let cfg =
            ExperimentConfig::from_toml("model = \"mlp_10\"\nsolution = \"abc\"").unwrap();
        assert_eq!(cfg.model, "mlp_10");
        assert_eq!(cfg.solution, "abc");
        assert_eq!(cfg.train.pretrain_steps, 120); // default
    }

    #[test]
    fn suite_from_model_key() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.suite(), crate::data::Suite::Cifar);
        cfg.model = "tiny_resnet_20".into();
        assert_eq!(cfg.suite(), crate::data::Suite::ImageNet);
    }

    #[test]
    fn parses_enums() {
        let cfg = ExperimentConfig::default();
        assert!(cfg.solution_parsed().is_ok());
        assert!(cfg.intensity_parsed().is_ok());
        assert!(cfg.train_config().is_ok());
    }
}
