//! State-of-the-art baselines (paper §2) on the same device substrate.
//!
//! Each baseline is characterised by how it transforms the effective
//! read-noise sigma, the energy, the cell count, and the latency of a
//! **conventionally trained** model (none of them trains with device noise
//! — that is exactly the gap techniques A/B/C exploit):
//!
//! * **Binarized encoding** (Zhu et al. [19]): an N-bit weight is stored
//!   in N single-bit cells and recombined digitally.  Per-bit-cell RTN
//!   with amplitude sigma recombines to
//!   `sigma_eff = sigma * sqrt(sum_p 4^p) / (2^N - 1)`, at N x cells and
//!   roughly `N * mean_bit / mean|w|` x cell energy (every bit cell burns
//!   full-scale current when set).
//! * **Weight scaling** (Ielmini et al. [25]): scales programmed
//!   conductances up by gamma, dividing sigma by gamma but multiplying
//!   cell energy by gamma — mathematically identical to tuning rho, so the
//!   sweep is exposed through the same rho axis.
//! * **Fluctuation compensation** (Wan et al. [31]): reads every cell K
//!   times and averages: `sigma_eff = sigma / sqrt(K)` at K x energy and
//!   K x delay.

use crate::energy::{EnergyModel, ReadMode};
use crate::models::ModelDesc;
use crate::timing::TimingModel;

/// Which method a measurement belongs to (ours + the three SOTA families).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Traditional optimizer, deployed raw (ablation reference).
    Traditional,
    /// Ours, technique A only.
    OursA,
    /// Ours, techniques A+B.
    OursAB,
    /// Ours, techniques A+B+C.
    OursABC,
    /// Binarized encoding [19] with `n_bits` single-bit cells per weight.
    BinarizedEncoding,
    /// Weight scaling [25].
    WeightScaling,
    /// Fluctuation compensation [31] with K-read averaging.
    FluctuationCompensation,
}

impl Method {
    pub const SOTA: [Method; 3] = [
        Method::BinarizedEncoding,
        Method::WeightScaling,
        Method::FluctuationCompensation,
    ];

    pub const OURS: [Method; 3] = [Method::OursA, Method::OursAB, Method::OursABC];

    pub fn name(self) -> &'static str {
        match self {
            Method::Traditional => "Traditional",
            Method::OursA => "Ours (A)",
            Method::OursAB => "Ours (A+B)",
            Method::OursABC => "Ours (A+B+C)",
            Method::BinarizedEncoding => "Binarized Encoding [19]",
            Method::WeightScaling => "Weight Scaling [25]",
            Method::FluctuationCompensation => "Fluctuation Compensation [31]",
        }
    }

    /// Noise-aware trained (technique A active)?
    pub fn noise_aware(self) -> bool {
        matches!(self, Method::OursA | Method::OursAB | Method::OursABC)
    }

    /// Trains rho jointly (technique B)?
    pub fn trains_rho(self) -> bool {
        matches!(self, Method::OursAB | Method::OursABC)
    }

    /// Uses the decomposed read mode (technique C)?
    pub fn read_mode(self) -> ReadMode {
        if self == Method::OursABC {
            ReadMode::Decomposed
        } else {
            ReadMode::Original
        }
    }
}

/// Bits per weight in the binarized-encoding baseline (paper Table 1:
/// 74M vs 15M cells on VGG-16 => 5 bit-cells per weight).
pub const BINARIZED_BITS: u32 = 5;
/// Averaging reads in the fluctuation-compensation baseline (paper Table 1:
/// 14 us vs 2.8 us => K = 5).
pub const COMPENSATION_READS: u32 = 5;

/// Hardware-level multipliers of a method relative to the plain analog
/// single-read scheme at the same rho.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchemeFactors {
    /// Multiplier on the effective weight-fluctuation sigma.
    pub sigma: f64,
    /// Multiplier on analog cell energy.
    pub cell_energy: f64,
    /// Multiplier on cell count.
    pub cells: f64,
    /// Multiplier on latency.
    pub delay: f64,
}

impl SchemeFactors {
    pub fn identity() -> Self {
        SchemeFactors {
            sigma: 1.0,
            cell_energy: 1.0,
            cells: 1.0,
            delay: 1.0,
        }
    }
}

/// Factors of the binarized-encoding scheme with `n` bit cells per weight.
pub fn binarized_factors(n: u32, mean_w_norm: f64) -> SchemeFactors {
    let denom = ((1u64 << n) - 1) as f64;
    let sum_4p: f64 = (0..n).map(|p| 4f64.powi(p as i32)).sum();
    // digital recombination of per-bit-cell noise
    let sigma = sum_4p.sqrt() / denom;
    // each set bit cell burns full-scale current; mean set fraction 0.5.
    // relative to the analog cell's mean |w| duty:
    let cell_energy = n as f64 * 0.5 / mean_w_norm;
    SchemeFactors {
        sigma,
        cell_energy,
        cells: n as f64,
        delay: 1.0, // bit cells are read in parallel columns
    }
}

/// Factors of K-read fluctuation compensation.
pub fn compensation_factors(k: u32) -> SchemeFactors {
    SchemeFactors {
        sigma: 1.0 / (k as f64).sqrt(),
        cell_energy: k as f64,
        cells: 1.0,
        delay: k as f64,
    }
}

/// Factors of weight scaling by gamma (gamma folds into rho; kept for the
/// explicit-gamma ablation).
pub fn weight_scaling_factors(gamma: f64) -> SchemeFactors {
    SchemeFactors {
        sigma: 1.0 / gamma,
        cell_energy: gamma,
        cells: 1.0,
        delay: 1.0,
    }
}

/// Per-method hardware factors (ours and trad use the identity scheme —
/// our gains come from training, rho, and the read mode).
pub fn method_factors(method: Method, mean_w_norm: f64) -> SchemeFactors {
    match method {
        Method::BinarizedEncoding => binarized_factors(BINARIZED_BITS, mean_w_norm),
        Method::FluctuationCompensation => compensation_factors(COMPENSATION_READS),
        _ => SchemeFactors::identity(),
    }
}

/// Full hardware cost of running `model` with `method` at uniform `rho`.
#[derive(Clone, Copy, Debug)]
pub struct HardwareCost {
    pub energy_uj: f64,
    pub cells: f64,
    pub delay_us: f64,
    /// Effective relative fluctuation sigma the network weights see.
    pub sigma_eff: f64,
}

pub fn hardware_cost(
    method: Method,
    model: &ModelDesc,
    rho: f64,
    intensity: f64,
    em: &EnergyModel,
    tm: &TimingModel,
) -> HardwareCost {
    let f = method_factors(method, em.stats.mean_w_norm);
    let mode = method.read_mode();
    let cell_pj: f64 = model
        .layers
        .iter()
        .map(|l| em.layer_cell_pj(l, rho, mode))
        .sum();
    let peri_pj: f64 = model
        .layers
        .iter()
        .map(|l| em.layer_peripheral_pj(l, mode))
        .sum();
    // peripheral scales with extra reads (delay factor) and extra columns
    // (cells factor for binarized encoding)
    let energy_uj = (cell_pj * f.cell_energy + peri_pj * f.delay * f.cells.max(1.0)) * 1e-6;
    let delay_us = tm.model_latency_us(model, mode) * f.delay;
    let sigma_base = crate::device::sigma_rel(rho as f32, intensity as f32) as f64;
    HardwareCost {
        energy_uj,
        cells: model.total_cells() as f64 * f.cells,
        delay_us,
        sigma_eff: sigma_base * f.sigma,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::paper_scale::{vgg16, Resolution};

    #[test]
    fn binarized_reduces_sigma_but_costs_cells() {
        let f = binarized_factors(5, 0.25);
        assert!(f.sigma < 1.0, "sigma mult {}", f.sigma);
        assert_eq!(f.cells, 5.0);
        assert!(f.cell_energy > 1.0);
    }

    #[test]
    fn binarized_energy_multiplier_matches_paper_order() {
        // paper Table 1 VGG-16: binarized 378 uJ vs ours(A+B) 36 uJ => ~10x
        let f = binarized_factors(5, 0.25);
        assert!((8.0..13.0).contains(&f.cell_energy), "{}", f.cell_energy);
    }

    #[test]
    fn compensation_sqrt_k() {
        let f = compensation_factors(5);
        assert!((f.sigma - 1.0 / 5f64.sqrt()).abs() < 1e-12);
        assert_eq!(f.cell_energy, 5.0);
        assert_eq!(f.delay, 5.0);
    }

    #[test]
    fn weight_scaling_is_rho_equivalent() {
        // doubling gamma == quadrupling rho in sigma terms, doubling energy
        let f = weight_scaling_factors(2.0);
        assert_eq!(f.sigma, 0.5);
        assert_eq!(f.cell_energy, 2.0);
    }

    #[test]
    fn hardware_cost_table_shape() {
        let em = EnergyModel::new(5);
        let tm = TimingModel::new(5);
        let m = vgg16(Resolution::Cifar);
        let ours = hardware_cost(Method::OursAB, &m, 1.0, 1.0, &em, &tm);
        let bin = hardware_cost(Method::BinarizedEncoding, &m, 1.0, 1.0, &em, &tm);
        let comp = hardware_cost(Method::FluctuationCompensation, &m, 1.0, 1.0, &em, &tm);
        let ours_c = hardware_cost(Method::OursABC, &m, 1.0, 1.0, &em, &tm);
        // Table 1 shapes
        assert!(bin.cells > 4.0 * ours.cells);
        assert!(bin.energy_uj > ours.energy_uj);
        assert!(comp.delay_us > 4.0 * ours.delay_us);
        assert!(ours_c.delay_us > ours.delay_us);
        assert!(ours_c.energy_uj < ours.energy_uj); // technique C saves energy
        assert!(comp.sigma_eff < ours.sigma_eff);
    }

    #[test]
    fn method_metadata() {
        assert!(Method::OursABC.noise_aware());
        assert!(Method::OursABC.trains_rho());
        assert_eq!(Method::OursABC.read_mode(), ReadMode::Decomposed);
        assert!(!Method::WeightScaling.noise_aware());
        assert_eq!(Method::SOTA.len(), 3);
    }
}
