//! # emtopt — in-memory deep learning with emerging memory technology
//!
//! Production reproduction of *"Optimizing for In-memory Deep Learning with
//! Emerging Memory Technology"* (Wang, Luo, Goh, Zhang, Wong; 2021).
//!
//! The paper proposes three co-design techniques for analog in-memory
//! neural-network inference on unstable EMT (RRAM/PCRAM) cells:
//!
//! * **A — device-enhanced dataset**: noise-aware training with sampled
//!   device fluctuation states,
//! * **B — energy regularization**: a trainable per-layer energy
//!   coefficient ρ optimized under the loss term `λ Σ α_t ρ |w_t|`,
//! * **C — low-fluctuation decomposition**: bit-serial crossbar reads that
//!   average out RTN fluctuation while cutting read energy.
//!
//! Architecture (see DESIGN.md): a Rust coordinator (this crate) owns the
//! request path.  The **native execution engine** — immutable
//! `crossbar::CrossbarArray`s shared behind an `Arc`, the batched
//! `inference::NoisyModel` with per-sample counter-based RNG streams, and
//! the `coordinator::router` worker pool — serves traffic directly off the
//! device simulation substrate.  With `--features aot` the crate
//! additionally loads JAX/Pallas computations that were AOT-lowered to
//! HLO text at build time (`make artifacts`) and executes them through
//! the PJRT CPU client (`runtime`) for the paper's full-model accuracy
//! experiments.

pub mod baselines;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod crossbar;
pub mod data;
pub mod device;
pub mod energy;
pub mod inference;
pub mod metrics;
pub mod models;
pub mod pool;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod timing;
pub mod trace;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
