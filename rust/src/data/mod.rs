//! Synthetic dataset generator (DESIGN.md §2 substitution for
//! CIFAR-10 / ImageNet).
//!
//! Class-conditional template images with per-sample uniform noise:
//!
//! ```text
//! x = clip(a * T_c + (1 - a) * u, 0, 1),   u ~ U[0,1)^d
//! ```
//!
//! `T_c` is a fixed random template per class (smoothed so the classes are
//! separable by conv features rather than single pixels).  `a` controls
//! difficulty: the nc=10 "synthetic-CIFAR" suite uses a=0.6, the nc=20
//! "synthetic-ImageNet" stand-in uses a=0.45 (harder, mirroring the paper's
//! observation that ImageNet recovery is the harder benchmark).
//!
//! Deterministic: (seed, split, index) fully determine a sample, so train /
//! eval batches are reproducible across runs and languages.

use crate::rng::{hash2, Rng};

/// Canonical dataset seed: training and evaluation MUST agree on it —
/// the class templates are a function of the seed, so different seeds
/// are different classification tasks.
pub const DATA_SEED: u64 = 7;

/// Image side (HW); all suites use 32x32x3 NHWC.
pub const HW: usize = 32;
/// Channels.
pub const CH: usize = 3;
/// Floats per image.
pub const IMG_LEN: usize = HW * HW * CH;

/// A deterministic synthetic classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub num_classes: usize,
    /// Template blend factor `a` (higher = easier).
    pub blend: f32,
    seed: u64,
    templates: Vec<f32>, // (num_classes, IMG_LEN)
}

/// Standard suites used across the experiments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    /// 10-class stand-in for CIFAR-10.
    Cifar,
    /// 20-class, harder stand-in for ImageNet.
    ImageNet,
}

impl Suite {
    pub fn num_classes(self) -> usize {
        match self {
            Suite::Cifar => 10,
            Suite::ImageNet => 20,
        }
    }

    pub fn blend(self) -> f32 {
        match self {
            Suite::Cifar => 0.6,
            Suite::ImageNet => 0.45,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Suite::Cifar => "synthetic-cifar10",
            Suite::ImageNet => "synthetic-imagenet",
        }
    }
}

impl Dataset {
    pub fn new(suite: Suite, seed: u64) -> Self {
        Self::with_params(suite.num_classes(), suite.blend(), seed)
    }

    pub fn with_params(num_classes: usize, blend: f32, seed: u64) -> Self {
        let mut templates = vec![0.0f32; num_classes * IMG_LEN];
        for c in 0..num_classes {
            let mut rng = Rng::new(hash2(seed, 0xC1A55 ^ c as u64));
            let raw: Vec<f32> = (0..IMG_LEN).map(|_| rng.next_f32()).collect();
            // 3x3 box smoothing per channel: templates get spatial structure
            // so conv models have an edge over pixel-wise ones.
            let t = &mut templates[c * IMG_LEN..(c + 1) * IMG_LEN];
            for ch in 0..CH {
                for y in 0..HW {
                    for x in 0..HW {
                        let mut acc = 0.0;
                        let mut n = 0.0;
                        for dy in -1i32..=1 {
                            for dx in -1i32..=1 {
                                let yy = y as i32 + dy;
                                let xx = x as i32 + dx;
                                if (0..HW as i32).contains(&yy)
                                    && (0..HW as i32).contains(&xx)
                                {
                                    acc += raw
                                        [(yy as usize * HW + xx as usize) * CH + ch];
                                    n += 1.0;
                                }
                            }
                        }
                        t[(y * HW + x) * CH + ch] = acc / n;
                    }
                }
            }
            // stretch to full [0,1] contrast
            let (mut lo, mut hi) = (f32::MAX, f32::MIN);
            for v in t.iter() {
                lo = lo.min(*v);
                hi = hi.max(*v);
            }
            let span = (hi - lo).max(1e-6);
            for v in t.iter_mut() {
                *v = (*v - lo) / span;
            }
        }
        Dataset {
            num_classes,
            blend,
            seed,
            templates,
        }
    }

    /// Class template (read-only view).
    pub fn template(&self, class: usize) -> &[f32] {
        &self.templates[class * IMG_LEN..(class + 1) * IMG_LEN]
    }

    /// Generate sample `index` of `split` into `out` (len IMG_LEN);
    /// returns the label.
    pub fn sample_into(&self, split: Split, index: u64, out: &mut [f32]) -> u32 {
        debug_assert_eq!(out.len(), IMG_LEN);
        let mut rng = Rng::new(hash2(
            self.seed ^ split.salt(),
            index.wrapping_mul(0x9E37),
        ));
        let label = rng.below(self.num_classes as u32);
        let t = self.template(label as usize);
        let a = self.blend;
        for (o, &tv) in out.iter_mut().zip(t.iter()) {
            let u = rng.next_f32();
            *o = (a * tv + (1.0 - a) * u).clamp(0.0, 1.0);
        }
        label
    }

    /// Generate a whole batch: returns (x NHWC flattened, labels).
    pub fn batch(&self, split: Split, start: u64, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let mut xs = vec![0.0f32; batch * IMG_LEN];
        let mut ys = Vec::with_capacity(batch);
        for i in 0..batch {
            let label = self.sample_into(
                split,
                start + i as u64,
                &mut xs[i * IMG_LEN..(i + 1) * IMG_LEN],
            );
            ys.push(label as i32);
        }
        (xs, ys)
    }
}

/// Train / test split tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

impl Split {
    fn salt(self) -> u64 {
        match self {
            Split::Train => 0x7E57_AB1E,
            Split::Test => 0x0DDB_A11,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let d = Dataset::new(Suite::Cifar, 7);
        let (x1, y1) = d.batch(Split::Train, 0, 8);
        let (x2, y2) = d.batch(Split::Train, 0, 8);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn splits_differ() {
        let d = Dataset::new(Suite::Cifar, 7);
        let (x1, _) = d.batch(Split::Train, 0, 4);
        let (x2, _) = d.batch(Split::Test, 0, 4);
        assert_ne!(x1, x2);
    }

    #[test]
    fn pixels_in_unit_range() {
        let d = Dataset::new(Suite::ImageNet, 3);
        let (x, y) = d.batch(Split::Train, 0, 16);
        assert!(x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(y.iter().all(|&v| (0..20).contains(&v)));
    }

    #[test]
    fn labels_cover_classes() {
        let d = Dataset::new(Suite::Cifar, 1);
        let (_, y) = d.batch(Split::Train, 0, 512);
        let mut seen = vec![false; 10];
        for v in y {
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all classes drawn in 512 samples");
    }

    #[test]
    fn templates_distinct() {
        let d = Dataset::new(Suite::Cifar, 1);
        let a = d.template(0);
        let b = d.template(1);
        let dist: f32 = a
            .iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f32>()
            / IMG_LEN as f32;
        assert!(dist > 0.01, "templates must be well separated, d2={dist}");
    }

    #[test]
    fn nearest_template_classifies_clean_samples() {
        // sanity: with blend 0.6 a nearest-template classifier is near
        // perfect => the task is learnable but noise matters.
        let d = Dataset::new(Suite::Cifar, 5);
        let mut buf = vec![0.0f32; IMG_LEN];
        let mut correct = 0;
        let n = 200;
        for i in 0..n {
            let label = d.sample_into(Split::Test, i, &mut buf);
            let mut best = (f32::MAX, 0);
            for c in 0..10 {
                let t = d.template(c);
                let dist: f32 = t
                    .iter()
                    .zip(buf.iter())
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == label as usize {
                correct += 1;
            }
        }
        assert!(correct as f32 / n as f32 > 0.95);
    }
}
