//! EMT cell device model — the canonical Rust mirror of
//! `python/compile/device.py` (keep the constants in sync; the integration
//! tests cross-check both through the AOT artifacts).
//!
//! An analog cell storing weight `w` (normalised to layer full-scale
//! `w_scale`) fluctuates between `m` RTN states. Read at state `l`:
//!
//! ```text
//! r_l(w, rho) = w + sigma_abs(rho, intensity, w_scale) * c_l
//! sigma_abs   = K_F * intensity / sqrt(rho) * w_scale
//! ```
//!
//! with zero-mean unit-variance evenly spaced offsets `c_l` (eq. 7 of the
//! paper; amplitude–energy coupling per Ielmini et al. [25]).

pub mod rtn;

pub use rtn::{RtnCell, RtnState};

/// Default number of RTN states per cell.
pub const DEFAULT_NUM_STATES: usize = 4;

/// Fluctuation constant: relative sigma at rho == 1, intensity == 1.
pub const K_F: f32 = 0.04;

/// Device energy unit of one full-scale full-duty analog read (normalised;
/// the `energy` module owns the absolute uJ calibration).
pub const E0: f32 = 1.0;

/// Default activation bits B_a (bit-planes in decomposed mode).
/// B_a = 5 matches the paper's 5x decomposed-mode delay (Table 1).
pub const DEFAULT_ACT_BITS: u32 = 5;

/// Default signed weight bits B_w.
pub const DEFAULT_WEIGHT_BITS: u32 = 8;

/// Fluctuation intensity level (paper §5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Intensity {
    Weak,
    Normal,
    Strong,
}

impl Intensity {
    pub const ALL: [Intensity; 3] = [Intensity::Weak, Intensity::Normal, Intensity::Strong];

    /// Multiplier applied to the fluctuation amplitude.
    pub fn factor(self) -> f32 {
        match self {
            Intensity::Weak => 0.5,
            Intensity::Normal => 1.0,
            Intensity::Strong => 2.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Intensity::Weak => "weak",
            Intensity::Normal => "normal",
            Intensity::Strong => "strong",
        }
    }
}

impl std::str::FromStr for Intensity {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "weak" => Ok(Intensity::Weak),
            "normal" => Ok(Intensity::Normal),
            "strong" => Ok(Intensity::Strong),
            other => Err(format!("unknown intensity {other:?}")),
        }
    }
}

/// Zero-mean, unit-variance, evenly spaced state offsets `c_l`.
///
/// Mirrors `device.state_offsets` in Python exactly.
pub fn state_offsets(m: usize) -> Vec<f32> {
    assert!(m >= 1, "need at least one state");
    if m == 1 {
        return vec![0.0];
    }
    let mut raw: Vec<f64> = (0..m)
        .map(|l| -1.0 + 2.0 * l as f64 / (m - 1) as f64)
        .collect();
    let mean = raw.iter().sum::<f64>() / m as f64;
    for v in raw.iter_mut() {
        *v -= mean;
    }
    let var = raw.iter().map(|v| v * v).sum::<f64>() / m as f64;
    let std = var.sqrt();
    raw.iter().map(|v| (*v / std) as f32).collect()
}

/// Relative fluctuation amplitude (fraction of full scale).
#[inline]
pub fn sigma_rel(rho: f32, intensity: f32) -> f32 {
    K_F * intensity / rho.sqrt()
}

/// Absolute fluctuation amplitude in weight units.
#[inline]
pub fn sigma_abs(rho: f32, intensity: f32, w_scale: f32) -> f32 {
    sigma_rel(rho, intensity) * w_scale
}

/// Energy of one analog read (normalised device units, eq. 19).
///
/// `w_abs_norm` in [0, 1] is |w| / w_scale; `act_level` is the integer DAC
/// level (original mode) or the number of set bit-planes (decomposed mode).
#[inline]
pub fn read_energy(rho: f32, w_abs_norm: f32, act_level: f32) -> f32 {
    E0 * rho * w_abs_norm * act_level
}

/// Device configuration shared by the simulation substrate.
#[derive(Clone, Debug)]
pub struct DeviceConfig {
    pub num_states: usize,
    pub intensity: Intensity,
    /// Global energy coefficient used when a layer has no trained rho.
    pub rho: f32,
    pub act_bits: u32,
    pub weight_bits: u32,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig {
            num_states: DEFAULT_NUM_STATES,
            intensity: Intensity::Normal,
            rho: 4.0,
            act_bits: DEFAULT_ACT_BITS,
            weight_bits: DEFAULT_WEIGHT_BITS,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offsets_zero_mean_unit_var() {
        for m in [2usize, 3, 4, 8, 16] {
            let c = state_offsets(m);
            let mean: f32 = c.iter().sum::<f32>() / m as f32;
            let var: f32 = c.iter().map(|v| v * v).sum::<f32>() / m as f32;
            assert!(mean.abs() < 1e-5, "m={m} mean={mean}");
            assert!((var - 1.0).abs() < 1e-4, "m={m} var={var}");
        }
    }

    #[test]
    fn offsets_match_python_m4() {
        // python: device.state_offsets(4) == [-1.3416, -0.4472, 0.4472, 1.3416]
        let c = state_offsets(4);
        let want = [-1.341_640_8, -0.447_213_6, 0.447_213_6, 1.341_640_8];
        for (got, want) in c.iter().zip(want.iter()) {
            assert!((got - want).abs() < 1e-4, "{got} vs {want}");
        }
    }

    #[test]
    fn single_state_noiseless() {
        assert_eq!(state_offsets(1), vec![0.0]);
    }

    #[test]
    fn sigma_sqrt_law() {
        let s1 = sigma_rel(1.0, 1.0);
        let s4 = sigma_rel(4.0, 1.0);
        assert!((s4 - s1 / 2.0).abs() < 1e-7);
    }

    #[test]
    fn intensity_ordering() {
        let w = sigma_rel(1.0, Intensity::Weak.factor());
        let n = sigma_rel(1.0, Intensity::Normal.factor());
        let s = sigma_rel(1.0, Intensity::Strong.factor());
        assert!(w < n && n < s);
        assert!((s - 4.0 * w).abs() < 1e-7);
    }

    #[test]
    fn energy_linear() {
        assert_eq!(read_energy(2.0, 0.5, 3.0), 2.0 * read_energy(1.0, 0.5, 3.0));
        assert_eq!(read_energy(1.0, 1.0, 4.0), 2.0 * read_energy(1.0, 0.5, 4.0));
    }

    #[test]
    fn decomposed_read_cheaper_eq19() {
        // E_new = rho * popcount(level) <= E_ori = rho * level, strict for
        // any level >= 2.
        for level in 2u32..16 {
            let e_ori = read_energy(1.0, 1.0, level as f32);
            let e_new = read_energy(1.0, 1.0, level.count_ones() as f32);
            assert!(e_new < e_ori, "level {level}");
        }
    }

    #[test]
    fn intensity_parse() {
        assert_eq!("weak".parse::<Intensity>().unwrap(), Intensity::Weak);
        assert!("loud".parse::<Intensity>().is_err());
    }
}
