//! RTN (random telegraph noise) cell state machine.
//!
//! Physically each trap in the oxide captures/emits electrons with
//! exponential dwell times, producing a multi-level telegraph signal in the
//! cell conductance [8][39].  We model the composite as an `m`-state
//! continuous-time Markov chain with uniform stationary distribution — the
//! stationary picture is what eq. (7)/(8) of the paper samples (each read
//! lands in state `l` with probability 1/m).
//!
//! Two sampling modes:
//!  * [`RtnCell::sample_stationary`] — i.i.d. stationary reads (what the
//!    paper's math assumes; used by the inference engine),
//!  * [`RtnCell::advance`] — time-correlated trajectory (used by
//!    `examples/device_explorer.rs` and the robustness tests to show the
//!    stationary assumption is conservative).

use crate::rng::Rng;

/// State of one RTN cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RtnState(pub usize);

/// One EMT cell with `m` RTN states.
#[derive(Clone, Debug)]
pub struct RtnCell {
    /// Zero-mean unit-variance offsets `c_l`.
    offsets: Vec<f32>,
    /// Mean dwell time per state, in read cycles.
    dwell: f32,
    state: usize,
}

impl RtnCell {
    pub fn new(num_states: usize, dwell_cycles: f32) -> Self {
        RtnCell {
            offsets: super::state_offsets(num_states),
            dwell: dwell_cycles.max(1e-6),
            state: 0,
        }
    }

    pub fn num_states(&self) -> usize {
        self.offsets.len()
    }

    pub fn state(&self) -> RtnState {
        RtnState(self.state)
    }

    /// Current fluctuation offset `c_l` of the cell.
    pub fn offset(&self) -> f32 {
        self.offsets[self.state]
    }

    /// Draw an i.i.d. stationary state and return its offset.
    #[inline]
    pub fn sample_stationary(&mut self, rng: &mut Rng) -> f32 {
        self.state = rng.below(self.offsets.len() as u32) as usize;
        self.offsets[self.state]
    }

    /// Advance the Markov chain by `cycles` read cycles and return the
    /// offset at the end.  Transition probability per cycle is
    /// `1 - exp(-1/dwell)`; on transition the next state is uniform among
    /// the others (composite multi-trap approximation).
    pub fn advance(&mut self, cycles: u32, rng: &mut Rng) -> f32 {
        let p_switch = 1.0 - (-1.0 / self.dwell).exp();
        for _ in 0..cycles {
            if rng.next_f32() < p_switch {
                let m = self.offsets.len() as u32;
                if m > 1 {
                    let mut next = rng.below(m - 1) as usize;
                    if next >= self.state {
                        next += 1;
                    }
                    self.state = next;
                }
            }
        }
        self.offsets[self.state]
    }

    /// Noisy read of a stored (normalised) weight value at the CURRENT
    /// state: `r_l(w, rho) = w + sigma_abs * c_l`.
    #[inline]
    pub fn read(&self, w: f32, sigma_abs: f32) -> f32 {
        w + sigma_abs * self.offsets[self.state]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stationary_is_uniform() {
        let mut cell = RtnCell::new(4, 10.0);
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 4];
        let n = 40_000;
        for _ in 0..n {
            cell.sample_stationary(&mut rng);
            counts[cell.state().0] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.25).abs() < 0.02, "frac {frac}");
        }
    }

    #[test]
    fn stationary_offset_moments() {
        let mut cell = RtnCell::new(4, 10.0);
        let mut rng = Rng::new(2);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let o = cell.sample_stationary(&mut rng) as f64;
            sum += o;
            sq += o * o;
        }
        assert!((sum / n as f64).abs() < 0.02);
        assert!((sq / n as f64 - 1.0).abs() < 0.02);
    }

    #[test]
    fn trajectory_converges_to_stationary() {
        let mut cell = RtnCell::new(2, 5.0);
        let mut rng = Rng::new(3);
        let mut hi = 0usize;
        let n = 20_000;
        for _ in 0..n {
            cell.advance(1, &mut rng);
            if cell.state().0 == 1 {
                hi += 1;
            }
        }
        let frac = hi as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "frac {frac}");
    }

    #[test]
    fn long_dwell_is_sticky() {
        let mut cell = RtnCell::new(2, 1e9);
        let mut rng = Rng::new(4);
        let s0 = cell.state().0;
        cell.advance(100, &mut rng);
        assert_eq!(cell.state().0, s0);
    }

    #[test]
    fn read_applies_offset() {
        let mut cell = RtnCell::new(4, 1.0);
        let mut rng = Rng::new(5);
        cell.sample_stationary(&mut rng);
        let w = 0.5;
        let sigma = 0.1;
        assert!((cell.read(w, sigma) - (w + sigma * cell.offset())).abs() < 1e-7);
        // noiseless when sigma == 0
        assert_eq!(cell.read(w, 0.0), w);
    }
}
