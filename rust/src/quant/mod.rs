//! Quantisers — Rust mirror of `python/compile/quant.py`.
//!
//! Weights: signed symmetric B_w-bit levels (programmed conductances).
//! Activations: unsigned B_a-bit levels (DAC input); decomposed mode splits
//! the level into bit-planes (LSB first).

/// Per-tensor full scale: max |w| (floored to avoid division by zero).
pub fn weight_scale(w: &[f32]) -> f32 {
    w.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-6)
}

/// Symmetric signed quantisation to `bits`. Returns integer levels in
/// [-(2^(bits-1)-1), 2^(bits-1)-1] together with the scale.
pub fn quant_weight(w: &[f32], bits: u32) -> (Vec<i32>, f32) {
    let levels = (1i32 << (bits - 1)) - 1;
    let s = weight_scale(w);
    let q = w
        .iter()
        .map(|&v| {
            let t = (v / s).clamp(-1.0, 1.0) * levels as f32;
            t.round() as i32
        })
        .collect();
    (q, s)
}

/// Dequantise weight levels.
pub fn dequant_weight(q: &[i32], scale: f32, bits: u32) -> Vec<f32> {
    let levels = ((1i32 << (bits - 1)) - 1) as f32;
    q.iter().map(|&v| v as f32 / levels * scale).collect()
}

/// Unsigned activation quantisation to `bits` with a dynamic per-tensor
/// scale. Returns (integer levels, scale): `x ≈ level * scale`.
pub fn quant_act(x: &[f32], bits: u32) -> (Vec<u32>, f32) {
    let mut q = Vec::new();
    let s = quant_act_into(x, bits, &mut q);
    (q, s)
}

/// Allocation-free variant of [`quant_act`]: writes the levels into `out`
/// (cleared and refilled, capacity reused) and returns the scale.  This is
/// the hot-path entry used by the crossbar MAC scratch.
pub fn quant_act_into(x: &[f32], bits: u32, out: &mut Vec<u32>) -> f32 {
    let n = ((1u32 << bits) - 1) as f32;
    let max = x.iter().fold(0.0f32, |m, &v| m.max(v)).max(1e-6);
    let s = max / n;
    out.clear();
    out.extend(x.iter().map(|&v| ((v / s).round().clamp(0.0, n)) as u32));
    s
}

/// Bit-plane decomposition of one activation level (LSB first).
#[inline]
pub fn bit_plane(level: u32, p: u32) -> u32 {
    (level >> p) & 1
}

/// Number of set bit-planes — the decomposed-mode read count (eq. 19).
#[inline]
pub fn popcount(level: u32) -> u32 {
    level.count_ones()
}

/// Plane-major bit-plane decomposition of a whole level vector:
/// `out[p * levels.len() + r] = bit_plane(levels[r], p)` for `p` in
/// `0..act_bits` (LSB first, matching the decomposed read order).
///
/// `out` is cleared and refilled (capacity reused).  The crossbar MAC
/// derives this once per read into its scratch, so decomposed mode reads
/// each plane as one contiguous slice instead of re-deriving
/// [`bit_plane`] per tile per plane.
pub fn bit_planes_into(levels: &[u32], act_bits: u32, out: &mut Vec<u32>) {
    out.clear();
    out.reserve(act_bits as usize * levels.len());
    for p in 0..act_bits {
        out.extend(levels.iter().map(|&l| bit_plane(l, p)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randvec(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn weight_roundtrip_error_bounded() {
        for bits in [2u32, 4, 6, 8] {
            let w = randvec(bits as u64, 512);
            let (q, s) = quant_weight(&w, bits);
            let deq = dequant_weight(&q, s, bits);
            let step = s / ((1i32 << (bits - 1)) - 1) as f32;
            for (a, b) in w.iter().zip(deq.iter()) {
                assert!((a - b).abs() <= step / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn weight_levels_in_range() {
        let w = randvec(1, 256);
        let (q, _) = quant_weight(&w, 8);
        assert!(q.iter().all(|&v| (-127..=127).contains(&v)));
    }

    #[test]
    fn act_levels_in_range() {
        let x: Vec<f32> = randvec(2, 256).iter().map(|v| v.abs()).collect();
        let (q, s) = quant_act(&x, 4);
        assert!(q.iter().all(|&v| v <= 15));
        for (lv, orig) in q.iter().zip(x.iter()) {
            assert!((*lv as f32 * s - orig).abs() <= s / 2.0 + 1e-6);
        }
    }

    #[test]
    fn act_scale_hits_full_range() {
        let x = vec![0.0, 0.25, 0.5, 1.0];
        let (q, _) = quant_act(&x, 2);
        assert_eq!(q, vec![0, 1, 2, 3]);
    }

    #[test]
    fn quant_act_into_matches_and_reuses_capacity() {
        let x: Vec<f32> = randvec(9, 128).iter().map(|v| v.abs()).collect();
        let (q, s) = quant_act(&x, 5);
        let mut buf = Vec::new();
        let s2 = quant_act_into(&x, 5, &mut buf);
        assert_eq!(q, buf);
        assert_eq!(s, s2);
        let cap = buf.capacity();
        quant_act_into(&x, 5, &mut buf);
        assert_eq!(buf.capacity(), cap, "no realloc on reuse");
    }

    #[test]
    fn bit_planes_recompose() {
        for level in 0u32..16 {
            let recomposed: u32 = (0..4).map(|p| bit_plane(level, p) << p).sum();
            assert_eq!(recomposed, level);
        }
    }

    #[test]
    fn bit_planes_into_plane_major_and_reuses_capacity() {
        let levels = vec![0u32, 1, 2, 3, 21, 30, 31];
        let bits = 5u32;
        let mut planes = Vec::new();
        bit_planes_into(&levels, bits, &mut planes);
        assert_eq!(planes.len(), bits as usize * levels.len());
        for p in 0..bits {
            for (r, &l) in levels.iter().enumerate() {
                assert_eq!(
                    planes[p as usize * levels.len() + r],
                    bit_plane(l, p),
                    "plane {p} row {r}"
                );
            }
        }
        // planes recompose the levels
        for (r, &l) in levels.iter().enumerate() {
            let re: u32 = (0..bits)
                .map(|p| planes[p as usize * levels.len() + r] << p)
                .sum();
            assert_eq!(re, l);
        }
        let cap = planes.capacity();
        bit_planes_into(&levels, bits, &mut planes);
        assert_eq!(planes.capacity(), cap, "no realloc on reuse");
    }

    #[test]
    fn popcount_le_level() {
        for level in 0u32..256 {
            assert!(popcount(level) <= level.max(1));
        }
    }

    #[test]
    fn degenerate_all_zero() {
        let w = vec![0.0f32; 16];
        let (q, s) = quant_weight(&w, 8);
        assert!(q.iter().all(|&v| v == 0));
        assert!(s > 0.0);
        let (qa, sa) = quant_act(&w, 4);
        assert!(qa.iter().all(|&v| v == 0));
        assert!(sa > 0.0);
    }
}
