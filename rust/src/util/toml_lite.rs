//! Minimal TOML subset parser (offline substitute for the `toml` crate).
//!
//! Supports what `ExperimentConfig` needs: `[section]` headers,
//! `key = "string"`, `key = 123`, `key = 1.5`, `key = true`, comments (#).

use std::collections::BTreeMap;

use crate::Result;

/// A flat TOML document: (section -> key -> raw value).  Top-level keys
/// live in the "" section.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim().to_string();
            let mut value = value.trim().to_string();
            if value.starts_with('"') && value.ends_with('"') && value.len() >= 2 {
                value = value[1..value.len() - 1].to_string();
            }
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(
        &self,
        section: &str,
        key: &str,
        default: T,
    ) -> Result<T> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for {section}.{key}: {v:?}")),
        }
    }

    pub fn set(&mut self, section: &str, key: &str, value: impl Into<String>) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value.into());
    }

    /// Render back to TOML text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        if let Some(top) = self.sections.get("") {
            for (k, v) in top {
                out.push_str(&render_kv(k, v));
            }
        }
        for (name, kv) in &self.sections {
            if name.is_empty() {
                continue;
            }
            out.push_str(&format!("\n[{name}]\n"));
            for (k, v) in kv {
                out.push_str(&render_kv(k, v));
            }
        }
        out
    }
}

fn render_kv(k: &str, v: &str) -> String {
    let quoted = v.parse::<f64>().is_err() && v != "true" && v != "false";
    if quoted {
        format!("{k} = \"{v}\"\n")
    } else {
        format!("{k} = {v}\n")
    }
}

fn strip_comment(line: &str) -> &str {
    // only strip # outside quotes (good enough for our configs)
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment
model = "tiny_resnet_10"
solution = "ab"

[train]
finetune_steps = 120   # steps
lam = 0.3
verbose = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("", "model"), Some("tiny_resnet_10"));
        assert_eq!(doc.parse_or("train", "finetune_steps", 0u32).unwrap(), 120);
        assert_eq!(doc.parse_or("train", "lam", 0.0f32).unwrap(), 0.3);
        assert_eq!(doc.parse_or("train", "verbose", false).unwrap(), true);
        assert_eq!(doc.parse_or("train", "missing", 7u32).unwrap(), 7);
    }

    #[test]
    fn roundtrip() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        let doc2 = TomlDoc::parse(&doc.render()).unwrap();
        assert_eq!(doc, doc2);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("keynovalue").is_err());
    }

    #[test]
    fn comments_inside_strings_kept() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "k"), Some("a#b"));
    }
}
