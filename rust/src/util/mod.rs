//! Small dependency-free utilities (offline substitutes; Cargo.toml note).

pub mod bench;
pub mod cli;
pub mod json;
pub mod toml_lite;
