//! Tiny `--flag value` argument parser (offline substitute for clap).

use std::collections::BTreeMap;

use crate::Result;

/// Parsed command line: a subcommand plus `--key value` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).  The first bare token
    /// is the subcommand; `--key value` pairs become flags; a trailing
    /// `--key` with no value is a boolean flag.
    pub fn parse() -> Result<Args> {
        Self::from_vec(std::env::args().skip(1).collect())
    }

    pub fn from_vec(tokens: Vec<String>) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(key) = t.strip_prefix("--") {
                if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.flags.insert(key.to_string(), tokens[i + 1].clone());
                    i += 2;
                } else {
                    out.bools.push(key.to_string());
                    i += 1;
                }
            } else {
                anyhow::ensure!(
                    out.command.is_none(),
                    "unexpected positional argument {t:?}"
                );
                out.command = Some(t.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v:?}")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = Args::from_vec(v(&["train", "--model", "mlp_10", "--steps", "50", "--fast"]))
            .unwrap();
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("mlp_10"));
        assert_eq!(a.parse_or("steps", 0u32).unwrap(), 50);
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
    }

    #[test]
    fn defaults() {
        let a = Args::from_vec(v(&["run"])).unwrap();
        assert_eq!(a.str_or("model", "tiny_resnet_10"), "tiny_resnet_10");
        assert_eq!(a.parse_or("lam", 0.3f32).unwrap(), 0.3);
    }

    #[test]
    fn rejects_two_positionals() {
        assert!(Args::from_vec(v(&["a", "b"])).is_err());
    }

    #[test]
    fn bad_value_errors() {
        let a = Args::from_vec(v(&["x", "--n", "abc"])).unwrap();
        assert!(a.parse_or("n", 1u32).is_err());
    }
}
