//! Micro-benchmark harness (offline substitute for criterion).
//!
//! Warmup + timed iterations with mean / stddev / min reporting, plus a
//! `Samples`-style throughput helper.  The `cargo bench` targets use this
//! to print both the paper-table reproductions and the hot-path timings.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Copy, Debug)]
pub struct BenchResult {
    pub iters: u32,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// items/s given `items` processed per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.mean_ns * 1e-9)
    }
}

/// Time `f` over `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
    let min = times.iter().cloned().fold(f64::MAX, f64::min);
    BenchResult {
        iters,
        mean_ns: mean,
        std_ns: var.sqrt(),
        min_ns: min,
    }
}

/// Run + pretty-print one named benchmark.
pub fn report<F: FnMut()>(name: &str, warmup: u32, iters: u32, f: F) -> BenchResult {
    let r = bench(warmup, iters, f);
    let (val, unit) = if r.mean_ns > 1e9 {
        (r.mean_ns / 1e9, "s")
    } else if r.mean_ns > 1e6 {
        (r.mean_ns / 1e6, "ms")
    } else if r.mean_ns > 1e3 {
        (r.mean_ns / 1e3, "us")
    } else {
        (r.mean_ns, "ns")
    };
    println!(
        "bench {name:<44} {val:>9.2} {unit}/iter  (+/- {:.1}%, n={})",
        100.0 * r.std_ns / r.mean_ns.max(1.0),
        r.iters
    );
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut acc = 0u64;
        let r = bench(2, 10, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns);
        assert_eq!(r.iters, 10);
        std::hint::black_box(acc);
    }

    #[test]
    fn throughput_sane() {
        let r = BenchResult {
            iters: 1,
            mean_ns: 1e9,
            std_ns: 0.0,
            min_ns: 1e9,
        };
        assert!((r.throughput(100.0) - 100.0).abs() < 1e-9);
    }
}
