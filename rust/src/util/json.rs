//! Minimal JSON parser + writer (offline substitute for serde_json; see
//! Cargo.toml note).  Covers the full JSON grammar needed by
//! `artifacts/manifest.json` and the result files: objects, arrays,
//! strings (with escapes), f64 numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::Result;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing JSON at byte {}", p.i);
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing key {key:?}")),
            _ => anyhow::bail!("not an object (want key {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => anyhow::bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_f64()? as u64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => anyhow::bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => anyhow::bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => anyhow::bail!("not an object"),
        }
    }

    /// Numeric array as `Vec<f32>` (image payloads on the serving API).
    pub fn as_f32s(&self) -> Result<Vec<f32>> {
        let a = self.as_arr()?;
        let mut out = Vec::with_capacity(a.len());
        for v in a {
            out.push(v.as_f64()? as f32);
        }
        Ok(out)
    }

    // -- builders ------------------------------------------------------------

    /// Object from key/value pairs (response-building sugar).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Numeric array from an `f32` slice (logits on the serving API).
    pub fn f32_arr(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect())
    }

    // -- writer --------------------------------------------------------------

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        anyhow::ensure!(
            self.peek()? == c,
            "expected {:?} at byte {}, got {:?}",
            c as char,
            self.i,
            self.peek()? as char
        );
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected , or }} at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => anyhow::bail!("expected , or ] at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "short \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => anyhow::bail!("bad escape \\{}", other as char),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        anyhow::ensure!(start + len <= self.b.len(), "truncated UTF-8");
                        s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shapes() {
        let j = Json::parse(
            r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#,
        )
        .unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2],
            Json::Num(-300.0)
        );
        assert_eq!(j.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(j.get("b").unwrap().get("d").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2],"s":"he\"llo","n":1.5,"t":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.render()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\": 1} trailing").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn missing_key_error() {
        let j = Json::parse("{\"a\": 1}").unwrap();
        assert!(j.get("b").is_err());
        assert!(j.opt("b").is_none());
    }

    #[test]
    fn f32_helpers_roundtrip() {
        let xs = [1.5f32, -2.0, 0.25];
        let j = Json::f32_arr(&xs);
        let back = j.as_f32s().unwrap();
        assert_eq!(back, xs.to_vec());
        // non-numeric element errors
        assert!(Json::parse("[1, \"x\"]").unwrap().as_f32s().is_err());
        assert!(Json::parse("{}").unwrap().as_f32s().is_err());
    }

    #[test]
    fn obj_builder() {
        let j = Json::obj(vec![
            ("class", Json::Num(3.0)),
            ("tier", Json::Str("low".into())),
        ]);
        assert_eq!(j.get("class").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("tier").unwrap().as_str().unwrap(), "low");
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back, j);
    }
}
