//! `emtopt` CLI — the coordinator leader entrypoint.
//!
//! Commands:
//!   info      artifact + model inventory
//!   train     train one (model, solution) and cache it under runs/cache
//!   sweep     accuracy-vs-energy curve (Fig 9 primitive)
//!   compare   ours-vs-SOTA at max accuracy (Fig 10/11 primitive)
//!   serve     run the dynamic-batching inference router demo
//!
//! Flags: --model KEY --solution trad|a|ab|abc --intensity weak|normal|strong
//!        --pretrain N --finetune N --lam F --seed N --artifacts DIR
//!        --config FILE (TOML; flags override)

use emtopt::baselines::Method;
use emtopt::config::ExperimentConfig;
use emtopt::coordinator::{self, store, Solution, TrainConfig};
use emtopt::data::Suite;
use emtopt::device::Intensity;
use emtopt::energy::EnergyModel;
use emtopt::metrics::{fmt_cells, fmt_delay_us, fmt_energy_uj, fmt_pct, Table};
use emtopt::runtime::{Artifacts, Evaluator};
use emtopt::timing::TimingModel;
use emtopt::util::cli::Args;
use emtopt::Result;

const USAGE: &str = "\
emtopt — in-memory deep learning with EMT (Wang et al., 2021)

USAGE: emtopt <command> [--flags]

COMMANDS:
  info      artifact + model inventory
  train     train one (model, solution); cached under runs/cache
  sweep     accuracy-vs-energy curve of a solution (Fig 9 primitive)
  compare   ours vs SOTA at max accuracy (Fig 10/11 primitive)
  serve     dynamic-batching inference router demo

FLAGS (defaults in parentheses):
  --artifacts DIR     (artifacts)
  --config FILE       TOML config; flags override
  --model KEY         (tiny_resnet_10)
  --solution S        trad|a|ab|abc (ab)
  --intensity I       weak|normal|strong (normal)
  --pretrain N        (120)   --finetune N (120)
  --lam F             (0.3)   --seed N (7)
  --requests N        serve: request count (256)
";

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:?}");
            1
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    // flags override config
    cfg.artifacts = args.str_or("artifacts", &cfg.artifacts);
    cfg.model = args.str_or("model", &cfg.model);
    cfg.solution = args.str_or("solution", &cfg.solution);
    cfg.intensity = args.str_or("intensity", &cfg.intensity);
    cfg.train.pretrain_steps = args.parse_or("pretrain", cfg.train.pretrain_steps)?;
    cfg.train.finetune_steps = args.parse_or("finetune", cfg.train.finetune_steps)?;
    cfg.train.lam = args.parse_or("lam", cfg.train.lam)?;
    cfg.train.seed = args.parse_or("seed", cfg.train.seed)?;

    match args.command.as_deref() {
        Some("info") => info(&cfg),
        Some("train") => train(&cfg),
        Some("sweep") => sweep(&cfg),
        Some("compare") => compare(&cfg),
        Some("serve") => serve(&cfg, args.parse_or("requests", 256u32)?),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn info(cfg: &ExperimentConfig) -> Result<()> {
    let arts = Artifacts::open(&cfg.artifacts)?;
    println!("platform: {}", arts.runtime.platform());
    println!(
        "device: {} RTN states, B_a={}, B_w={}",
        arts.manifest.device.num_states,
        arts.manifest.device.act_bits,
        arts.manifest.device.weight_bits
    );
    let mut t = Table::new("Models", &["key", "layers", "cells", "reads/inf"]);
    for key in arts.manifest.model_keys() {
        let m = arts.model(&key)?;
        let cells: u64 = m.layer_meta.iter().map(|l| l.cells).sum();
        let reads: u64 = m.layer_meta.iter().map(|l| l.reads()).sum();
        t.row(vec![
            key.clone(),
            m.n_layers.to_string(),
            fmt_cells(cells as f64),
            format!("{:.1}M", reads as f64 / 1e6),
        ]);
    }
    t.print();
    println!("{} artifacts in {}", arts.manifest.artifacts.len(), cfg.artifacts);
    Ok(())
}

fn train(cfg: &ExperimentConfig) -> Result<()> {
    let arts = Artifacts::open(&cfg.artifacts)?;
    let sol = cfg.solution_parsed()?;
    let inten = cfg.intensity_parsed()?;
    let mut tc = cfg.train_config()?;
    tc.log_every = 20;
    let trained = coordinator::train_solution(&arts, &cfg.model, cfg.suite(), sol, &tc)?;
    let path = store::cache_path(
        &cfg.model,
        sol,
        inten.name(),
        tc.pretrain_steps,
        tc.finetune_steps,
    );
    store::save(&trained, &path)?;
    println!(
        "trained {} [{}]: rho = {:?}",
        cfg.model,
        sol.name(),
        trained
            .rho()
            .iter()
            .map(|r| (r * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );
    println!("saved to {}", path.display());
    Ok(())
}

fn sweep(cfg: &ExperimentConfig) -> Result<()> {
    let arts = Artifacts::open(&cfg.artifacts)?;
    let sol = cfg.solution_parsed()?;
    let inten = cfg.intensity_parsed()?;
    let tc = cfg.train_config()?;
    let trained = store::train_cached(&arts, &cfg.model, cfg.suite(), sol, &tc)?;
    let evaluator = Evaluator::new(&arts, &cfg.model, sol.decomposed())?;
    let setup = coordinator::EvalSetup {
        suite: cfg.suite(),
        intensity: inten,
        batches: cfg.eval.batches,
        seed: cfg.eval.seed,
    };
    let paper = coordinator::experiments::paper_model_for(&cfg.model)
        .ok_or_else(|| anyhow::anyhow!("no paper-scale mapping for {}", cfg.model))?;
    let em = EnergyModel::new(arts.manifest.device.act_bits);
    let points = coordinator::sweep_accuracy_vs_energy(
        &evaluator,
        &trained,
        &setup,
        &paper,
        sol.method(),
        &em,
        &coordinator::experiments::default_rho_grid(),
    )?;
    let mut t = Table::new(
        format!("{} [{}] accuracy vs energy", cfg.model, sol.name()),
        &["rho-scale", "mean rho", "energy (uJ)", "top-1", "top-5"],
    );
    for p in points {
        t.row(vec![
            format!("{:.3}", p.rho_scale),
            format!("{:.2}", p.mean_rho),
            fmt_energy_uj(p.energy_uj),
            fmt_pct(p.top1),
            fmt_pct(p.top5),
        ]);
    }
    t.print();
    Ok(())
}

fn compare(cfg: &ExperimentConfig) -> Result<()> {
    let arts = Artifacts::open(&cfg.artifacts)?;
    let inten = cfg.intensity_parsed()?;
    let tc = cfg.train_config()?;
    let suite = cfg.suite();
    let em = EnergyModel::new(arts.manifest.device.act_bits);
    let tm = TimingModel::new(arts.manifest.device.act_bits);
    let paper = coordinator::experiments::paper_model_for(&cfg.model)
        .ok_or_else(|| anyhow::anyhow!("no paper-scale mapping for {}", cfg.model))?;
    let setup = coordinator::EvalSetup {
        suite,
        intensity: inten,
        batches: cfg.eval.batches,
        seed: cfg.eval.seed,
    };
    let grid = coordinator::experiments::default_rho_grid();

    let mut t = Table::new(
        format!("{} @ {}: energy at max accuracy", cfg.model, cfg.intensity),
        &["method", "top-1", "energy (uJ)", "cells", "delay (us)"],
    );
    let methods = [
        (Method::BinarizedEncoding, Solution::Traditional),
        (Method::WeightScaling, Solution::Traditional),
        (Method::FluctuationCompensation, Solution::Traditional),
        (Method::OursAB, Solution::AB),
        (Method::OursABC, Solution::ABC),
    ];
    for (method, sol) in methods {
        let trained = store::train_cached(&arts, &cfg.model, suite, sol, &tc)?;
        let evaluator = Evaluator::new(&arts, &cfg.model, sol.decomposed())?;
        let pts = coordinator::sweep_accuracy_vs_energy(
            &evaluator, &trained, &setup, &paper, method, &em, &grid,
        )?;
        if let Some(best) = coordinator::experiments::best_accuracy_point(&pts) {
            let cost = emtopt::baselines::hardware_cost(
                method,
                &paper,
                best.mean_rho,
                inten.factor() as f64,
                &em,
                &tm,
            );
            t.row(vec![
                method.name().into(),
                fmt_pct(best.top1),
                fmt_energy_uj(best.energy_uj),
                fmt_cells(cost.cells),
                fmt_delay_us(cost.delay_us),
            ]);
        }
    }
    t.print();
    Ok(())
}

fn serve(cfg: &ExperimentConfig, requests: u32) -> Result<()> {
    let suite = cfg.suite();
    let trained = {
        let arts = Artifacts::open(&cfg.artifacts)?;
        let tc = cfg.train_config()?;
        store::train_cached(&arts, &cfg.model, suite, Solution::AB, &tc)?
    };
    let server_cfg = coordinator::router::ServerConfig {
        artifacts_dir: cfg.artifacts.clone(),
        intensity: cfg.intensity_parsed()?,
        ..Default::default()
    };
    let (client, stats, handle) = coordinator::router::serve(trained, server_cfg)?;

    let dataset = emtopt::data::Dataset::new(suite, 42);
    let t0 = std::time::Instant::now();
    let workers = 8usize;
    let per = requests as usize / workers;
    let oks: Vec<std::thread::JoinHandle<u32>> = (0..workers)
        .map(|w| {
            let c = client.clone();
            let d = dataset.clone();
            std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..per {
                    let (x, _) =
                        d.batch(emtopt::data::Split::Test, (w * per + i) as u64, 1);
                    if c.infer(x).is_ok() {
                        ok += 1;
                    }
                }
                ok
            })
        })
        .collect();
    let ok: u32 = oks.into_iter().map(|h| h.join().unwrap_or(0)).sum();
    let dt = t0.elapsed();
    println!(
        "{ok}/{requests} ok in {:.2}s  ({:.0} req/s, mean queue {:.1} ms, batch fill {:.0}%)",
        dt.as_secs_f64(),
        requests as f64 / dt.as_secs_f64(),
        stats.mean_queue_us() / 1000.0,
        stats.mean_batch_fill(16) * 100.0,
    );
    drop(client);
    handle.join().ok();
    Ok(())
}

// Intensity is referenced in type signatures above; keep the import honest.
#[allow(dead_code)]
fn _unused(_: Intensity, _: Suite, _: TrainConfig) {}
