//! `emtopt` CLI — the coordinator leader entrypoint.
//!
//! Commands:
//!   info        artifact + model inventory                  [--features aot]
//!   train       train one (model, solution), cache it       [--features aot]
//!   sweep       accuracy-vs-energy curve (Fig 9 primitive)  [--features aot]
//!   compare     ours-vs-SOTA at max accuracy (Fig 10/11)    [--features aot]
//!   serve       in-process router demo over the NATIVE crossbar engine
//!   serve-http  HTTP/1.1 front end over the native engine (energy tiers)
//!   loadgen     open-loop load generator against a running serve-http
//!
//! The native serving commands run entirely on the device substrate (no
//! XLA needed): a nearest-template classifier is programmed onto crossbar
//! arrays and served by per-tier worker pools sharing one immutable model.
//!
//! Flags: --model KEY --solution trad|a|ab|abc --intensity weak|normal|strong
//!        --pretrain N --finetune N --lam F --seed N --artifacts DIR
//!        --config FILE (TOML; flags override)

use std::sync::Arc;

use emtopt::config::ExperimentConfig;
use emtopt::coordinator::router::{serve_native, NativeServerConfig};
use emtopt::data::{Dataset, Split};
use emtopt::device::DeviceConfig;
use emtopt::server::loadgen::{self, LadderConfig, LoadgenConfig};
use emtopt::server::{parse_tier_arg, serve_http, HttpServerConfig};
use emtopt::util::cli::Args;
use emtopt::Result;

#[cfg(feature = "aot")]
use emtopt::baselines::Method;
#[cfg(feature = "aot")]
use emtopt::coordinator::{self, store, Solution};
#[cfg(feature = "aot")]
use emtopt::energy::EnergyModel;
#[cfg(feature = "aot")]
use emtopt::metrics::{fmt_cells, fmt_delay_us, fmt_energy_uj, fmt_pct, Table};
#[cfg(feature = "aot")]
use emtopt::runtime::{Artifacts, Evaluator};
#[cfg(feature = "aot")]
use emtopt::timing::TimingModel;

const USAGE: &str = "\
emtopt — in-memory deep learning with EMT (Wang et al., 2021)

USAGE: emtopt <command> [--flags]

COMMANDS:
  info        artifact + model inventory                  [needs --features aot]
  train       train one (model, solution); cached         [needs --features aot]
  sweep       accuracy-vs-energy curve (Fig 9 primitive)  [needs --features aot]
  compare     ours vs SOTA at max accuracy (Fig 10/11)    [needs --features aot]
  serve       in-process router demo over the native crossbar engine
  serve-http  HTTP/1.1 front end over the native engine (tiered energy lanes)
  loadgen     open-loop load generator against a running serve-http

FLAGS (defaults in parentheses):
  --artifacts DIR     (artifacts)
  --config FILE       TOML config; flags override
  --model KEY         (tiny_resnet_10)
  --solution S        trad|a|ab|abc (ab)
  --intensity I       weak|normal|strong (normal)
  --pretrain N        (120)   --finetune N (120)
  --lam F             (0.3)   --seed N (7)
  --requests N        serve: request count (256); loadgen: total requests (1000)
  --workers N         serve/serve-http: workers in the shared engine pool (2)
  --host H            serve-http: bind host (127.0.0.1)
  --port N            serve-http: bind port, 0 = ephemeral (8080)
  --duration S        serve-http: run seconds, 0 = until POST /admin/shutdown (0)
  --batch N           serve-http: device batch size (16); loadgen: images
                      per request body, >1 sends {\"images\": ...} (1)
  --queue-depth N     serve-http: bounded request queue per lane (256)
  --max-client-batch N serve-http: images accepted per request, 413 above (64)
  --max-body-mb N     serve-http: request body cap in MiB, 413 above (8)
  --max-conns N       serve-http: global open-connection cap, typed 503 +
                      Retry-After above it (10000)
  --no-alloc-pool     serve-http: disable the serve-path buffer pool
                      (fresh allocation per request — the byte-identity
                      reference path; pooled is the default)
  --max-conns-per-peer N serve-http: simultaneous connections per peer IP,
                      429 above (64)
  --cache-entries N   serve-http: exact result cache capacity in entries;
                      0 disables the cache entirely (0)
  --cache-mb N        serve-http: exact result cache payload cap in MiB;
                      0 disables the cache (64 — so --cache-entries N
                      alone arms it)
  --model-store FILE  serve-http: stored model (.emtm) whose trained
                      per-layer rho shapes the tier energy plans
                      (plan source \"trained\"; analytic otherwise)
  --energy-budget-uj-s F serve-http: fleet energy budget in uJ/s — over
                      it, low tiers shed with 503 + Retry-After (off)
  --rebalance-ms N    serve-http: scheduler rebalance interval, 0
                      disables the loop (50)
  --addr A            loadgen: target server (127.0.0.1:8080)
  --connections N     loadgen: concurrent keep-alive connections (8)
  --event-loop        loadgen: drive all connections from one epoll
                      event loop (C10K client: thousands of connections
                      without thousands of threads)
  --qps F             loadgen: aggregate target rate, 0 = closed loop (0)
  --key-reuse SPEC    loadgen: zipf:S,N — draw request images from N
                      distinct contents under a Zipf(S) popularity law
                      (deterministic), so a server-side result cache
                      sees repeats; the report gains a \"cache\" block
                      (hit_ratio, saved_uj, hit/miss p50) (off)
  --tier T            loadgen: low|normal|high|mixed (normal)
  --endpoint E        loadgen: classify|infer (classify)
  --blocking          loadgen: send \"blocking\": true on every request,
                      driving the server's backpressure infer path (wait
                      for queue space) instead of load-shedding 503s —
                      compare the two tails in BENCH_serve.json
  --ladder            loadgen: sweep a qps ladder (0.25x..2x measured
                      capacity) per tier and record the full curve
  --ladder-points N   loadgen: rungs on the ladder (5)
  --batch-sweep LIST  loadgen: with --ladder, sweep these images-per-
                      request sizes per tier (e.g. 1,4,16) to map the
                      batch-amortisation surface
  --calib-requests N  loadgen: closed-loop calibration requests (= --requests)
  --trace-sample N    loadgen: mark every Nth request \"trace\": true and
                      summarize the echoed span breakdowns (0 = off)
  --out FILE          loadgen: report path (BENCH_serve.json)
";

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:?}");
            1
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    // flags override config
    cfg.artifacts = args.str_or("artifacts", &cfg.artifacts);
    cfg.model = args.str_or("model", &cfg.model);
    cfg.solution = args.str_or("solution", &cfg.solution);
    cfg.intensity = args.str_or("intensity", &cfg.intensity);
    cfg.train.pretrain_steps = args.parse_or("pretrain", cfg.train.pretrain_steps)?;
    cfg.train.finetune_steps = args.parse_or("finetune", cfg.train.finetune_steps)?;
    cfg.train.lam = args.parse_or("lam", cfg.train.lam)?;
    cfg.train.seed = args.parse_or("seed", cfg.train.seed)?;

    match args.command.as_deref() {
        Some("info") => info(&cfg),
        Some("train") => train(&cfg),
        Some("sweep") => sweep(&cfg),
        Some("compare") => compare(&cfg),
        Some("serve") => serve(
            &cfg,
            args.parse_or("requests", 256u32)?,
            args.parse_or("workers", 2usize)?,
        ),
        Some("serve-http") => serve_http_cmd(&cfg, &args),
        Some("loadgen") => loadgen_cmd(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

#[cfg(not(feature = "aot"))]
fn aot_missing(cmd: &str) -> Result<()> {
    anyhow::bail!(
        "`{cmd}` drives the PJRT/XLA artifact runtime, which is not compiled \
         in; rebuild with `cargo build --release --features aot` (see \
         rust/Cargo.toml for the xla dependency note)"
    )
}

#[cfg(not(feature = "aot"))]
fn info(_cfg: &ExperimentConfig) -> Result<()> {
    aot_missing("info")
}

#[cfg(not(feature = "aot"))]
fn train(_cfg: &ExperimentConfig) -> Result<()> {
    aot_missing("train")
}

#[cfg(not(feature = "aot"))]
fn sweep(_cfg: &ExperimentConfig) -> Result<()> {
    aot_missing("sweep")
}

#[cfg(not(feature = "aot"))]
fn compare(_cfg: &ExperimentConfig) -> Result<()> {
    aot_missing("compare")
}

#[cfg(feature = "aot")]
fn info(cfg: &ExperimentConfig) -> Result<()> {
    let arts = Artifacts::open(&cfg.artifacts)?;
    println!("platform: {}", arts.runtime.platform());
    println!(
        "device: {} RTN states, B_a={}, B_w={}",
        arts.manifest.device.num_states,
        arts.manifest.device.act_bits,
        arts.manifest.device.weight_bits
    );
    let mut t = Table::new("Models", &["key", "layers", "cells", "reads/inf"]);
    for key in arts.manifest.model_keys() {
        let m = arts.model(&key)?;
        let cells: u64 = m.layer_meta.iter().map(|l| l.cells).sum();
        let reads: u64 = m.layer_meta.iter().map(|l| l.reads()).sum();
        t.row(vec![
            key.clone(),
            m.n_layers.to_string(),
            fmt_cells(cells as f64),
            format!("{:.1}M", reads as f64 / 1e6),
        ]);
    }
    t.print();
    println!("{} artifacts in {}", arts.manifest.artifacts.len(), cfg.artifacts);
    Ok(())
}

#[cfg(feature = "aot")]
fn train(cfg: &ExperimentConfig) -> Result<()> {
    let arts = Artifacts::open(&cfg.artifacts)?;
    let sol = cfg.solution_parsed()?;
    let inten = cfg.intensity_parsed()?;
    let mut tc = cfg.train_config()?;
    tc.log_every = 20;
    let trained = coordinator::train_solution(&arts, &cfg.model, cfg.suite(), sol, &tc)?;
    let path = store::cache_path(
        &cfg.model,
        sol,
        inten.name(),
        tc.pretrain_steps,
        tc.finetune_steps,
    );
    store::save(&trained, &path)?;
    println!(
        "trained {} [{}]: rho = {:?}",
        cfg.model,
        sol.name(),
        trained
            .rho()
            .iter()
            .map(|r| (r * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );
    println!("saved to {}", path.display());
    Ok(())
}

#[cfg(feature = "aot")]
fn sweep(cfg: &ExperimentConfig) -> Result<()> {
    let arts = Artifacts::open(&cfg.artifacts)?;
    let sol = cfg.solution_parsed()?;
    let inten = cfg.intensity_parsed()?;
    let tc = cfg.train_config()?;
    let trained = store::train_cached(&arts, &cfg.model, cfg.suite(), sol, &tc)?;
    let evaluator = Evaluator::new(&arts, &cfg.model, sol.decomposed())?;
    let setup = coordinator::EvalSetup {
        suite: cfg.suite(),
        intensity: inten,
        batches: cfg.eval.batches,
        seed: cfg.eval.seed,
    };
    let paper = coordinator::experiments::paper_model_for(&cfg.model)
        .ok_or_else(|| anyhow::anyhow!("no paper-scale mapping for {}", cfg.model))?;
    let em = EnergyModel::new(arts.manifest.device.act_bits);
    let points = coordinator::sweep_accuracy_vs_energy(
        &evaluator,
        &trained,
        &setup,
        &paper,
        sol.method(),
        &em,
        &coordinator::experiments::default_rho_grid(),
    )?;
    let mut t = Table::new(
        format!("{} [{}] accuracy vs energy", cfg.model, sol.name()),
        &["rho-scale", "mean rho", "energy (uJ)", "top-1", "top-5"],
    );
    for p in points {
        t.row(vec![
            format!("{:.3}", p.rho_scale),
            format!("{:.2}", p.mean_rho),
            fmt_energy_uj(p.energy_uj),
            fmt_pct(p.top1),
            fmt_pct(p.top5),
        ]);
    }
    t.print();
    Ok(())
}

#[cfg(feature = "aot")]
fn compare(cfg: &ExperimentConfig) -> Result<()> {
    let arts = Artifacts::open(&cfg.artifacts)?;
    let inten = cfg.intensity_parsed()?;
    let tc = cfg.train_config()?;
    let suite = cfg.suite();
    let em = EnergyModel::new(arts.manifest.device.act_bits);
    let tm = TimingModel::new(arts.manifest.device.act_bits);
    let paper = coordinator::experiments::paper_model_for(&cfg.model)
        .ok_or_else(|| anyhow::anyhow!("no paper-scale mapping for {}", cfg.model))?;
    let setup = coordinator::EvalSetup {
        suite,
        intensity: inten,
        batches: cfg.eval.batches,
        seed: cfg.eval.seed,
    };
    let grid = coordinator::experiments::default_rho_grid();

    let mut t = Table::new(
        format!("{} @ {}: energy at max accuracy", cfg.model, cfg.intensity),
        &["method", "top-1", "energy (uJ)", "cells", "delay (us)"],
    );
    let methods = [
        (Method::BinarizedEncoding, Solution::Traditional),
        (Method::WeightScaling, Solution::Traditional),
        (Method::FluctuationCompensation, Solution::Traditional),
        (Method::OursAB, Solution::AB),
        (Method::OursABC, Solution::ABC),
    ];
    for (method, sol) in methods {
        let trained = store::train_cached(&arts, &cfg.model, suite, sol, &tc)?;
        let evaluator = Evaluator::new(&arts, &cfg.model, sol.decomposed())?;
        let pts = coordinator::sweep_accuracy_vs_energy(
            &evaluator, &trained, &setup, &paper, method, &em, &grid,
        )?;
        if let Some(best) = coordinator::experiments::best_accuracy_point(&pts) {
            let cost = emtopt::baselines::hardware_cost(
                method,
                &paper,
                best.mean_rho,
                inten.factor() as f64,
                &em,
                &tm,
            );
            t.row(vec![
                method.name().into(),
                fmt_pct(best.top1),
                fmt_energy_uj(best.energy_uj),
                fmt_cells(cost.cells),
                fmt_delay_us(cost.delay_us),
            ]);
        }
    }
    t.print();
    Ok(())
}

/// Serve on the native engine: a nearest-template classifier programmed on
/// crossbar arrays, shared by a worker pool, hit by concurrent clients.
fn serve(cfg: &ExperimentConfig, requests: u32, workers: usize) -> Result<()> {
    let suite = cfg.suite();
    let sol = cfg.solution_parsed()?;
    let dev = DeviceConfig {
        intensity: cfg.intensity_parsed()?,
        ..DeviceConfig::default()
    };
    let dataset = Dataset::new(suite, emtopt::data::DATA_SEED);
    let model = Arc::new(emtopt::inference::template_classifier(&dataset, &dev)?);
    println!(
        "native engine: template classifier, {} cells, {} workers, read mode {:?}",
        model.num_cells(),
        workers,
        sol.read_mode()
    );
    let server_cfg = NativeServerConfig {
        workers,
        plan: Some(model.uniform_plan(sol.read_mode())),
        device: dev,
        ..Default::default()
    };
    let batch = server_cfg.batch;
    let (client, stats, engines) = serve_native(model.clone(), server_cfg)?;

    let t0 = std::time::Instant::now();
    let client_threads = 8usize;
    let per = (requests as usize).div_ceil(client_threads);
    let handles: Vec<_> = (0..client_threads)
        .map(|c| {
            let cl = client.clone();
            let ds = dataset.clone();
            std::thread::spawn(move || {
                let (mut ok, mut correct) = (0u32, 0u32);
                for i in 0..per {
                    let idx = (c * per + i) as u64;
                    let mut img = vec![0.0f32; emtopt::data::IMG_LEN];
                    let label = ds.sample_into(Split::Test, idx, &mut img);
                    if let Ok(pred) = cl.classify(img) {
                        ok += 1;
                        if pred == label as usize {
                            correct += 1;
                        }
                    }
                }
                (ok, correct)
            })
        })
        .collect();
    let (mut ok, mut correct) = (0u32, 0u32);
    for h in handles {
        let (o, c) = h.join().unwrap();
        ok += o;
        correct += c;
    }
    let dt = t0.elapsed();
    println!(
        "{ok}/{} ok in {:.2}s  ({:.0} req/s)",
        per * client_threads,
        dt.as_secs_f64(),
        ok as f64 / dt.as_secs_f64(),
    );
    println!(
        "accuracy {:.1}% | mean queue {:.2} ms | mean infer {:.2} ms/batch | \
         batch fill {:.0}% | {:.1} nJ/request",
        100.0 * correct as f64 / ok.max(1) as f64,
        stats.mean_queue_us() / 1000.0,
        stats.mean_infer_us() / 1000.0,
        stats.mean_batch_fill(batch) * 100.0,
        stats.mean_energy_pj_per_request() / 1000.0,
    );
    drop(client);
    for h in engines {
        h.join().ok();
    }
    Ok(())
}

/// Serve the native engine over HTTP: tiered energy lanes behind a
/// thread-per-connection HTTP/1.1 front end.  Runs for `--duration`
/// seconds, or until `POST /admin/shutdown`.
fn serve_http_cmd(cfg: &ExperimentConfig, args: &Args) -> Result<()> {
    let host = args.str_or("host", "127.0.0.1");
    let port: u16 = args.parse_or("port", 8080)?;
    let duration: u64 = args.parse_or("duration", 0)?;
    let dev = DeviceConfig {
        intensity: cfg.intensity_parsed()?,
        ..DeviceConfig::default()
    };
    let dataset = Dataset::new(cfg.suite(), emtopt::data::DATA_SEED);
    let model = Arc::new(emtopt::inference::template_classifier(&dataset, &dev)?);
    // trained per-layer rho (technique B) from a stored model: the tier
    // plans rescale it to each budget; analytic plans otherwise
    let trained_rho = match args.get("model-store") {
        Some(path) => {
            let rho = emtopt::server::load_trained_rho(std::path::Path::new(path))?;
            println!("model store {path}: trained rho {rho:?}");
            Some(rho)
        }
        None => None,
    };
    // fleet energy budget: arms the scheduler's governor (energy-SLO
    // admission control; low tiers shed with 503 when the rolling uJ/s
    // runs over)
    let energy_budget_uj_s = match args.get("energy-budget-uj-s") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("bad --energy-budget-uj-s {v:?}"))?,
        ),
        None => None,
    };
    let http_cfg = HttpServerConfig {
        addr: format!("{host}:{port}"),
        max_conns: args.parse_or("max-conns", 10_000usize)?,
        max_conns_per_peer: args.parse_or("max-conns-per-peer", 64usize)?,
        // exact result cache: off unless --cache-entries is set (the MiB
        // cap defaults on so one flag arms it; either knob at 0 disables)
        cache_entries: args.parse_or("cache-entries", 0usize)?,
        cache_bytes: args.parse_or("cache-mb", 64usize)? << 20,
        trained_rho,
        // batch bodies are big (a 64-image CIFAR batch is ~2 MiB of JSON),
        // so the body cap is a first-class knob
        max_body_bytes: args.parse_or("max-body-mb", 8usize)? << 20,
        engine: NativeServerConfig {
            batch: args.parse_or("batch", 16usize)?,
            workers: args.parse_or("workers", 2usize)?,
            queue_depth: args.parse_or("queue-depth", 256usize)?,
            max_client_batch: args.parse_or("max-client-batch", 64usize)?,
            rebalance_interval: std::time::Duration::from_millis(
                args.parse_or("rebalance-ms", 50u64)?,
            ),
            energy_budget_uj_s,
            alloc_pool: !args.has("no-alloc-pool"),
            device: dev,
            ..Default::default()
        },
        ..Default::default()
    };
    let handle = serve_http(model, http_cfg)?;
    println!("serving on http://{}", handle.addr());
    println!(
        "  POST /v1/infer | /v1/classify   GET /healthz | /metrics | /admin/trace   \
         POST /admin/shutdown"
    );
    for (plan, _) in handle.per_tier() {
        println!("  {}", plan.describe());
    }
    if let Some(b) = energy_budget_uj_s {
        println!("  energy governor armed: budget {b} uJ/s (low tiers shed over it)");
    }
    let t0 = std::time::Instant::now();
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
        if handle.shutdown_requested() {
            println!("shutdown requested via /admin/shutdown");
            break;
        }
        if duration > 0 && t0.elapsed().as_secs() >= duration {
            println!("--duration {duration}s elapsed");
            break;
        }
    }
    // final report before the graceful drain
    println!("http responses by status:");
    for (code, n) in handle.http_stats().by_code() {
        if n > 0 {
            println!("  {code}: {n}");
        }
    }
    print!("{}", handle.tier_summary());
    handle.shutdown()
}

/// Drive a running serve-http and write `BENCH_serve.json` — one
/// operating point by default, or a full per-tier latency–throughput
/// curve with `--ladder`.
fn loadgen_cmd(args: &Args) -> Result<()> {
    let endpoint = args.str_or("endpoint", "classify");
    anyhow::ensure!(
        endpoint == "classify" || endpoint == "infer",
        "bad --endpoint {endpoint:?} (want classify|infer)"
    );
    let lg = LoadgenConfig {
        addr: args.str_or("addr", "127.0.0.1:8080"),
        connections: args.parse_or("connections", 8usize)?,
        requests: args.parse_or("requests", 1000u64)?,
        target_qps: args.parse_or("qps", 0.0f64)?,
        tier: parse_tier_arg(&args.str_or("tier", "normal"))?,
        classify: endpoint == "classify",
        batch: args.parse_or("batch", 1usize)?,
        blocking: args.has("blocking"),
        trace_sample: args.parse_or("trace-sample", 0usize)?,
        event_loop: args.has("event-loop"),
        key_reuse: match args.get("key-reuse") {
            Some(spec) => Some(spec.parse().map_err(|e: String| anyhow::anyhow!(e))?),
            None => None,
        },
    };
    let out = args.str_or("out", "BENCH_serve.json");
    let batch_sweep: Vec<usize> = match args.get("batch-sweep") {
        Some(s) => s
            .split(',')
            .map(|t| {
                t.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad --batch-sweep entry {t:?}"))
            })
            .collect::<Result<Vec<_>>>()?,
        None => Vec::new(),
    };
    anyhow::ensure!(
        batch_sweep.is_empty() || args.has("ladder"),
        "--batch-sweep requires --ladder"
    );
    if args.has("ladder") {
        let points = args.parse_or("ladder-points", 5usize)?;
        let ladder = LadderConfig {
            base: lg,
            fractions: loadgen::ladder_fractions(points),
            calib_requests: args.parse_or("calib-requests", 0u64)?,
            batch_sweep,
        };
        let report = loadgen::run_ladder(&ladder)?;
        print!("{}", report.render());
        loadgen::write_bench_ladder(&report, &out)?;
    } else {
        let report = loadgen::run(&lg)?;
        println!("{}", report.render());
        loadgen::write_bench(&report, &out)?;
    }
    println!("wrote {out}");
    Ok(())
}
