//! Native noisy inference engine over the crossbar simulator.
//!
//! Runs fully-connected stacks directly on [`CrossbarArray`]s with
//! ReLU between layers — the device-level ground truth used by the
//! hot-path bench, the property tests, and the Pallas-kernel
//! cross-validation.  (Full-model accuracy experiments run through the
//! AOT artifacts; see `coordinator`.)

use crate::crossbar::{CrossbarArray, ReadCounters};
use crate::device::DeviceConfig;
use crate::energy::ReadMode;
use crate::rng::Rng;
use crate::Result;

/// One dense layer programmed on a crossbar, with a digital bias.
pub struct NoisyLinear {
    pub array: CrossbarArray,
    pub bias: Vec<f32>,
    pub d_in: usize,
    pub d_out: usize,
}

impl NoisyLinear {
    pub fn new(w: &[f32], bias: &[f32], d_in: usize, d_out: usize, cfg: &DeviceConfig) -> Self {
        assert_eq!(bias.len(), d_out);
        NoisyLinear {
            array: CrossbarArray::program(w, d_in, d_out, cfg),
            bias: bias.to_vec(),
            d_in,
            d_out,
        }
    }

    pub fn forward(
        &mut self,
        x: &[f32],
        out: &mut [f32],
        mode: ReadMode,
        cfg: &DeviceConfig,
        rng: &mut Rng,
    ) {
        self.array
            .mac(x, out, mode, cfg.act_bits, cfg.intensity.factor(), rng);
        for (o, &b) in out.iter_mut().zip(self.bias.iter()) {
            *o += b;
        }
    }

    pub fn forward_clean(&self, x: &[f32], out: &mut [f32], cfg: &DeviceConfig) {
        self.array.mac_clean(x, out, cfg.act_bits);
        for (o, &b) in out.iter_mut().zip(self.bias.iter()) {
            *o += b;
        }
    }
}

/// A stack of [`NoisyLinear`] layers with ReLU activations in between.
pub struct NoisyMlp {
    pub layers: Vec<NoisyLinear>,
    scratch: Vec<Vec<f32>>,
}

impl NoisyMlp {
    /// Build from per-layer (weights row-major (d_in, d_out), bias).
    pub fn new(specs: &[(&[f32], &[f32], usize, usize)], cfg: &DeviceConfig) -> Result<Self> {
        let mut layers = Vec::with_capacity(specs.len());
        let mut scratch = Vec::with_capacity(specs.len());
        for &(w, b, d_in, d_out) in specs {
            anyhow::ensure!(w.len() == d_in * d_out, "weight shape mismatch");
            layers.push(NoisyLinear::new(w, b, d_in, d_out, cfg));
            scratch.push(vec![0.0f32; d_out]);
        }
        Ok(NoisyMlp { layers, scratch })
    }

    /// Noisy forward of one sample; returns the logits slice.
    pub fn forward(
        &mut self,
        x: &[f32],
        mode: ReadMode,
        cfg: &DeviceConfig,
        rng: &mut Rng,
    ) -> &[f32] {
        let n = self.layers.len();
        for i in 0..n {
            // split scratch so we can borrow input and output disjointly
            let (head, tail) = self.scratch.split_at_mut(i);
            let input: &[f32] = if i == 0 { x } else { &head[i - 1] };
            let out = &mut tail[0];
            // activations entering a crossbar must be non-negative (DAC)
            let relu_in: Vec<f32>;
            let input = if i == 0 {
                input
            } else {
                relu_in = input.iter().map(|&v| v.max(0.0)).collect();
                &relu_in[..]
            };
            self.layers[i].forward(input, out, mode, cfg, rng);
        }
        &self.scratch[n - 1]
    }

    /// Noiseless forward (reference).
    pub fn forward_clean(&mut self, x: &[f32], cfg: &DeviceConfig) -> Vec<f32> {
        let mut cur = x.to_vec();
        for layer in &self.layers {
            let mut out = vec![0.0f32; layer.d_out];
            let input: Vec<f32> = cur.iter().map(|&v| v.max(0.0)).collect();
            layer.forward_clean(&input, &mut out, cfg);
            cur = out;
        }
        cur
    }

    /// Aggregate energy/cycle counters over all layers.
    pub fn counters(&self) -> ReadCounters {
        let mut total = ReadCounters::default();
        for l in &self.layers {
            total.merge(&l.array.counters);
        }
        total
    }

    pub fn num_cells(&self) -> usize {
        self.layers.iter().map(|l| l.array.num_cells()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_mlp(cfg: &DeviceConfig) -> NoisyMlp {
        let mut rng = Rng::new(1);
        let dims = [(16usize, 12usize), (12, 8), (8, 4)];
        let data: Vec<(Vec<f32>, Vec<f32>)> = dims
            .iter()
            .map(|&(i, o)| {
                let w: Vec<f32> = (0..i * o).map(|_| rng.normal() * 0.3).collect();
                let b: Vec<f32> = (0..o).map(|_| rng.normal() * 0.05).collect();
                (w, b)
            })
            .collect();
        let specs: Vec<(&[f32], &[f32], usize, usize)> = data
            .iter()
            .zip(dims.iter())
            .map(|((w, b), &(i, o))| (w.as_slice(), b.as_slice(), i, o))
            .collect();
        NoisyMlp::new(&specs, cfg).unwrap()
    }

    #[test]
    fn forward_shapes_and_finite() {
        let cfg = DeviceConfig::default();
        let mut mlp = mk_mlp(&cfg);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();
        let y = mlp.forward(&x, ReadMode::Original, &cfg, &mut rng);
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn noisy_tracks_clean_at_high_rho() {
        let mut cfg = DeviceConfig::default();
        cfg.rho = 64.0; // nearly noiseless
        let mut mlp = mk_mlp(&cfg);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();
        let clean = mlp.forward_clean(&x, &cfg);
        let noisy = mlp.forward(&x, ReadMode::Original, &cfg, &mut rng).to_vec();
        for (a, b) in noisy.iter().zip(clean.iter()) {
            assert!((a - b).abs() < 0.25 * (b.abs() + 1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn counters_accumulate() {
        let cfg = DeviceConfig::default();
        let mut mlp = mk_mlp(&cfg);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();
        mlp.forward(&x, ReadMode::Original, &cfg, &mut rng);
        let c1 = mlp.counters();
        mlp.forward(&x, ReadMode::Original, &cfg, &mut rng);
        let c2 = mlp.counters();
        assert!(c2.cell_pj > c1.cell_pj);
        assert_eq!(c2.cycles, 2 * c1.cycles);
    }

    #[test]
    fn decomposed_more_cycles_less_cell_energy() {
        let cfg = DeviceConfig::default();
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();

        let mut m1 = mk_mlp(&cfg);
        m1.forward(&x, ReadMode::Original, &cfg, &mut rng);
        let mut m2 = mk_mlp(&cfg);
        m2.forward(&x, ReadMode::Decomposed, &cfg, &mut rng);
        assert!(m2.counters().cycles > m1.counters().cycles);
        assert!(m2.counters().cell_pj < m1.counters().cell_pj);
    }
}
