//! Native noisy inference engine over the crossbar simulator.
//!
//! Runs fully-connected stacks directly on [`CrossbarArray`]s with
//! ReLU between layers — the device-level ground truth used by the
//! hot-path bench, the property tests, the native serving backend
//! (`coordinator::router::serve_native`) and the Pallas-kernel
//! cross-validation.  (Full-model accuracy experiments run through the
//! AOT artifacts; see `coordinator`, `--features aot`.)
//!
//! **Execution model (DESIGN.md):** a [`NoisyModel`] is immutable shared
//! state — programmed once, then read concurrently from any number of
//! threads.  All mutable per-stream state lives in a caller-owned
//! [`Scratch`] arena (layer ping-pong buffers + MAC scratch; zero
//! allocations per forward) and a caller-owned [`ReadCounters`].
//! [`NoisyModel::forward_batch`] fans a batch across rayon workers with
//! counter-based per-sample RNG streams (`Rng::stream(seed, i)`), so
//! logits AND energy counters are bit-identical at any thread count.

use crate::crossbar::{CrossbarArray, MacScratch, MacScratchBlock, ReadCounters};
use crate::data::{Dataset, IMG_LEN};
use crate::device::DeviceConfig;
use crate::energy::{EnergyPlan, LayerPlan, ReadMode};
use crate::rng::Rng;
use crate::trace::{LayerSpans, MAX_TRACE_LAYERS};
use crate::Result;

use rayon::prelude::*;
use std::sync::Mutex;

/// Per-sample trace output of [`NoisyModel::forward_batch_seeds_traced`]:
/// the sample's own energy/cycle counters (for per-request attribution)
/// plus wall time and observed uJ per layer.  Tracing reads the clock and
/// snapshots counters — it never touches the RNG stream, so the traced
/// path is bit-identical to the untraced one (pinned by tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct SampleTrace {
    pub counters: ReadCounters,
    pub layers: LayerSpans,
}

/// One dense layer programmed on a crossbar, with a digital bias.
pub struct NoisyLinear {
    pub array: CrossbarArray,
    pub bias: Vec<f32>,
    pub d_in: usize,
    pub d_out: usize,
}

impl NoisyLinear {
    pub fn new(w: &[f32], bias: &[f32], d_in: usize, d_out: usize, cfg: &DeviceConfig) -> Self {
        assert_eq!(bias.len(), d_out);
        NoisyLinear {
            array: CrossbarArray::program(w, d_in, d_out, cfg),
            bias: bias.to_vec(),
            d_in,
            d_out,
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn forward(
        &self,
        x: &[f32],
        out: &mut [f32],
        plan: LayerPlan,
        cfg: &DeviceConfig,
        rng: &mut Rng,
        counters: &mut ReadCounters,
        scratch: &mut MacScratch,
    ) {
        self.array.mac_scratch(
            x,
            out,
            plan,
            cfg.act_bits,
            cfg.intensity.factor(),
            rng,
            counters,
            scratch,
        );
        for (o, &b) in out.iter_mut().zip(self.bias.iter()) {
            *o += b;
        }
    }

    pub fn forward_clean(&self, x: &[f32], out: &mut [f32], cfg: &DeviceConfig) {
        self.array.mac_clean(x, out, cfg.act_bits);
        for (o, &b) in out.iter_mut().zip(self.bias.iter()) {
            *o += b;
        }
    }
}

/// Per-stream scratch arena: two ping-pong activation buffers sized to the
/// widest layer, plus the MAC level/bit-plane scratch.  ReLU is applied in
/// place in these buffers, so a whole forward pass allocates nothing.
#[derive(Clone, Debug)]
pub struct Scratch {
    a: Vec<f32>,
    b: Vec<f32>,
    mac: MacScratch,
}

impl Scratch {
    pub fn for_model(model: &NoisyModel) -> Self {
        let w = model.max_width();
        Scratch {
            a: vec![0.0f32; w],
            b: vec![0.0f32; w],
            mac: MacScratch::default(),
        }
    }
}

/// Per-block arena for the layer-major batched forward: ping-pong
/// activation slabs sized `block * max_width`, per-image RNG streams and
/// counters, counter snapshots for per-layer span attribution, and the
/// batched MAC scratch.  Reused across layers, dispatches, and (via
/// [`SlabPool`]) scheduler workers — steady-state batched inference
/// allocates nothing per dispatch beyond the logits it returns.
#[derive(Clone, Debug, Default)]
pub struct BatchSlab {
    a: Vec<f32>,
    b: Vec<f32>,
    rngs: Vec<Rng>,
    counters: Vec<ReadCounters>,
    snaps: Vec<ReadCounters>,
    mac: MacScratchBlock,
}

impl BatchSlab {
    /// Grow to hold `n` images of a model `width` wide (never shrinks).
    fn ensure(&mut self, n: usize, width: usize) {
        if self.a.len() < n * width {
            self.a.resize(n * width, 0.0);
            self.b.resize(n * width, 0.0);
        }
        if self.rngs.len() < n {
            self.rngs.resize_with(n, || Rng::new(0));
        }
        if self.counters.len() < n {
            self.counters.resize(n, ReadCounters::default());
            self.snaps.resize(n, ReadCounters::default());
        }
    }
}

/// A shared free-list of [`BatchSlab`]s: rayon block tasks check a slab
/// out per block and return it afterwards, so repeated dispatches reuse
/// the same arenas instead of reallocating them.  Scheduler workers own
/// one pool per engine (`scheduler::Engine`); callers without a pool
/// just pay a fresh slab per block.
#[derive(Debug, Default)]
pub struct SlabPool {
    slabs: Mutex<Vec<BatchSlab>>,
}

/// Retained slabs are capped so a one-off huge dispatch cannot pin
/// arenas forever; steady-state serving uses far fewer than this.
const SLAB_POOL_CAP: usize = 64;

impl SlabPool {
    pub fn new() -> SlabPool {
        SlabPool::default()
    }

    pub fn get(&self) -> BatchSlab {
        self.slabs.lock().unwrap().pop().unwrap_or_default()
    }

    pub fn put(&self, slab: BatchSlab) {
        let mut g = self.slabs.lock().unwrap();
        if g.len() < SLAB_POOL_CAP {
            g.push(slab);
        }
    }

    /// Slabs currently parked in the pool (observability/tests).
    pub fn idle(&self) -> usize {
        self.slabs.lock().unwrap().len()
    }
}

/// A stack of [`NoisyLinear`] layers with ReLU activations in between —
/// immutable once built, `Send + Sync`, shareable behind an `Arc`.
pub struct NoisyModel {
    layers: Vec<NoisyLinear>,
}

impl NoisyModel {
    /// Build from per-layer (weights row-major (d_in, d_out), bias).
    pub fn new(specs: &[(&[f32], &[f32], usize, usize)], cfg: &DeviceConfig) -> Result<Self> {
        anyhow::ensure!(!specs.is_empty(), "model needs at least one layer");
        let mut layers = Vec::with_capacity(specs.len());
        for (i, &(w, b, d_in, d_out)) in specs.iter().enumerate() {
            anyhow::ensure!(w.len() == d_in * d_out, "layer {i}: weight shape mismatch");
            if i > 0 {
                anyhow::ensure!(
                    specs[i - 1].3 == d_in,
                    "layer {i}: d_in {d_in} != previous d_out {}",
                    specs[i - 1].3
                );
            }
            layers.push(NoisyLinear::new(w, b, d_in, d_out, cfg));
        }
        Ok(NoisyModel { layers })
    }

    pub fn layers(&self) -> &[NoisyLinear] {
        &self.layers
    }

    /// Input width of the first layer.
    pub fn d_in(&self) -> usize {
        self.layers[0].d_in
    }

    /// Output width of the last layer (number of logits).
    pub fn d_out(&self) -> usize {
        self.layers[self.layers.len() - 1].d_out
    }

    /// Widest layer output — the scratch buffer size.
    pub fn max_width(&self) -> usize {
        self.layers.iter().map(|l| l.d_out).max().unwrap_or(0)
    }

    /// The model's default [`EnergyPlan`]: every layer at its array's
    /// programming-time rho, reading in `mode` — bit-identical to the
    /// pre-plan behaviour where reads always used the programmed rho.
    pub fn uniform_plan(&self, mode: ReadMode) -> EnergyPlan {
        EnergyPlan::new(
            self.layers.iter().map(|l| l.array.read_plan(mode)).collect(),
            crate::energy::PlanSource::Analytic,
        )
    }

    pub fn num_cells(&self) -> usize {
        self.layers.iter().map(|l| l.array.num_cells()).sum()
    }

    /// Noisy forward of one sample into the caller's scratch arena;
    /// returns the logits slice (borrowed from `scratch`).  Activations
    /// entering a crossbar are ReLU'd in place in the scratch buffers
    /// (the raw input `x` is assumed DAC-compatible, i.e. non-negative).
    pub fn forward_into<'s>(
        &self,
        x: &[f32],
        scratch: &'s mut Scratch,
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        rng: &mut Rng,
        counters: &mut ReadCounters,
    ) -> &'s [f32] {
        self.forward_into_impl(x, scratch, plan, cfg, rng, counters, None)
    }

    /// [`NoisyModel::forward_into`] with per-layer span capture: wall
    /// time and counter-delta uJ per layer land in `spans` (first
    /// [`MAX_TRACE_LAYERS`] layers; `spans.n` is the true layer count).
    /// Identical RNG stream and logits as the untraced path.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_into_traced<'s>(
        &self,
        x: &[f32],
        scratch: &'s mut Scratch,
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        rng: &mut Rng,
        counters: &mut ReadCounters,
        spans: &mut LayerSpans,
    ) -> &'s [f32] {
        self.forward_into_impl(x, scratch, plan, cfg, rng, counters, Some(spans))
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_into_impl<'s>(
        &self,
        x: &[f32],
        scratch: &'s mut Scratch,
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        rng: &mut Rng,
        counters: &mut ReadCounters,
        mut spans: Option<&mut LayerSpans>,
    ) -> &'s [f32] {
        assert_eq!(x.len(), self.d_in(), "input width mismatch");
        assert_eq!(plan.len(), self.layers.len(), "plan entry per layer");
        let Scratch { a, b, mac } = scratch;
        for (i, layer) in self.layers.iter().enumerate() {
            // span capture reads the clock and snapshots the counters;
            // the RNG stream is untouched, so traced == untraced bitwise
            let span_t0 = spans
                .as_ref()
                .map(|_| (std::time::Instant::now(), *counters));
            // ping-pong: even layers write a, odd layers write b
            let (prev, cur): (&mut [f32], &mut [f32]) = if i % 2 == 0 {
                (b.as_mut_slice(), a.as_mut_slice())
            } else {
                (a.as_mut_slice(), b.as_mut_slice())
            };
            let out = &mut cur[..layer.d_out];
            if i == 0 {
                layer.forward(x, out, plan.layer(i), cfg, rng, counters, mac);
            } else {
                let input = &mut prev[..self.layers[i - 1].d_out];
                for v in input.iter_mut() {
                    *v = v.max(0.0); // ReLU in place — no temporary Vec
                }
                layer.forward(input, out, plan.layer(i), cfg, rng, counters, mac);
            }
            if let (Some(sp), Some((t0, c0))) = (spans.as_deref_mut(), span_t0) {
                sp.n = self.layers.len();
                if i < MAX_TRACE_LAYERS {
                    sp.us[i] = t0.elapsed().as_micros().min(u32::MAX as u128) as u32;
                    sp.uj[i] = counters.uj_since(&c0) as f32;
                }
            }
        }
        let last = self.layers.len() - 1;
        let d_out = self.layers[last].d_out;
        if last % 2 == 0 {
            &a[..d_out]
        } else {
            &b[..d_out]
        }
    }

    /// Convenience single-sample forward (allocates its own scratch).
    pub fn forward_single(
        &self,
        x: &[f32],
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        rng: &mut Rng,
        counters: &mut ReadCounters,
    ) -> Vec<f32> {
        let mut scratch = Scratch::for_model(self);
        self.forward_into(x, &mut scratch, plan, cfg, rng, counters)
            .to_vec()
    }

    /// Batched noisy forward: `xs` is `batch * d_in` row-major samples;
    /// returns `batch * d_out` logits and accumulates the whole batch's
    /// energy/cycle accounting into `counters`.
    ///
    /// Samples fan out across the current rayon thread pool.  Sample `i`
    /// draws from the counter-based stream `Rng::stream(seed, i)` and
    /// accumulates into its own private counters; per-sample counters are
    /// merged in index order afterwards — so logits and counters are
    /// **bit-identical for a given `seed` at any thread count**, and
    /// identical to [`NoisyModel::forward_batch_seq`].
    pub fn forward_batch(
        &self,
        xs: &[f32],
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        seed: u64,
        counters: &mut ReadCounters,
    ) -> Vec<f32> {
        // Rng::stream(seed, i) == Rng::new(hash2(seed, i)), so routing
        // through the per-sample-seed impl is bit-identical to the
        // historical behaviour (pinned by tests/batch_parity.rs).
        let n = xs.len() / self.d_in().max(1);
        let seeds: Vec<u64> = (0..n).map(|i| crate::rng::hash2(seed, i as u64)).collect();
        self.forward_batch_seeds(xs, plan, cfg, &seeds, counters)
    }

    /// Like [`NoisyModel::forward_batch`], but sample `i` seeds its RNG
    /// directly from `seeds[i]` instead of a shared batch seed.  This is
    /// the serving router's path: each request image carries a
    /// content-derived seed (`coordinator::router::image_seed`), so an
    /// image's logits depend only on its own pixels and the lane seed —
    /// never on how the router packed it into a device batch.  A
    /// multi-image client batch is therefore bit-identical to the same
    /// images sent as sequential single requests, at any worker or rayon
    /// thread count.
    ///
    /// Since PR 10 this executes **layer-major**: every image in the
    /// batch advances through layer L (tile-outer, image-inner, via
    /// [`CrossbarArray::mac_scratch_block`]) before any image enters
    /// layer L+1, so each tile's weights/plane cache stream from memory
    /// once per image-block instead of once per image.  Per-image RNG
    /// streams and counters live in a [`BatchSlab`]; the per-image
    /// draw/accumulation order is unchanged, so logits and counters are
    /// bit-identical to the sample-major reference
    /// ([`NoisyModel::forward_batch_seeds_sample_major`]) and to
    /// [`NoisyModel::forward_batch_seq`] at any thread count.
    pub fn forward_batch_seeds(
        &self,
        xs: &[f32],
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        seeds: &[u64],
        counters: &mut ReadCounters,
    ) -> Vec<f32> {
        self.forward_batch_layer_major(xs, plan, cfg, seeds, counters, false, None)
            .0
    }

    /// [`NoisyModel::forward_batch_seeds`] drawing its [`BatchSlab`]s
    /// from a caller-owned [`SlabPool`] — the scheduler's steady-state
    /// zero-allocation path.
    pub fn forward_batch_seeds_pooled(
        &self,
        xs: &[f32],
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        seeds: &[u64],
        counters: &mut ReadCounters,
        pool: &SlabPool,
    ) -> Vec<f32> {
        self.forward_batch_layer_major(xs, plan, cfg, seeds, counters, false, Some(pool))
            .0
    }

    /// [`NoisyModel::forward_batch_seeds`] with per-sample tracing: the
    /// returned `Vec<SampleTrace>` carries each sample's own energy
    /// counters and per-layer spans (the serving stack's per-request
    /// attribution).  Same per-sample RNG streams and the same
    /// index-order counter merge into `counters` as the untraced path —
    /// logits and merged counters are bit-identical to
    /// [`NoisyModel::forward_batch_seeds`] at any thread count.
    ///
    /// Span semantics under layer-major execution: per-layer uJ stays
    /// exact (counter snapshots around each layer of the image's own
    /// counters); per-layer wall time is the block's layer wall time
    /// split evenly across the block's images, since images co-execute a
    /// layer and no longer have private layer timings.
    pub fn forward_batch_seeds_traced(
        &self,
        xs: &[f32],
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        seeds: &[u64],
        counters: &mut ReadCounters,
    ) -> (Vec<f32>, Vec<SampleTrace>) {
        let (logits, traces) =
            self.forward_batch_layer_major(xs, plan, cfg, seeds, counters, true, None);
        (logits, traces.unwrap_or_default())
    }

    /// [`NoisyModel::forward_batch_seeds_traced`] drawing its
    /// [`BatchSlab`]s from a caller-owned [`SlabPool`].
    pub fn forward_batch_seeds_traced_pooled(
        &self,
        xs: &[f32],
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        seeds: &[u64],
        counters: &mut ReadCounters,
        pool: &SlabPool,
    ) -> (Vec<f32>, Vec<SampleTrace>) {
        let (logits, traces) =
            self.forward_batch_layer_major(xs, plan, cfg, seeds, counters, true, Some(pool));
        (logits, traces.unwrap_or_default())
    }

    /// The checked-in **sample-major reference**: fan samples across
    /// rayon, each image running all its layers on a private
    /// [`Scratch`], per-sample counters merged in index order.  This is
    /// the pre-PR-10 execution order, kept as the parity oracle for the
    /// layer-major engine (tests/batch_parity.rs) and the denominator of
    /// the `layer_major_speedup` bench gate.
    pub fn forward_batch_seeds_sample_major(
        &self,
        xs: &[f32],
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        seeds: &[u64],
        counters: &mut ReadCounters,
    ) -> Vec<f32> {
        let d_in = self.d_in();
        let d_out = self.d_out();
        assert!(
            xs.len() % d_in == 0,
            "batch input length {} not a multiple of d_in {}",
            xs.len(),
            d_in
        );
        let batch = xs.len() / d_in;
        assert_eq!(seeds.len(), batch, "one seed per sample required");
        let mut logits = vec![0.0f32; batch * d_out];
        let per_sample: Vec<ReadCounters> = logits
            .par_chunks_mut(d_out)
            .enumerate()
            .map_init(
                || Scratch::for_model(self),
                |scratch, (i, out)| {
                    let mut rng = Rng::new(seeds[i]);
                    let mut c = ReadCounters::default();
                    let y = self.forward_into(
                        &xs[i * d_in..(i + 1) * d_in],
                        scratch,
                        plan,
                        cfg,
                        &mut rng,
                        &mut c,
                    );
                    out.copy_from_slice(y);
                    c
                },
            )
            .collect();
        for c in &per_sample {
            counters.merge(c);
        }
        logits
    }

    /// Layer-major batched forward body.  The batch is split into
    /// contiguous image blocks (one per rayon thread); each block walks
    /// the layer stack with [`CrossbarArray::mac_scratch_block`], so
    /// parallelism is per-(tile, image-block) while each image's RNG
    /// stream and accumulation order stay exactly sample-major.  Block
    /// boundaries cannot affect results (per-image state is private), so
    /// logits and counters are bit-identical at any thread count.
    #[allow(clippy::type_complexity)]
    fn forward_batch_layer_major(
        &self,
        xs: &[f32],
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        seeds: &[u64],
        counters: &mut ReadCounters,
        want_traces: bool,
        pool: Option<&SlabPool>,
    ) -> (Vec<f32>, Option<Vec<SampleTrace>>) {
        let d_in = self.d_in();
        let d_out = self.d_out();
        assert!(
            xs.len() % d_in == 0,
            "batch input length {} not a multiple of d_in {}",
            xs.len(),
            d_in
        );
        let batch = xs.len() / d_in;
        assert_eq!(seeds.len(), batch, "one seed per sample required");
        let mut logits = vec![0.0f32; batch * d_out];
        let mut per_image = vec![ReadCounters::default(); batch];
        let mut traces = if want_traces {
            vec![SampleTrace::default(); batch]
        } else {
            Vec::new()
        };
        if batch > 0 {
            let threads = rayon::current_num_threads().max(1);
            let bsize = batch.div_ceil(threads);
            let nblocks = batch.div_ceil(bsize);
            let trace_chunks: Vec<Option<&mut [SampleTrace]>> = if want_traces {
                traces.chunks_mut(bsize).map(Some).collect()
            } else {
                (0..nblocks).map(|_| None).collect()
            };
            let jobs: Vec<_> = xs
                .chunks(bsize * d_in)
                .zip(seeds.chunks(bsize))
                .zip(logits.chunks_mut(bsize * d_out))
                .zip(per_image.chunks_mut(bsize))
                .zip(trace_chunks)
                .collect();
            jobs.into_par_iter().for_each(|((((xb, sb), lb), cb), tb)| {
                let mut slab = pool.map(|p| p.get()).unwrap_or_default();
                self.forward_block(xb, sb, plan, cfg, lb, cb, tb, &mut slab);
                if let Some(p) = pool {
                    p.put(slab);
                }
            });
        }
        for c in &per_image {
            counters.merge(c);
        }
        (logits, want_traces.then_some(traces))
    }

    /// Run one contiguous image block through every layer, layer-major.
    /// `xs` is `n * d_in`, `logits_out` is `n * d_out`; per-image
    /// counters land in `per_image` (overwritten, not accumulated).
    #[allow(clippy::too_many_arguments)]
    fn forward_block(
        &self,
        xs: &[f32],
        seeds: &[u64],
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        logits_out: &mut [f32],
        per_image: &mut [ReadCounters],
        mut traces: Option<&mut [SampleTrace]>,
        slab: &mut BatchSlab,
    ) {
        let n = seeds.len();
        assert_eq!(plan.len(), self.layers.len(), "plan entry per layer");
        slab.ensure(n, self.max_width());
        let BatchSlab {
            a,
            b,
            rngs,
            counters,
            snaps,
            mac,
        } = slab;
        for i in 0..n {
            rngs[i] = Rng::new(seeds[i]);
            counters[i] = ReadCounters::default();
        }
        for (li, layer) in self.layers.iter().enumerate() {
            let t0 = traces.as_ref().map(|_| std::time::Instant::now());
            if traces.is_some() {
                snaps[..n].copy_from_slice(&counters[..n]);
            }
            // same ping-pong parity as the single-sample path: even
            // layers write slab a, odd layers write slab b
            let (prev, cur): (&mut [f32], &mut [f32]) = if li % 2 == 0 {
                (b.as_mut_slice(), a.as_mut_slice())
            } else {
                (a.as_mut_slice(), b.as_mut_slice())
            };
            let outs = &mut cur[..n * layer.d_out];
            let input: &[f32] = if li == 0 {
                xs
            } else {
                let d_prev = self.layers[li - 1].d_out;
                let inp = &mut prev[..n * d_prev];
                for v in inp.iter_mut() {
                    *v = v.max(0.0); // ReLU in place, elementwise as before
                }
                inp
            };
            layer.array.mac_scratch_block(
                input,
                outs,
                plan.layer(li),
                cfg.act_bits,
                cfg.intensity.factor(),
                &mut rngs[..n],
                &mut counters[..n],
                mac,
            );
            for i in 0..n {
                let o = &mut outs[i * layer.d_out..(i + 1) * layer.d_out];
                for (ov, &bv) in o.iter_mut().zip(layer.bias.iter()) {
                    *ov += bv;
                }
            }
            if let (Some(tr), Some(t0)) = (traces.as_deref_mut(), t0) {
                // uJ per image is exact (its own counters); wall time is
                // the block's layer time split evenly across its images
                let us = (t0.elapsed().as_micros() / n.max(1) as u128)
                    .min(u32::MAX as u128) as u32;
                for i in 0..n {
                    tr[i].layers.n = self.layers.len();
                    if li < MAX_TRACE_LAYERS {
                        tr[i].layers.us[li] = us;
                        tr[i].layers.uj[li] = counters[i].uj_since(&snaps[i]) as f32;
                    }
                }
            }
        }
        let last = self.layers.len() - 1;
        let src = if last % 2 == 0 { &*a } else { &*b };
        logits_out.copy_from_slice(&src[..n * self.layers[last].d_out]);
        per_image.copy_from_slice(&counters[..n]);
        if let Some(tr) = traces {
            for i in 0..n {
                tr[i].counters = counters[i];
            }
        }
    }

    /// Sequential reference for [`NoisyModel::forward_batch`]: identical
    /// per-sample RNG streams and identical counter merge order, one
    /// thread, one reused scratch.  Used by the parity tests and as the
    /// single-sample-loop baseline in the hot-path bench.
    pub fn forward_batch_seq(
        &self,
        xs: &[f32],
        plan: &EnergyPlan,
        cfg: &DeviceConfig,
        seed: u64,
        counters: &mut ReadCounters,
    ) -> Vec<f32> {
        let d_in = self.d_in();
        let d_out = self.d_out();
        assert!(xs.len() % d_in == 0, "batch input length mismatch");
        let batch = xs.len() / d_in;
        let mut logits = vec![0.0f32; batch * d_out];
        let mut scratch = Scratch::for_model(self);
        let mut per_sample = Vec::with_capacity(batch);
        for i in 0..batch {
            let mut rng = Rng::stream(seed, i as u64);
            let mut c = ReadCounters::default();
            let y = self.forward_into(
                &xs[i * d_in..(i + 1) * d_in],
                &mut scratch,
                plan,
                cfg,
                &mut rng,
                &mut c,
            );
            logits[i * d_out..(i + 1) * d_out].copy_from_slice(y);
            per_sample.push(c);
        }
        for c in &per_sample {
            counters.merge(c);
        }
        logits
    }

    /// Noiseless forward (reference).
    pub fn forward_clean(&self, x: &[f32], cfg: &DeviceConfig) -> Vec<f32> {
        let mut cur = x.to_vec();
        for layer in &self.layers {
            let mut out = vec![0.0f32; layer.d_out];
            let input: Vec<f32> = cur.iter().map(|&v| v.max(0.0)).collect();
            layer.forward_clean(&input, &mut out, cfg);
            cur = out;
        }
        cur
    }
}

/// Index of the largest logit (ties break to the lowest index; empty
/// slices return 0).  Shared by `InferenceClient::classify` and the HTTP
/// `/v1/classify` route so tie/NaN policy cannot diverge between them.
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Nearest-template linear classifier over a [`Dataset`]'s class
/// templates, programmed on a crossbar: `logit_c = x . t_c - |t_c|^2 / 2`
/// (exact nearest-template decision as one noisy analog layer).  Gives the
/// native serving path a model with real accuracy without needing the AOT
/// training stack.
pub fn template_classifier(dataset: &Dataset, cfg: &DeviceConfig) -> Result<NoisyModel> {
    let nc = dataset.num_classes;
    let d = IMG_LEN;
    let mut w = vec![0.0f32; d * nc];
    let mut b = vec![0.0f32; nc];
    for c in 0..nc {
        let t = dataset.template(c);
        for (r, &tv) in t.iter().enumerate() {
            w[r * nc + c] = tv;
        }
        b[c] = -0.5 * t.iter().map(|&v| v * v).sum::<f32>();
    }
    NoisyModel::new(&[(w.as_slice(), b.as_slice(), d, nc)], cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Split, Suite};

    fn mk_model(cfg: &DeviceConfig) -> NoisyModel {
        let mut rng = Rng::new(1);
        let dims = [(16usize, 12usize), (12, 8), (8, 4)];
        let data: Vec<(Vec<f32>, Vec<f32>)> = dims
            .iter()
            .map(|&(i, o)| {
                let w: Vec<f32> = (0..i * o).map(|_| rng.normal() * 0.3).collect();
                let b: Vec<f32> = (0..o).map(|_| rng.normal() * 0.05).collect();
                (w, b)
            })
            .collect();
        let specs: Vec<(&[f32], &[f32], usize, usize)> = data
            .iter()
            .zip(dims.iter())
            .map(|((w, b), &(i, o))| (w.as_slice(), b.as_slice(), i, o))
            .collect();
        NoisyModel::new(&specs, cfg).unwrap()
    }

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn model_is_shareable() {
        assert_send_sync::<NoisyModel>();
        assert_send_sync::<NoisyLinear>();
        assert_send_sync::<Scratch>();
    }

    #[test]
    fn forward_shapes_and_finite() {
        let cfg = DeviceConfig::default();
        let model = mk_model(&cfg);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();
        let mut counters = ReadCounters::default();
        let plan = model.uniform_plan(ReadMode::Original);
        let y = model.forward_single(&x, &plan, &cfg, &mut rng, &mut counters);
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|v| v.is_finite()));
        assert_eq!(model.d_in(), 16);
        assert_eq!(model.d_out(), 4);
        assert_eq!(model.max_width(), 12);
    }

    #[test]
    fn noisy_tracks_clean_at_high_rho() {
        let cfg = DeviceConfig {
            rho: 64.0, // nearly noiseless
            ..DeviceConfig::default()
        };
        let model = mk_model(&cfg);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();
        let clean = model.forward_clean(&x, &cfg);
        let mut counters = ReadCounters::default();
        let plan = model.uniform_plan(ReadMode::Original);
        let noisy = model.forward_single(&x, &plan, &cfg, &mut rng, &mut counters);
        for (a, b) in noisy.iter().zip(clean.iter()) {
            assert!((a - b).abs() < 0.25 * (b.abs() + 1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn counters_accumulate_across_calls() {
        let cfg = DeviceConfig::default();
        let model = mk_model(&cfg);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();
        let mut counters = ReadCounters::default();
        let plan = model.uniform_plan(ReadMode::Original);
        model.forward_single(&x, &plan, &cfg, &mut rng, &mut counters);
        let c1 = counters;
        model.forward_single(&x, &plan, &cfg, &mut rng, &mut counters);
        assert!(counters.cell_pj > c1.cell_pj);
        assert_eq!(counters.cycles, 2 * c1.cycles);
    }

    #[test]
    fn decomposed_more_cycles_less_cell_energy() {
        let cfg = DeviceConfig::default();
        let model = mk_model(&cfg);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();

        let mut c1 = ReadCounters::default();
        let ori = model.uniform_plan(ReadMode::Original);
        let dec = model.uniform_plan(ReadMode::Decomposed);
        model.forward_single(&x, &ori, &cfg, &mut rng, &mut c1);
        let mut c2 = ReadCounters::default();
        model.forward_single(&x, &dec, &cfg, &mut rng, &mut c2);
        assert!(c2.cycles > c1.cycles);
        assert!(c2.cell_pj < c1.cell_pj);
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        // the same stream + the same scratch arena reproduce bit-identical
        // logits; a fresh scratch does too (no state leaks between runs)
        let cfg = DeviceConfig::default();
        let model = mk_model(&cfg);
        let x: Vec<f32> = {
            let mut r = Rng::new(7);
            (0..16).map(|_| r.next_f32()).collect()
        };
        let mut scratch = Scratch::for_model(&model);
        let mut c = ReadCounters::default();
        let plan = model.uniform_plan(ReadMode::Original);
        let mut rng = Rng::stream(99, 0);
        let y1 = model
            .forward_into(&x, &mut scratch, &plan, &cfg, &mut rng, &mut c)
            .to_vec();
        let mut rng = Rng::stream(99, 0);
        let y2 = model
            .forward_into(&x, &mut scratch, &plan, &cfg, &mut rng, &mut c)
            .to_vec();
        let mut rng = Rng::stream(99, 0);
        let y3 = model.forward_single(&x, &plan, &cfg, &mut rng, &mut c);
        assert_eq!(y1, y2);
        assert_eq!(y1, y3);
    }

    #[test]
    fn batch_matches_sequential_quick() {
        // quick in-module check; the full thread-count matrix lives in
        // tests/batch_parity.rs
        let cfg = DeviceConfig::default();
        let model = mk_model(&cfg);
        let xs: Vec<f32> = {
            let mut r = Rng::new(8);
            (0..16 * 6).map(|_| r.next_f32()).collect()
        };
        let mut c_par = ReadCounters::default();
        let mut c_seq = ReadCounters::default();
        let plan = model.uniform_plan(ReadMode::Original);
        let par = model.forward_batch(&xs, &plan, &cfg, 42, &mut c_par);
        let seq = model.forward_batch_seq(&xs, &plan, &cfg, 42, &mut c_seq);
        assert_eq!(par, seq);
        assert_eq!(c_par, c_seq);
        assert_eq!(par.len(), 6 * 4);
    }

    #[test]
    fn batch_seeds_match_forward_batch_and_pack_independent() {
        let cfg = DeviceConfig::default();
        let model = mk_model(&cfg);
        let n = 6usize;
        let xs: Vec<f32> = {
            let mut r = Rng::new(9);
            (0..16 * n).map(|_| r.next_f32()).collect()
        };
        // explicit seeds hash2(s, i) reproduce forward_batch(seed = s)
        let seeds: Vec<u64> = (0..n).map(|i| crate::rng::hash2(42, i as u64)).collect();
        let mut c_a = ReadCounters::default();
        let mut c_b = ReadCounters::default();
        let plan = model.uniform_plan(ReadMode::Original);
        let a = model.forward_batch(&xs, &plan, &cfg, 42, &mut c_a);
        let b = model.forward_batch_seeds(&xs, &plan, &cfg, &seeds, &mut c_b);
        assert_eq!(a, b);
        assert_eq!(c_a, c_b);
        // a sample's logits depend only on (pixels, seed), not on batch
        // packing: running sample 3 alone reproduces its in-batch row
        let i = 3usize;
        let mut c_solo = ReadCounters::default();
        let solo = model.forward_batch_seeds(
            &xs[i * 16..(i + 1) * 16],
            &plan,
            &cfg,
            &seeds[i..i + 1],
            &mut c_solo,
        );
        assert_eq!(solo.as_slice(), &b[i * 4..(i + 1) * 4]);
    }

    #[test]
    fn traced_batch_is_bit_identical_and_attributes_energy() {
        // tracing reads clocks/counters only: logits and merged counters
        // must match the untraced path exactly, and per-sample/per-layer
        // energy must reconcile with the merged totals
        let cfg = DeviceConfig::default();
        let model = mk_model(&cfg);
        let n = 5usize;
        let xs: Vec<f32> = {
            let mut r = Rng::new(17);
            (0..16 * n).map(|_| r.next_f32()).collect()
        };
        let seeds: Vec<u64> = (0..n).map(|i| crate::rng::hash2(7, i as u64)).collect();
        let plan = model.uniform_plan(ReadMode::Decomposed);
        let mut c_plain = ReadCounters::default();
        let plain = model.forward_batch_seeds(&xs, &plan, &cfg, &seeds, &mut c_plain);
        let mut c_traced = ReadCounters::default();
        let (traced, traces) =
            model.forward_batch_seeds_traced(&xs, &plan, &cfg, &seeds, &mut c_traced);
        assert_eq!(plain, traced);
        assert_eq!(c_plain, c_traced);
        assert_eq!(traces.len(), n);
        let sum: f64 = traces.iter().map(|t| t.counters.total_pj()).sum();
        assert!((sum - c_traced.total_pj()).abs() < 1e-9);
        for t in &traces {
            assert_eq!(t.layers.n, 3);
            // per-layer uJ sums to the sample's counters
            let layer_uj: f64 = t.layers.uj.iter().map(|&u| u as f64).sum();
            let sample_uj = t.counters.total_pj() * 1e-6;
            assert!(
                (layer_uj - sample_uj).abs() < 1e-6 * sample_uj.max(1e-12) + 1e-9,
                "{layer_uj} vs {sample_uj}"
            );
            assert!(t.counters.cycles > 0);
        }
    }

    #[test]
    fn template_classifier_classifies() {
        let cfg = DeviceConfig::default();
        let ds = Dataset::new(Suite::Cifar, 5);
        let model = template_classifier(&ds, &cfg).unwrap();
        assert_eq!(model.d_in(), IMG_LEN);
        assert_eq!(model.d_out(), 10);
        let n = 48usize;
        let mut xs = vec![0.0f32; n * IMG_LEN];
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            labels.push(ds.sample_into(
                Split::Test,
                i as u64,
                &mut xs[i * IMG_LEN..(i + 1) * IMG_LEN],
            ));
        }
        let mut counters = ReadCounters::default();
        let plan = model.uniform_plan(ReadMode::Original);
        let logits = model.forward_batch(&xs, &plan, &cfg, 1, &mut counters);
        let mut correct = 0;
        for (i, &label) in labels.iter().enumerate() {
            let row = &logits[i * 10..(i + 1) * 10];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(c, _)| c)
                .unwrap();
            if pred == label as usize {
                correct += 1;
            }
        }
        assert!(
            correct as f64 / n as f64 > 0.8,
            "template classifier should beat 80% on the noisy device, got {correct}/{n}"
        );
        assert!(counters.cell_pj > 0.0 && counters.cycles == n as u64);
    }
}
