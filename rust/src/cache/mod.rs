//! Content-keyed exact result cache for the deterministic serving path.
//!
//! The whole native pipeline is deterministic by construction: an
//! image's device noise is seeded from its own pixels
//! (`coordinator::router::image_seed`, PR 3) and each tier's
//! `EnergyPlan` is fixed at boot (PR 4), so two requests with the same
//! pixels, tier, and plan produce **bit-identical logits**.  This
//! module memoizes that function: a sharded, lock-striped LRU from a
//! 128-bit content key to the computed logits plus the device energy
//! the original computation paid — a hit is served straight off the
//! event loop with zero crossbar reads and zero uJ (DESIGN.md §13).
//!
//! **Key derivation** ([`CacheKey::derive`]): two independent 64-bit
//! `hash2` folds of the pixel bit patterns (plus the image count) under
//! salts derived from `(model fingerprint, plan hash, tier)`.  The
//! fingerprint/plan salts are computed once at boot
//! ([`CacheKey::tier_salt`]); anything that would change the served
//! bytes — pixels, batch shape, tier, plan, model — changes the key.
//! 128 bits make accidental collisions negligible (~2^-64 at a billion
//! distinct entries); there is no adversarial collision concern beyond
//! a wrong-but-well-formed logits vector for the colliding client.
//!
//! **Sharding**: [`SHARDS`] independent `Mutex<Shard>`es selected by
//! the key's low bits; each shard is a `HashMap` over an intrusive
//! doubly-linked LRU list in a slab (`Vec`) arena — O(1) lookup,
//! insert, touch, and eviction, and no cross-shard contention.  Bounds
//! (entries and bytes) are split evenly across shards.
//!
//! All counters are atomics readable from any thread without touching
//! the shard locks ([`CacheStats`] → `emtopt_cache_*` on `/metrics`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::rng::hash2;

/// Number of lock stripes.  A power of two so shard selection is a
/// mask; 16 is comfortably more than the event loop + completion
/// threads that ever touch the cache concurrently.
pub const SHARDS: usize = 16;

/// Fixed per-entry overhead charged to the byte budget on top of the
/// logits payload: key + links + lengths + allocator slack, rounded up.
const ENTRY_OVERHEAD_BYTES: usize = 96;

/// 128-bit content key of one inference request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey(pub u128);

impl CacheKey {
    /// Per-(model, plan, tier) salt, computed once at boot: folds the
    /// model fingerprint and plan hash with the tier index so the same
    /// pixels never alias across tiers, plans, or deployed models.
    pub fn tier_salt(model_fingerprint: u64, plan_hash: u64, tier_index: usize) -> u64 {
        hash2(hash2(model_fingerprint, plan_hash), tier_index as u64)
    }

    /// Derive the key of a request: `count` images of `pixels`
    /// (`count * input_len` floats, row-major), under a boot-time
    /// `tier_salt`.  Two independent folds (distinct derived salts)
    /// give 128 bits; `f32::to_bits` makes the fold exact — any pixel
    /// bit-pattern change changes the key, matching the determinism
    /// contract bit for bit.
    pub fn derive(tier_salt: u64, pixels: &[f32], count: usize) -> CacheKey {
        let mut hi = hash2(tier_salt, 0xcafe_0001 ^ count as u64);
        let mut lo = hash2(tier_salt ^ 0x9e37_79b9_7f4a_7c15, 0xcafe_0002 ^ pixels.len() as u64);
        for &v in pixels {
            let b = u64::from(v.to_bits());
            hi = hash2(hi, b);
            lo = hash2(lo, b);
        }
        CacheKey(((hi as u128) << 64) | lo as u128)
    }

    fn shard(&self) -> usize {
        (self.0 as usize) & (SHARDS - 1)
    }
}

/// A memoized reply: the logits the engine computed for this key, the
/// image count of the request, and the device energy the original
/// computation spent (credited to `saved_uj_total` on every hit).
#[derive(Clone, Debug, PartialEq)]
pub struct CachedReply {
    pub logits: Vec<f32>,
    pub count: usize,
    pub energy_uj: f64,
}

impl CachedReply {
    fn cost_bytes(&self) -> usize {
        self.logits.len() * std::mem::size_of::<f32>() + ENTRY_OVERHEAD_BYTES
    }
}

/// Lock-free f64 accumulator stored as bits.
fn atomic_add_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

/// Atomic cache counters, readable without the shard locks.
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub evictions: AtomicU64,
    /// Live entries across all shards (gauge).
    pub entries: AtomicU64,
    /// Live payload bytes across all shards (gauge).
    pub bytes: AtomicU64,
    /// f64 bit-pattern: cumulative device uJ hits did NOT spend.
    saved_uj_bits: AtomicU64,
}

impl CacheStats {
    pub fn saved_uj(&self) -> f64 {
        f64::from_bits(self.saved_uj_bits.load(Ordering::Relaxed))
    }
}

const NIL: usize = usize::MAX;

struct Slot {
    key: CacheKey,
    value: CachedReply,
    prev: usize,
    next: usize,
}

/// One lock stripe: hash index + intrusive LRU list over a slab arena.
struct Shard {
    map: HashMap<CacheKey, usize>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    /// Most-recently-used slot (NIL when empty).
    head: usize,
    /// Least-recently-used slot (NIL when empty).
    tail: usize,
    bytes: usize,
}

impl Shard {
    fn new() -> Self {
        Shard {
            map: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            bytes: 0,
        }
    }

    /// Unlink `i` from the LRU list (must be linked).
    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev != NIL {
            self.slots[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    /// Link `i` at the MRU head.
    fn link_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Remove the LRU entry; returns its byte cost (0 when empty).
    fn evict_tail(&mut self, stats: &CacheStats) -> usize {
        let i = self.tail;
        if i == NIL {
            return 0;
        }
        self.unlink(i);
        let key = self.slots[i].key;
        self.map.remove(&key);
        let cost = self.slots[i].value.cost_bytes();
        self.bytes -= cost;
        // drop the payload now; the slot is recycled
        self.slots[i].value = CachedReply {
            logits: Vec::new(),
            count: 0,
            energy_uj: 0.0,
        };
        self.free.push(i);
        stats.evictions.fetch_add(1, Ordering::Relaxed);
        stats.entries.fetch_sub(1, Ordering::Relaxed);
        stats.bytes.fetch_sub(cost as u64, Ordering::Relaxed);
        cost
    }
}

/// The sharded, lock-striped, doubly-bounded LRU result cache.
///
/// Constructed once at server boot; shared behind an `Arc`.  Both
/// bounds must be positive — a zero bound means "cache off" and the
/// server simply does not construct one (`--cache-entries 0`).
pub struct ResultCache {
    shards: Vec<Mutex<Shard>>,
    /// Max entries per shard (global bound split evenly, min 1).
    shard_entries: usize,
    /// Max payload bytes per shard (global bound split evenly).
    shard_bytes: usize,
    stats: CacheStats,
}

impl ResultCache {
    /// `max_entries` entries / `max_bytes` payload bytes, globally
    /// (split evenly across [`SHARDS`] stripes, each holding at least
    /// one entry so a tiny bound still caches something).
    pub fn new(max_entries: usize, max_bytes: usize) -> Self {
        ResultCache {
            shards: (0..SHARDS).map(|_| Mutex::new(Shard::new())).collect(),
            shard_entries: (max_entries / SHARDS).max(1),
            shard_bytes: (max_bytes / SHARDS).max(ENTRY_OVERHEAD_BYTES + 64),
            stats: CacheStats::default(),
        }
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Look `key` up; a hit clones the memoized reply, bumps it to MRU,
    /// and credits its recorded energy to `saved_uj_total`.
    pub fn lookup(&self, key: CacheKey) -> Option<CachedReply> {
        let mut shard = self.shards[key.shard()].lock().unwrap();
        match shard.map.get(&key).copied() {
            Some(i) => {
                shard.unlink(i);
                shard.link_front(i);
                let value = shard.slots[i].value.clone();
                drop(shard);
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                atomic_add_f64(&self.stats.saved_uj_bits, value.energy_uj);
                Some(value)
            }
            None => {
                drop(shard);
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) `key`.  Evicts from the shard's LRU tail
    /// until both the entry and byte bounds hold.  A reply too large to
    /// ever fit the shard byte bound is not cached.
    pub fn insert(&self, key: CacheKey, value: CachedReply) {
        let cost = value.cost_bytes();
        if cost > self.shard_bytes {
            return;
        }
        let mut shard = self.shards[key.shard()].lock().unwrap();
        if let Some(i) = shard.map.get(&key).copied() {
            // the pipeline is deterministic, so a racing duplicate
            // compute produced the same bytes — just refresh recency
            shard.unlink(i);
            shard.link_front(i);
            return;
        }
        while shard.map.len() >= self.shard_entries
            || shard.bytes + cost > self.shard_bytes
        {
            if shard.evict_tail(&self.stats) == 0 {
                break;
            }
        }
        let i = match shard.free.pop() {
            Some(i) => {
                shard.slots[i].key = key;
                shard.slots[i].value = value;
                i
            }
            None => {
                shard.slots.push(Slot {
                    key,
                    value,
                    prev: NIL,
                    next: NIL,
                });
                shard.slots.len() - 1
            }
        };
        shard.link_front(i);
        shard.map.insert(key, i);
        shard.bytes += cost;
        self.stats.entries.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes.fetch_add(cost as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn reply(tag: f32, n: usize) -> CachedReply {
        CachedReply {
            logits: (0..n).map(|i| tag + i as f32).collect(),
            count: 1,
            energy_uj: tag as f64,
        }
    }

    #[test]
    fn key_is_content_addressed_and_128_bit() {
        let a = [0.1f32, 0.2, 0.3];
        let b = [0.1f32, 0.2, 0.3];
        let c = [0.1f32, 0.2, 0.4];
        assert_eq!(CacheKey::derive(7, &a, 1), CacheKey::derive(7, &b, 1));
        assert_ne!(CacheKey::derive(7, &a, 1), CacheKey::derive(8, &a, 1), "salt");
        assert_ne!(CacheKey::derive(7, &a, 1), CacheKey::derive(7, &c, 1), "pixels");
        assert_ne!(CacheKey::derive(7, &a, 1), CacheKey::derive(7, &a, 3), "count");
        assert_ne!(
            CacheKey::derive(7, &a, 1),
            CacheKey::derive(7, &a[..2], 1),
            "length"
        );
        // the two 64-bit halves are independent folds
        let k = CacheKey::derive(7, &a, 1);
        assert_ne!((k.0 >> 64) as u64, k.0 as u64);
        // tier salts separate tiers under one (model, plan)
        assert_ne!(CacheKey::tier_salt(1, 2, 0), CacheKey::tier_salt(1, 2, 1));
        assert_ne!(CacheKey::tier_salt(1, 2, 0), CacheKey::tier_salt(1, 3, 0));
        assert_ne!(CacheKey::tier_salt(1, 2, 0), CacheKey::tier_salt(9, 2, 0));
    }

    #[test]
    fn hit_miss_and_saved_energy_accounting() {
        let cache = ResultCache::new(64, 1 << 20);
        let k = CacheKey::derive(1, &[0.5, 0.25], 1);
        assert!(cache.lookup(k).is_none());
        assert_eq!(cache.stats().misses.load(Ordering::Relaxed), 1);
        cache.insert(k, reply(3.0, 4));
        let hit = cache.lookup(k).expect("inserted key must hit");
        assert_eq!(hit, reply(3.0, 4));
        assert_eq!(cache.stats().hits.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats().entries.load(Ordering::Relaxed), 1);
        assert!(cache.stats().bytes.load(Ordering::Relaxed) > 0);
        // each hit credits the entry's recorded compute energy
        assert!((cache.stats().saved_uj() - 3.0).abs() < 1e-12);
        cache.lookup(k).unwrap();
        assert!((cache.stats().saved_uj() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_oldest_within_shard() {
        // a 1-entry-per-shard cache: inserting two keys of the same
        // shard evicts the older, and touching refreshes recency
        let cache = ResultCache::new(SHARDS, 1 << 20);
        // craft three keys landing on one shard
        let mut keys = Vec::new();
        let mut i = 0u64;
        while keys.len() < 3 {
            let k = CacheKey::derive(i, &[i as f32], 1);
            if k.shard() == 0 {
                keys.push(k);
            }
            i += 1;
        }
        cache.insert(keys[0], reply(0.0, 2));
        cache.insert(keys[1], reply(1.0, 2)); // evicts keys[0]
        assert_eq!(cache.stats().evictions.load(Ordering::Relaxed), 1);
        assert!(cache.lookup(keys[0]).is_none());
        assert_eq!(cache.lookup(keys[1]).unwrap(), reply(1.0, 2));
        assert_eq!(cache.stats().entries.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn byte_bound_evicts_and_oversize_is_skipped() {
        // tiny byte budget: a shard holds ~1 small entry; a huge entry
        // never enters and never evicts what's there
        let per_shard = ENTRY_OVERHEAD_BYTES + 64;
        let cache = ResultCache::new(1 << 20, per_shard * SHARDS);
        let mut keys = Vec::new();
        let mut i = 0u64;
        while keys.len() < 2 {
            let k = CacheKey::derive(1000 + i, &[i as f32, 2.0], 1);
            if k.shard() == 3 {
                keys.push(k);
            }
            i += 1;
        }
        cache.insert(keys[0], reply(0.0, 8)); // 32B payload: fits
        let before = cache.stats().bytes.load(Ordering::Relaxed);
        assert!(before > 0);
        cache.insert(keys[1], reply(1.0, 4096)); // 16KiB: oversize, skipped
        assert!(cache.lookup(keys[0]).is_some(), "oversize insert must not evict");
        assert!(cache.lookup(keys[1]).is_none());
        assert_eq!(cache.stats().bytes.load(Ordering::Relaxed), before);
        // a second small entry displaces the first under the byte bound
        cache.insert(keys[1], reply(2.0, 12)); // 48B: over 64B budget with [0] live
        assert!(cache.lookup(keys[1]).is_some());
        assert!(cache.lookup(keys[0]).is_none(), "byte bound must evict LRU");
    }

    #[test]
    fn duplicate_insert_refreshes_without_growing() {
        let cache = ResultCache::new(64, 1 << 20);
        let k = CacheKey::derive(5, &[1.0], 1);
        cache.insert(k, reply(1.0, 4));
        cache.insert(k, reply(1.0, 4));
        assert_eq!(cache.stats().entries.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats().evictions.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn concurrent_insert_lookup_is_safe_and_consistent() {
        // generation safety under concurrency: values always match their
        // key (never another thread's payload), counters reconcile, and
        // entries/bytes gauges return to a consistent steady state
        let cache = Arc::new(ResultCache::new(128, 1 << 20));
        let threads: Vec<_> = (0..4u64)
            .map(|t| {
                let cache = cache.clone();
                std::thread::spawn(move || {
                    for round in 0..400u64 {
                        let id = (t * 31 + round) % 200; // overlapping key space
                        let k = CacheKey::derive(99, &[id as f32], 1);
                        if let Some(v) = cache.lookup(k) {
                            // the payload must be the one keyed by `id`
                            assert_eq!(v.logits[0], id as f32, "foreign payload under key");
                            assert_eq!(v.logits.len(), 4);
                        } else {
                            cache.insert(k, reply(id as f32, 4));
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let s = cache.stats();
        let entries = s.entries.load(Ordering::Relaxed);
        assert!(entries > 0 && entries <= 128 + SHARDS as u64);
        // hits + misses == lookups issued; every miss either inserted,
        // refreshed a racing duplicate, or lost a race — all consistent
        assert!(s.hits.load(Ordering::Relaxed) + s.misses.load(Ordering::Relaxed) > 0);
        // byte gauge reconciles with a full sweep of live entries
        let live_bytes: usize = cache
            .shards
            .iter()
            .map(|sh| sh.lock().unwrap().bytes)
            .sum();
        assert_eq!(s.bytes.load(Ordering::Relaxed), live_bytes as u64);
    }

    #[test]
    fn entry_bound_holds_under_pressure() {
        let cache = ResultCache::new(32, 1 << 20);
        for i in 0..1000u64 {
            cache.insert(CacheKey::derive(3, &[i as f32], 1), reply(i as f32, 4));
        }
        let entries = cache.stats().entries.load(Ordering::Relaxed);
        // per-shard bound is max(1, 32/16) = 2 entries -> ≤ 32 global
        assert!(entries <= 32, "entry bound violated: {entries}");
        assert!(cache.stats().evictions.load(Ordering::Relaxed) > 0);
    }
}
