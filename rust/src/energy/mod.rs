//! Analytical energy model (NCPower-style [33][37] substitution).
//!
//! Per-layer analog read energy (eq. 19, Fig 2a):
//!
//! ```text
//! E_cell(layer)  = cells * alpha * E0_PJ * rho * mean|w|_norm * duty
//! ```
//!
//! where `duty` is the mean DAC level (original mode) or the mean number of
//! set bit-planes (decomposed mode).  Peripheral energy per read cycle is
//! DAC per active row + ADC per column; decomposed mode pays `B_a` cycles.
//!
//! Calibration: `E0_PJ`, `E_DAC_PJ`, `E_ADC_PJ` are chosen so that
//! VGG-16/CIFAR at rho == 1 lands in the paper's tens-of-uJ range; all
//! comparisons in EXPERIMENTS.md are ratios, which are calibration-free.

use std::sync::Mutex;
use std::time::Duration;

use crate::device::{self, Intensity};
use crate::models::{LayerMeta, ModelDesc};

/// Energy of one full-scale unit-level analog cell read at rho == 1 (pJ).
pub const E0_PJ: f64 = 0.05;
/// DAC energy per active row per read cycle (pJ).
pub const E_DAC_PJ: f64 = 0.02;
/// ADC energy per column conversion per read cycle (pJ).
pub const E_ADC_PJ: f64 = 0.2;

/// Read mode of the crossbar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadMode {
    /// Single analog read with a multi-bit DAC level (paper "original").
    Original,
    /// Technique C: bit-serial over `act_bits` planes.
    Decomposed,
}

impl ReadMode {
    /// Wire/report name (serving API responses, Prometheus labels).
    pub fn name(self) -> &'static str {
        match self {
            ReadMode::Original => "original",
            ReadMode::Decomposed => "decomposed",
        }
    }
}

/// Where a serving [`EnergyPlan`] came from: solved analytically from the
/// layer geometry, or rescaled from a trained per-layer rho vector
/// (technique B, `store::load`).  Advertised end-to-end: `/healthz`,
/// `/v1/infer` responses, `/metrics`, and the `BENCH_*.json` records all
/// carry the source so a serving measurement is attributable to the plan
/// that produced it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanSource {
    /// Uniform or water-filled rho from the analytical energy model.
    Analytic,
    /// Trained per-layer rho vector, rescaled to the serving budget.
    Trained,
}

impl PlanSource {
    /// Wire/report name (serving API responses, Prometheus labels).
    pub fn name(self) -> &'static str {
        match self {
            PlanSource::Analytic => "analytic",
            PlanSource::Trained => "trained",
        }
    }
}

/// Read plan of one layer: the energy coefficient its cells are read at
/// and the read mode of the access.  This is what the device layer
/// actually consumes — `CrossbarArray::mac*` takes the layer's entry, so
/// per-layer energy shaping reaches the noise draw, not just the report.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerPlan {
    /// Per-read energy coefficient (eq. 7/8: sigma ∝ 1/sqrt(rho)).
    pub rho: f32,
    pub mode: ReadMode,
}

impl LayerPlan {
    pub fn new(rho: f32, mode: ReadMode) -> Self {
        LayerPlan { rho, mode }
    }

    /// Relative fluctuation sigma this layer sees (fraction of full
    /// scale) at a given intensity factor.
    pub fn sigma_rel(&self, intensity: f32) -> f32 {
        device::sigma_rel(self.rho, intensity)
    }
}

/// Per-layer energy allocation of a whole model: one [`LayerPlan`] per
/// layer plus the provenance of the vector.  The forward paths
/// (`NoisyModel::forward_*`) consume this instead of a global
/// `(ReadMode, rho)` scalar pair, so a noise-sensitive layer can buy a
/// larger rho than its neighbours (the paper's technique B at serving
/// time).
#[derive(Clone, Debug, PartialEq)]
pub struct EnergyPlan {
    layers: Vec<LayerPlan>,
    pub source: PlanSource,
}

impl EnergyPlan {
    /// Build from explicit per-layer entries.
    pub fn new(layers: Vec<LayerPlan>, source: PlanSource) -> Self {
        EnergyPlan { layers, source }
    }

    /// The classic global knob: every layer at the same (rho, mode).
    pub fn uniform(n_layers: usize, rho: f32, mode: ReadMode) -> Self {
        EnergyPlan {
            layers: vec![LayerPlan::new(rho, mode); n_layers],
            source: PlanSource::Analytic,
        }
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// The plan entry of layer `i` (panics out of range, like indexing).
    pub fn layer(&self, i: usize) -> LayerPlan {
        self.layers[i]
    }

    pub fn layers(&self) -> &[LayerPlan] {
        &self.layers
    }

    /// Per-layer rho values (reporting order == layer order).
    pub fn rhos(&self) -> Vec<f32> {
        self.layers.iter().map(|l| l.rho).collect()
    }

    pub fn mean_rho(&self) -> f32 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.rho).sum::<f32>() / self.layers.len() as f32
    }

    /// Read mode of the first layer — tier plans keep one mode for the
    /// whole stack, so this is the lane's mode for reporting.
    pub fn lead_mode(&self) -> ReadMode {
        self.layers.first().map(|l| l.mode).unwrap_or(ReadMode::Original)
    }

    /// Worst-case per-layer relative fluctuation sigma at an intensity
    /// factor — the accuracy-risk summary of a plan.
    pub fn max_sigma_rel(&self, intensity: f32) -> f32 {
        self.layers
            .iter()
            .map(|l| l.sigma_rel(intensity))
            .fold(0.0f32, f32::max)
    }

    /// Check the plan is usable against a deployed model: one entry per
    /// layer, every rho finite and positive.
    pub fn validate(&self, n_layers: usize) -> crate::Result<()> {
        anyhow::ensure!(
            self.layers.len() == n_layers,
            "energy plan has {} layers, model has {n_layers}",
            self.layers.len()
        );
        for (i, l) in self.layers.iter().enumerate() {
            anyhow::ensure!(
                l.rho.is_finite() && l.rho > 0.0,
                "layer {i}: rho {} must be finite and positive",
                l.rho
            );
        }
        Ok(())
    }
}

/// Workload statistics of a trained model (measured or assumed).
#[derive(Clone, Copy, Debug)]
pub struct ReadStats {
    /// Mean |w| / w_scale over programmed cells (Gaussian init: ~0.25).
    pub mean_w_norm: f64,
    /// Mean DAC integer level per read, original mode.
    pub mean_level: f64,
    /// Mean set bit-planes per read, decomposed mode.
    pub mean_bits: f64,
}

impl ReadStats {
    /// Defaults for B_a activation bits assuming half-range uniform
    /// activation levels (used when no measured stats are available).
    pub fn assumed(act_bits: u32) -> Self {
        let max_level = ((1u64 << act_bits) - 1) as f64;
        ReadStats {
            mean_w_norm: 0.25,
            mean_level: 0.3 * max_level,
            mean_bits: 0.3 * act_bits as f64,
        }
    }
}

/// The analytical energy model.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub act_bits: u32,
    pub stats: ReadStats,
}

impl EnergyModel {
    pub fn new(act_bits: u32) -> Self {
        EnergyModel {
            act_bits,
            stats: ReadStats::assumed(act_bits),
        }
    }

    pub fn with_stats(act_bits: u32, stats: ReadStats) -> Self {
        EnergyModel { act_bits, stats }
    }

    fn duty(&self, mode: ReadMode) -> f64 {
        match mode {
            ReadMode::Original => self.stats.mean_level,
            ReadMode::Decomposed => self.stats.mean_bits,
        }
    }

    fn cycles_per_read(&self, mode: ReadMode) -> f64 {
        match mode {
            ReadMode::Original => 1.0,
            ReadMode::Decomposed => self.act_bits as f64,
        }
    }

    /// Analog cell energy of one layer per inference (pJ).
    pub fn layer_cell_pj(&self, meta: &LayerMeta, rho: f64, mode: ReadMode) -> f64 {
        meta.reads() as f64 * E0_PJ * rho * self.stats.mean_w_norm * self.duty(mode)
    }

    /// Peripheral (DAC + ADC) energy of one layer per inference (pJ).
    pub fn layer_peripheral_pj(&self, meta: &LayerMeta, mode: ReadMode) -> f64 {
        let cycles = meta.alpha as f64 * self.cycles_per_read(mode);
        cycles * (meta.fan_in as f64 * E_DAC_PJ + meta.out_features as f64 * E_ADC_PJ)
    }

    /// Total energy of one layer per inference (pJ).
    pub fn layer_pj(&self, meta: &LayerMeta, rho: f64, mode: ReadMode) -> f64 {
        self.layer_cell_pj(meta, rho, mode) + self.layer_peripheral_pj(meta, mode)
    }

    /// Whole-model energy per inference in uJ, with per-layer rho.
    pub fn model_uj(&self, model: &ModelDesc, rhos: &[f64], mode: ReadMode) -> f64 {
        assert_eq!(model.layers.len(), rhos.len(), "rho per layer");
        let pj: f64 = model
            .layers
            .iter()
            .zip(rhos.iter())
            .map(|(l, &r)| self.layer_pj(l, r, mode))
            .sum();
        pj * 1e-6
    }

    /// Whole-model energy with a single global rho.
    pub fn model_uj_uniform(&self, model: &ModelDesc, rho: f64, mode: ReadMode) -> f64 {
        let rhos = vec![rho; model.layers.len()];
        self.model_uj(model, &rhos, mode)
    }

    /// Invert `model_uj_uniform` for rho: find the global rho whose
    /// energy equals `budget_uj` (cell energy is linear in rho, peripheral
    /// constant, so this is a closed form).  The f64-exact scalar sibling
    /// of [`EnergyModel::plan_for_budget`] — plans store per-layer rho as
    /// `f32` (the device's precision), so callers that only need the
    /// uniform knob keep the full-precision closed form here.
    pub fn rho_for_budget(
        &self,
        model: &ModelDesc,
        budget_uj: f64,
        mode: ReadMode,
    ) -> Option<f64> {
        let peripheral_pj: f64 = model
            .layers
            .iter()
            .map(|l| self.layer_peripheral_pj(l, mode))
            .sum();
        let cell_at_rho1: f64 = model
            .layers
            .iter()
            .map(|l| self.layer_cell_pj(l, 1.0, mode))
            .sum();
        let remaining = budget_uj * 1e6 - peripheral_pj;
        if remaining <= 0.0 {
            return None; // budget below the peripheral floor
        }
        Some(remaining / cell_at_rho1)
    }

    /// Per-layer expected energy of a plan, picojoules.
    pub fn plan_layer_pj(&self, model: &ModelDesc, plan: &EnergyPlan) -> Vec<f64> {
        assert_eq!(model.layers.len(), plan.len(), "plan entry per layer");
        model
            .layers
            .iter()
            .zip(plan.layers().iter())
            .map(|(meta, l)| self.layer_pj(meta, l.rho as f64, l.mode))
            .collect()
    }

    /// Whole-model energy of a plan per inference, microjoules.
    pub fn plan_uj(&self, model: &ModelDesc, plan: &EnergyPlan) -> f64 {
        self.plan_layer_pj(model, plan).iter().sum::<f64>() * 1e-6
    }

    /// Budget → plan solver (closed-form water-filling).
    ///
    /// Splits `budget_uj` across layers so the whole-model energy hits
    /// the budget exactly.  With per-layer noise-sensitivity weights
    /// `g_l` it minimises `sum_l g_l * sigma_l^2` subject to the budget:
    /// sigma^2 ∝ 1/rho and cell energy is linear in rho, so the
    /// Lagrangian optimum is `rho_l ∝ sqrt(g_l / c_l)` with `c_l` the
    /// layer's cell energy at rho == 1 — a closed form, no iteration.
    /// Without sensitivity stats every layer gets the same rho (the
    /// uniform fallback, identical to [`EnergyModel::rho_for_budget`]).
    ///
    /// Returns `None` when the budget does not clear the mode's
    /// peripheral floor (DAC/ADC energy is rho-independent; no rho
    /// allocation can hit such a budget).
    pub fn plan_for_budget(
        &self,
        model: &ModelDesc,
        budget_uj: f64,
        mode: ReadMode,
        sensitivity: Option<&[f64]>,
    ) -> Option<EnergyPlan> {
        let n = model.layers.len();
        if n == 0 {
            return None; // a plan over zero layers is meaningless
        }
        if let Some(g) = sensitivity {
            assert_eq!(g.len(), n, "sensitivity weight per layer");
        }
        let cell1: Vec<f64> = model
            .layers
            .iter()
            .map(|l| self.layer_cell_pj(l, 1.0, mode))
            .collect();
        let peripheral_pj: f64 = model
            .layers
            .iter()
            .map(|l| self.layer_peripheral_pj(l, mode))
            .sum();
        let remaining = budget_uj * 1e6 - peripheral_pj;
        if remaining <= 0.0 {
            return None; // budget at or below the peripheral floor
        }
        // relative shape of the allocation: uniform, or sqrt(g/c).
        // Non-positive weights are floored to a tiny fraction of the
        // largest one: the mathematical optimum for a zero-sensitivity
        // layer is rho -> 0, but a zero-rho entry is an invalid plan
        // (infinite sigma), so the starved layer keeps a sliver instead.
        let shape: Vec<f64> = match sensitivity {
            None => vec![1.0; n],
            Some(g) => {
                let g_max = g.iter().cloned().fold(0.0f64, f64::max);
                if g_max <= 0.0 {
                    return None; // no layer is sensitive: no shape exists
                }
                cell1
                    .iter()
                    .zip(g.iter())
                    .map(|(&c, &gl)| {
                        (gl.max(1e-6 * g_max) / c.max(f64::MIN_POSITIVE)).sqrt()
                    })
                    .collect()
            }
        };
        let denom: f64 = cell1.iter().zip(shape.iter()).map(|(&c, &s)| c * s).sum();
        if denom <= 0.0 {
            return None; // degenerate model (no cell reads)
        }
        let scale = remaining / denom;
        Some(EnergyPlan::new(
            shape
                .iter()
                .map(|&s| LayerPlan::new((scale * s) as f32, mode))
                .collect(),
            PlanSource::Analytic,
        ))
    }

    /// Rescale a trained per-layer rho vector (technique B) onto a
    /// serving budget: `rho_l = s * trained_l` with one global `s`, so
    /// the trained **relative** allocation between layers is preserved
    /// exactly while the total energy hits `budget_uj`.  `None` when the
    /// budget does not clear the peripheral floor.
    pub fn plan_from_trained(
        &self,
        model: &ModelDesc,
        trained_rho: &[f32],
        budget_uj: f64,
        mode: ReadMode,
    ) -> Option<EnergyPlan> {
        assert_eq!(model.layers.len(), trained_rho.len(), "trained rho per layer");
        let peripheral_pj: f64 = model
            .layers
            .iter()
            .map(|l| self.layer_peripheral_pj(l, mode))
            .sum();
        let cell_at_trained: f64 = model
            .layers
            .iter()
            .zip(trained_rho.iter())
            .map(|(l, &r)| self.layer_cell_pj(l, r as f64, mode))
            .sum();
        let remaining = budget_uj * 1e6 - peripheral_pj;
        if remaining <= 0.0 || cell_at_trained <= 0.0 {
            return None;
        }
        let scale = remaining / cell_at_trained;
        Some(EnergyPlan::new(
            trained_rho
                .iter()
                .map(|&r| LayerPlan::new((scale * r as f64) as f32, mode))
                .collect(),
            PlanSource::Trained,
        ))
    }
}

// ---------------------------------------------------------------------------
// rolling energy accounting + fleet budget math (serving-time energy SLO)
// ---------------------------------------------------------------------------

/// Ring slots of the [`EnergyMeter`] window (16 slots keeps the rate
/// estimate within one-sixteenth of the window of the true value while
/// the state stays a fixed-size array).
pub const ENERGY_METER_SLOTS: usize = 16;

/// Rolling-window energy meter: the **observed** side of the serving
/// energy SLO.  Batch workers record their device energy (uJ) with a
/// monotonic microsecond timestamp; [`EnergyMeter::rate_uj_s`] reports
/// the uJ/s spent over the trailing window.  The window is a fixed ring
/// of [`ENERGY_METER_SLOTS`] coarse slots, so memory is constant no
/// matter the request rate, and a slot falls out of the sum exactly one
/// window after it was filled.
#[derive(Debug)]
pub struct EnergyMeter {
    slot_us: u64,
    /// `(slot id, uJ sum)` ring; recording happens once per dispatched
    /// batch (not per read), so a mutex is plenty.
    slots: Mutex<Vec<(u64, f64)>>,
}

impl EnergyMeter {
    pub fn new(window: Duration) -> Self {
        let slot_us = (window.as_micros() as u64 / ENERGY_METER_SLOTS as u64).max(1);
        EnergyMeter {
            slot_us,
            slots: Mutex::new(vec![(u64::MAX, 0.0); ENERGY_METER_SLOTS]),
        }
    }

    /// Effective window length in seconds (slot-rounded).
    pub fn window_s(&self) -> f64 {
        (self.slot_us * ENERGY_METER_SLOTS as u64) as f64 / 1e6
    }

    /// Record `uj` microjoules observed at monotonic time `t_us`.
    pub fn record(&self, t_us: u64, uj: f64) {
        let id = t_us / self.slot_us;
        let mut slots = self.slots.lock().expect("energy meter poisoned");
        let slot = &mut slots[(id % ENERGY_METER_SLOTS as u64) as usize];
        if slot.0 != id {
            *slot = (id, 0.0);
        }
        slot.1 += uj;
    }

    /// Rolling energy rate over the window ending at `t_us`, uJ/s.
    pub fn rate_uj_s(&self, t_us: u64) -> f64 {
        let id_now = t_us / self.slot_us;
        let slots = self.slots.lock().expect("energy meter poisoned");
        let sum: f64 = slots
            .iter()
            .filter(|&&(id, _)| {
                id != u64::MAX && id <= id_now && id_now - id < ENERGY_METER_SLOTS as u64
            })
            .map(|&(_, uj)| uj)
            .sum();
        sum / self.window_s()
    }
}

/// Over-budget ratio per extra shed tier: at `budget < rate <= 1.5x`
/// only the lowest tier sheds; each further 1.5x multiple sheds the
/// next tier up (the top tier is never shed, see
/// [`EnergyBudget::shed_lanes`]).
pub const SHED_ESCALATE_RATIO: f64 = 1.5;

/// Fleet-level serving energy budget (uJ/s) and its shedding policy —
/// the closed loop on the paper's accuracy-per-joule contract: when the
/// rolling observed rate exceeds the budget, the cheapest (lowest-tier)
/// work is refused first, so the remaining joules buy the accuracy the
/// premium tiers paid for.
#[derive(Clone, Copy, Debug)]
pub struct EnergyBudget {
    /// Target ceiling for the rolling device energy rate, uJ/s
    /// (validated positive at governor construction).
    pub budget_uj_s: f64,
}

impl EnergyBudget {
    /// Budget minus observed rate: positive = headroom, negative = the
    /// overshoot the governor is currently shedding against.
    pub fn headroom_uj_s(&self, rate_uj_s: f64) -> f64 {
        self.budget_uj_s - rate_uj_s
    }

    /// How many of the lowest-priority lanes to shed at `rate_uj_s`:
    /// 0 within budget, 1 just above it, one more lane per
    /// [`SHED_ESCALATE_RATIO`] multiple of over-budget.  The
    /// highest-priority lane is **never** shed — for premium traffic the
    /// budget surfaces as a throughput squeeze, not a hard `503`, so a
    /// single-lane engine with a budget never sheds at all.
    pub fn shed_lanes(&self, rate_uj_s: f64, n_lanes: usize) -> usize {
        if rate_uj_s <= self.budget_uj_s {
            return 0;
        }
        let ratio = rate_uj_s / self.budget_uj_s;
        let mut shed = 1usize;
        let mut threshold = SHED_ESCALATE_RATIO;
        while ratio > threshold && shed + 1 < n_lanes {
            shed += 1;
            threshold *= SHED_ESCALATE_RATIO;
        }
        shed.min(n_lanes.saturating_sub(1))
    }

    /// Honest `Retry-After` for an energy-shed request: the time the
    /// rolling window needs to decay back under budget if no further
    /// energy were spent — `window_s * (1 - budget/rate)` — rounded up
    /// and clamped to [1, 30] s.
    pub fn retry_after_s(&self, rate_uj_s: f64, window_s: f64) -> u64 {
        if rate_uj_s <= self.budget_uj_s {
            return 1;
        }
        let wait = window_s * (1.0 - self.budget_uj_s / rate_uj_s);
        (wait.ceil() as u64).clamp(1, 30)
    }
}

/// Fluctuation sigma that a model sees at a given uniform rho (relative to
/// full-scale). Convenience glue for accuracy-vs-energy sweeps.
pub fn sigma_at(rho: f64, intensity: Intensity) -> f64 {
    device::sigma_rel(rho as f32, intensity.factor()) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::paper_scale::{vgg16, Resolution};

    fn model() -> ModelDesc {
        vgg16(Resolution::Cifar)
    }

    #[test]
    fn energy_linear_in_rho() {
        let em = EnergyModel::new(5);
        let m = model();
        let e1 = em.model_uj_uniform(&m, 1.0, ReadMode::Original);
        let e2 = em.model_uj_uniform(&m, 2.0, ReadMode::Original);
        let peri: f64 = m
            .layers
            .iter()
            .map(|l| em.layer_peripheral_pj(l, ReadMode::Original))
            .sum::<f64>()
            * 1e-6;
        assert!(((e2 - peri) - 2.0 * (e1 - peri)).abs() < 1e-9);
    }

    #[test]
    fn decomposed_cell_energy_lower() {
        // eq (20): mean_bits << mean_level
        let em = EnergyModel::new(5);
        let m = model();
        let meta = &m.layers[0];
        assert!(
            em.layer_cell_pj(meta, 1.0, ReadMode::Decomposed)
                < em.layer_cell_pj(meta, 1.0, ReadMode::Original)
        );
    }

    #[test]
    fn decomposed_peripheral_higher() {
        let em = EnergyModel::new(5);
        let m = model();
        let meta = &m.layers[0];
        assert!(
            em.layer_peripheral_pj(meta, ReadMode::Decomposed)
                > em.layer_peripheral_pj(meta, ReadMode::Original)
        );
    }

    #[test]
    fn vgg16_cifar_in_paper_range() {
        // tens of uJ at moderate rho (Table 1 scale)
        let em = EnergyModel::new(5);
        let e = em.model_uj_uniform(&model(), 1.0, ReadMode::Original);
        assert!((5.0..200.0).contains(&e), "vgg16 energy {e} uJ");
    }

    #[test]
    fn rho_budget_roundtrip() {
        let em = EnergyModel::new(5);
        let m = model();
        let budget = 16.0;
        let rho = em.rho_for_budget(&m, budget, ReadMode::Original).unwrap();
        let back = em.model_uj_uniform(&m, rho, ReadMode::Original);
        assert!((back - budget).abs() / budget < 1e-9);
    }

    #[test]
    fn budget_below_peripheral_floor_is_none() {
        let em = EnergyModel::new(5);
        assert!(em
            .rho_for_budget(&model(), 1e-9, ReadMode::Original)
            .is_none());
    }

    #[test]
    fn plan_for_budget_uniform_matches_rho_for_budget() {
        let em = EnergyModel::new(5);
        let m = model();
        let budget = 16.0;
        let plan = em
            .plan_for_budget(&m, budget, ReadMode::Original, None)
            .unwrap();
        assert_eq!(plan.len(), m.layers.len());
        assert_eq!(plan.source, PlanSource::Analytic);
        let rho = em.rho_for_budget(&m, budget, ReadMode::Original).unwrap();
        for l in plan.layers() {
            // plans store rho at device precision (f32)
            assert!(
                (l.rho as f64 - rho).abs() / rho < 1e-6,
                "{} vs {rho}",
                l.rho
            );
        }
        // the plan hits the budget (up to f32 rho storage)
        assert!((em.plan_uj(&m, &plan) - budget).abs() / budget < 1e-6);
    }

    #[test]
    fn plan_for_budget_peripheral_floor_edge() {
        // budget exactly at the peripheral floor: no energy is left for
        // cell reads, so no rho allocation exists -> None (and anything
        // epsilon above it is solvable)
        let em = EnergyModel::new(5);
        let m = model();
        let floor_uj = m
            .layers
            .iter()
            .map(|l| em.layer_peripheral_pj(l, ReadMode::Original))
            .sum::<f64>()
            * 1e-6;
        // at (a hair below, guarding the uJ<->pJ rounding) the floor: None
        assert!(em
            .plan_for_budget(&m, floor_uj * (1.0 - 1e-9), ReadMode::Original, None)
            .is_none());
        // epsilon above it: solvable, every layer strictly positive
        let plan = em
            .plan_for_budget(&m, floor_uj * 1.01, ReadMode::Original, None)
            .unwrap();
        assert!(plan.layers().iter().all(|l| l.rho > 0.0));
    }

    #[test]
    fn plan_for_budget_single_layer_model() {
        let em = EnergyModel::new(5);
        let m = ModelDesc {
            name: "one".into(),
            layers: vec![LayerMeta::dense(64, 10)],
        };
        let budget = 0.5;
        let plan = em
            .plan_for_budget(&m, budget, ReadMode::Original, None)
            .unwrap();
        assert_eq!(plan.len(), 1);
        assert!((em.plan_uj(&m, &plan) - budget).abs() / budget < 1e-6);
        // with one layer, sensitivity weights cannot change the answer
        let weighted = em
            .plan_for_budget(&m, budget, ReadMode::Original, Some(&[42.0]))
            .unwrap();
        assert!((weighted.layer(0).rho / plan.layer(0).rho - 1.0).abs() < 1e-5);
    }

    #[test]
    fn plan_for_budget_water_filling_favours_sensitive_layers() {
        // two identical layers, one 4x more noise-sensitive: the optimum
        // rho ratio is sqrt(4) = 2, and the budget still holds exactly
        let em = EnergyModel::new(5);
        let m = ModelDesc {
            name: "two".into(),
            layers: vec![LayerMeta::dense(128, 32), LayerMeta::dense(128, 32)],
        };
        let budget = 2.0;
        let plan = em
            .plan_for_budget(&m, budget, ReadMode::Original, Some(&[4.0, 1.0]))
            .unwrap();
        let r = plan.rhos();
        assert!(
            (r[0] / r[1] - 2.0).abs() < 1e-4,
            "water-filling ratio {} vs sqrt(4)",
            r[0] / r[1]
        );
        assert!((em.plan_uj(&m, &plan) - budget).abs() / budget < 1e-6);
        // and it beats the uniform plan on sensitivity-weighted sigma^2
        let uniform = em
            .plan_for_budget(&m, budget, ReadMode::Original, None)
            .unwrap();
        let cost = |p: &EnergyPlan| -> f64 {
            [4.0, 1.0]
                .iter()
                .zip(p.layers().iter())
                .map(|(g, l)| g * (l.sigma_rel(1.0) as f64).powi(2))
                .sum()
        };
        assert!(cost(&plan) < cost(&uniform));
        // a zero-sensitivity layer is floored, never starved to rho == 0
        // (which would be an invalid plan with infinite sigma)
        let floored = em
            .plan_for_budget(&m, budget, ReadMode::Original, Some(&[0.0, 1.0]))
            .unwrap();
        assert!(floored.validate(2).is_ok(), "{floored:?}");
        assert!(floored.layer(0).rho > 0.0 && floored.layer(0).rho < floored.layer(1).rho);
        // all-zero sensitivity: no allocation shape exists
        assert!(em
            .plan_for_budget(&m, budget, ReadMode::Original, Some(&[0.0, 0.0]))
            .is_none());
    }

    #[test]
    fn plan_from_trained_preserves_layer_ratios() {
        let em = EnergyModel::new(5);
        let m = ModelDesc {
            name: "two".into(),
            layers: vec![LayerMeta::dense(64, 48), LayerMeta::dense(48, 10)],
        };
        let trained = [2.0f32, 6.0];
        for budget in [0.5, 2.0, 8.0] {
            let plan = em
                .plan_from_trained(&m, &trained, budget, ReadMode::Original)
                .unwrap();
            assert_eq!(plan.source, PlanSource::Trained);
            let r = plan.rhos();
            assert!(
                (r[1] / r[0] - 3.0).abs() < 1e-4,
                "budget {budget}: trained 1:3 ratio must survive rescaling, got {r:?}"
            );
            assert!((em.plan_uj(&m, &plan) - budget).abs() / budget < 1e-6);
        }
        // below the peripheral floor: unsolvable, same as the analytic path
        assert!(em
            .plan_from_trained(&m, &trained, 1e-9, ReadMode::Original)
            .is_none());
    }

    #[test]
    fn plan_validate_rejects_bad_shapes() {
        let plan = EnergyPlan::uniform(3, 4.0, ReadMode::Original);
        assert!(plan.validate(3).is_ok());
        assert!(plan.validate(2).is_err(), "layer-count mismatch");
        let bad = EnergyPlan::new(
            vec![
                LayerPlan::new(4.0, ReadMode::Original),
                LayerPlan::new(f32::NAN, ReadMode::Original),
            ],
            PlanSource::Analytic,
        );
        assert!(bad.validate(2).is_err(), "non-finite rho");
        let neg = EnergyPlan::new(
            vec![LayerPlan::new(-1.0, ReadMode::Original)],
            PlanSource::Analytic,
        );
        assert!(neg.validate(1).is_err(), "non-positive rho");
        assert_eq!(plan.mean_rho(), 4.0);
        assert_eq!(plan.lead_mode(), ReadMode::Original);
        assert_eq!(PlanSource::Trained.name(), "trained");
    }

    #[test]
    fn energy_meter_rolls_its_window() {
        // 1 s window -> 62.5 ms slots, window_s exactly 1.0
        let m = EnergyMeter::new(Duration::from_secs(1));
        assert!((m.window_s() - 1.0).abs() < 1e-12);
        assert_eq!(m.rate_uj_s(0), 0.0);
        m.record(0, 50.0);
        m.record(10_000, 50.0); // same window
        assert!((m.rate_uj_s(10_000) - 100.0).abs() < 1e-9);
        // a fresh spend half a window later still sees the old one
        m.record(500_000, 100.0);
        assert!((m.rate_uj_s(500_000) - 200.0).abs() < 1e-9);
        // two windows later everything has fallen out
        assert_eq!(m.rate_uj_s(2_600_000), 0.0);
        // and slots are reused, not accumulated forever
        m.record(2_600_000, 30.0);
        assert!((m.rate_uj_s(2_600_000) - 30.0).abs() < 1e-9);
    }

    #[test]
    fn budget_shed_lanes_escalate_lowest_first() {
        let b = EnergyBudget { budget_uj_s: 10.0 };
        assert_eq!(b.shed_lanes(5.0, 3), 0, "under budget sheds nothing");
        assert_eq!(b.shed_lanes(10.0, 3), 0, "at budget sheds nothing");
        assert_eq!(b.shed_lanes(12.0, 3), 1, "just over: lowest tier only");
        assert_eq!(b.shed_lanes(20.0, 3), 2, "2x over: two lowest tiers");
        assert_eq!(b.shed_lanes(1e6, 3), 2, "the top tier is never shed");
        // a single-lane engine never sheds (its only lane is the top one)
        assert_eq!(b.shed_lanes(1e6, 1), 0);
        assert!((b.headroom_uj_s(4.0) - 6.0).abs() < 1e-12);
        assert!((b.headroom_uj_s(14.0) + 4.0).abs() < 1e-12);
    }

    #[test]
    fn budget_retry_after_tracks_window_decay() {
        let b = EnergyBudget { budget_uj_s: 10.0 };
        // under budget: minimal back-off
        assert_eq!(b.retry_after_s(5.0, 2.0), 1);
        // 2x over a 2 s window: half the window must decay -> 1 s
        assert_eq!(b.retry_after_s(20.0, 2.0), 1);
        // far over: approaches the full window, rounded up
        assert_eq!(b.retry_after_s(1e9, 2.0), 2);
        // clamped to the [1, 30] s hint range
        assert_eq!(b.retry_after_s(1e9, 100.0), 30);
    }

    #[test]
    fn depthwise_peripheral_overhead_dominates_conv() {
        // the paper's MobileNet observation (§5.1): depthwise layers read
        // only nine cells per output, so a much larger *fraction* of their
        // energy goes to the peripheral circuits than for regular convs.
        use crate::models::paper_scale::mobilenet;
        let em = EnergyModel::new(5);
        let m = mobilenet(Resolution::Cifar);
        let ratio = |meta: &crate::models::LayerMeta| {
            em.layer_peripheral_pj(meta, ReadMode::Original)
                / em.layer_cell_pj(meta, 1.0, ReadMode::Original)
        };
        let dw = m.layers.iter().find(|l| l.kind == "dwconv").unwrap();
        let conv = m
            .layers
            .iter()
            .filter(|l| l.kind == "conv")
            .max_by_key(|l| l.fan_in)
            .unwrap();
        assert!(
            ratio(dw) > 5.0 * ratio(conv),
            "depthwise peripheral fraction must dwarf conv: dw={} conv={}",
            ratio(dw),
            ratio(conv)
        );
    }
}
