//! Analytical energy model (NCPower-style [33][37] substitution).
//!
//! Per-layer analog read energy (eq. 19, Fig 2a):
//!
//! ```text
//! E_cell(layer)  = cells * alpha * E0_PJ * rho * mean|w|_norm * duty
//! ```
//!
//! where `duty` is the mean DAC level (original mode) or the mean number of
//! set bit-planes (decomposed mode).  Peripheral energy per read cycle is
//! DAC per active row + ADC per column; decomposed mode pays `B_a` cycles.
//!
//! Calibration: `E0_PJ`, `E_DAC_PJ`, `E_ADC_PJ` are chosen so that
//! VGG-16/CIFAR at rho == 1 lands in the paper's tens-of-uJ range; all
//! comparisons in EXPERIMENTS.md are ratios, which are calibration-free.

use crate::device::{self, Intensity};
use crate::models::{LayerMeta, ModelDesc};

/// Energy of one full-scale unit-level analog cell read at rho == 1 (pJ).
pub const E0_PJ: f64 = 0.05;
/// DAC energy per active row per read cycle (pJ).
pub const E_DAC_PJ: f64 = 0.02;
/// ADC energy per column conversion per read cycle (pJ).
pub const E_ADC_PJ: f64 = 0.2;

/// Read mode of the crossbar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadMode {
    /// Single analog read with a multi-bit DAC level (paper "original").
    Original,
    /// Technique C: bit-serial over `act_bits` planes.
    Decomposed,
}

impl ReadMode {
    /// Wire/report name (serving API responses, Prometheus labels).
    pub fn name(self) -> &'static str {
        match self {
            ReadMode::Original => "original",
            ReadMode::Decomposed => "decomposed",
        }
    }
}

/// Workload statistics of a trained model (measured or assumed).
#[derive(Clone, Copy, Debug)]
pub struct ReadStats {
    /// Mean |w| / w_scale over programmed cells (Gaussian init: ~0.25).
    pub mean_w_norm: f64,
    /// Mean DAC integer level per read, original mode.
    pub mean_level: f64,
    /// Mean set bit-planes per read, decomposed mode.
    pub mean_bits: f64,
}

impl ReadStats {
    /// Defaults for B_a activation bits assuming half-range uniform
    /// activation levels (used when no measured stats are available).
    pub fn assumed(act_bits: u32) -> Self {
        let max_level = ((1u64 << act_bits) - 1) as f64;
        ReadStats {
            mean_w_norm: 0.25,
            mean_level: 0.3 * max_level,
            mean_bits: 0.3 * act_bits as f64,
        }
    }
}

/// The analytical energy model.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub act_bits: u32,
    pub stats: ReadStats,
}

impl EnergyModel {
    pub fn new(act_bits: u32) -> Self {
        EnergyModel {
            act_bits,
            stats: ReadStats::assumed(act_bits),
        }
    }

    pub fn with_stats(act_bits: u32, stats: ReadStats) -> Self {
        EnergyModel { act_bits, stats }
    }

    fn duty(&self, mode: ReadMode) -> f64 {
        match mode {
            ReadMode::Original => self.stats.mean_level,
            ReadMode::Decomposed => self.stats.mean_bits,
        }
    }

    fn cycles_per_read(&self, mode: ReadMode) -> f64 {
        match mode {
            ReadMode::Original => 1.0,
            ReadMode::Decomposed => self.act_bits as f64,
        }
    }

    /// Analog cell energy of one layer per inference (pJ).
    pub fn layer_cell_pj(&self, meta: &LayerMeta, rho: f64, mode: ReadMode) -> f64 {
        meta.reads() as f64 * E0_PJ * rho * self.stats.mean_w_norm * self.duty(mode)
    }

    /// Peripheral (DAC + ADC) energy of one layer per inference (pJ).
    pub fn layer_peripheral_pj(&self, meta: &LayerMeta, mode: ReadMode) -> f64 {
        let cycles = meta.alpha as f64 * self.cycles_per_read(mode);
        cycles * (meta.fan_in as f64 * E_DAC_PJ + meta.out_features as f64 * E_ADC_PJ)
    }

    /// Total energy of one layer per inference (pJ).
    pub fn layer_pj(&self, meta: &LayerMeta, rho: f64, mode: ReadMode) -> f64 {
        self.layer_cell_pj(meta, rho, mode) + self.layer_peripheral_pj(meta, mode)
    }

    /// Whole-model energy per inference in uJ, with per-layer rho.
    pub fn model_uj(&self, model: &ModelDesc, rhos: &[f64], mode: ReadMode) -> f64 {
        assert_eq!(model.layers.len(), rhos.len(), "rho per layer");
        let pj: f64 = model
            .layers
            .iter()
            .zip(rhos.iter())
            .map(|(l, &r)| self.layer_pj(l, r, mode))
            .sum();
        pj * 1e-6
    }

    /// Whole-model energy with a single global rho.
    pub fn model_uj_uniform(&self, model: &ModelDesc, rho: f64, mode: ReadMode) -> f64 {
        let rhos = vec![rho; model.layers.len()];
        self.model_uj(model, &rhos, mode)
    }

    /// Invert `model_uj_uniform` for rho: find the global rho whose
    /// energy equals `budget_uj` (cell energy is linear in rho, peripheral
    /// constant, so this is a closed form).
    pub fn rho_for_budget(
        &self,
        model: &ModelDesc,
        budget_uj: f64,
        mode: ReadMode,
    ) -> Option<f64> {
        let peripheral_pj: f64 = model
            .layers
            .iter()
            .map(|l| self.layer_peripheral_pj(l, mode))
            .sum();
        let cell_at_rho1: f64 = model
            .layers
            .iter()
            .map(|l| self.layer_cell_pj(l, 1.0, mode))
            .sum();
        let remaining = budget_uj * 1e6 - peripheral_pj;
        if remaining <= 0.0 {
            return None; // budget below the peripheral floor
        }
        Some(remaining / cell_at_rho1)
    }
}

/// Fluctuation sigma that a model sees at a given uniform rho (relative to
/// full-scale). Convenience glue for accuracy-vs-energy sweeps.
pub fn sigma_at(rho: f64, intensity: Intensity) -> f64 {
    device::sigma_rel(rho as f32, intensity.factor()) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::paper_scale::{vgg16, Resolution};

    fn model() -> ModelDesc {
        vgg16(Resolution::Cifar)
    }

    #[test]
    fn energy_linear_in_rho() {
        let em = EnergyModel::new(5);
        let m = model();
        let e1 = em.model_uj_uniform(&m, 1.0, ReadMode::Original);
        let e2 = em.model_uj_uniform(&m, 2.0, ReadMode::Original);
        let peri: f64 = m
            .layers
            .iter()
            .map(|l| em.layer_peripheral_pj(l, ReadMode::Original))
            .sum::<f64>()
            * 1e-6;
        assert!(((e2 - peri) - 2.0 * (e1 - peri)).abs() < 1e-9);
    }

    #[test]
    fn decomposed_cell_energy_lower() {
        // eq (20): mean_bits << mean_level
        let em = EnergyModel::new(5);
        let m = model();
        let meta = &m.layers[0];
        assert!(
            em.layer_cell_pj(meta, 1.0, ReadMode::Decomposed)
                < em.layer_cell_pj(meta, 1.0, ReadMode::Original)
        );
    }

    #[test]
    fn decomposed_peripheral_higher() {
        let em = EnergyModel::new(5);
        let m = model();
        let meta = &m.layers[0];
        assert!(
            em.layer_peripheral_pj(meta, ReadMode::Decomposed)
                > em.layer_peripheral_pj(meta, ReadMode::Original)
        );
    }

    #[test]
    fn vgg16_cifar_in_paper_range() {
        // tens of uJ at moderate rho (Table 1 scale)
        let em = EnergyModel::new(5);
        let e = em.model_uj_uniform(&model(), 1.0, ReadMode::Original);
        assert!((5.0..200.0).contains(&e), "vgg16 energy {e} uJ");
    }

    #[test]
    fn rho_budget_roundtrip() {
        let em = EnergyModel::new(5);
        let m = model();
        let budget = 16.0;
        let rho = em.rho_for_budget(&m, budget, ReadMode::Original).unwrap();
        let back = em.model_uj_uniform(&m, rho, ReadMode::Original);
        assert!((back - budget).abs() / budget < 1e-9);
    }

    #[test]
    fn budget_below_peripheral_floor_is_none() {
        let em = EnergyModel::new(5);
        assert!(em
            .rho_for_budget(&model(), 1e-9, ReadMode::Original)
            .is_none());
    }

    #[test]
    fn depthwise_peripheral_overhead_dominates_conv() {
        // the paper's MobileNet observation (§5.1): depthwise layers read
        // only nine cells per output, so a much larger *fraction* of their
        // energy goes to the peripheral circuits than for regular convs.
        use crate::models::paper_scale::mobilenet;
        let em = EnergyModel::new(5);
        let m = mobilenet(Resolution::Cifar);
        let ratio = |meta: &crate::models::LayerMeta| {
            em.layer_peripheral_pj(meta, ReadMode::Original)
                / em.layer_cell_pj(meta, 1.0, ReadMode::Original)
        };
        let dw = m.layers.iter().find(|l| l.kind == "dwconv").unwrap();
        let conv = m
            .layers
            .iter()
            .filter(|l| l.kind == "conv")
            .max_by_key(|l| l.fan_in)
            .unwrap();
        assert!(
            ratio(dw) > 5.0 * ratio(conv),
            "depthwise peripheral fraction must dwarf conv: dw={} conv={}",
            ratio(dw),
            ratio(conv)
        );
    }
}
