//! Latency model.
//!
//! One crossbar read cycle (analog settle + ADC conversion) takes
//! [`T_READ_NS`].  All tiles of one layer fire in parallel; output
//! positions of a layer are sequential read cycles, so a layer costs
//! `alpha` cycles and a model costs `sum_l alpha_l` cycles per inference.
//! Decomposed mode multiplies by the `B_a` bit-planes; the multi-read
//! fluctuation-compensation baseline multiplies by its `K` reads.
//!
//! Calibrated at T_READ_NS = 1: VGG-16/CIFAR -> ~2.8 us and
//! ResNet-18/CIFAR -> ~6.8 us, matching Table 1, and the decomposed /
//! compensation variants land at the paper's 5x (B_a = 5).

use crate::energy::ReadMode;
use crate::models::ModelDesc;

/// Nanoseconds per crossbar read cycle.
pub const T_READ_NS: f64 = 1.0;

/// Latency model.
#[derive(Clone, Copy, Debug)]
pub struct TimingModel {
    pub act_bits: u32,
    /// Extra serial reads of the same cell (1 = single read; the
    /// fluctuation-compensation baseline uses K > 1).
    pub reads_per_cell: u32,
}

impl TimingModel {
    pub fn new(act_bits: u32) -> Self {
        TimingModel {
            act_bits,
            reads_per_cell: 1,
        }
    }

    pub fn with_multi_read(act_bits: u32, k: u32) -> Self {
        TimingModel {
            act_bits,
            reads_per_cell: k,
        }
    }

    fn cycle_multiplier(&self, mode: ReadMode) -> f64 {
        let base = match mode {
            ReadMode::Original => 1.0,
            ReadMode::Decomposed => self.act_bits as f64,
        };
        base * self.reads_per_cell as f64
    }

    /// Per-inference latency in microseconds.
    pub fn model_latency_us(&self, model: &ModelDesc, mode: ReadMode) -> f64 {
        model.total_cycles() as f64 * T_READ_NS * self.cycle_multiplier(mode) * 1e-3
    }

    /// Batched throughput (inferences/s) assuming perfect pipelining
    /// across `parallel_arrays` replicas.
    pub fn throughput(
        &self,
        model: &ModelDesc,
        mode: ReadMode,
        parallel_arrays: u32,
    ) -> f64 {
        let lat_s = self.model_latency_us(model, mode) * 1e-6;
        parallel_arrays as f64 / lat_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::paper_scale::{resnet, vgg16, Resolution};

    #[test]
    fn vgg16_cifar_close_to_paper() {
        // Table 1: 2.8 us
        let t = TimingModel::new(5);
        let us = t.model_latency_us(&vgg16(Resolution::Cifar), ReadMode::Original);
        assert!((2.0..3.6).contains(&us), "vgg {us} us");
    }

    #[test]
    fn resnet18_cifar_close_to_paper() {
        // Table 1: 6.8 us
        let t = TimingModel::new(5);
        let us = t.model_latency_us(&resnet(18, Resolution::Cifar), ReadMode::Original);
        assert!((5.5..8.0).contains(&us), "resnet {us} us");
    }

    #[test]
    fn decomposed_is_act_bits_slower() {
        // Table 1: ours(A+B+C) delay = 5x ours(A+B)
        let t = TimingModel::new(5);
        let m = vgg16(Resolution::Cifar);
        let a = t.model_latency_us(&m, ReadMode::Original);
        let b = t.model_latency_us(&m, ReadMode::Decomposed);
        assert!((b / a - 5.0).abs() < 1e-9);
    }

    #[test]
    fn multi_read_multiplies() {
        let t1 = TimingModel::new(5);
        let t5 = TimingModel::with_multi_read(5, 5);
        let m = vgg16(Resolution::Cifar);
        let a = t1.model_latency_us(&m, ReadMode::Original);
        let b = t5.model_latency_us(&m, ReadMode::Original);
        assert!((b / a - 5.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_inverse_of_latency() {
        let t = TimingModel::new(5);
        let m = vgg16(Resolution::Cifar);
        let lat = t.model_latency_us(&m, ReadMode::Original);
        let thr = t.throughput(&m, ReadMode::Original, 1);
        assert!((thr * lat * 1e-6 - 1.0).abs() < 1e-9);
    }
}
