//! Request-path tracing: per-stage spans from socket to crossbar tile.
//!
//! Always-on, dependency-free, and deliberately boring: a request picks
//! up a [`TraceContext`] at HTTP parse time (trace id = the request's
//! content-derived seed, anchors = monotonic `Instant`s) and the
//! scheduler/engine fill in a fixed-size [`SpanRecord`] as the request
//! moves through admission -> lane queue -> worker pickup (which lane,
//! which worker, stolen or home) -> batch formation -> device compute
//! (per-layer spans with observed uJ from the `ReadCounters` path) ->
//! response serialization/write.
//!
//! Three consumers (DESIGN.md §12):
//!
//! * per-stage latency histograms on `/metrics`
//!   (`emtopt_stage_latency_us{tier,stage}`, reusing
//!   [`metrics::LatencyHistogram`]);
//! * a lock-cheap [`FlightRecorder`] ring of the last N complete traces,
//!   dumped by `GET /admin/trace` as Chrome trace-event JSON (loadable
//!   in Perfetto / `chrome://tracing`), plus a `"trace": true` request
//!   flag echoing one request's breakdown inline;
//! * `loadgen` scrapes the stage histograms per ladder rung into the
//!   `stage_breakdown` section of `BENCH_serve.json`.
//!
//! Determinism contract: tracing reads clocks and energy counters and
//! writes atomics — it never touches the RNG stream, so noisy outputs
//! are bit-identical with tracing on (it is never off).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::metrics::LatencyHistogram;
use crate::util::json::Json;

/// Per-layer spans kept in the fixed-size record.  Deeper models get the
/// first `MAX_TRACE_LAYERS` layers traced and the rest folded into the
/// aggregate compute span — the record never allocates.
pub const MAX_TRACE_LAYERS: usize = 16;

/// Default flight-recorder capacity (last N complete traces).
pub const DEFAULT_FLIGHT_CAPACITY: usize = 256;

// ---------------------------------------------------------------------------
// span taxonomy
// ---------------------------------------------------------------------------

/// The four request-path stages every request passes through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Admission to worker pickup: time the request sat in its lane queue.
    QueueWait = 0,
    /// Worker pickup to batch dispatch: time spent waiting for the device
    /// batch to fill (or `max_wait` to expire).
    BatchWait = 1,
    /// Device batch forward: the crossbar compute the request rode in.
    Compute = 2,
    /// Response serialization + socket write-back.
    Write = 3,
}

/// Number of stages in [`Stage::ALL`].
pub const NUM_STAGES: usize = 4;

impl Stage {
    pub const ALL: [Stage; NUM_STAGES] =
        [Stage::QueueWait, Stage::BatchWait, Stage::Compute, Stage::Write];

    /// Prometheus label value / span name.
    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::BatchWait => "batch_wait",
            Stage::Compute => "compute",
            Stage::Write => "write",
        }
    }
}

/// Per-tier stage latency histograms — the `/metrics` consumer.  One
/// lock-free [`LatencyHistogram`] per stage, `Default`-constructible so
/// it lives inside `ServerStats` without touching its construction.
#[derive(Debug, Default)]
pub struct StageHistograms {
    hists: [LatencyHistogram; NUM_STAGES],
}

impl StageHistograms {
    pub fn record(&self, stage: Stage, us: u64) {
        self.hists[stage as usize].record_us(us);
    }

    pub fn hist(&self, stage: Stage) -> &LatencyHistogram {
        &self.hists[stage as usize]
    }
}

// ---------------------------------------------------------------------------
// the per-request record
// ---------------------------------------------------------------------------

/// Per-layer compute spans for one request: wall time and observed
/// energy per traced layer.  Fixed-size, index = layer index.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerSpans {
    pub us: [u32; MAX_TRACE_LAYERS],
    pub uj: [f32; MAX_TRACE_LAYERS],
    /// Number of layers the model actually has (clamped to
    /// [`MAX_TRACE_LAYERS`] for the arrays; the aggregate compute span
    /// still covers the untraced tail).
    pub n: usize,
}

impl LayerSpans {
    /// Add another sample's layer spans (client-batch requests attribute
    /// the sum of their samples to the request).
    pub fn merge(&mut self, other: &LayerSpans) {
        self.n = self.n.max(other.n);
        for i in 0..self.n.min(MAX_TRACE_LAYERS) {
            self.us[i] = self.us[i].saturating_add(other.us[i]);
            self.uj[i] += other.uj[i];
        }
    }
}

/// One request's complete span breakdown — the fixed-size record the
/// flight recorder keeps and `"trace": true` echoes inline.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SpanRecord {
    /// Content-derived request seed (same fold the noise seeding uses —
    /// read-only; tracing never advances any RNG).
    pub trace_id: u64,
    /// Microseconds since the flight recorder's epoch at HTTP parse time
    /// (the Chrome trace `ts` origin).
    pub start_us: u64,
    /// Lane index == energy tier index.
    pub tier: usize,
    /// Worker that dispatched the batch this request rode in.
    pub worker: usize,
    /// Whether the pick was a steal (worker's home lane != `tier`).
    pub stolen: bool,
    /// Images in the dispatched device batch (including padding slots'
    /// siblings — the amortisation this request actually got).
    pub batch_images: usize,
    /// Images in this request (1 for singles, >1 for client batches).
    pub images: usize,
    pub queue_wait_us: u64,
    pub batch_wait_us: u64,
    pub compute_us: u64,
    /// Response serialization + socket write (filled at the HTTP layer;
    /// zero in the inline `"trace": true` echo, whose bytes are already
    /// formed before the write happens).
    pub write_us: u64,
    /// End-to-end: HTTP parse start -> response written.  Zero until the
    /// HTTP layer completes the record.
    pub total_us: u64,
    /// Observed energy attributed to this request's samples (uJ).
    pub energy_uj: f64,
    /// Served from the exact result cache: the request skipped the
    /// scheduler entirely (queue/batch/compute spans stay zero, energy
    /// stays zero — the saved energy is credited to
    /// `emtopt_cache_saved_uj_total` instead).
    pub cache_hit: bool,
    pub layers: LayerSpans,
}

impl SpanRecord {
    /// Sum of the four stage spans — must never exceed `total_us` once
    /// the record is complete (parse/admission/reply-hop overhead is the
    /// remainder; pinned by tests).
    pub fn stage_sum_us(&self) -> u64 {
        self.queue_wait_us + self.batch_wait_us + self.compute_us + self.write_us
    }

    /// Inline JSON breakdown for the `"trace": true` response echo.
    /// `write_us`/`total_us` are omitted: the response bytes are formed
    /// before the write span can finish (use `/admin/trace` for those).
    pub fn to_inline_json(&self, tier_name: &str) -> Json {
        let mut layers = Vec::with_capacity(self.layers.n.min(MAX_TRACE_LAYERS));
        for i in 0..self.layers.n.min(MAX_TRACE_LAYERS) {
            layers.push(Json::obj(vec![
                ("layer", Json::Num(i as f64)),
                ("us", Json::Num(self.layers.us[i] as f64)),
                ("uj", Json::Num(self.layers.uj[i] as f64)),
            ]));
        }
        Json::obj(vec![
            ("trace_id", Json::Str(format!("{:#018x}", self.trace_id))),
            ("tier", Json::Str(tier_name.to_string())),
            ("worker", Json::Num(self.worker as f64)),
            ("stolen", Json::Bool(self.stolen)),
            ("batch_images", Json::Num(self.batch_images as f64)),
            ("queue_wait_us", Json::Num(self.queue_wait_us as f64)),
            ("batch_wait_us", Json::Num(self.batch_wait_us as f64)),
            ("compute_us", Json::Num(self.compute_us as f64)),
            ("energy_uj", Json::Num(self.energy_uj)),
            ("cache_hit", Json::Bool(self.cache_hit)),
            ("layers", Json::Arr(layers)),
        ])
    }
}

// ---------------------------------------------------------------------------
// trace context (HTTP-parse-time anchor)
// ---------------------------------------------------------------------------

/// Created at HTTP parse time and threaded through admission; the
/// scheduler copies `trace_id`/`start_us` into the [`SpanRecord`] it
/// returns with the reply.
#[derive(Clone, Copy, Debug)]
pub struct TraceContext {
    pub trace_id: u64,
    pub start_us: u64,
    /// Monotonic anchor at parse start — the `total_us` origin.
    pub t_start: Instant,
}

impl TraceContext {
    /// Context for internal (non-HTTP) submitters: spans still feed the
    /// stage histograms, the record just carries a zero id/origin.
    pub fn internal() -> TraceContext {
        TraceContext {
            trace_id: 0,
            start_us: 0,
            t_start: Instant::now(),
        }
    }
}

// ---------------------------------------------------------------------------
// flight recorder
// ---------------------------------------------------------------------------

/// Lock-cheap ring of the last N complete traces.
///
/// `push` claims a slot with one relaxed `fetch_add` and then
/// `try_lock`s only that slot; under contention the record is dropped
/// (counted), never blocked on — the request path must not stall on the
/// observer.  `snapshot` locks slots one at a time, so a dump can at
/// worst displace a handful of concurrent pushes.
#[derive(Debug)]
pub struct FlightRecorder {
    slots: Vec<Mutex<Option<SpanRecord>>>,
    cursor: AtomicUsize,
    dropped: AtomicU64,
    epoch: Instant,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> FlightRecorder {
        let capacity = capacity.max(1);
        FlightRecorder {
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
            cursor: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Microseconds since the recorder's epoch — the shared `ts` origin
    /// for every trace this process emits.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Records dropped because their slot was contended.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Store one complete record; drops (never blocks) under contention.
    pub fn push(&self, rec: SpanRecord) {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed) % self.slots.len();
        match self.slots[i].try_lock() {
            Ok(mut slot) => *slot = Some(rec),
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// The current ring contents, oldest-first by `start_us`.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let mut out: Vec<SpanRecord> = self
            .slots
            .iter()
            .filter_map(|s| s.try_lock().ok().and_then(|g| *g))
            .collect();
        out.sort_by_key(|r| r.start_us);
        out
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event rendering (GET /admin/trace)
// ---------------------------------------------------------------------------

/// Render records as Chrome trace-event JSON (the "JSON Array Format"
/// with a `traceEvents` wrapper) — loadable in Perfetto and
/// `chrome://tracing`.  Convention: `pid` = tier index (named via
/// process_name metadata), `tid` = worker index, `ts`/`dur` in
/// microseconds since the recorder epoch.  Stages are laid end-to-end
/// from `start_us`; the small parse/reply-hop gaps are folded into the
/// queue_wait start rather than drawn (documented in DESIGN.md §12).
pub fn to_chrome_json(records: &[SpanRecord], tier_names: &[&str]) -> Json {
    let mut events = Vec::new();
    let mut tiers_seen = [false; 16];
    for r in records {
        if let Some(seen) = tiers_seen.get_mut(r.tier) {
            if !*seen {
                *seen = true;
                let name = tier_names.get(r.tier).copied().unwrap_or("tier");
                events.push(Json::obj(vec![
                    ("name", Json::Str("process_name".into())),
                    ("ph", Json::Str("M".into())),
                    ("pid", Json::Num(r.tier as f64)),
                    ("tid", Json::Num(0.0)),
                    (
                        "args",
                        Json::obj(vec![("name", Json::Str(format!("tier:{name}")))]),
                    ),
                ]));
            }
        }
        let spans = [
            (Stage::QueueWait, r.queue_wait_us),
            (Stage::BatchWait, r.batch_wait_us),
            (Stage::Compute, r.compute_us),
            (Stage::Write, r.write_us),
        ];
        let mut ts = r.start_us;
        for (stage, dur) in spans {
            let mut args = vec![("trace_id", Json::Str(format!("{:#018x}", r.trace_id)))];
            if stage == Stage::Compute {
                args.push(("energy_uj", Json::Num(r.energy_uj)));
                args.push(("stolen", Json::Bool(r.stolen)));
                args.push(("batch_images", Json::Num(r.batch_images as f64)));
                args.push(("total_us", Json::Num(r.total_us as f64)));
                args.push(("cache_hit", Json::Bool(r.cache_hit)));
            }
            events.push(Json::obj(vec![
                ("name", Json::Str(stage.name().into())),
                ("cat", Json::Str("request".into())),
                ("ph", Json::Str("X".into())),
                ("ts", Json::Num(ts as f64)),
                ("dur", Json::Num(dur as f64)),
                ("pid", Json::Num(r.tier as f64)),
                ("tid", Json::Num(r.worker as f64)),
                ("args", Json::obj(args)),
            ]));
            if stage == Stage::Compute {
                let mut lts = ts;
                for i in 0..r.layers.n.min(MAX_TRACE_LAYERS) {
                    events.push(Json::obj(vec![
                        ("name", Json::Str(format!("layer{i}"))),
                        ("cat", Json::Str("layer".into())),
                        ("ph", Json::Str("X".into())),
                        ("ts", Json::Num(lts as f64)),
                        ("dur", Json::Num(r.layers.us[i] as f64)),
                        ("pid", Json::Num(r.tier as f64)),
                        ("tid", Json::Num(r.worker as f64)),
                        (
                            "args",
                            Json::obj(vec![("uj", Json::Num(r.layers.uj[i] as f64))]),
                        ),
                    ]));
                    lts += r.layers.us[i] as u64;
                }
            }
            ts += dur;
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

// ---------------------------------------------------------------------------
// build info
// ---------------------------------------------------------------------------

/// The provenance triple `/metrics` and `/healthz` both advertise
/// (standard Prometheus build-info pattern).  `rustc`/`git_sha` are
/// stamped by `build.rs` (falling back to "unknown" outside a git
/// checkout); the version is the crate version.
#[derive(Clone, Copy, Debug)]
pub struct BuildInfo {
    pub version: &'static str,
    pub rustc: &'static str,
    pub git_sha: &'static str,
}

/// The build-info triple baked into this binary.
pub fn build_info() -> BuildInfo {
    BuildInfo {
        version: env!("CARGO_PKG_VERSION"),
        rustc: env!("EMTOPT_RUSTC"),
        git_sha: env!("EMTOPT_GIT_SHA"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(trace_id: u64, start_us: u64) -> SpanRecord {
        SpanRecord {
            trace_id,
            start_us,
            tier: 1,
            worker: 0,
            stolen: false,
            batch_images: 4,
            images: 1,
            queue_wait_us: 10,
            batch_wait_us: 20,
            compute_us: 300,
            write_us: 5,
            total_us: 400,
            energy_uj: 1.25,
            cache_hit: false,
            layers: LayerSpans {
                us: {
                    let mut a = [0u32; MAX_TRACE_LAYERS];
                    a[0] = 200;
                    a[1] = 100;
                    a
                },
                uj: {
                    let mut a = [0f32; MAX_TRACE_LAYERS];
                    a[0] = 1.0;
                    a[1] = 0.25;
                    a
                },
                n: 2,
            },
        }
    }

    #[test]
    fn stage_names_and_order() {
        let names: Vec<_> = Stage::ALL.iter().map(|s| s.name()).collect();
        assert_eq!(names, ["queue_wait", "batch_wait", "compute", "write"]);
        let h = StageHistograms::default();
        h.record(Stage::Compute, 42);
        assert_eq!(h.hist(Stage::Compute).count(), 1);
        assert_eq!(h.hist(Stage::QueueWait).count(), 0);
    }

    #[test]
    fn stage_sum_is_bounded_by_total() {
        let r = rec(7, 0);
        assert!(r.stage_sum_us() <= r.total_us);
        assert_eq!(r.stage_sum_us(), 335);
    }

    #[test]
    fn ring_keeps_last_n_oldest_first() {
        let fr = FlightRecorder::new(4);
        for i in 0..10u64 {
            fr.push(rec(i, i * 100));
        }
        let snap = fr.snapshot();
        assert_eq!(snap.len(), 4);
        let ids: Vec<u64> = snap.iter().map(|r| r.trace_id).collect();
        assert_eq!(ids, [6, 7, 8, 9]);
        assert_eq!(fr.dropped(), 0);
    }

    #[test]
    fn ring_wraps_under_concurrent_load_without_losing_structure() {
        use std::sync::Arc;
        let fr = Arc::new(FlightRecorder::new(16));
        let threads: Vec<_> = (0..4u64)
            .map(|t| {
                let fr = fr.clone();
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        fr.push(rec(t * 1000 + i, t * 1000 + i));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let snap = fr.snapshot();
        // dropped-not-blocked: whatever survived is structurally intact
        assert!(snap.len() <= 16);
        assert!(!snap.is_empty());
        for r in &snap {
            // every record is one of the pushed ones, not torn
            assert_eq!(r.trace_id, r.start_us);
            assert_eq!(r.stage_sum_us(), 335);
        }
        // the ring saw 2000 pushes; drops are possible but bounded by
        // actual contention, not systematic
        assert!(fr.dropped() < 2000);
    }

    #[test]
    fn chrome_json_shape_parses_and_orders() {
        let records = [rec(1, 100), rec(2, 700)];
        let j = to_chrome_json(&records, &["low", "normal", "high"]);
        let text = j.render();
        let back = Json::parse(&text).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process_name metadata + per record: 4 stage + 2 layer events
        assert_eq!(events.len(), 1 + 2 * (4 + 2));
        let meta = &events[0];
        assert_eq!(meta.get("ph").unwrap().as_str().unwrap(), "M");
        assert_eq!(
            meta.get("args").unwrap().get("name").unwrap().as_str().unwrap(),
            "tier:normal"
        );
        // complete events: stages laid end-to-end from start_us
        let stages: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("cat").map(|c| c.as_str().unwrap()) == Ok("request"))
            .collect();
        assert_eq!(stages.len(), 8);
        let first = stages[0];
        assert_eq!(first.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(first.get("name").unwrap().as_str().unwrap(), "queue_wait");
        assert_eq!(first.get("ts").unwrap().as_u64().unwrap(), 100);
        let compute = stages
            .iter()
            .find(|e| e.get("name").unwrap().as_str().unwrap() == "compute")
            .unwrap();
        assert_eq!(compute.get("ts").unwrap().as_u64().unwrap(), 130);
        assert_eq!(compute.get("dur").unwrap().as_u64().unwrap(), 300);
        let args = compute.get("args").unwrap();
        assert!(args.get("energy_uj").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            args.get("trace_id").unwrap().as_str().unwrap(),
            "0x0000000000000001"
        );
    }

    #[test]
    fn inline_json_echo_shape() {
        let j = rec(0xabc, 0).to_inline_json("low");
        assert_eq!(j.get("tier").unwrap().as_str().unwrap(), "low");
        assert_eq!(j.get("queue_wait_us").unwrap().as_u64().unwrap(), 10);
        assert_eq!(j.get("compute_us").unwrap().as_u64().unwrap(), 300);
        assert_eq!(j.get("layers").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.get("trace_id").unwrap().as_str().unwrap(),
            "0x0000000000000abc"
        );
        // write/total are NOT echoed inline (bytes formed pre-write)
        assert!(j.opt("write_us").is_none());
        assert!(j.opt("total_us").is_none());
        // the bypass marker is always echoed (false on the compute path)
        assert_eq!(j.get("cache_hit").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn layer_spans_merge_sums() {
        let mut a = rec(1, 0).layers;
        let b = rec(2, 0).layers;
        a.merge(&b);
        assert_eq!(a.n, 2);
        assert_eq!(a.us[0], 400);
        assert_eq!(a.uj[1], 0.5);
    }

    #[test]
    fn build_info_is_stamped() {
        let b = build_info();
        assert!(!b.version.is_empty());
        assert!(!b.rustc.is_empty());
        assert!(!b.git_sha.is_empty());
    }
}
