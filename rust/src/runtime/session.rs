//! Train / eval / predict sessions over the AOT artifacts.
//!
//! The flat argument convention is defined in `python/compile/model.py`
//! (docstring) and mirrored here:
//!
//! ```text
//! train  in:  params(2L) rho_raw m(2L) v(2L) m_rho v_rho
//!             step x y seed intensity lam rho_gate noise_gate
//! train  out: params'(2L) rho_raw' m'(2L) v'(2L) m_rho' v_rho'
//!             loss acc energy
//! eval   in:  params(2L) rho_raw x y seed intensity noise_gate
//! eval   out: top1 top5 loss_sum energy
//! predict in: params(2L) rho_raw x seed intensity noise_gate
//! predict out: logits
//! ```

use super::{execute, lit_f32, lit_i32, scalar_f32, scalar_i32, to_vec_f32, Artifacts};
use crate::data::IMG_LEN;
use crate::Result;

/// Gate/knob inputs of one train step (solution selection, Fig 4).
#[derive(Clone, Copy, Debug)]
pub struct TrainKnobs {
    pub seed: i32,
    pub intensity: f32,
    pub lam: f32,
    pub rho_gate: f32,
    pub noise_gate: f32,
}

impl TrainKnobs {
    /// Traditional optimizer: no noise awareness, fixed rho.
    pub fn traditional() -> Self {
        TrainKnobs {
            seed: 0,
            intensity: 1.0,
            lam: 0.0,
            rho_gate: 0.0,
            noise_gate: 0.0,
        }
    }

    /// Solution A: device-enhanced dataset (noise-aware training).
    pub fn solution_a(intensity: f32) -> Self {
        TrainKnobs {
            seed: 0,
            intensity,
            lam: 0.0,
            rho_gate: 0.0,
            noise_gate: 1.0,
        }
    }

    /// Solutions A+B / A+B+C: + energy regularization with trainable rho.
    pub fn solution_ab(intensity: f32, lam: f32) -> Self {
        TrainKnobs {
            seed: 0,
            intensity,
            lam,
            rho_gate: 1.0,
            noise_gate: 1.0,
        }
    }
}

/// Scalar outputs of one train step.
#[derive(Clone, Copy, Debug)]
pub struct TrainOutput {
    pub loss: f32,
    pub acc: f32,
    /// Normalised analog read energy of the batch (device units).
    pub energy: f32,
}

/// Owns the train executable + optimizer state for one model.
pub struct Trainer {
    exe: xla::PjRtLoadedExecutable,
    pub model_key: String,
    pub batch: usize,
    pub n_layers: usize,
    n_params: usize,
    params: Vec<xla::Literal>,
    rho_raw: Vec<f32>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    m_rho: Vec<f32>,
    v_rho: Vec<f32>,
    pub step: u32,
}

impl Trainer {
    /// Compile the train artifact and initialise parameters through the
    /// model's `init` artifact (He init, identical to the Python tests).
    pub fn new(arts: &Artifacts, model_key: &str, decomposed: bool, seed: i32) -> Result<Self> {
        let info = arts.model(model_key)?.clone();
        let kind = if decomposed { "train_decomp" } else { "train" };
        let train_info = arts.manifest.artifact(&format!("{model_key}_{kind}"))?;
        let exe = arts.runtime.load_hlo(&arts.dir.join(&train_info.file))?;

        let init_info = arts.manifest.artifact(&format!("{model_key}_init"))?;
        let init_exe = arts.runtime.load_hlo(&arts.dir.join(&init_info.file))?;
        let mut outs = execute(&init_exe, &[scalar_i32(seed)])?;
        let rho_lit = outs.pop().ok_or_else(|| anyhow::anyhow!("empty init output"))?;
        let rho_raw = to_vec_f32(&rho_lit)?;
        let params = outs;
        let n_params = params.len();
        anyhow::ensure!(n_params == 2 * info.n_layers, "init output mismatch");

        // zero optimizer state, shaped like params
        let mut m = Vec::with_capacity(n_params);
        let mut v = Vec::with_capacity(n_params);
        for (i, spec) in train_info.inputs.iter().enumerate().take(n_params) {
            let _ = i;
            let zeros = vec![0.0f32; spec.numel()];
            m.push(lit_f32(&zeros, &spec.shape)?);
            v.push(lit_f32(&zeros, &spec.shape)?);
        }
        let batch = arts.manifest.batches.train;
        Ok(Trainer {
            exe,
            model_key: model_key.to_string(),
            batch,
            n_layers: info.n_layers,
            n_params,
            params,
            rho_raw,
            m,
            v,
            m_rho: vec![0.0; info.n_layers],
            v_rho: vec![0.0; info.n_layers],
            step: 0,
        })
    }

    /// Run one train step on a host batch (x: NHWC flattened, y labels).
    pub fn step(&mut self, x: &[f32], y: &[i32], knobs: &TrainKnobs) -> Result<TrainOutput> {
        anyhow::ensure!(x.len() == self.batch * IMG_LEN, "bad x batch");
        anyhow::ensure!(y.len() == self.batch, "bad y batch");
        let n = self.n_params;
        let l = self.n_layers;

        let rho_lit = lit_f32(&self.rho_raw, &[l])?;
        let m_rho_lit = lit_f32(&self.m_rho, &[l])?;
        let v_rho_lit = lit_f32(&self.v_rho, &[l])?;
        let step_lit = scalar_f32(self.step as f32);
        let x_lit = lit_f32(x, &[self.batch, 32, 32, 3])?;
        let y_lit = lit_i32(y, &[self.batch])?;
        let seed_lit = scalar_i32(knobs.seed);
        let inten_lit = scalar_f32(knobs.intensity);
        let lam_lit = scalar_f32(knobs.lam);
        let rho_gate_lit = scalar_f32(knobs.rho_gate);
        let noise_gate_lit = scalar_f32(knobs.noise_gate);

        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * n + 11);
        args.extend(self.params.iter());
        args.push(&rho_lit);
        args.extend(self.m.iter());
        args.extend(self.v.iter());
        args.push(&m_rho_lit);
        args.push(&v_rho_lit);
        args.extend([
            &step_lit,
            &x_lit,
            &y_lit,
            &seed_lit,
            &inten_lit,
            &lam_lit,
            &rho_gate_lit,
            &noise_gate_lit,
        ]);

        let mut outs = execute(&self.exe, &args)?;
        anyhow::ensure!(outs.len() == 3 * n + 3 + 3, "train output arity");
        let energy = to_vec_f32(&outs.pop().unwrap())?[0];
        let acc = to_vec_f32(&outs.pop().unwrap())?[0];
        let loss = to_vec_f32(&outs.pop().unwrap())?[0];
        self.v_rho = to_vec_f32(&outs.pop().unwrap())?;
        self.m_rho = to_vec_f32(&outs.pop().unwrap())?;
        self.v = outs.split_off(2 * n + 1);
        self.m = outs.split_off(n + 1);
        self.rho_raw = to_vec_f32(&outs.pop().unwrap())?;
        self.params = outs;
        self.step += 1;
        Ok(TrainOutput { loss, acc, energy })
    }

    pub fn params(&self) -> &[xla::Literal] {
        &self.params
    }

    pub fn rho_raw(&self) -> &[f32] {
        &self.rho_raw
    }

    /// Trained per-layer rho values.
    pub fn rho(&self) -> Vec<f32> {
        self.rho_raw.iter().map(|&r| super::rho_of_raw(r)).collect()
    }

    /// Override rho (used by sweeps that scale the energy coefficient).
    pub fn set_rho_raw(&mut self, raw: Vec<f32>) {
        assert_eq!(raw.len(), self.n_layers);
        self.rho_raw = raw;
    }

    /// Replace the parameters (e.g. resume from a cached pretrain) and
    /// reset the optimizer state.
    pub fn set_params(&mut self, params: &[(Vec<usize>, Vec<f32>)]) -> Result<()> {
        anyhow::ensure!(params.len() == self.n_params, "param count mismatch");
        let mut lits = Vec::with_capacity(params.len());
        let mut m = Vec::with_capacity(params.len());
        let mut v = Vec::with_capacity(params.len());
        for (shape, data) in params {
            lits.push(lit_f32(data, shape)?);
            let zeros = vec![0.0f32; data.len()];
            m.push(lit_f32(&zeros, shape)?);
            v.push(lit_f32(&zeros, shape)?);
        }
        self.params = lits;
        self.m = m;
        self.v = v;
        self.m_rho = vec![0.0; self.n_layers];
        self.v_rho = vec![0.0; self.n_layers];
        self.step = 0;
        Ok(())
    }
}

/// Aggregated evaluation metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub samples: u32,
    pub top1: u32,
    pub top5: u32,
    pub loss_sum: f64,
    /// Normalised analog energy summed over batches (device units).
    pub energy: f64,
}

impl EvalResult {
    pub fn top1_acc(&self) -> f64 {
        self.top1 as f64 / self.samples.max(1) as f64
    }

    pub fn top5_acc(&self) -> f64 {
        self.top5 as f64 / self.samples.max(1) as f64
    }

    pub fn mean_loss(&self) -> f64 {
        self.loss_sum / self.samples.max(1) as f64
    }

    pub fn merge(&mut self, other: &EvalResult) {
        self.samples += other.samples;
        self.top1 += other.top1;
        self.top5 += other.top5;
        self.loss_sum += other.loss_sum;
        self.energy += other.energy;
    }
}

/// Owns an eval executable for one (model, read-mode).
pub struct Evaluator {
    exe: xla::PjRtLoadedExecutable,
    pub model_key: String,
    pub batch: usize,
    pub decomposed: bool,
}

impl Evaluator {
    pub fn new(arts: &Artifacts, model_key: &str, decomposed: bool) -> Result<Self> {
        let kind = if decomposed { "eval_decomp" } else { "eval" };
        let info = arts.manifest.artifact(&format!("{model_key}_{kind}"))?;
        let exe = arts.runtime.load_hlo(&arts.dir.join(&info.file))?;
        Ok(Evaluator {
            exe,
            model_key: model_key.to_string(),
            batch: arts.manifest.batches.eval,
            decomposed,
        })
    }

    /// Evaluate one batch.
    pub fn eval_batch(
        &self,
        params: &[xla::Literal],
        rho_raw: &[f32],
        x: &[f32],
        y: &[i32],
        seed: i32,
        intensity: f32,
        noise_gate: f32,
    ) -> Result<EvalResult> {
        anyhow::ensure!(x.len() == self.batch * IMG_LEN, "bad x batch");
        let rho_lit = lit_f32(rho_raw, &[rho_raw.len()])?;
        let x_lit = lit_f32(x, &[self.batch, 32, 32, 3])?;
        let y_lit = lit_i32(y, &[self.batch])?;
        let seed_lit = scalar_i32(seed);
        let inten_lit = scalar_f32(intensity);
        let gate_lit = scalar_f32(noise_gate);
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(params.len() + 6);
        args.extend(params.iter());
        args.extend([&rho_lit, &x_lit, &y_lit, &seed_lit, &inten_lit, &gate_lit]);
        let outs = execute(&self.exe, &args)?;
        anyhow::ensure!(outs.len() == 4, "eval output arity");
        Ok(EvalResult {
            samples: self.batch as u32,
            top1: to_vec_f32(&outs[0])?[0] as u32,
            top5: to_vec_f32(&outs[1])?[0] as u32,
            loss_sum: to_vec_f32(&outs[2])?[0] as f64,
            energy: to_vec_f32(&outs[3])?[0] as f64,
        })
    }
}

/// Owns a predict executable (logit service for the router example).
pub struct Predictor {
    exe: xla::PjRtLoadedExecutable,
    pub model_key: String,
    pub batch: usize,
    pub num_classes: usize,
}

impl Predictor {
    pub fn new(arts: &Artifacts, model_key: &str) -> Result<Self> {
        let info = arts.manifest.artifact(&format!("{model_key}_predict"))?;
        let exe = arts.runtime.load_hlo(&arts.dir.join(&info.file))?;
        let num_classes = arts.model(model_key)?.num_classes;
        Ok(Predictor {
            exe,
            model_key: model_key.to_string(),
            batch: arts.manifest.batches.predict,
            num_classes,
        })
    }

    /// Run a batch of images through the noisy model; returns flat logits
    /// (batch * num_classes).
    pub fn predict(
        &self,
        params: &[xla::Literal],
        rho_raw: &[f32],
        x: &[f32],
        seed: i32,
        intensity: f32,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(x.len() == self.batch * IMG_LEN, "bad x batch");
        let rho_lit = lit_f32(rho_raw, &[rho_raw.len()])?;
        let x_lit = lit_f32(x, &[self.batch, 32, 32, 3])?;
        let seed_lit = scalar_i32(seed);
        let inten_lit = scalar_f32(intensity);
        let gate_lit = scalar_f32(1.0);
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(params.len() + 5);
        args.extend(params.iter());
        args.extend([&rho_lit, &x_lit, &seed_lit, &inten_lit, &gate_lit]);
        let outs = execute(&self.exe, &args)?;
        to_vec_f32(&outs[0])
    }
}
