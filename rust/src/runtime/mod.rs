//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin).  The interchange format
//! is HLO **text** — xla_extension 0.5.1 rejects jax>=0.5 serialized
//! protos (64-bit instruction ids); the text parser reassigns ids.
//! Pattern adapted from /opt/xla-example/load_hlo/.
//!
//! Everything touching PJRT/XLA is behind the default-off `aot` feature
//! (see rust/Cargo.toml), so the native execution engine builds without
//! the XLA toolchain.  The manifest schema and the rho parameterisation
//! helpers below are plain Rust and always available.

pub mod manifest;
#[cfg(feature = "aot")]
pub mod session;

pub use manifest::{ArtifactInfo, Manifest, ModelInfo, TensorSpec};
#[cfg(feature = "aot")]
pub use session::{EvalResult, Evaluator, Predictor, TrainOutput, Trainer};

#[cfg(feature = "aot")]
use std::collections::HashMap;
#[cfg(feature = "aot")]
use std::path::{Path, PathBuf};

#[cfg(feature = "aot")]
use crate::Result;

/// Shared PJRT CPU client.
#[cfg(feature = "aot")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "aot")]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
    }
}

/// Artifact store: manifest + lazily compiled executables.
#[cfg(feature = "aot")]
pub struct Artifacts {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub runtime: Runtime,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "aot")]
impl Artifacts {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        Ok(Artifacts {
            dir,
            manifest,
            runtime: Runtime::cpu()?,
            cache: HashMap::new(),
        })
    }

    /// Default artifact dir: $EMTOPT_ARTIFACTS or ./artifacts.
    pub fn open_default() -> Result<Self> {
        let dir =
            std::env::var("EMTOPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    /// Get (compiling on first use) the executable of artifact `name`.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let info = self.manifest.artifact(name)?.clone();
            let exe = self.runtime.load_hlo(&self.dir.join(&info.file))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    pub fn model(&self, key: &str) -> Result<&ModelInfo> {
        self.manifest.model(key)
    }
}

// ---------------------------------------------------------------------------
// literal helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal of the given shape.
#[cfg(feature = "aot")]
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    anyhow::ensure!(data.len() == numel, "shape/data mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Build an i32 literal of the given shape.
#[cfg(feature = "aot")]
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    anyhow::ensure!(data.len() == numel, "shape/data mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// (1,)-shaped f32 literal (the flat-signature scalar convention).
#[cfg(feature = "aot")]
pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::vec1(&[v])
}

/// (1,)-shaped i32 literal.
#[cfg(feature = "aot")]
pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::vec1(&[v])
}

/// Execute an executable on literal args and unpack the tuple of outputs.
/// Accepts owned or borrowed literals (`&[Literal]` or `&[&Literal]`).
#[cfg(feature = "aot")]
pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
    exe: &xla::PjRtLoadedExecutable,
    args: &[L],
) -> Result<Vec<xla::Literal>> {
    let result = exe
        .execute::<L>(args)
        .map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("{e:?}"))?;
    lit.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Read an f32 literal back into a Vec.
#[cfg(feature = "aot")]
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
}

// ---------------------------------------------------------------------------
// rho parameterisation helpers (mirror python models.rho_of)
// ---------------------------------------------------------------------------

/// rho = clip(softplus(raw), 0.05, 100)
pub fn rho_of_raw(raw: f32) -> f32 {
    let sp = if raw > 30.0 { raw } else { (raw.exp() + 1.0).ln() };
    sp.clamp(0.05, 100.0)
}

/// Inverse of `rho_of_raw` on its open interval: raw = ln(e^rho - 1).
pub fn raw_of_rho(rho: f32) -> f32 {
    let r = rho.clamp(0.0501, 99.9);
    if r > 30.0 {
        r
    } else {
        (r.exp() - 1.0).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rho_raw_roundtrip() {
        for rho in [0.06f32, 0.5, 1.0, 4.0, 20.0, 90.0] {
            let raw = raw_of_rho(rho);
            let back = rho_of_raw(raw);
            assert!((back - rho).abs() / rho < 1e-4, "{rho} -> {raw} -> {back}");
        }
    }

    #[test]
    fn rho_clipped() {
        assert_eq!(rho_of_raw(-100.0), 0.05);
        assert_eq!(rho_of_raw(1000.0), 100.0);
    }
}
