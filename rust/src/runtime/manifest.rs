//! `artifacts/manifest.json` schema — written by `python/compile/aot.py`,
//! parsed with the in-crate JSON parser (`util::json`).

use std::collections::HashMap;
use std::path::Path;

use crate::models::LayerMeta;
use crate::util::json::Json;
use crate::Result;

#[derive(Clone, Debug)]
pub struct Manifest {
    pub device: DeviceMeta,
    pub batches: Batches,
    pub models: HashMap<String, ModelInfo>,
    pub artifacts: Vec<ArtifactInfo>,
}

#[derive(Clone, Debug)]
pub struct DeviceMeta {
    pub num_states: usize,
    pub k_f: f32,
    pub intensity: HashMap<String, f32>,
    pub act_bits: u32,
    pub weight_bits: u32,
    pub e0: f32,
}

#[derive(Clone, Debug)]
pub struct Batches {
    pub train: usize,
    pub eval: usize,
    pub predict: usize,
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub model: String,
    pub num_classes: usize,
    pub n_layers: usize,
    pub layer_meta: Vec<LayerMeta>,
}

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub model: String,
    pub kind: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }
}

fn layer_meta_from_json(j: &Json) -> Result<LayerMeta> {
    Ok(LayerMeta {
        kind: j.get("kind")?.as_str()?.to_string(),
        cells: j.get("cells")?.as_u64()?,
        fan_in: j.get("fan_in")?.as_u64()?,
        alpha: j.get("alpha")?.as_u64()?,
        out_features: j.get("out_features")?.as_u64()?,
    })
}

impl Manifest {
    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;

        let d = j.get("device")?;
        let mut intensity = HashMap::new();
        for (k, v) in d.get("intensity")?.as_obj()? {
            intensity.insert(k.clone(), v.as_f64()? as f32);
        }
        let device = DeviceMeta {
            num_states: d.get("num_states")?.as_usize()?,
            k_f: d.get("k_f")?.as_f64()? as f32,
            intensity,
            act_bits: d.get("act_bits")?.as_u64()? as u32,
            weight_bits: d.get("weight_bits")?.as_u64()? as u32,
            e0: d.get("e0")?.as_f64()? as f32,
        };

        let b = j.get("batches")?;
        let batches = Batches {
            train: b.get("train")?.as_usize()?,
            eval: b.get("eval")?.as_usize()?,
            predict: b.get("predict")?.as_usize()?,
        };

        let mut models = HashMap::new();
        for (key, m) in j.get("models")?.as_obj()? {
            let layer_meta = m
                .get("layer_meta")?
                .as_arr()?
                .iter()
                .map(layer_meta_from_json)
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                key.clone(),
                ModelInfo {
                    model: m.get("model")?.as_str()?.to_string(),
                    num_classes: m.get("num_classes")?.as_usize()?,
                    n_layers: m.get("n_layers")?.as_usize()?,
                    layer_meta,
                },
            );
        }

        let mut artifacts = Vec::new();
        for a in j.get("artifacts")?.as_arr()? {
            let inputs = a
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactInfo {
                name: a.get("name")?.as_str()?.to_string(),
                model: a.get("model")?.as_str()?.to_string(),
                kind: a.get("kind")?.as_str()?.to_string(),
                file: a.get("file")?.as_str()?.to_string(),
                inputs,
                outputs,
            });
        }

        Ok(Manifest {
            device,
            batches,
            models,
            artifacts,
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json_text(&text)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn model(&self, key: &str) -> Result<&ModelInfo> {
        self.models
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("model {key:?} not in manifest"))
    }

    /// Keys of all models in the manifest (sorted for determinism).
    pub fn model_keys(&self) -> Vec<String> {
        let mut keys: Vec<String> = self.models.keys().cloned().collect();
        keys.sort();
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "device": {"num_states": 4, "k_f": 0.04,
                   "intensity": {"weak": 0.5, "normal": 1.0, "strong": 2.0},
                   "act_bits": 5, "weight_bits": 8, "e0": 1.0},
        "batches": {"train": 64, "eval": 256, "predict": 16},
        "models": {"mlp_10": {"model": "mlp", "num_classes": 10, "n_layers": 3,
            "layer_meta": [{"kind": "dense", "cells": 786432, "fan_in": 3072,
                            "alpha": 1, "out_features": 256}]}},
        "artifacts": [{"name": "mlp_10_eval", "model": "mlp_10", "kind": "eval",
            "file": "mlp_10_eval.hlo.txt",
            "inputs": [{"name": "param0", "shape": [3072, 256], "dtype": "f32"}],
            "outputs": [{"name": "out0", "shape": [1], "dtype": "f32"}]}]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert_eq!(m.device.act_bits, 5);
        assert_eq!(m.device.intensity["strong"], 2.0);
        assert_eq!(m.batches.eval, 256);
        assert_eq!(m.model("mlp_10").unwrap().n_layers, 3);
        let a = m.artifact("mlp_10_eval").unwrap();
        assert_eq!(a.inputs[0].numel(), 3072 * 256);
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn layer_meta_reads() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        let meta = &m.model("mlp_10").unwrap().layer_meta[0];
        assert_eq!(meta.reads(), 786432);
    }

    #[test]
    fn model_keys_sorted() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert_eq!(m.model_keys(), vec!["mlp_10".to_string()]);
    }
}
