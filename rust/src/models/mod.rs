//! Model descriptions.
//!
//! Two families:
//!  * **paper-scale tables** ([`paper_scale`]): layer-by-layer descriptions
//!    of the exact evaluation models of the paper (VGG-16, ResNet-18/34,
//!    MobileNet at CIFAR-10 / ImageNet resolutions).  These drive the
//!    energy / #cells / delay accounting of Tables 1–2 (the paper reports
//!    these from an analytical model too, DESIGN.md §2).
//!  * **tiny zoo** (from `artifacts/manifest.json`): the scaled-down
//!    trainable stand-ins whose accuracy experiments run through the AOT
//!    artifacts.

pub mod paper_scale;

/// Static metadata of one crossbar-mapped layer.
///
/// * `cells`  — number of EMT cells (== number of weights; one bipolar
///   multi-level cell per weight in our scheme),
/// * `fan_in` — crossbar rows contributing to one output (K of the MAC),
/// * `alpha`  — reads of each weight per inference (conv: output area),
/// * `out_features` — columns (ADC conversions per read cycle).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerMeta {
    pub kind: String,
    pub cells: u64,
    pub fan_in: u64,
    pub alpha: u64,
    pub out_features: u64,
}

impl LayerMeta {
    pub fn conv(k: u64, cin: u64, cout: u64, out_hw: u64) -> Self {
        LayerMeta {
            kind: "conv".into(),
            cells: k * k * cin * cout,
            fan_in: k * k * cin,
            alpha: out_hw * out_hw,
            out_features: cout,
        }
    }

    pub fn dwconv(k: u64, c: u64, out_hw: u64) -> Self {
        LayerMeta {
            kind: "dwconv".into(),
            cells: k * k * c,
            fan_in: k * k,
            alpha: out_hw * out_hw,
            out_features: c,
        }
    }

    pub fn dense(d_in: u64, d_out: u64) -> Self {
        LayerMeta {
            kind: "dense".into(),
            cells: d_in * d_out,
            fan_in: d_in,
            alpha: 1,
            out_features: d_out,
        }
    }

    /// Total weight reads per inference.
    pub fn reads(&self) -> u64 {
        self.cells * self.alpha
    }
}

/// A named stack of crossbar layers.
#[derive(Clone, Debug)]
pub struct ModelDesc {
    pub name: String,
    pub layers: Vec<LayerMeta>,
}

impl ModelDesc {
    pub fn total_cells(&self) -> u64 {
        self.layers.iter().map(|l| l.cells).sum()
    }

    pub fn total_reads(&self) -> u64 {
        self.layers.iter().map(|l| l.reads()).sum()
    }

    /// Total read cycles per inference (each output position of each layer
    /// is one crossbar read cycle; tiles of one layer fire in parallel).
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.alpha).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_meta() {
        let m = LayerMeta::conv(3, 64, 128, 16);
        assert_eq!(m.cells, 3 * 3 * 64 * 128);
        assert_eq!(m.fan_in, 576);
        assert_eq!(m.alpha, 256);
        assert_eq!(m.reads(), m.cells * 256);
    }

    #[test]
    fn dwconv_meta() {
        let m = LayerMeta::dwconv(3, 64, 16);
        assert_eq!(m.cells, 9 * 64);
        assert_eq!(m.fan_in, 9); // the paper's depthwise observation: only
                                 // nine rows per read -> peripheral-bound
        assert_eq!(m.out_features, 64);
    }

    #[test]
    fn dense_meta() {
        let m = LayerMeta::dense(512, 10);
        assert_eq!(m.cells, 5120);
        assert_eq!(m.alpha, 1);
    }
}
