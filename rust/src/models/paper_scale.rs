//! Paper-scale model layer tables: VGG-16, ResNet-18/34, MobileNet-v1 at
//! CIFAR-10 (32x32) and ImageNet (224x224) resolutions.
//!
//! Cell counts reproduce the paper's Tables 1–2 "#Cells" column:
//!   VGG-16 CIFAR ~15M, ResNet-18 CIFAR ~11M, MobileNet CIFAR ~3.2M,
//!   ResNet-18 ImageNet ~12M, ResNet-34 ImageNet ~22M
//! (one analog multi-level cell per weight; binarized encoding multiplies
//! by its bit count — see `baselines`).

use super::{LayerMeta, ModelDesc};

/// Dataset resolution for the paper-scale tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resolution {
    /// 32x32, 10 classes.
    Cifar,
    /// 224x224, 1000 classes.
    ImageNet,
}

/// VGG-16 (13 conv + 2 dense; CIFAR variant has a 512-d head).
pub fn vgg16(res: Resolution) -> ModelDesc {
    let mut layers = Vec::new();
    let (mut hw, classes) = match res {
        Resolution::Cifar => (32u64, 10u64),
        Resolution::ImageNet => (224, 1000),
    };
    let cfg: &[(u64, u64)] = &[
        (3, 64),
        (64, 64), // pool
        (64, 128),
        (128, 128), // pool
        (128, 256),
        (256, 256),
        (256, 256), // pool
        (256, 512),
        (512, 512),
        (512, 512), // pool
        (512, 512),
        (512, 512),
        (512, 512), // pool
    ];
    let pool_after = [1usize, 3, 6, 9, 12];
    for (i, &(cin, cout)) in cfg.iter().enumerate() {
        layers.push(LayerMeta::conv(3, cin, cout, hw));
        if pool_after.contains(&i) {
            hw /= 2;
        }
    }
    match res {
        Resolution::Cifar => {
            layers.push(LayerMeta::dense(512, 512));
            layers.push(LayerMeta::dense(512, classes));
        }
        Resolution::ImageNet => {
            layers.push(LayerMeta::dense(512 * 7 * 7, 4096));
            layers.push(LayerMeta::dense(4096, 4096));
            layers.push(LayerMeta::dense(4096, classes));
        }
    }
    ModelDesc {
        name: format!("vgg16-{res:?}").to_lowercase(),
        layers,
    }
}

/// ResNet-18/34 (basic blocks).
pub fn resnet(depth: u32, res: Resolution) -> ModelDesc {
    let blocks: &[u64] = match depth {
        18 => &[2, 2, 2, 2],
        34 => &[3, 4, 6, 3],
        other => panic!("unsupported resnet depth {other}"),
    };
    let mut layers = Vec::new();
    let (mut hw, classes) = match res {
        Resolution::Cifar => (32u64, 10u64),
        Resolution::ImageNet => (224, 1000),
    };
    // stem
    match res {
        Resolution::Cifar => {
            layers.push(LayerMeta::conv(3, 3, 64, hw));
        }
        Resolution::ImageNet => {
            hw /= 2; // 7x7 stride-2 conv
            layers.push(LayerMeta::conv(7, 3, 64, hw));
            hw /= 2; // 3x3 max-pool stride 2
        }
    }
    let mut cin = 64u64;
    for (stage, &reps) in blocks.iter().enumerate() {
        let cout = 64 << stage;
        for r in 0..reps {
            let stride = if stage > 0 && r == 0 { 2 } else { 1 };
            if stride == 2 {
                hw /= 2;
            }
            layers.push(LayerMeta::conv(3, cin, cout, hw));
            layers.push(LayerMeta::conv(3, cout, cout, hw));
            if stride == 2 || cin != cout {
                layers.push(LayerMeta::conv(1, cin, cout, hw)); // projection
            }
            cin = cout;
        }
    }
    layers.push(LayerMeta::dense(512, classes));
    ModelDesc {
        name: format!("resnet{depth}-{res:?}").to_lowercase(),
        layers,
    }
}

/// MobileNet-v1 width 1.0.
pub fn mobilenet(res: Resolution) -> ModelDesc {
    let mut layers = Vec::new();
    let (mut hw, classes) = match res {
        Resolution::Cifar => (32u64, 10u64),
        Resolution::ImageNet => (224, 1000),
    };
    // stem conv stride 2 (stride 1 on CIFAR to keep spatial detail)
    if res == Resolution::ImageNet {
        hw /= 2;
    }
    layers.push(LayerMeta::conv(3, 3, 32, hw));
    // (cin, cout, stride) of the 13 depthwise-separable blocks
    let cfg: &[(u64, u64, u64)] = &[
        (32, 64, 1),
        (64, 128, 2),
        (128, 128, 1),
        (128, 256, 2),
        (256, 256, 1),
        (256, 512, 2),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 1024, 2),
        (1024, 1024, 1),
    ];
    for &(cin, cout, stride) in cfg {
        if stride == 2 {
            hw /= 2;
        }
        layers.push(LayerMeta::dwconv(3, cin, hw));
        layers.push(LayerMeta::conv(1, cin, cout, hw));
    }
    layers.push(LayerMeta::dense(1024, classes));
    ModelDesc {
        name: format!("mobilenet-{res:?}").to_lowercase(),
        layers,
    }
}

/// The paper's evaluation matrix: (display name, model) per suite.
pub fn table1_models() -> Vec<(&'static str, ModelDesc)> {
    vec![
        ("VGG-16", vgg16(Resolution::Cifar)),
        ("ResNet-18", resnet(18, Resolution::Cifar)),
        ("MobileNet", mobilenet(Resolution::Cifar)),
    ]
}

pub fn table2_models() -> Vec<(&'static str, ModelDesc)> {
    vec![
        ("ResNet-18", resnet(18, Resolution::ImageNet)),
        ("ResNet-34", resnet(34, Resolution::ImageNet)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_cifar_cells_match_paper() {
        // paper Table 1: 15M cells
        let m = vgg16(Resolution::Cifar);
        let cells = m.total_cells();
        assert!(
            (14_000_000..16_000_000).contains(&cells),
            "vgg16 cells {cells}"
        );
    }

    #[test]
    fn resnet18_cifar_cells_match_paper() {
        // paper Table 1: 11M cells
        let cells = resnet(18, Resolution::Cifar).total_cells();
        assert!(
            (10_500_000..11_900_000).contains(&cells),
            "resnet18 cells {cells}"
        );
    }

    #[test]
    fn mobilenet_cifar_cells_match_paper() {
        // paper Table 1: 3.2M cells
        let cells = mobilenet(Resolution::Cifar).total_cells();
        assert!(
            (3_000_000..3_500_000).contains(&cells),
            "mobilenet cells {cells}"
        );
    }

    #[test]
    fn resnet_imagenet_cells_match_paper() {
        // paper Table 2: 12M / 22M cells
        let r18 = resnet(18, Resolution::ImageNet).total_cells();
        let r34 = resnet(34, Resolution::ImageNet).total_cells();
        assert!((11_000_000..12_500_000).contains(&r18), "r18 {r18}");
        assert!((21_000_000..23_000_000).contains(&r34), "r34 {r34}");
    }

    #[test]
    fn cifar_delay_cycles_match_paper_ratio() {
        // paper Table 1 delay: VGG-16 2.8us, ResNet-18 6.8us at 1ns/read:
        // cycle counts must land near 2800 / 6800.
        let vgg = vgg16(Resolution::Cifar).total_cycles();
        let r18 = resnet(18, Resolution::Cifar).total_cycles();
        assert!((2_300..3_300).contains(&vgg), "vgg cycles {vgg}");
        assert!((5_800..7_800).contains(&r18), "r18 cycles {r18}");
    }

    #[test]
    fn mobilenet_has_depthwise_layers() {
        let m = mobilenet(Resolution::Cifar);
        assert!(m.layers.iter().any(|l| l.kind == "dwconv"));
        // depthwise fan-in is 9 -> peripheral-bound reads
        for l in m.layers.iter().filter(|l| l.kind == "dwconv") {
            assert_eq!(l.fan_in, 9);
        }
    }

    #[test]
    fn resnet34_deeper_than_18() {
        assert!(
            resnet(34, Resolution::ImageNet).layers.len()
                > resnet(18, Resolution::ImageNet).layers.len()
        );
    }
}
