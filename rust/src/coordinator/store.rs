//! Trained-model persistence (simple, dependency-free binary format).
//!
//! Benches and examples cache `TrainedModel`s under `runs/cache/` so the
//! table/figure reproductions don't retrain on every invocation.
//!
//! Format (little endian):
//!   magic "EMTM" u32-version
//!   model_key: u32 len + utf8
//!   solution:  u8
//!   rho_raw:   u32 len + f32s
//!   n_params:  u32, then per tensor: u32 ndim + u64 dims + u32 len + f32s
//!   loss_trace: u32 len + f32s

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::coordinator::{Solution, TrainedModel};
use crate::Result;

const MAGIC: &[u8; 4] = b"EMTM";
const VERSION: u32 = 1;

/// Validate a model's trained rho vector against its parameters.
///
/// Serving trusts `rho_raw` end-to-end (it shapes every tier's
/// [`EnergyPlan`](crate::energy::EnergyPlan)), so corruption must be
/// caught at the store boundary, not three layers up: every raw entry
/// must be finite, its softplus-decoded rho finite and positive, and the
/// vector must carry exactly one entry per weight tensor (ndim >= 2 —
/// biases are digital and carry no rho).  Enforced by both [`save`]
/// (reject before a bad vector reaches disk) and [`load`] (reject
/// hand-edited or truncated files).
pub fn validate(model: &TrainedModel) -> Result<()> {
    for (i, &raw) in model.rho_raw.iter().enumerate() {
        anyhow::ensure!(raw.is_finite(), "rho_raw[{i}] = {raw} is not finite");
        let rho = crate::runtime::rho_of_raw(raw);
        anyhow::ensure!(
            rho.is_finite() && rho > 0.0,
            "rho_raw[{i}] = {raw} decodes to non-positive rho {rho}"
        );
    }
    let weight_tensors = model
        .params
        .iter()
        .filter(|(shape, _)| shape.len() >= 2)
        .count();
    if weight_tensors > 0 {
        anyhow::ensure!(
            model.rho_raw.len() == weight_tensors,
            "rho_raw has {} entries but the model has {weight_tensors} weight tensors",
            model.rho_raw.len()
        );
    }
    Ok(())
}

fn sol_tag(s: Solution) -> u8 {
    match s {
        Solution::Traditional => 0,
        Solution::A => 1,
        Solution::AB => 2,
        Solution::ABC => 3,
    }
}

fn tag_sol(t: u8) -> Result<Solution> {
    Ok(match t {
        0 => Solution::Traditional,
        1 => Solution::A,
        2 => Solution::AB,
        3 => Solution::ABC,
        other => anyhow::bail!("bad solution tag {other}"),
    })
}

fn w_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_f32s(w: &mut impl Write, v: &[f32]) -> Result<()> {
    w_u32(w, v.len() as u32)?;
    for &x in v {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = r_u32(r)? as usize;
    anyhow::ensure!(n < (1 << 28), "unreasonable tensor size");
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Save a trained model (validating its rho vector first — see
/// [`validate`]).
pub fn save(model: &TrainedModel, path: &Path) -> Result<()> {
    validate(model)?;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w_u32(&mut w, VERSION)?;
    w_u32(&mut w, model.model_key.len() as u32)?;
    w.write_all(model.model_key.as_bytes())?;
    w.write_all(&[sol_tag(model.solution)])?;
    w_f32s(&mut w, &model.rho_raw)?;
    w_u32(&mut w, model.params.len() as u32)?;
    for (shape, data) in &model.params {
        w_u32(&mut w, shape.len() as u32)?;
        for &d in shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        w_f32s(&mut w, data)?;
    }
    w_f32s(&mut w, &model.loss_trace)?;
    Ok(())
}

/// Load a trained model.
pub fn load(path: &Path) -> Result<TrainedModel> {
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not an EMTM file");
    let version = r_u32(&mut r)?;
    anyhow::ensure!(version == VERSION, "unsupported version {version}");
    let klen = r_u32(&mut r)? as usize;
    let mut kbuf = vec![0u8; klen];
    r.read_exact(&mut kbuf)?;
    let model_key = String::from_utf8(kbuf)?;
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    let solution = tag_sol(tag[0])?;
    let rho_raw = r_f32s(&mut r)?;
    let n = r_u32(&mut r)? as usize;
    let mut params = Vec::with_capacity(n);
    for _ in 0..n {
        let ndim = r_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let data = r_f32s(&mut r)?;
        anyhow::ensure!(data.len() == shape.iter().product::<usize>(), "shape mismatch");
        params.push((shape, data));
    }
    let loss_trace = r_f32s(&mut r)?;
    let model = TrainedModel {
        model_key,
        solution,
        params,
        rho_raw,
        loss_trace,
    };
    validate(&model)?;
    Ok(model)
}

/// Cache path of a (model, solution, intensity, schedule) combination.
pub fn cache_path(
    model_key: &str,
    solution: Solution,
    intensity: &str,
    pretrain: u32,
    finetune: u32,
) -> PathBuf {
    PathBuf::from("runs/cache").join(format!(
        "{model_key}_{}_{intensity}_p{pretrain}_f{finetune}.emtm",
        solution.name().replace('+', "")
    ))
}

/// Load from cache or train + save.
#[cfg(feature = "aot")]
pub fn train_cached(
    arts: &crate::runtime::Artifacts,
    model_key: &str,
    suite: crate::data::Suite,
    solution: Solution,
    cfg: &crate::coordinator::TrainConfig,
) -> Result<TrainedModel> {
    let path = cache_path(
        model_key,
        solution,
        cfg.intensity.name(),
        cfg.pretrain_steps,
        cfg.finetune_steps,
    );
    if path.exists() {
        if let Ok(m) = load(&path) {
            if m.model_key == model_key && m.solution == solution {
                return Ok(m);
            }
        }
    }
    let trained = crate::coordinator::train_solution(arts, model_key, suite, solution, cfg)?;
    save(&trained, &path)?;
    Ok(trained)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrainedModel {
        TrainedModel {
            model_key: "mlp_10".into(),
            solution: Solution::AB,
            params: vec![
                (vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
                (vec![3], vec![0.1, 0.2, 0.3]),
                (vec![3, 4], vec![0.5; 12]),
                (vec![4], vec![0.0; 4]),
            ],
            rho_raw: vec![4.0, 3.0],
            loss_trace: vec![2.3, 1.1, 0.6],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("emtopt_store_test");
        let path = dir.join("m.emtm");
        let m = sample();
        save(&m, &path).unwrap();
        let got = load(&path).unwrap();
        assert_eq!(got.model_key, m.model_key);
        assert_eq!(got.solution, m.solution);
        assert_eq!(got.params, m.params);
        assert_eq!(got.rho_raw, m.rho_raw);
        assert_eq!(got.loss_trace, m.loss_trace);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("emtopt_store_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.emtm");
        std::fs::write(&path, b"not a model").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_rejects_invalid_rho_raw() {
        let dir = std::env::temp_dir().join("emtopt_store_validate");
        let path = dir.join("bad.emtm");
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut m = sample();
            m.rho_raw[1] = bad;
            let err = save(&m, &path).unwrap_err();
            assert!(err.to_string().contains("not finite"), "{err}");
        }
        // layer-count mismatch: 2 weight tensors need exactly 2 entries
        let mut m = sample();
        m.rho_raw = vec![4.0];
        assert!(save(&m, &path).is_err());
        let mut m = sample();
        m.rho_raw = vec![4.0, 3.0, 2.0];
        assert!(save(&m, &path).is_err());
        assert!(!path.exists(), "a rejected save must not touch disk");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_corrupted_rho_raw() {
        // Hand-corrupt a valid file: rho_raw starts right after
        // magic(4) + version(4) + key_len(4) + key + solution_tag(1) +
        // vec_len(4); flip the first entry's bytes to NaN.
        let dir = std::env::temp_dir().join("emtopt_store_validate_load");
        let path = dir.join("m.emtm");
        let m = sample();
        save(&m, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let off = 4 + 4 + 4 + m.model_key.len() + 1 + 4;
        bytes[off..off + 4].copy_from_slice(&f32::NAN.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("not finite"), "{err}");
        // truncate the rho vector (drop the last entry's bytes and patch
        // the length prefix): layer-count mismatch at load time
        save(&m, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[off - 4..off].copy_from_slice(&1u32.to_le_bytes());
        bytes.drain(off + 4..off + 8);
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("weight tensors"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cache_path_distinct() {
        let a = cache_path("mlp_10", Solution::A, "normal", 100, 100);
        let b = cache_path("mlp_10", Solution::AB, "normal", 100, 100);
        assert_ne!(a, b);
    }
}
