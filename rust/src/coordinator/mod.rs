//! L3 coordinator: experiment orchestration, training driver, solution
//! definitions, and the inference router (`router`).
//!
//! This is the paper's "system" layer: it owns process lifecycle, the
//! event loop, dataset streaming, artifact execution, the rho/energy
//! search loops behind every table and figure, and result persistence.

pub mod experiments;
pub mod router;
pub mod store;

pub use experiments::{
    find_energy_at_drop, AccuracyPoint, EvalSetup, TrainConfig, TrainedModel,
};
#[cfg(feature = "aot")]
pub use experiments::{sweep_accuracy_vs_energy, train_solution};

use crate::baselines::Method;
use crate::energy::ReadMode;
#[cfg(feature = "aot")]
use crate::runtime::session::TrainKnobs;

/// The paper's solution ladder (Fig 4 / §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Solution {
    /// Traditional optimizer (ablation reference).
    Traditional,
    /// A: device-enhanced dataset.
    A,
    /// A+B: + energy regularization (trainable rho).
    AB,
    /// A+B+C: + low-fluctuation decomposition.
    ABC,
}

impl Solution {
    pub const ALL: [Solution; 4] =
        [Solution::Traditional, Solution::A, Solution::AB, Solution::ABC];

    pub fn name(self) -> &'static str {
        match self {
            Solution::Traditional => "traditional",
            Solution::A => "A",
            Solution::AB => "A+B",
            Solution::ABC => "A+B+C",
        }
    }

    /// Does inference (and noise-aware training) use the decomposed mode?
    pub fn decomposed(self) -> bool {
        self == Solution::ABC
    }

    pub fn read_mode(self) -> ReadMode {
        if self.decomposed() {
            ReadMode::Decomposed
        } else {
            ReadMode::Original
        }
    }

    /// Fine-tuning knobs for this solution.
    #[cfg(feature = "aot")]
    pub fn knobs(self, intensity: f32, lam: f32) -> TrainKnobs {
        match self {
            Solution::Traditional => TrainKnobs::traditional(),
            Solution::A => TrainKnobs::solution_a(intensity),
            Solution::AB | Solution::ABC => TrainKnobs::solution_ab(intensity, lam),
        }
    }

    pub fn method(self) -> Method {
        match self {
            Solution::Traditional => Method::Traditional,
            Solution::A => Method::OursA,
            Solution::AB => Method::OursAB,
            Solution::ABC => Method::OursABC,
        }
    }
}

impl std::str::FromStr for Solution {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "trad" | "traditional" => Ok(Solution::Traditional),
            "a" => Ok(Solution::A),
            "ab" | "a+b" => Ok(Solution::AB),
            "abc" | "a+b+c" => Ok(Solution::ABC),
            other => Err(format!("unknown solution {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solution_parsing() {
        assert_eq!("a+b".parse::<Solution>().unwrap(), Solution::AB);
        assert_eq!("ABC".parse::<Solution>().unwrap(), Solution::ABC);
        assert!("xyz".parse::<Solution>().is_err());
    }

    #[cfg(feature = "aot")]
    #[test]
    fn knob_gates_match_solutions() {
        let t = Solution::Traditional.knobs(1.0, 0.1);
        assert_eq!(t.noise_gate, 0.0);
        assert_eq!(t.rho_gate, 0.0);
        let a = Solution::A.knobs(1.0, 0.1);
        assert_eq!(a.noise_gate, 1.0);
        assert_eq!(a.rho_gate, 0.0);
        assert_eq!(a.lam, 0.0);
        let ab = Solution::AB.knobs(1.0, 0.1);
        assert_eq!(ab.rho_gate, 1.0);
        assert!(ab.lam > 0.0);
        assert!(Solution::ABC.decomposed());
        assert!(!Solution::AB.decomposed());
    }
}
