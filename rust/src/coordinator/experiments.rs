//! Experiment drivers behind every table and figure.
//!
//! All paper experiments decompose into three primitives:
//!
//! 1. [`train_solution`] — clean pretrain + solution fine-tune of a tiny
//!    zoo model through the AOT train artifacts (results disk-cached via
//!    `store` so benches don't retrain),
//! 2. [`sweep_accuracy_vs_energy`] — evaluate a trained model across a
//!    grid of global rho scales and map each point onto the paper-scale
//!    energy axis,
//! 3. [`find_energy_at_drop`] — invert the sweep: minimum energy whose
//!    accuracy drop (vs the GPU/noiseless baseline) is within a target.

use crate::baselines::Method;
#[cfg(feature = "aot")]
use crate::baselines::method_factors;
use crate::coordinator::Solution;
use crate::data::Suite;
#[cfg(feature = "aot")]
use crate::data::{Dataset, Split};
use crate::device::Intensity;
#[cfg(feature = "aot")]
use crate::energy::EnergyModel;
use crate::energy::ReadMode;
use crate::models::ModelDesc;
use crate::runtime::{raw_of_rho, rho_of_raw};
#[cfg(feature = "aot")]
use crate::runtime::{Artifacts, Evaluator, Trainer};
#[cfg(feature = "aot")]
use crate::Result;

/// Training schedule of one solution run.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub pretrain_steps: u32,
    pub finetune_steps: u32,
    pub lam: f32,
    pub intensity: Intensity,
    pub seed: i32,
    /// Log every N steps (0 = silent).
    pub log_every: u32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            pretrain_steps: 120,
            finetune_steps: 120,
            lam: 0.3,
            intensity: Intensity::Normal,
            seed: 7,
            log_every: 0,
        }
    }
}

/// A trained model exported to host memory (cacheable, serialisable).
#[derive(Clone, Debug)]
pub struct TrainedModel {
    pub model_key: String,
    pub solution: Solution,
    /// (shape, data) per parameter tensor, artifact order.
    pub params: Vec<(Vec<usize>, Vec<f32>)>,
    pub rho_raw: Vec<f32>,
    /// Loss trace of the fine-tune phase (for EXPERIMENTS.md curves).
    pub loss_trace: Vec<f32>,
}

impl TrainedModel {
    #[cfg(feature = "aot")]
    pub fn params_literals(&self) -> Result<Vec<xla::Literal>> {
        self.params
            .iter()
            .map(|(shape, data)| crate::runtime::lit_f32(data, shape))
            .collect()
    }

    /// Trained per-layer rho.
    pub fn rho(&self) -> Vec<f32> {
        self.rho_raw.iter().map(|&r| rho_of_raw(r)).collect()
    }

    /// rho_raw after scaling every layer's rho by `scale`.
    pub fn scaled_rho_raw(&self, scale: f32) -> Vec<f32> {
        self.rho_raw
            .iter()
            .map(|&r| raw_of_rho(rho_of_raw(r) * scale))
            .collect()
    }

    /// Mean per-layer rho at a global scale.
    pub fn mean_rho(&self, scale: f32) -> f64 {
        let r = self.rho();
        r.iter().map(|&v| (v * scale) as f64).sum::<f64>() / r.len() as f64
    }
}

/// Clean pretrain of one tiny zoo model ("start from a well-trained
/// model", §5).  Cached on disk: all four solutions of a model share it.
#[cfg(feature = "aot")]
pub fn pretrain_cached(
    arts: &Artifacts,
    model_key: &str,
    suite: Suite,
    cfg: &TrainConfig,
) -> Result<TrainedModel> {
    let path = crate::coordinator::store::cache_path(
        model_key,
        Solution::Traditional,
        "pre",
        cfg.pretrain_steps,
        0,
    );
    if path.exists() {
        if let Ok(m) = crate::coordinator::store::load(&path) {
            if m.model_key == model_key {
                return Ok(m);
            }
        }
    }
    let dataset = Dataset::new(suite, crate::data::DATA_SEED);
    let mut trainer = Trainer::new(arts, model_key, false, cfg.seed)?;
    let batch = trainer.batch;
    let mut knobs = crate::runtime::session::TrainKnobs::traditional();
    knobs.seed = cfg.seed;
    for s in 0..cfg.pretrain_steps {
        let (x, y) = dataset.batch(Split::Train, (s as u64) * batch as u64, batch);
        let out = trainer.step(&x, &y, &knobs)?;
        if cfg.log_every > 0 && s % cfg.log_every == 0 {
            println!(
                "[pretrain {model_key}] step {s:4} loss {:.4} acc {:.3}",
                out.loss, out.acc
            );
        }
    }
    let trained = export(arts, model_key, Solution::Traditional, &trainer, Vec::new())?;
    crate::coordinator::store::save(&trained, &path)?;
    Ok(trained)
}

#[cfg(feature = "aot")]
fn export(
    arts: &Artifacts,
    model_key: &str,
    solution: Solution,
    trainer: &Trainer,
    loss_trace: Vec<f32>,
) -> Result<TrainedModel> {
    let info = arts.manifest.artifact(&format!("{model_key}_train"))?;
    let mut params = Vec::with_capacity(trainer.params().len());
    for (lit, spec) in trainer.params().iter().zip(info.inputs.iter()) {
        params.push((spec.shape.clone(), crate::runtime::to_vec_f32(lit)?));
    }
    Ok(TrainedModel {
        model_key: model_key.to_string(),
        solution,
        params,
        rho_raw: trainer.rho_raw().to_vec(),
        loss_trace,
    })
}

/// Clean-pretrain (cached) + solution fine-tune of one tiny zoo model.
#[cfg(feature = "aot")]
pub fn train_solution(
    arts: &Artifacts,
    model_key: &str,
    suite: Suite,
    solution: Solution,
    cfg: &TrainConfig,
) -> Result<TrainedModel> {
    let dataset = Dataset::new(suite, crate::data::DATA_SEED);
    let pretrained = pretrain_cached(arts, model_key, suite, cfg)?;
    let mut trainer = Trainer::new(arts, model_key, solution.decomposed(), cfg.seed)?;
    trainer.set_params(&pretrained.params)?;
    let batch = trainer.batch;
    let mut loss_trace = Vec::new();

    // Phase 2: solution fine-tune.
    let mut knobs = solution.knobs(cfg.intensity.factor(), cfg.lam);
    knobs.seed = cfg.seed + 1;
    for s in 0..cfg.finetune_steps {
        let off = (cfg.pretrain_steps + s) as u64 * batch as u64;
        let (x, y) = dataset.batch(Split::Train, off, batch);
        let out = trainer.step(&x, &y, &knobs)?;
        loss_trace.push(out.loss);
        if cfg.log_every > 0 && s % cfg.log_every == 0 {
            println!(
                "[finetune {model_key} {}] step {s:4} loss {:.4} acc {:.3} E {:.0}",
                solution.name(),
                out.loss,
                out.acc,
                out.energy
            );
        }
    }

    export(arts, model_key, solution, &trainer, loss_trace)
}

/// Evaluation context: which dataset, how many batches, what device noise.
#[derive(Clone, Copy, Debug)]
pub struct EvalSetup {
    pub suite: Suite,
    pub batches: u32,
    pub intensity: Intensity,
    pub seed: i32,
}

impl Default for EvalSetup {
    fn default() -> Self {
        EvalSetup {
            suite: Suite::Cifar,
            batches: 2,
            intensity: Intensity::Normal,
            seed: 1234,
        }
    }
}

/// Evaluate a trained model at a given global rho scale and effective
/// sigma multiplier (baseline read schemes pass `sigma_mult != 1`).
#[cfg(feature = "aot")]
pub fn eval_at_scale(
    evaluator: &Evaluator,
    trained: &TrainedModel,
    setup: &EvalSetup,
    rho_scale: f32,
    sigma_mult: f32,
    noise_gate: f32,
) -> Result<crate::runtime::EvalResult> {
    let dataset = Dataset::new(setup.suite, crate::data::DATA_SEED);
    let params = trained.params_literals()?;
    let rho_raw = trained.scaled_rho_raw(rho_scale);
    let eff_intensity = setup.intensity.factor() * sigma_mult;
    let mut total = crate::runtime::EvalResult::default();
    for b in 0..setup.batches {
        let (x, y) = dataset.batch(
            Split::Test,
            b as u64 * evaluator.batch as u64,
            evaluator.batch,
        );
        let r = evaluator.eval_batch(
            &params,
            &rho_raw,
            &x,
            &y,
            setup.seed + b as i32,
            eff_intensity,
            noise_gate,
        )?;
        total.merge(&r);
    }
    Ok(total)
}

/// Noiseless ("GPU baseline") accuracy of a trained model.
#[cfg(feature = "aot")]
pub fn eval_baseline(
    evaluator: &Evaluator,
    trained: &TrainedModel,
    setup: &EvalSetup,
) -> Result<crate::runtime::EvalResult> {
    eval_at_scale(evaluator, trained, setup, 1.0, 1.0, 0.0)
}

/// One point of an accuracy-vs-energy curve.
#[derive(Clone, Copy, Debug)]
pub struct AccuracyPoint {
    pub rho_scale: f32,
    pub mean_rho: f64,
    pub energy_uj: f64,
    pub top1: f64,
    pub top5: f64,
}

/// Sweep a trained model over global rho scales; energy is reported on the
/// paper-scale model `paper_model` with the method's hardware factors.
#[cfg(feature = "aot")]
#[allow(clippy::too_many_arguments)]
pub fn sweep_accuracy_vs_energy(
    evaluator: &Evaluator,
    trained: &TrainedModel,
    setup: &EvalSetup,
    paper_model: &ModelDesc,
    method: Method,
    em: &EnergyModel,
    rho_scales: &[f32],
) -> Result<Vec<AccuracyPoint>> {
    let f = method_factors(method, em.stats.mean_w_norm);
    let mode = method.read_mode();
    let mut points = Vec::with_capacity(rho_scales.len());
    for &s in rho_scales {
        let r = eval_at_scale(evaluator, trained, setup, s, f.sigma as f32, 1.0)?;
        let mean_rho = trained.mean_rho(s);
        let cell_pj: f64 = paper_model
            .layers
            .iter()
            .map(|l| em.layer_cell_pj(l, mean_rho, mode))
            .sum();
        let peri_pj: f64 = paper_model
            .layers
            .iter()
            .map(|l| em.layer_peripheral_pj(l, mode))
            .sum();
        let energy_uj =
            (cell_pj * f.cell_energy + peri_pj * f.delay * f.cells.max(1.0)) * 1e-6;
        points.push(AccuracyPoint {
            rho_scale: s,
            mean_rho,
            energy_uj,
            top1: r.top1_acc(),
            top5: r.top5_acc(),
        });
    }
    Ok(points)
}

/// Per-model training schedule sized for this testbed (single-core CPU
/// PJRT).  Set `EMTOPT_BENCH_FULL=1` for the 8x longer full-reproduction
/// schedules.  Results are cached under runs/cache either way.
pub fn schedule_for(model_key: &str) -> TrainConfig {
    let full = std::env::var("EMTOPT_BENCH_FULL").is_ok();
    let (pre, fine) = match model_key {
        "mlp_10" => (80, 80),
        "tiny_mobilenet_10" => (16, 16),
        "tiny_vgg_10" => (10, 10),
        k if k.starts_with("tiny_resnet34") => (8, 8),
        k if k.starts_with("tiny_resnet") => (10, 10),
        _ => (60, 60),
    };
    let mult = if full { 8 } else { 1 };
    TrainConfig {
        pretrain_steps: pre * mult,
        finetune_steps: fine * mult,
        ..Default::default()
    }
}

/// Default geometric rho-scale grid for sweeps.
pub fn default_rho_grid() -> Vec<f32> {
    // trained rho is ~4; scales cover rho ~0.05 .. ~100
    vec![
        0.0125, 0.025, 0.05, 0.1, 0.2, 0.4, 0.8, 1.6, 3.2, 6.4, 12.8, 25.6,
    ]
}

/// Minimum energy on a sweep whose top-1 accuracy drop vs `baseline_acc`
/// is at most `max_drop`.  Returns the matching point if reachable.
pub fn find_energy_at_drop(
    points: &[AccuracyPoint],
    baseline_acc: f64,
    max_drop: f64,
) -> Option<AccuracyPoint> {
    points
        .iter()
        .filter(|p| baseline_acc - p.top1 <= max_drop + 1e-9)
        .min_by(|a, b| a.energy_uj.total_cmp(&b.energy_uj))
        .copied()
}

/// Best (maximum) accuracy on a sweep and its energy (Fig 10: "energy when
/// the model achieves its maximum accuracy").
pub fn best_accuracy_point(points: &[AccuracyPoint]) -> Option<AccuracyPoint> {
    points
        .iter()
        .max_by(|a, b| {
            a.top1
                .total_cmp(&b.top1)
                .then(b.energy_uj.total_cmp(&a.energy_uj))
        })
        .copied()
}

/// Map a tiny-zoo manifest key to the paper-scale model used for the
/// energy / cells / delay axes of the tables.
pub fn paper_model_for(model_key: &str) -> Option<ModelDesc> {
    use crate::models::paper_scale::*;
    match model_key {
        "tiny_vgg_10" | "mlp_10" => Some(vgg16(Resolution::Cifar)),
        "tiny_resnet_10" => Some(resnet(18, Resolution::Cifar)),
        "tiny_mobilenet_10" => Some(mobilenet(Resolution::Cifar)),
        "tiny_resnet_20" => Some(resnet(18, Resolution::ImageNet)),
        "tiny_resnet34_20" => Some(resnet(34, Resolution::ImageNet)),
        _ => None,
    }
}

/// Energy mode for a method (ours-ABC decomposes, everything else doesn't).
pub fn read_mode_for(method: Method) -> ReadMode {
    method.read_mode()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts() -> Vec<AccuracyPoint> {
        vec![
            AccuracyPoint {
                rho_scale: 0.1,
                mean_rho: 0.4,
                energy_uj: 2.0,
                top1: 0.70,
                top5: 0.9,
            },
            AccuracyPoint {
                rho_scale: 1.0,
                mean_rho: 4.0,
                energy_uj: 20.0,
                top1: 0.90,
                top5: 0.99,
            },
            AccuracyPoint {
                rho_scale: 4.0,
                mean_rho: 16.0,
                energy_uj: 80.0,
                top1: 0.935,
                top5: 1.0,
            },
        ]
    }

    #[test]
    fn drop_search_picks_min_energy() {
        let p = find_energy_at_drop(&pts(), 0.94, 0.05).unwrap();
        assert_eq!(p.energy_uj, 20.0);
        let p = find_energy_at_drop(&pts(), 0.94, 0.30).unwrap();
        assert_eq!(p.energy_uj, 2.0);
        assert!(find_energy_at_drop(&pts(), 0.94, 0.0).is_none());
    }

    #[test]
    fn best_point_max_acc() {
        let p = best_accuracy_point(&pts()).unwrap();
        assert_eq!(p.top1, 0.935);
    }

    #[test]
    fn paper_model_mapping() {
        assert!(paper_model_for("tiny_resnet_10").is_some());
        assert!(paper_model_for("nope").is_none());
        let r34 = paper_model_for("tiny_resnet34_20").unwrap();
        assert!(r34.total_cells() > 20_000_000);
    }

    #[test]
    fn rho_grid_monotone() {
        let g = default_rho_grid();
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }
}
