//! Inference request router: the client-facing front of the engines.
//!
//! Clients submit single images or multi-image batches through a
//! clonable [`InferenceClient`].  Clients with bulk traffic skip any
//! batching wait: [`InferenceClient::try_infer_batch`] submits a
//! multi-image request that dispatches as its own device batch (still
//! through the same bounded queues — admission control is identical,
//! and oversize batches fail fast with the typed [`BatchTooLarge`]
//! error the HTTP layer maps to `413`).
//!
//! **Noise determinism (native engine):** every image draws its device
//! noise from a content-derived stream, [`image_seed`]`(lane_seed,
//! pixels)`, fed to [`NoisyModel::forward_batch_seeds`].  An image's
//! logits therefore depend only on its own pixels and the lane seed —
//! never on how the scheduler packed or which worker ran it — so a
//! multi-image request is bit-identical to the same images as
//! sequential single requests at any worker/thread count, even with
//! work stealing active.  The AOT backend cannot honour this: its
//! executables take one seed scalar per padded batch (see DESIGN.md
//! §8), so there batch packing does affect the noise draw.
//!
//! Two engine backends share the same [`InferenceClient`] front:
//!
//! * **Native** ([`serve_native`]) — the default: a single-lane
//!   [`scheduler::Engine`](crate::scheduler::Engine) (shared worker
//!   pool, bounded per-lane queue, dynamic batching inside the
//!   workers).  The tiered HTTP front end (`server`) starts one
//!   multi-lane engine instead and wraps each lane in a client via
//!   [`clients_for_engine`] — one pool serves every tier, stealing
//!   capacity toward the loaded lanes (DESIGN.md §10).
//! * **AOT** ([`serve`], `--features aot`) — the PJRT executable path.
//!   PJRT handles are `!Send`, so that engine is pinned to one thread
//!   and fed over a channel (the single-owner pattern a real
//!   accelerator queue uses).
//!
//! **Backpressure contract:** each lane's request queue is bounded
//! (`queue_depth`).  [`InferenceClient::infer`] blocks when the queue
//! is full; [`InferenceClient::try_infer`] fails fast with a typed
//! [`Overloaded`] error instead, which the HTTP front end maps to `503
//! Service Unavailable`.  With an energy budget configured, admission
//! additionally consults the engine's governor, whose typed
//! `EnergyShed` refusal also maps to `503` (see `scheduler::governor`).
//! An overload therefore surfaces as latency or load-shedding, never as
//! unbounded memory.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::crossbar::ReadCounters;
use crate::device::DeviceConfig;
use crate::energy::{EnergyPlan, ReadMode};
use crate::inference::NoisyModel;
use crate::metrics::{BatchSizeHistogram, LatencyHistogram};
use crate::rng::hash2;
use crate::scheduler::{CompletionQueue, Engine, LaneSpec, Reply};
use crate::trace::{StageHistograms, TraceContext};
use crate::Result;

#[cfg(feature = "aot")]
use std::sync::mpsc::{self, RecvTimeoutError, TrySendError};
#[cfg(feature = "aot")]
use std::time::Instant;

#[cfg(feature = "aot")]
use crate::coordinator::TrainedModel;
#[cfg(feature = "aot")]
use crate::data::IMG_LEN;
#[cfg(feature = "aot")]
use crate::device::Intensity;
#[cfg(feature = "aot")]
use crate::runtime::{Artifacts, Predictor};

/// One inference request on the channel-fed AOT engine: one or more
/// images and a reply slot for the concatenated per-image logits.  (The
/// native scheduler keeps its own queue item type; see
/// `scheduler::Engine`.)
#[cfg(feature = "aot")]
struct Request {
    /// `count * input_len` row-major pixels.
    images: Vec<f32>,
    /// Number of images (1 on the single-image path).
    count: usize,
    reply: mpsc::Sender<Result<Vec<f32>>>,
    enqueued: Instant,
}

/// Content-derived noise seed of one request image: a fold of the pixel
/// bit patterns under the lane seed.  Both native paths (dynamic
/// batching and direct client batches) seed sample RNGs with this,
/// which is what makes a served image's logits independent of batch
/// packing and worker identity (see the module docs).  Deterministic
/// across platforms — `f32::to_bits` of identical pixels is identical
/// everywhere.
pub fn image_seed(lane_seed: u64, image: &[f32]) -> u64 {
    let mut h = hash2(lane_seed, image.len() as u64);
    for v in image {
        h = hash2(h, u64::from(v.to_bits()));
    }
    h
}

/// Lock-free add of an f64 stored as bits in an [`AtomicU64`].
fn atomic_add_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

/// Server statistics (atomic, read from any thread).
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Client requests admitted into the bounded queue (incremented at
    /// admission time; `requests` is incremented at reply time, so
    /// `submitted - requests` is the live in-flight count, see
    /// [`ServerStats::queued_requests`]).
    pub submitted: AtomicU64,
    /// Client requests replied to (a multi-image request counts once).
    pub requests: AtomicU64,
    /// Images served (`>= requests` once multi-image bodies arrive).
    pub images: AtomicU64,
    /// Multi-image client requests served via the direct batch path.
    pub client_batch_requests: AtomicU64,
    /// Images per dispatched engine batch (1/2/4/... buckets), the
    /// batch-amortisation signal surfaced on `/metrics`.
    pub dispatch_batch_sizes: BatchSizeHistogram,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Cumulative queueing latency in microseconds.
    pub queue_us: AtomicU64,
    /// Cumulative model-execution latency in microseconds (per batch).
    pub infer_us: AtomicU64,
    /// Cumulative device read cycles (native engine).
    pub read_cycles: AtomicU64,
    /// Per-request end-to-end engine latency (enqueue -> reply), with
    /// `p50/p95/p99` accessors for tail-latency reporting (`/metrics`).
    pub latency: LatencyHistogram,
    /// Per-stage latency histograms (queue_wait / batch_wait / compute /
    /// write) feeding `emtopt_stage_latency_us` on `/metrics`.  The
    /// scheduler records the first three at reply fan-out; the HTTP
    /// front end records the write stage after the response hits the
    /// socket.
    pub stages: StageHistograms,
    /// f64 bit-patterns of the cumulative analog / peripheral energy (pJ).
    cell_pj_bits: AtomicU64,
    peripheral_pj_bits: AtomicU64,
}

impl ServerStats {
    pub fn mean_queue_us(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.queue_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn mean_batch_fill(&self, batch: usize) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        let total_slots = b * batch as u64;
        let padded = self.padded_slots.load(Ordering::Relaxed);
        (total_slots - padded) as f64 / total_slots as f64
    }

    /// Mean model-execution latency per batch, microseconds.
    pub fn mean_infer_us(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.infer_us.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Accumulate a batch's device energy/cycle accounting.
    pub fn add_counters(&self, c: &ReadCounters) {
        atomic_add_f64(&self.cell_pj_bits, c.cell_pj);
        atomic_add_f64(&self.peripheral_pj_bits, c.peripheral_pj);
        self.read_cycles.fetch_add(c.cycles, Ordering::Relaxed);
    }

    /// Snapshot of the cumulative device energy/cycle accounting.
    pub fn energy(&self) -> ReadCounters {
        ReadCounters {
            cell_pj: f64::from_bits(self.cell_pj_bits.load(Ordering::Relaxed)),
            peripheral_pj: f64::from_bits(self.peripheral_pj_bits.load(Ordering::Relaxed)),
            cycles: self.read_cycles.load(Ordering::Relaxed),
        }
    }

    /// Requests currently waiting or in flight (admitted but not yet
    /// replied).  A point-in-time gauge — submit and reply race by
    /// design, so transient off-by-a-few reads are expected.  The
    /// scheduler additionally exposes the *true* per-lane queue length
    /// (waiting only, not in flight) via its snapshot.
    pub fn queued_requests(&self) -> u64 {
        self.submitted
            .load(Ordering::Relaxed)
            .saturating_sub(self.requests.load(Ordering::Relaxed))
    }

    /// Honest back-off hint for a shed request (`Retry-After` on `503`):
    /// current queue depth x amortised per-request execution time,
    /// rounded up to whole seconds and clamped to [1, 30].  `infer_us`
    /// accumulates per batch, so dividing by served requests amortises
    /// batching for free.
    pub fn retry_after_s(&self) -> u64 {
        let served = self.requests.load(Ordering::Relaxed);
        let per_request_us = if served == 0 {
            10_000.0 // no history yet: assume 10 ms/request
        } else {
            self.infer_us.load(Ordering::Relaxed) as f64 / served as f64
        };
        let wait_s = self.queued_requests() as f64 * per_request_us / 1e6;
        (wait_s.ceil() as u64).clamp(1, 30)
    }

    /// Mean analog+peripheral energy per image served, microjoules —
    /// the observed side of the planned-vs-observed `/metrics` pair.
    pub fn mean_energy_uj_per_image(&self) -> f64 {
        let n = self.images.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.energy().total_pj() * 1e-6 / n as f64
        }
    }

    /// Mean analog+peripheral energy per served request, picojoules.
    pub fn mean_energy_pj_per_request(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.energy().total_pj() / n as f64
        }
    }
}

/// Typed load-shedding error: the bounded request queue is full.
///
/// Returned (inside `anyhow::Error`) by [`InferenceClient::try_infer`];
/// check with `err.is::<Overloaded>()`.  The HTTP front end maps it to
/// `503 Service Unavailable`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Overloaded;

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server overloaded: request queue full")
    }
}

impl std::error::Error for Overloaded {}

/// Typed admission error: a multi-image request exceeds the per-request
/// image cap ([`NativeServerConfig::max_client_batch`]).
///
/// Returned (inside `anyhow::Error`) by the `*_batch` client methods;
/// check with `err.is::<BatchTooLarge>()`.  The HTTP front end maps it to
/// `413 Payload Too Large` — unlike [`Overloaded`] this is the client's
/// fault and retrying unchanged will never succeed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchTooLarge {
    pub count: usize,
    pub max: usize,
}

impl std::fmt::Display for BatchTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "batch of {} images exceeds the per-request limit of {}",
            self.count, self.max
        )
    }
}

impl std::error::Error for BatchTooLarge {}

/// Where a client's requests go: a lane of the native scheduler engine,
/// or the channel feeding the single-owner AOT engine.
#[derive(Clone)]
enum ClientBackend {
    Scheduler { engine: Engine, lane: usize },
    #[cfg(feature = "aot")]
    Channel(mpsc::SyncSender<Request>),
}

/// Handle used by clients to submit requests (clonable across threads).
#[derive(Clone)]
pub struct InferenceClient {
    backend: ClientBackend,
    /// Lane stats (shared with the engine).
    stats: Arc<ServerStats>,
    pub num_classes: usize,
    /// Expected input length (d_in of the deployed model).
    pub input_len: usize,
    /// Max images accepted in one multi-image request (see
    /// [`BatchTooLarge`]).
    pub max_client_batch: usize,
}

impl InferenceClient {
    fn check_single(&self, image: &[f32]) -> Result<()> {
        anyhow::ensure!(
            image.len() == self.input_len,
            "image must be {} floats, got {}",
            self.input_len,
            image.len()
        );
        Ok(())
    }

    fn check_batch(&self, images: &[f32]) -> Result<usize> {
        anyhow::ensure!(
            !images.is_empty() && images.len() % self.input_len == 0,
            "batch must be a non-empty multiple of {} floats, got {}",
            self.input_len,
            images.len()
        );
        let count = images.len() / self.input_len;
        if count > self.max_client_batch {
            return Err(anyhow::Error::new(BatchTooLarge {
                count,
                max: self.max_client_batch,
            }));
        }
        Ok(count)
    }

    /// Submit and wait for the logits (admission first, then the reply).
    fn submit(&self, images: Vec<f32>, count: usize, block: bool) -> Result<Vec<f32>> {
        self.submit_traced(images, count, block, &TraceContext::internal())
            .map(|r| r.logits)
    }

    /// Submit and wait for the full [`Reply`] — logits plus the span
    /// record the scheduler filled in (queue/batch/compute spans, worker
    /// attribution, observed energy).  The AOT channel backend cannot
    /// attribute spans per request; it returns a default record carrying
    /// only the trace identity.
    fn submit_traced(
        &self,
        images: Vec<f32>,
        count: usize,
        block: bool,
        tctx: &TraceContext,
    ) -> Result<Reply> {
        match &self.backend {
            ClientBackend::Scheduler { engine, lane } => {
                let rx = engine.submit(*lane, images, count, block, tctx)?;
                rx.recv()
                    .map_err(|_| anyhow::anyhow!("server dropped request"))?
            }
            #[cfg(feature = "aot")]
            ClientBackend::Channel(tx) => {
                let (reply, rx) = mpsc::channel();
                let req = Request {
                    images,
                    count,
                    reply,
                    enqueued: Instant::now(),
                };
                if block {
                    tx.send(req).map_err(|_| anyhow::anyhow!("server stopped"))?;
                } else {
                    match tx.try_send(req) {
                        Ok(()) => {}
                        Err(TrySendError::Full(_)) => {
                            return Err(anyhow::Error::new(Overloaded))
                        }
                        Err(TrySendError::Disconnected(_)) => anyhow::bail!("server stopped"),
                    }
                }
                self.stats.submitted.fetch_add(1, Ordering::Relaxed);
                let logits = rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("server dropped request"))??;
                Ok(Reply {
                    logits,
                    span: crate::trace::SpanRecord {
                        trace_id: tctx.trace_id,
                        start_us: tctx.start_us,
                        images: count,
                        ..Default::default()
                    },
                })
            }
        }
    }

    /// Lane stats handle (queue depth, energy, latency accessors).
    pub fn stats(&self) -> &Arc<ServerStats> {
        &self.stats
    }

    /// Classify one image (len `input_len`); blocks until the logits
    /// arrive.  If the bounded request queue is full, blocks until a slot
    /// frees up (backpressure) — use [`InferenceClient::try_infer`] to
    /// shed load instead.  On an engine with an energy budget armed,
    /// admission can still fail fast with a typed `EnergyShed` error:
    /// an exhausted budget clears on the governor's window timescale
    /// (seconds), not on queue drain, so blocking for it would be a
    /// stall, not backpressure.
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        self.check_single(&image)?;
        self.submit(image, 1, true)
    }

    /// Like [`InferenceClient::infer`], but fails fast with a typed
    /// [`Overloaded`] error when the bounded request queue is full instead
    /// of blocking (admission control for the serving front end).
    pub fn try_infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        self.check_single(&image)?;
        self.submit(image, 1, false)
    }

    /// Submit `count = images.len() / input_len` images as one request;
    /// blocks until the concatenated `count * num_classes` logits arrive.
    /// The engine dispatches the whole request immediately as its own
    /// device batch (no `max_wait`).  On the **native** backend,
    /// per-image logits are bit-identical to the same images sent through
    /// [`InferenceClient::infer`] one at a time (content-derived noise
    /// seeds); the AOT backend draws noise from one per-batch seed
    /// scalar, so no such guarantee holds there.  Like
    /// [`InferenceClient::infer`], a governed engine may refuse with a
    /// typed `EnergyShed` instead of blocking.
    pub fn infer_batch(&self, images: Vec<f32>) -> Result<Vec<f32>> {
        let count = self.check_batch(&images)?;
        self.submit(images, count, true)
    }

    /// Like [`InferenceClient::infer_batch`], but fails fast with
    /// [`Overloaded`] when the bounded request queue is full (and with
    /// [`BatchTooLarge`] when the request exceeds the per-request image
    /// cap) instead of blocking.
    pub fn try_infer_batch(&self, images: Vec<f32>) -> Result<Vec<f32>> {
        let count = self.check_batch(&images)?;
        self.submit(images, count, false)
    }

    /// Traced single-image flavour of [`InferenceClient::infer`] /
    /// [`InferenceClient::try_infer`] (`block` selects which): returns
    /// the logits together with the request's [`Reply::span`] so the
    /// HTTP layer can finish the record (write/total) and feed the
    /// flight recorder.
    pub fn infer_traced(&self, image: Vec<f32>, block: bool, tctx: &TraceContext) -> Result<Reply> {
        self.check_single(&image)?;
        self.submit_traced(image, 1, block, tctx)
    }

    /// Traced multi-image flavour of [`InferenceClient::infer_batch`] /
    /// [`InferenceClient::try_infer_batch`] (`block` selects which).
    pub fn infer_batch_traced(
        &self,
        images: Vec<f32>,
        block: bool,
        tctx: &TraceContext,
    ) -> Result<Reply> {
        let count = self.check_batch(&images)?;
        self.submit_traced(images, count, block, tctx)
    }

    /// Event-loop flavour of [`InferenceClient::infer_traced`]: the
    /// reply lands on `cq` under `key` instead of blocking this thread.
    /// Admission errors ([`Overloaded`], `EnergyShed`) still surface
    /// synchronously so the caller can answer with live retry stats.
    pub fn infer_completion(
        &self,
        image: Vec<f32>,
        block: bool,
        tctx: &TraceContext,
        cq: &Arc<CompletionQueue>,
        key: u64,
    ) -> Result<()> {
        self.check_single(&image)?;
        self.submit_completion(image, 1, block, tctx, cq, key)
    }

    /// Event-loop flavour of [`InferenceClient::infer_batch_traced`].
    pub fn infer_batch_completion(
        &self,
        images: Vec<f32>,
        block: bool,
        tctx: &TraceContext,
        cq: &Arc<CompletionQueue>,
        key: u64,
    ) -> Result<()> {
        let count = self.check_batch(&images)?;
        self.submit_completion(images, count, block, tctx, cq, key)
    }

    fn submit_completion(
        &self,
        images: Vec<f32>,
        count: usize,
        block: bool,
        tctx: &TraceContext,
        cq: &Arc<CompletionQueue>,
        key: u64,
    ) -> Result<()> {
        match &self.backend {
            ClientBackend::Scheduler { engine, lane } => {
                engine.submit_async(*lane, images, count, block, tctx, cq, key)
            }
            #[cfg(feature = "aot")]
            ClientBackend::Channel(_) => {
                anyhow::bail!("completion-queue submission needs the native scheduler backend")
            }
        }
    }

    /// Classify and argmax.
    pub fn classify(&self, image: Vec<f32>) -> Result<usize> {
        let logits = self.infer(image)?;
        Ok(crate::inference::argmax(&logits))
    }
}

// ---------------------------------------------------------------------------
// native engine: thin wrappers over scheduler::Engine
// ---------------------------------------------------------------------------

/// Configuration of the native serving engine.
#[derive(Clone, Debug)]
pub struct NativeServerConfig {
    /// Device batch size (requests per crossbar dispatch).
    pub batch: usize,
    /// Worker threads in the engine's **shared** pool (`forward_batch`
    /// additionally parallelises inside a batch via rayon).  A tiered
    /// engine shares this pool across all its lanes — capacity moves
    /// between tiers with load instead of being statically split.
    pub workers: usize,
    /// Max time the oldest request may wait before a partial batch fires.
    pub max_wait: Duration,
    /// Bounded request-queue depth per lane: `infer` blocks and
    /// `try_infer` returns [`Overloaded`] once this many requests are
    /// waiting on the lane.
    pub queue_depth: usize,
    /// Max images accepted in one multi-image client request
    /// ([`BatchTooLarge`] above it).  Bounds the memory one queue slot
    /// can pin: a lane's queue holds at most
    /// `queue_depth * max_client_batch` images.
    pub max_client_batch: usize,
    /// Per-layer energy allocation this lane reads with.  `None` falls
    /// back to the deployed model's uniform plan (each array at its
    /// programming-time rho) in `Original` mode; `Some` is validated
    /// against the model at [`serve_native`] start.
    pub plan: Option<EnergyPlan>,
    pub device: DeviceConfig,
    /// Lane RNG seed; image `x` draws noise from
    /// `Rng::new(image_seed(seed, x))` (see [`image_seed`]).
    pub seed: u64,
    /// Interval of the scheduler's capacity rebalancer (multi-lane
    /// engines only).  `Duration::ZERO` disables the background loop —
    /// tests drive `Engine::rebalance_once` manually instead.
    pub rebalance_interval: Duration,
    /// Fleet-level energy budget in uJ/s: when the rolling observed
    /// device energy rate exceeds it, the engine's governor sheds the
    /// lowest-priority lanes with a typed `EnergyShed` error (HTTP
    /// `503` + `Retry-After`).  `None` disables the governor.
    pub energy_budget_uj_s: Option<f64>,
    /// Recycle serve-path buffers (request bodies, pixel arenas, reply
    /// logits, batch slabs) through the engine's size-classed
    /// [`BufferPool`](crate::pool::BufferPool) instead of heap-allocating
    /// per request.  Responses are byte-identical either way (pooling
    /// only reuses capacity); `false` is the allocation-per-request
    /// reference path (`--no-alloc-pool`).
    pub alloc_pool: bool,
}

impl Default for NativeServerConfig {
    fn default() -> Self {
        NativeServerConfig {
            batch: 16,
            workers: 2,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
            max_client_batch: 64,
            plan: None,
            device: DeviceConfig::default(),
            seed: 1,
            rebalance_interval: Duration::from_millis(50),
            energy_budget_uj_s: None,
            alloc_pool: true,
        }
    }
}

/// Build one [`InferenceClient`] per engine lane (the tiered HTTP front
/// end's path; [`serve_native`] is the single-lane flavour).  Clients
/// are clonable and share the engine's stop token — the engine stops
/// once every client (and the engine handle itself) is dropped.
pub fn clients_for_engine(engine: &Engine, max_client_batch: usize) -> Vec<InferenceClient> {
    (0..engine.n_lanes())
        .map(|lane| InferenceClient {
            backend: ClientBackend::Scheduler {
                engine: engine.clone(),
                lane,
            },
            stats: engine.stats(lane).clone(),
            num_classes: engine.d_out(),
            input_len: engine.d_in(),
            max_client_batch,
        })
        .collect()
}

/// Spawn a single-lane scheduler engine over a shared immutable model.
///
/// Returns the client handle, stats, and the engine thread handles.
/// Drop all clients to stop the engine; then join the handles.
pub fn serve_native(
    model: Arc<NoisyModel>,
    cfg: NativeServerConfig,
) -> Result<(InferenceClient, Arc<ServerStats>, Vec<std::thread::JoinHandle<()>>)> {
    anyhow::ensure!(cfg.max_client_batch > 0, "max_client_batch must be positive");
    let plan = match cfg.plan.clone() {
        Some(p) => p,
        None => model.uniform_plan(ReadMode::Original),
    };
    let lanes = vec![LaneSpec {
        plan,
        seed: cfg.seed,
    }];
    let (engine, handles) = Engine::start(model, &cfg, lanes)?;
    let stats = engine.stats(0).clone();
    let client = clients_for_engine(&engine, cfg.max_client_batch)
        .pop()
        .expect("single-lane engine yields one client");
    Ok((client, stats, handles))
}

// ---------------------------------------------------------------------------
// AOT engine (PJRT executables; --features aot)
// ---------------------------------------------------------------------------

/// Configuration of the AOT serving loop.
#[cfg(feature = "aot")]
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: String,
    pub intensity: Intensity,
    /// Max time the oldest request may wait before a partial batch fires.
    pub max_wait: Duration,
    /// Bounded request-queue depth (see [`NativeServerConfig::queue_depth`]).
    pub queue_depth: usize,
    pub seed: i32,
}

#[cfg(feature = "aot")]
impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifacts_dir: "artifacts".into(),
            intensity: Intensity::Normal,
            max_wait: Duration::from_millis(5),
            queue_depth: 256,
            seed: 1,
        }
    }
}

/// Spawn the router + AOT engine; returns the client handle, stats, and
/// the engine join handle (drop all clients to stop the engine).
#[cfg(feature = "aot")]
pub fn serve(
    model: TrainedModel,
    cfg: ServerConfig,
) -> Result<(InferenceClient, Arc<ServerStats>, std::thread::JoinHandle<()>)> {
    // Probe batch/classes up front (cheap manifest read) so the client
    // handle exists before the engine finishes compiling.
    let probe = crate::runtime::Manifest::load(
        std::path::Path::new(&cfg.artifacts_dir)
            .join("manifest.json")
            .as_path(),
    )?;
    let num_classes = probe
        .models
        .get(&model.model_key)
        .ok_or_else(|| anyhow::anyhow!("unknown model {}", model.model_key))?
        .num_classes;
    let batch = probe.batches.predict;

    anyhow::ensure!(cfg.queue_depth > 0, "queue_depth must be positive");
    let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_depth);
    let stats = Arc::new(ServerStats::default());
    let stats_engine = stats.clone();

    let handle = std::thread::spawn(move || {
        // The engine owns all PJRT state on this thread.
        let run = move || -> Result<()> {
            let arts = Artifacts::open(&cfg.artifacts_dir)?;
            let predictor = Predictor::new(&arts, &model.model_key)?;
            let params = model.params_literals()?;
            let rho_raw = model.rho_raw.clone();
            let mut seed = cfg.seed;

            let mut pending: Vec<Request> = Vec::with_capacity(batch);
            // A request that does not fit the current padded batch is
            // carried into the next one (the executable shape is fixed,
            // so a batch can never run more than `batch` images).
            let mut carry: Option<Request> = None;
            loop {
                // Block for the first request of a batch.
                let first = match carry.take() {
                    Some(r) => r,
                    None => match rx.recv() {
                        Ok(r) => r,
                        Err(_) => return Ok(()), // all clients dropped
                    },
                };
                let mut n_images = first.count;
                pending.push(first);
                let deadline = Instant::now() + cfg.max_wait;
                while n_images < batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => {
                            if n_images + r.count > batch {
                                carry = Some(r);
                                break;
                            }
                            n_images += r.count;
                            pending.push(r);
                        }
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }

                // Build the padded device batch.
                let mut x = vec![0.0f32; batch * IMG_LEN];
                let mut off = 0usize;
                for r in &pending {
                    x[off * IMG_LEN..off * IMG_LEN + r.images.len()]
                        .copy_from_slice(&r.images);
                    off += r.count;
                }
                let padded = batch - n_images;
                seed = seed.wrapping_add(1);
                let t0 = Instant::now();
                let logits =
                    predictor.predict(&params, &rho_raw, &x, seed, cfg.intensity.factor())?;
                let infer_us = t0.elapsed().as_micros() as u64;
                let nc = predictor.num_classes;

                stats_engine
                    .requests
                    .fetch_add(pending.len() as u64, Ordering::Relaxed);
                stats_engine
                    .images
                    .fetch_add(n_images as u64, Ordering::Relaxed);
                stats_engine.batches.fetch_add(1, Ordering::Relaxed);
                stats_engine
                    .padded_slots
                    .fetch_add(padded as u64, Ordering::Relaxed);
                stats_engine.infer_us.fetch_add(infer_us, Ordering::Relaxed);
                stats_engine
                    .dispatch_batch_sizes
                    .record(n_images as u64);

                let mut off = 0usize;
                for r in pending.drain(..) {
                    if r.count > 1 {
                        stats_engine
                            .client_batch_requests
                            .fetch_add(1, Ordering::Relaxed);
                    }
                    let out = logits[off * nc..(off + r.count) * nc].to_vec();
                    off += r.count;
                    let total_us = r.enqueued.elapsed().as_micros() as u64;
                    stats_engine.queue_us.fetch_add(total_us, Ordering::Relaxed);
                    stats_engine.latency.record_us(total_us);
                    let _ = r.reply.send(Ok(out));
                }
            }
        };
        if let Err(e) = run() {
            eprintln!("engine error: {e:?}");
        }
    });

    Ok((
        InferenceClient {
            backend: ClientBackend::Channel(tx),
            stats: stats.clone(),
            num_classes,
            input_len: IMG_LEN,
            // the AOT executable shape is fixed: one request can never
            // carry more images than fit a single padded batch
            max_client_batch: batch,
        },
        stats,
        handle,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn stats_fill_fraction() {
        let s = ServerStats::default();
        s.batches.store(2, Ordering::Relaxed);
        s.padded_slots.store(8, Ordering::Relaxed);
        // 2 batches of 16 slots, 8 padded -> 24/32 filled
        assert!((s.mean_batch_fill(16) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn stats_empty_safe() {
        let s = ServerStats::default();
        assert_eq!(s.mean_queue_us(), 0.0);
        assert_eq!(s.mean_batch_fill(16), 0.0);
        assert_eq!(s.mean_infer_us(), 0.0);
        assert_eq!(s.mean_energy_pj_per_request(), 0.0);
        assert_eq!(s.energy(), ReadCounters::default());
    }

    #[test]
    fn stats_energy_accumulates_atomically() {
        let s = Arc::new(ServerStats::default());
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        s.add_counters(&ReadCounters {
                            cell_pj: 0.5,
                            peripheral_pj: 0.25,
                            cycles: 2,
                        });
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let e = s.energy();
        assert!((e.cell_pj - 2000.0).abs() < 1e-9);
        assert!((e.peripheral_pj - 1000.0).abs() < 1e-9);
        assert_eq!(e.cycles, 8000);
    }

    #[test]
    fn native_engine_serves_concurrent_clients() {
        // tiny model, shared by 2 workers, hit from 4 client threads
        let dev = DeviceConfig::default();
        let mut rng = Rng::new(3);
        let (d_in, d_out) = (6usize, 3usize);
        let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() * 0.4).collect();
        let b = vec![0.0f32; d_out];
        let model = Arc::new(
            NoisyModel::new(&[(w.as_slice(), b.as_slice(), d_in, d_out)], &dev).unwrap(),
        );
        let cfg = NativeServerConfig {
            batch: 4,
            workers: 2,
            max_wait: Duration::from_millis(1),
            device: dev,
            ..Default::default()
        };
        let (client, stats, handles) = serve_native(model, cfg).unwrap();
        let per_client = 8u64;
        let clients: Vec<_> = (0..4u64)
            .map(|c| {
                let cl = client.clone();
                std::thread::spawn(move || {
                    let mut ok = 0u64;
                    for i in 0..per_client {
                        let mut r = Rng::stream(100 + c, i);
                        let img: Vec<f32> = (0..6).map(|_| r.next_f32()).collect();
                        let logits = cl.infer(img).unwrap();
                        assert_eq!(logits.len(), 3);
                        assert!(logits.iter().all(|v| v.is_finite()));
                        ok += 1;
                    }
                    ok
                })
            })
            .collect();
        let served: u64 = clients.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(served, 32);
        assert_eq!(stats.requests.load(Ordering::Relaxed), 32);
        assert!(stats.batches.load(Ordering::Relaxed) >= 8); // 32 reqs / batch 4
        assert!(stats.energy().total_pj() > 0.0);
        assert!(stats.mean_energy_pj_per_request() > 0.0);
        drop(client);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn batch_request_bit_identical_to_singles_any_worker_count() {
        // the same 5 images, three ways: one multi-image request on a
        // 1-worker engine, sequential singles on a 3-worker engine, and a
        // multi-image request on the 3-worker engine — all logits must be
        // bit-identical (content-derived per-image seeds; DESIGN.md §3)
        let dev = DeviceConfig::default();
        let (d_in, d_out) = (6usize, 3usize);
        let mk_engine = |workers: usize| {
            let mut rng = Rng::new(13);
            let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() * 0.4).collect();
            let b = vec![0.0f32; d_out];
            let model = Arc::new(
                NoisyModel::new(&[(w.as_slice(), b.as_slice(), d_in, d_out)], &dev).unwrap(),
            );
            let cfg = NativeServerConfig {
                batch: 4,
                workers,
                max_wait: Duration::from_millis(1),
                device: dev.clone(),
                ..Default::default()
            };
            serve_native(model, cfg).unwrap()
        };
        let (client_a, stats_a, handles_a) = mk_engine(1);
        let (client_b, _stats_b, handles_b) = mk_engine(3);

        let n = 5usize;
        let mut images = Vec::with_capacity(n * d_in);
        for i in 0..n {
            let mut r = Rng::stream(500, i as u64);
            for _ in 0..d_in {
                images.push(r.next_f32());
            }
        }
        let batch_a = client_a.try_infer_batch(images.clone()).unwrap();
        let batch_b = client_b.infer_batch(images.clone()).unwrap();
        assert_eq!(batch_a.len(), n * d_out);
        assert_eq!(batch_a, batch_b, "batch logits must not depend on worker count");
        for i in 0..n {
            let single = client_b.infer(images[i * d_in..(i + 1) * d_in].to_vec()).unwrap();
            assert_eq!(
                single.as_slice(),
                &batch_a[i * d_out..(i + 1) * d_out],
                "image {i}: single-request logits must match the batch row"
            );
        }
        // accounting: the batch was one request carrying n images
        assert_eq!(stats_a.requests.load(Ordering::Relaxed), 1);
        assert_eq!(stats_a.images.load(Ordering::Relaxed), n as u64);
        assert_eq!(stats_a.client_batch_requests.load(Ordering::Relaxed), 1);
        assert_eq!(stats_a.dispatch_batch_sizes.count(), 1);
        drop(client_a);
        drop(client_b);
        for h in handles_a.into_iter().chain(handles_b) {
            h.join().unwrap();
        }
    }

    #[test]
    fn batch_too_large_is_typed() {
        let dev = DeviceConfig::default();
        let w = vec![0.1f32; 4 * 2];
        let b = vec![0.0f32; 2];
        let model =
            Arc::new(NoisyModel::new(&[(w.as_slice(), b.as_slice(), 4, 2)], &dev).unwrap());
        let cfg = NativeServerConfig {
            max_client_batch: 2,
            device: dev,
            ..Default::default()
        };
        let (client, _stats, handles) = serve_native(model, cfg).unwrap();
        // 3 images > cap 2: typed BatchTooLarge from both flavours
        let images = vec![0.25f32; 3 * 4];
        let err = client.try_infer_batch(images.clone()).unwrap_err();
        assert!(err.is::<BatchTooLarge>(), "unexpected error: {err:?}");
        let err = client.infer_batch(images).unwrap_err();
        assert!(err.is::<BatchTooLarge>(), "unexpected error: {err:?}");
        // ragged / empty payloads are plain errors, not typed admission ones
        assert!(client.try_infer_batch(vec![0.0; 5]).is_err());
        assert!(client.try_infer_batch(Vec::new()).is_err());
        // within the cap works
        assert_eq!(client.infer_batch(vec![0.25f32; 2 * 4]).unwrap().len(), 2 * 2);
        drop(client);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn image_seed_is_content_addressed() {
        let a = [0.1f32, 0.2, 0.3];
        let b = [0.1f32, 0.2, 0.3];
        let c = [0.1f32, 0.2, 0.4];
        assert_eq!(image_seed(7, &a), image_seed(7, &b));
        assert_ne!(image_seed(7, &a), image_seed(8, &a), "lane seed must matter");
        assert_ne!(image_seed(7, &a), image_seed(7, &c), "pixels must matter");
        assert_ne!(
            image_seed(7, &a),
            image_seed(7, &a[..2]),
            "length must matter"
        );
    }

    #[test]
    fn latency_histogram_tracks_requests() {
        let dev = DeviceConfig::default();
        let w = vec![0.1f32; 8 * 4];
        let b = vec![0.0f32; 4];
        let model =
            Arc::new(NoisyModel::new(&[(w.as_slice(), b.as_slice(), 8, 4)], &dev).unwrap());
        let (client, stats, handles) =
            serve_native(model, NativeServerConfig::default()).unwrap();
        for i in 0..10u64 {
            let mut r = Rng::stream(7, i);
            let img: Vec<f32> = (0..8).map(|_| r.next_f32()).collect();
            client.infer(img).unwrap();
        }
        assert_eq!(stats.latency.count(), 10);
        let (p50, p95, p99) = (
            stats.latency.p50_us(),
            stats.latency.p95_us(),
            stats.latency.p99_us(),
        );
        assert!(p50 > 0.0);
        assert!(p50 <= p95 && p95 <= p99, "p50={p50} p95={p95} p99={p99}");
        drop(client);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn try_infer_sheds_load_when_queue_full() {
        // A deliberately slow model (two 192x192 layers) with queue_depth 1,
        // one worker, batch 1: a burst of concurrent try_infer calls can
        // park at most a few requests (in flight + the one queue slot);
        // the rest must fail fast with Overloaded.
        let dev = DeviceConfig::default();
        let d = 192usize;
        let mut rng = Rng::new(11);
        let w1: Vec<f32> = (0..d * d).map(|_| rng.normal() * 0.1).collect();
        let w2: Vec<f32> = (0..d * d).map(|_| rng.normal() * 0.1).collect();
        let b = vec![0.0f32; d];
        let model = Arc::new(
            NoisyModel::new(
                &[
                    (w1.as_slice(), b.as_slice(), d, d),
                    (w2.as_slice(), b.as_slice(), d, d),
                ],
                &dev,
            )
            .unwrap(),
        );
        let cfg = NativeServerConfig {
            batch: 1,
            workers: 1,
            queue_depth: 1,
            max_wait: Duration::from_millis(1),
            device: dev,
            ..Default::default()
        };
        let (client, stats, handles) = serve_native(model, cfg).unwrap();
        let n = 16u64;
        let clients: Vec<_> = (0..n)
            .map(|c| {
                let cl = client.clone();
                std::thread::spawn(move || {
                    let mut r = Rng::stream(400 + c, 0);
                    let img: Vec<f32> = (0..192).map(|_| r.next_f32()).collect();
                    match cl.try_infer(img) {
                        Ok(logits) => {
                            assert_eq!(logits.len(), 192);
                            (1u64, 0u64)
                        }
                        Err(e) => {
                            assert!(e.is::<Overloaded>(), "unexpected error: {e:?}");
                            (0u64, 1u64)
                        }
                    }
                })
            })
            .collect();
        let (mut ok, mut shed) = (0u64, 0u64);
        for h in clients {
            let (o, s) = h.join().unwrap();
            ok += o;
            shed += s;
        }
        assert_eq!(ok + shed, n);
        assert!(ok >= 1, "at least the first request must be admitted");
        assert!(shed >= 1, "burst of {n} at queue_depth 1 must shed load");
        assert_eq!(stats.requests.load(Ordering::Relaxed), ok);
        drop(client);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn client_rejects_wrong_input_len() {
        let dev = DeviceConfig::default();
        let w = vec![0.1f32; 4 * 2];
        let b = vec![0.0f32; 2];
        let model =
            Arc::new(NoisyModel::new(&[(w.as_slice(), b.as_slice(), 4, 2)], &dev).unwrap());
        let (client, _stats, handles) =
            serve_native(model, NativeServerConfig::default()).unwrap();
        assert!(client.infer(vec![0.0; 3]).is_err());
        drop(client);
        for h in handles {
            h.join().unwrap();
        }
    }
}
