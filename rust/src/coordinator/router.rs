//! Inference request router + dynamic batcher.
//!
//! The serving front of the coordinator (vllm-router-style): clients
//! submit single images; the router accumulates them into fixed-size
//! device batches (padding stragglers), executes on a dedicated engine
//! thread that owns the PJRT executable (PJRT handles are `!Send`, so the
//! engine is pinned to one thread and fed over a channel — the same
//! single-owner pattern a real accelerator queue uses), and fans the
//! per-sample logits back to the callers.
//!
//! Batching policy: fire when the batch is full OR `max_wait` elapsed
//! since the oldest queued request (classic dynamic batching).
//!
//! Channels are std::sync::mpsc (this build is offline — no tokio); each
//! request carries its own reply channel, so any number of client threads
//! can share one [`InferenceClient`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::TrainedModel;
use crate::data::IMG_LEN;
use crate::device::Intensity;
use crate::runtime::{Artifacts, Predictor};
use crate::Result;

/// One inference request: an image and a reply slot for the logits.
struct Request {
    image: Vec<f32>,
    reply: mpsc::Sender<Result<Vec<f32>>>,
    enqueued: std::time::Instant,
}

/// Server statistics (atomic, read from any thread).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Cumulative queueing latency in microseconds.
    pub queue_us: AtomicU64,
}

impl ServerStats {
    pub fn mean_queue_us(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.queue_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn mean_batch_fill(&self, batch: usize) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        let total_slots = b * batch as u64;
        let padded = self.padded_slots.load(Ordering::Relaxed);
        (total_slots - padded) as f64 / total_slots as f64
    }
}

/// Handle used by clients to submit requests (clonable across threads).
#[derive(Clone)]
pub struct InferenceClient {
    tx: mpsc::Sender<Request>,
    pub num_classes: usize,
}

impl InferenceClient {
    /// Classify one image (len IMG_LEN); blocks until the logits arrive.
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        anyhow::ensure!(image.len() == IMG_LEN, "image must be {IMG_LEN} floats");
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request {
                image,
                reply,
                enqueued: std::time::Instant::now(),
            })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    /// Classify and argmax.
    pub fn classify(&self, image: Vec<f32>) -> Result<usize> {
        let logits = self.infer(image)?;
        Ok(logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0))
    }
}

/// Configuration of the serving loop.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: String,
    pub intensity: Intensity,
    /// Max time the oldest request may wait before a partial batch fires.
    pub max_wait: Duration,
    pub seed: i32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifacts_dir: "artifacts".into(),
            intensity: Intensity::Normal,
            max_wait: Duration::from_millis(5),
            seed: 1,
        }
    }
}

/// Spawn the router + engine; returns the client handle, stats, and the
/// engine join handle (drop all clients to stop the engine).
pub fn serve(
    model: TrainedModel,
    cfg: ServerConfig,
) -> Result<(InferenceClient, Arc<ServerStats>, std::thread::JoinHandle<()>)> {
    // Probe batch/classes up front (cheap manifest read) so the client
    // handle exists before the engine finishes compiling.
    let probe = crate::runtime::Manifest::load(
        std::path::Path::new(&cfg.artifacts_dir)
            .join("manifest.json")
            .as_path(),
    )?;
    let num_classes = probe
        .models
        .get(&model.model_key)
        .ok_or_else(|| anyhow::anyhow!("unknown model {}", model.model_key))?
        .num_classes;
    let batch = probe.batches.predict;

    let (tx, rx) = mpsc::channel::<Request>();
    let stats = Arc::new(ServerStats::default());
    let stats_engine = stats.clone();

    let handle = std::thread::spawn(move || {
        // The engine owns all PJRT state on this thread.
        let run = move || -> Result<()> {
            let arts = Artifacts::open(&cfg.artifacts_dir)?;
            let predictor = Predictor::new(&arts, &model.model_key)?;
            let params = model.params_literals()?;
            let rho_raw = model.rho_raw.clone();
            let mut seed = cfg.seed;

            let mut pending: Vec<Request> = Vec::with_capacity(batch);
            loop {
                // Block for the first request of a batch.
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => return Ok(()), // all clients dropped
                };
                pending.push(first);
                let deadline = std::time::Instant::now() + cfg.max_wait;
                while pending.len() < batch {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }

                // Build the padded device batch.
                let mut x = vec![0.0f32; batch * IMG_LEN];
                for (i, r) in pending.iter().enumerate() {
                    x[i * IMG_LEN..(i + 1) * IMG_LEN].copy_from_slice(&r.image);
                }
                let padded = batch - pending.len();
                seed = seed.wrapping_add(1);
                let logits =
                    predictor.predict(&params, &rho_raw, &x, seed, cfg.intensity.factor())?;
                let nc = predictor.num_classes;

                stats_engine
                    .requests
                    .fetch_add(pending.len() as u64, Ordering::Relaxed);
                stats_engine.batches.fetch_add(1, Ordering::Relaxed);
                stats_engine
                    .padded_slots
                    .fetch_add(padded as u64, Ordering::Relaxed);

                for (i, r) in pending.drain(..).enumerate() {
                    let out = logits[i * nc..(i + 1) * nc].to_vec();
                    stats_engine
                        .queue_us
                        .fetch_add(r.enqueued.elapsed().as_micros() as u64, Ordering::Relaxed);
                    let _ = r.reply.send(Ok(out));
                }
            }
        };
        if let Err(e) = run() {
            eprintln!("engine error: {e:?}");
        }
    });

    Ok((InferenceClient { tx, num_classes }, stats, handle))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_fill_fraction() {
        let s = ServerStats::default();
        s.batches.store(2, Ordering::Relaxed);
        s.padded_slots.store(8, Ordering::Relaxed);
        // 2 batches of 16 slots, 8 padded -> 24/32 filled
        assert!((s.mean_batch_fill(16) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn stats_empty_safe() {
        let s = ServerStats::default();
        assert_eq!(s.mean_queue_us(), 0.0);
        assert_eq!(s.mean_batch_fill(16), 0.0);
    }
}
