//! Inference request router + dynamic batcher.
//!
//! The serving front of the coordinator (vllm-router-style): clients
//! submit single images; the router accumulates them into fixed-size
//! device batches (padding stragglers) and fans the per-sample logits
//! back to the callers.  Clients with bulk traffic skip the wait
//! entirely: [`InferenceClient::try_infer_batch`] submits a multi-image
//! request that the batcher dispatches immediately as its own device
//! batch (still through the same bounded queues — admission control is
//! identical, and oversize batches fail fast with the typed
//! [`BatchTooLarge`] error the HTTP layer maps to `413`).
//!
//! **Noise determinism (native engine):** every image draws its device
//! noise from a content-derived stream, [`image_seed`]`(lane_seed,
//! pixels)`, fed to [`NoisyModel::forward_batch_seeds`].  An image's
//! logits therefore depend only on its own pixels and the lane seed —
//! never on how the batcher packed it — so a multi-image request is
//! bit-identical to the same images as sequential single requests at any
//! worker/thread count.  The AOT backend cannot honour this: its
//! executables take one seed scalar per padded batch (see DESIGN.md §8),
//! so there batch packing does affect the noise draw.
//!
//! Two engine backends share the same [`InferenceClient`] front:
//!
//! * **Native** ([`serve_native`]) — the default.  A pool of worker
//!   threads shares one immutable `Arc<NoisyModel>` (the crossbar arrays
//!   are `Send + Sync` shared state); each worker pulls a padded batch off
//!   the dispatch queue and runs [`NoisyModel::forward_batch`], which
//!   additionally fans the batch across rayon.  Per-batch energy/latency
//!   is aggregated into [`ServerStats`].
//! * **AOT** ([`serve`], `--features aot`) — the PJRT executable path.
//!   PJRT handles are `!Send`, so that engine is pinned to one thread and
//!   fed over a channel (the single-owner pattern a real accelerator
//!   queue uses).
//!
//! Batching policy: fire when the batch is full OR `max_wait` elapsed
//! since the oldest queued request (classic dynamic batching).
//!
//! Channels are std::sync::mpsc (this build is offline — no tokio); each
//! request carries its own reply channel, so any number of client threads
//! can share one [`InferenceClient`].
//!
//! **Backpressure contract:** the request queue is a bounded
//! `sync_channel` (`queue_depth`), and the batcher→worker job queue is
//! bounded at `workers` jobs.  [`InferenceClient::infer`] blocks when the
//! queue is full; [`InferenceClient::try_infer`] fails fast with a typed
//! [`Overloaded`] error instead, which the HTTP front end
//! (`server`) maps to `503 Service Unavailable`.  An overload therefore
//! surfaces as latency or load-shedding, never as unbounded memory.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::crossbar::ReadCounters;
use crate::device::DeviceConfig;
use crate::energy::{EnergyPlan, ReadMode};
use crate::inference::NoisyModel;
use crate::metrics::{BatchSizeHistogram, LatencyHistogram};
use crate::rng::hash2;
use crate::Result;

#[cfg(feature = "aot")]
use crate::coordinator::TrainedModel;
#[cfg(feature = "aot")]
use crate::data::IMG_LEN;
#[cfg(feature = "aot")]
use crate::device::Intensity;
#[cfg(feature = "aot")]
use crate::runtime::{Artifacts, Predictor};

/// One inference request: one or more images and a reply slot for the
/// concatenated per-image logits.
struct Request {
    /// `count * input_len` row-major pixels.
    images: Vec<f32>,
    /// Number of images (1 on the single-image path).
    count: usize,
    reply: mpsc::Sender<Result<Vec<f32>>>,
    enqueued: Instant,
}

/// Content-derived noise seed of one request image: a fold of the pixel
/// bit patterns under the lane seed.  Both router paths (dynamic batcher
/// and direct client batches) seed sample RNGs with this, which is what
/// makes a served image's logits independent of batch packing (see the
/// module docs).  Deterministic across platforms — `f32::to_bits` of
/// identical pixels is identical everywhere.
pub fn image_seed(lane_seed: u64, image: &[f32]) -> u64 {
    let mut h = hash2(lane_seed, image.len() as u64);
    for v in image {
        h = hash2(h, u64::from(v.to_bits()));
    }
    h
}

/// Lock-free add of an f64 stored as bits in an [`AtomicU64`].
fn atomic_add_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

/// Server statistics (atomic, read from any thread).
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Client requests admitted into the bounded queue (incremented at
    /// submit time; `requests` is incremented at reply time, so
    /// `submitted - requests` is the live queue depth, see
    /// [`ServerStats::queued_requests`]).
    pub submitted: AtomicU64,
    /// Client requests replied to (a multi-image request counts once).
    pub requests: AtomicU64,
    /// Images served (`>= requests` once multi-image bodies arrive).
    pub images: AtomicU64,
    /// Multi-image client requests served via the direct batch path.
    pub client_batch_requests: AtomicU64,
    /// Images per dispatched engine batch (1/2/4/... buckets), the
    /// batch-amortisation signal surfaced on `/metrics`.
    pub dispatch_batch_sizes: BatchSizeHistogram,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Cumulative queueing latency in microseconds.
    pub queue_us: AtomicU64,
    /// Cumulative model-execution latency in microseconds (per batch).
    pub infer_us: AtomicU64,
    /// Cumulative device read cycles (native engine).
    pub read_cycles: AtomicU64,
    /// Per-request end-to-end engine latency (enqueue -> reply), with
    /// `p50/p95/p99` accessors for tail-latency reporting (`/metrics`).
    pub latency: LatencyHistogram,
    /// f64 bit-patterns of the cumulative analog / peripheral energy (pJ).
    cell_pj_bits: AtomicU64,
    peripheral_pj_bits: AtomicU64,
}

impl ServerStats {
    pub fn mean_queue_us(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.queue_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn mean_batch_fill(&self, batch: usize) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        let total_slots = b * batch as u64;
        let padded = self.padded_slots.load(Ordering::Relaxed);
        (total_slots - padded) as f64 / total_slots as f64
    }

    /// Mean model-execution latency per batch, microseconds.
    pub fn mean_infer_us(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.infer_us.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Accumulate a batch's device energy/cycle accounting.
    pub fn add_counters(&self, c: &ReadCounters) {
        atomic_add_f64(&self.cell_pj_bits, c.cell_pj);
        atomic_add_f64(&self.peripheral_pj_bits, c.peripheral_pj);
        self.read_cycles.fetch_add(c.cycles, Ordering::Relaxed);
    }

    /// Snapshot of the cumulative device energy/cycle accounting.
    pub fn energy(&self) -> ReadCounters {
        ReadCounters {
            cell_pj: f64::from_bits(self.cell_pj_bits.load(Ordering::Relaxed)),
            peripheral_pj: f64::from_bits(self.peripheral_pj_bits.load(Ordering::Relaxed)),
            cycles: self.read_cycles.load(Ordering::Relaxed),
        }
    }

    /// Requests currently waiting or in flight (admitted but not yet
    /// replied).  A point-in-time gauge — submit and reply race by
    /// design, so transient off-by-a-few reads are expected.
    pub fn queued_requests(&self) -> u64 {
        self.submitted
            .load(Ordering::Relaxed)
            .saturating_sub(self.requests.load(Ordering::Relaxed))
    }

    /// Honest back-off hint for a shed request (`Retry-After` on `503`):
    /// current queue depth x amortised per-request execution time,
    /// rounded up to whole seconds and clamped to [1, 30].  `infer_us`
    /// accumulates per batch, so dividing by served requests amortises
    /// batching for free.
    pub fn retry_after_s(&self) -> u64 {
        let served = self.requests.load(Ordering::Relaxed);
        let per_request_us = if served == 0 {
            10_000.0 // no history yet: assume 10 ms/request
        } else {
            self.infer_us.load(Ordering::Relaxed) as f64 / served as f64
        };
        let wait_s = self.queued_requests() as f64 * per_request_us / 1e6;
        (wait_s.ceil() as u64).clamp(1, 30)
    }

    /// Mean analog+peripheral energy per image served, microjoules —
    /// the observed side of the planned-vs-observed `/metrics` pair.
    pub fn mean_energy_uj_per_image(&self) -> f64 {
        let n = self.images.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.energy().total_pj() * 1e-6 / n as f64
        }
    }

    /// Mean analog+peripheral energy per served request, picojoules.
    pub fn mean_energy_pj_per_request(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.energy().total_pj() / n as f64
        }
    }
}

/// Typed load-shedding error: the bounded request queue is full.
///
/// Returned (inside `anyhow::Error`) by [`InferenceClient::try_infer`];
/// check with `err.is::<Overloaded>()`.  The HTTP front end maps it to
/// `503 Service Unavailable`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Overloaded;

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server overloaded: request queue full")
    }
}

impl std::error::Error for Overloaded {}

/// Typed admission error: a multi-image request exceeds the per-request
/// image cap ([`NativeServerConfig::max_client_batch`]).
///
/// Returned (inside `anyhow::Error`) by the `*_batch` client methods;
/// check with `err.is::<BatchTooLarge>()`.  The HTTP front end maps it to
/// `413 Payload Too Large` — unlike [`Overloaded`] this is the client's
/// fault and retrying unchanged will never succeed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchTooLarge {
    pub count: usize,
    pub max: usize,
}

impl std::fmt::Display for BatchTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "batch of {} images exceeds the per-request limit of {}",
            self.count, self.max
        )
    }
}

impl std::error::Error for BatchTooLarge {}

/// Handle used by clients to submit requests (clonable across threads).
#[derive(Clone)]
pub struct InferenceClient {
    tx: mpsc::SyncSender<Request>,
    /// Lane stats (shared with the engine): the client stamps
    /// `submitted` on successful admission so queue depth is observable.
    stats: Arc<ServerStats>,
    pub num_classes: usize,
    /// Expected input length (d_in of the deployed model).
    pub input_len: usize,
    /// Max images accepted in one multi-image request (see
    /// [`BatchTooLarge`]).
    pub max_client_batch: usize,
}

impl InferenceClient {
    fn make_request(
        &self,
        images: Vec<f32>,
        count: usize,
    ) -> (Request, mpsc::Receiver<Result<Vec<f32>>>) {
        let (reply, rx) = mpsc::channel();
        (
            Request {
                images,
                count,
                reply,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    fn make_single(
        &self,
        image: Vec<f32>,
    ) -> Result<(Request, mpsc::Receiver<Result<Vec<f32>>>)> {
        anyhow::ensure!(
            image.len() == self.input_len,
            "image must be {} floats, got {}",
            self.input_len,
            image.len()
        );
        Ok(self.make_request(image, 1))
    }

    fn make_batch(
        &self,
        images: Vec<f32>,
    ) -> Result<(Request, mpsc::Receiver<Result<Vec<f32>>>)> {
        anyhow::ensure!(
            !images.is_empty() && images.len() % self.input_len == 0,
            "batch must be a non-empty multiple of {} floats, got {}",
            self.input_len,
            images.len()
        );
        let count = images.len() / self.input_len;
        if count > self.max_client_batch {
            return Err(anyhow::Error::new(BatchTooLarge {
                count,
                max: self.max_client_batch,
            }));
        }
        Ok(self.make_request(images, count))
    }

    fn submit_blocking(
        &self,
        req: Request,
        rx: mpsc::Receiver<Result<Vec<f32>>>,
    ) -> Result<Vec<f32>> {
        self.tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    fn submit_nonblocking(
        &self,
        req: Request,
        rx: mpsc::Receiver<Result<Vec<f32>>>,
    ) -> Result<Vec<f32>> {
        match self.tx.try_send(req) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => return Err(anyhow::Error::new(Overloaded)),
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("server stopped"),
        }
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    /// Lane stats handle (queue depth, energy, latency accessors).
    pub fn stats(&self) -> &Arc<ServerStats> {
        &self.stats
    }

    /// Classify one image (len `input_len`); blocks until the logits
    /// arrive.  If the bounded request queue is full, blocks until a slot
    /// frees up (backpressure) — use [`InferenceClient::try_infer`] to
    /// shed load instead.
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        let (req, rx) = self.make_single(image)?;
        self.submit_blocking(req, rx)
    }

    /// Like [`InferenceClient::infer`], but fails fast with a typed
    /// [`Overloaded`] error when the bounded request queue is full instead
    /// of blocking (admission control for the serving front end).
    pub fn try_infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        let (req, rx) = self.make_single(image)?;
        self.submit_nonblocking(req, rx)
    }

    /// Submit `count = images.len() / input_len` images as one request;
    /// blocks until the concatenated `count * num_classes` logits arrive.
    /// The batcher dispatches the whole request immediately (no
    /// `max_wait`).  On the **native** backend, per-image logits are
    /// bit-identical to the same images sent through
    /// [`InferenceClient::infer`] one at a time (content-derived noise
    /// seeds); the AOT backend draws noise from one per-batch seed
    /// scalar, so no such guarantee holds there.
    pub fn infer_batch(&self, images: Vec<f32>) -> Result<Vec<f32>> {
        let (req, rx) = self.make_batch(images)?;
        self.submit_blocking(req, rx)
    }

    /// Like [`InferenceClient::infer_batch`], but fails fast with
    /// [`Overloaded`] when the bounded request queue is full (and with
    /// [`BatchTooLarge`] when the request exceeds the per-request image
    /// cap) instead of blocking.
    pub fn try_infer_batch(&self, images: Vec<f32>) -> Result<Vec<f32>> {
        let (req, rx) = self.make_batch(images)?;
        self.submit_nonblocking(req, rx)
    }

    /// Classify and argmax.
    pub fn classify(&self, image: Vec<f32>) -> Result<usize> {
        let logits = self.infer(image)?;
        Ok(crate::inference::argmax(&logits))
    }
}

// ---------------------------------------------------------------------------
// native engine: shared Arc<NoisyModel>, pool of batch workers
// ---------------------------------------------------------------------------

/// Configuration of the native serving engine.
#[derive(Clone, Debug)]
pub struct NativeServerConfig {
    /// Device batch size (requests per crossbar dispatch).
    pub batch: usize,
    /// Engine worker threads sharing the model (each runs whole batches;
    /// `forward_batch` additionally parallelises inside a batch via rayon).
    pub workers: usize,
    /// Max time the oldest request may wait before a partial batch fires.
    pub max_wait: Duration,
    /// Bounded request-queue depth: `infer` blocks and `try_infer`
    /// returns [`Overloaded`] once this many requests are waiting.
    pub queue_depth: usize,
    /// Max images accepted in one multi-image client request
    /// ([`BatchTooLarge`] above it).  Bounds the memory one queue slot
    /// can pin: the request queue holds at most
    /// `queue_depth * max_client_batch` images.
    pub max_client_batch: usize,
    /// Per-layer energy allocation this lane reads with.  `None` falls
    /// back to the deployed model's uniform plan (each array at its
    /// programming-time rho) in `Original` mode; `Some` is validated
    /// against the model at [`serve_native`] start.
    pub plan: Option<EnergyPlan>,
    pub device: DeviceConfig,
    /// Lane RNG seed; image `x` draws noise from
    /// `Rng::new(image_seed(seed, x))` (see [`image_seed`]).
    pub seed: u64,
}

impl Default for NativeServerConfig {
    fn default() -> Self {
        NativeServerConfig {
            batch: 16,
            workers: 2,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
            max_client_batch: 64,
            plan: None,
            device: DeviceConfig::default(),
            seed: 1,
        }
    }
}

/// One device batch handed from the batcher to a worker: accumulated
/// single-image requests, or one multi-image request dispatched alone.
struct Job {
    requests: Vec<Request>,
}

/// Everything a native engine worker needs (shared model + accounting).
struct Worker {
    model: Arc<NoisyModel>,
    stats: Arc<ServerStats>,
    device: DeviceConfig,
    /// The lane's resolved per-layer energy plan (validated, one entry
    /// per model layer).
    plan: EnergyPlan,
    batch: usize,
    seed: u64,
}

impl Worker {
    fn run_batch(&self, job: Job) {
        let d_in = self.model.d_in();
        let nc = self.model.d_out();
        let n_images: usize = job.requests.iter().map(|r| r.count).sum();
        // Unlike the fixed-shape AOT executables, the native engine accepts
        // any batch length — run exactly the real images, so under-filled
        // batches burn no device energy on padding (padded_slots still
        // records the unfilled share for the batch-fill statistic).
        let mut x = vec![0.0f32; n_images * d_in];
        let mut seeds = Vec::with_capacity(n_images);
        let mut off = 0usize;
        for r in &job.requests {
            x[off * d_in..off * d_in + r.images.len()].copy_from_slice(&r.images);
            for i in 0..r.count {
                seeds.push(image_seed(self.seed, &r.images[i * d_in..(i + 1) * d_in]));
            }
            off += r.count;
        }
        let t0 = Instant::now();
        let mut counters = ReadCounters::default();
        let logits =
            self.model
                .forward_batch_seeds(&x, &self.plan, &self.device, &seeds, &mut counters);
        let infer_us = t0.elapsed().as_micros() as u64;

        self.stats
            .requests
            .fetch_add(job.requests.len() as u64, Ordering::Relaxed);
        self.stats.images.fetch_add(n_images as u64, Ordering::Relaxed);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        self.stats
            .padded_slots
            .fetch_add(self.batch.saturating_sub(n_images) as u64, Ordering::Relaxed);
        self.stats.infer_us.fetch_add(infer_us, Ordering::Relaxed);
        self.stats.dispatch_batch_sizes.record(n_images as u64);
        self.stats.add_counters(&counters);

        let mut off = 0usize;
        for r in &job.requests {
            if r.count > 1 {
                self.stats
                    .client_batch_requests
                    .fetch_add(1, Ordering::Relaxed);
            }
            let total_us = r.enqueued.elapsed().as_micros() as u64;
            self.stats.queue_us.fetch_add(total_us, Ordering::Relaxed);
            self.stats.latency.record_us(total_us);
            let _ = r
                .reply
                .send(Ok(logits[off * nc..(off + r.count) * nc].to_vec()));
            off += r.count;
        }
    }
}

/// Spawn the router + native engine pool over a shared immutable model.
///
/// Returns the client handle, stats, and the engine thread handles (the
/// batcher plus `cfg.workers` workers).  Drop all clients to stop the
/// engine; then join the handles.
pub fn serve_native(
    model: Arc<NoisyModel>,
    cfg: NativeServerConfig,
) -> Result<(InferenceClient, Arc<ServerStats>, Vec<std::thread::JoinHandle<()>>)> {
    anyhow::ensure!(cfg.batch > 0, "batch must be positive");
    anyhow::ensure!(cfg.workers > 0, "need at least one worker");
    anyhow::ensure!(cfg.queue_depth > 0, "queue_depth must be positive");
    anyhow::ensure!(cfg.max_client_batch > 0, "max_client_batch must be positive");
    let plan = match cfg.plan.clone() {
        Some(p) => p,
        None => model.uniform_plan(ReadMode::Original),
    };
    plan.validate(model.layers().len())?;
    let input_len = model.d_in();
    let num_classes = model.d_out();

    // Bounded queues end-to-end: requests cap at `queue_depth`, and the
    // batcher can run at most `workers` jobs ahead of the pool, so an
    // overload propagates back to the clients instead of growing memory.
    let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_depth);
    let (job_tx, job_rx) = mpsc::sync_channel::<Job>(cfg.workers);
    let job_rx = Arc::new(Mutex::new(job_rx));
    let stats = Arc::new(ServerStats::default());
    let mut handles = Vec::with_capacity(cfg.workers + 1);

    // Batcher: collects single-image requests into batches and hands them
    // to the pool.  A multi-image request is already a batch — it is
    // dispatched as its own job immediately, never waiting out `max_wait`
    // (the whole point of the client batch path), and never merged with
    // accumulated singles (whose job fires first, preserving arrival
    // order).
    let (batch, max_wait) = (cfg.batch, cfg.max_wait);
    handles.push(std::thread::spawn(move || loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all clients dropped
        };
        if first.count > 1 {
            if job_tx.send(Job { requests: vec![first] }).is_err() {
                return; // workers gone
            }
            continue;
        }
        let mut pending = Vec::with_capacity(batch);
        pending.push(first);
        // A multi-image request that arrives mid-accumulation closes the
        // single-image batch early and follows it as its own job.
        let mut express: Option<Request> = None;
        let deadline = Instant::now() + max_wait;
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) if r.count > 1 => {
                    express = Some(r);
                    break;
                }
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        if job_tx.send(Job { requests: pending }).is_err() {
            return;
        }
        if let Some(r) = express {
            if job_tx.send(Job { requests: vec![r] }).is_err() {
                return;
            }
        }
    }));

    // Worker pool: all workers read the same Arc<NoisyModel>.
    for _ in 0..cfg.workers {
        let worker = Worker {
            model: model.clone(),
            stats: stats.clone(),
            device: cfg.device.clone(),
            plan: plan.clone(),
            batch: cfg.batch,
            seed: cfg.seed,
        };
        let job_rx = job_rx.clone();
        handles.push(std::thread::spawn(move || loop {
            let job = {
                let guard = job_rx.lock().expect("job queue poisoned");
                match guard.recv() {
                    Ok(j) => j,
                    Err(_) => return, // batcher gone
                }
            };
            worker.run_batch(job);
        }));
    }

    Ok((
        InferenceClient {
            tx,
            stats: stats.clone(),
            num_classes,
            input_len,
            max_client_batch: cfg.max_client_batch,
        },
        stats,
        handles,
    ))
}

// ---------------------------------------------------------------------------
// AOT engine (PJRT executables; --features aot)
// ---------------------------------------------------------------------------

/// Configuration of the AOT serving loop.
#[cfg(feature = "aot")]
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: String,
    pub intensity: Intensity,
    /// Max time the oldest request may wait before a partial batch fires.
    pub max_wait: Duration,
    /// Bounded request-queue depth (see [`NativeServerConfig::queue_depth`]).
    pub queue_depth: usize,
    pub seed: i32,
}

#[cfg(feature = "aot")]
impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifacts_dir: "artifacts".into(),
            intensity: Intensity::Normal,
            max_wait: Duration::from_millis(5),
            queue_depth: 256,
            seed: 1,
        }
    }
}

/// Spawn the router + AOT engine; returns the client handle, stats, and
/// the engine join handle (drop all clients to stop the engine).
#[cfg(feature = "aot")]
pub fn serve(
    model: TrainedModel,
    cfg: ServerConfig,
) -> Result<(InferenceClient, Arc<ServerStats>, std::thread::JoinHandle<()>)> {
    // Probe batch/classes up front (cheap manifest read) so the client
    // handle exists before the engine finishes compiling.
    let probe = crate::runtime::Manifest::load(
        std::path::Path::new(&cfg.artifacts_dir)
            .join("manifest.json")
            .as_path(),
    )?;
    let num_classes = probe
        .models
        .get(&model.model_key)
        .ok_or_else(|| anyhow::anyhow!("unknown model {}", model.model_key))?
        .num_classes;
    let batch = probe.batches.predict;

    anyhow::ensure!(cfg.queue_depth > 0, "queue_depth must be positive");
    let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_depth);
    let stats = Arc::new(ServerStats::default());
    let stats_engine = stats.clone();

    let handle = std::thread::spawn(move || {
        // The engine owns all PJRT state on this thread.
        let run = move || -> Result<()> {
            let arts = Artifacts::open(&cfg.artifacts_dir)?;
            let predictor = Predictor::new(&arts, &model.model_key)?;
            let params = model.params_literals()?;
            let rho_raw = model.rho_raw.clone();
            let mut seed = cfg.seed;

            let mut pending: Vec<Request> = Vec::with_capacity(batch);
            // A request that does not fit the current padded batch is
            // carried into the next one (the executable shape is fixed,
            // so a batch can never run more than `batch` images).
            let mut carry: Option<Request> = None;
            loop {
                // Block for the first request of a batch.
                let first = match carry.take() {
                    Some(r) => r,
                    None => match rx.recv() {
                        Ok(r) => r,
                        Err(_) => return Ok(()), // all clients dropped
                    },
                };
                let mut n_images = first.count;
                pending.push(first);
                let deadline = Instant::now() + cfg.max_wait;
                while n_images < batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => {
                            if n_images + r.count > batch {
                                carry = Some(r);
                                break;
                            }
                            n_images += r.count;
                            pending.push(r);
                        }
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }

                // Build the padded device batch.
                let mut x = vec![0.0f32; batch * IMG_LEN];
                let mut off = 0usize;
                for r in &pending {
                    x[off * IMG_LEN..off * IMG_LEN + r.images.len()]
                        .copy_from_slice(&r.images);
                    off += r.count;
                }
                let padded = batch - n_images;
                seed = seed.wrapping_add(1);
                let t0 = Instant::now();
                let logits =
                    predictor.predict(&params, &rho_raw, &x, seed, cfg.intensity.factor())?;
                let infer_us = t0.elapsed().as_micros() as u64;
                let nc = predictor.num_classes;

                stats_engine
                    .requests
                    .fetch_add(pending.len() as u64, Ordering::Relaxed);
                stats_engine
                    .images
                    .fetch_add(n_images as u64, Ordering::Relaxed);
                stats_engine.batches.fetch_add(1, Ordering::Relaxed);
                stats_engine
                    .padded_slots
                    .fetch_add(padded as u64, Ordering::Relaxed);
                stats_engine.infer_us.fetch_add(infer_us, Ordering::Relaxed);
                stats_engine
                    .dispatch_batch_sizes
                    .record(n_images as u64);

                let mut off = 0usize;
                for r in pending.drain(..) {
                    if r.count > 1 {
                        stats_engine
                            .client_batch_requests
                            .fetch_add(1, Ordering::Relaxed);
                    }
                    let out = logits[off * nc..(off + r.count) * nc].to_vec();
                    off += r.count;
                    let total_us = r.enqueued.elapsed().as_micros() as u64;
                    stats_engine.queue_us.fetch_add(total_us, Ordering::Relaxed);
                    stats_engine.latency.record_us(total_us);
                    let _ = r.reply.send(Ok(out));
                }
            }
        };
        if let Err(e) = run() {
            eprintln!("engine error: {e:?}");
        }
    });

    Ok((
        InferenceClient {
            tx,
            stats: stats.clone(),
            num_classes,
            input_len: IMG_LEN,
            // the AOT executable shape is fixed: one request can never
            // carry more images than fit a single padded batch
            max_client_batch: batch,
        },
        stats,
        handle,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn stats_fill_fraction() {
        let s = ServerStats::default();
        s.batches.store(2, Ordering::Relaxed);
        s.padded_slots.store(8, Ordering::Relaxed);
        // 2 batches of 16 slots, 8 padded -> 24/32 filled
        assert!((s.mean_batch_fill(16) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn stats_empty_safe() {
        let s = ServerStats::default();
        assert_eq!(s.mean_queue_us(), 0.0);
        assert_eq!(s.mean_batch_fill(16), 0.0);
        assert_eq!(s.mean_infer_us(), 0.0);
        assert_eq!(s.mean_energy_pj_per_request(), 0.0);
        assert_eq!(s.energy(), ReadCounters::default());
    }

    #[test]
    fn stats_energy_accumulates_atomically() {
        let s = Arc::new(ServerStats::default());
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        s.add_counters(&ReadCounters {
                            cell_pj: 0.5,
                            peripheral_pj: 0.25,
                            cycles: 2,
                        });
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let e = s.energy();
        assert!((e.cell_pj - 2000.0).abs() < 1e-9);
        assert!((e.peripheral_pj - 1000.0).abs() < 1e-9);
        assert_eq!(e.cycles, 8000);
    }

    #[test]
    fn native_engine_serves_concurrent_clients() {
        // tiny model, shared by 2 workers, hit from 4 client threads
        let dev = DeviceConfig::default();
        let mut rng = Rng::new(3);
        let (d_in, d_out) = (6usize, 3usize);
        let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() * 0.4).collect();
        let b = vec![0.0f32; d_out];
        let model = Arc::new(
            NoisyModel::new(&[(w.as_slice(), b.as_slice(), d_in, d_out)], &dev).unwrap(),
        );
        let cfg = NativeServerConfig {
            batch: 4,
            workers: 2,
            max_wait: Duration::from_millis(1),
            device: dev,
            ..Default::default()
        };
        let (client, stats, handles) = serve_native(model, cfg).unwrap();
        let per_client = 8u64;
        let clients: Vec<_> = (0..4u64)
            .map(|c| {
                let cl = client.clone();
                std::thread::spawn(move || {
                    let mut ok = 0u64;
                    for i in 0..per_client {
                        let mut r = Rng::stream(100 + c, i);
                        let img: Vec<f32> = (0..6).map(|_| r.next_f32()).collect();
                        let logits = cl.infer(img).unwrap();
                        assert_eq!(logits.len(), 3);
                        assert!(logits.iter().all(|v| v.is_finite()));
                        ok += 1;
                    }
                    ok
                })
            })
            .collect();
        let served: u64 = clients.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(served, 32);
        assert_eq!(stats.requests.load(Ordering::Relaxed), 32);
        assert!(stats.batches.load(Ordering::Relaxed) >= 8); // 32 reqs / batch 4
        assert!(stats.energy().total_pj() > 0.0);
        assert!(stats.mean_energy_pj_per_request() > 0.0);
        drop(client);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn batch_request_bit_identical_to_singles_any_worker_count() {
        // the same 5 images, three ways: one multi-image request on a
        // 1-worker engine, sequential singles on a 3-worker engine, and a
        // multi-image request on the 3-worker engine — all logits must be
        // bit-identical (content-derived per-image seeds; DESIGN.md §3)
        let dev = DeviceConfig::default();
        let (d_in, d_out) = (6usize, 3usize);
        let mk_engine = |workers: usize| {
            let mut rng = Rng::new(13);
            let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() * 0.4).collect();
            let b = vec![0.0f32; d_out];
            let model = Arc::new(
                NoisyModel::new(&[(w.as_slice(), b.as_slice(), d_in, d_out)], &dev).unwrap(),
            );
            let cfg = NativeServerConfig {
                batch: 4,
                workers,
                max_wait: Duration::from_millis(1),
                device: dev.clone(),
                ..Default::default()
            };
            serve_native(model, cfg).unwrap()
        };
        let (client_a, stats_a, handles_a) = mk_engine(1);
        let (client_b, _stats_b, handles_b) = mk_engine(3);

        let n = 5usize;
        let mut images = Vec::with_capacity(n * d_in);
        for i in 0..n {
            let mut r = Rng::stream(500, i as u64);
            for _ in 0..d_in {
                images.push(r.next_f32());
            }
        }
        let batch_a = client_a.try_infer_batch(images.clone()).unwrap();
        let batch_b = client_b.infer_batch(images.clone()).unwrap();
        assert_eq!(batch_a.len(), n * d_out);
        assert_eq!(batch_a, batch_b, "batch logits must not depend on worker count");
        for i in 0..n {
            let single = client_b.infer(images[i * d_in..(i + 1) * d_in].to_vec()).unwrap();
            assert_eq!(
                single.as_slice(),
                &batch_a[i * d_out..(i + 1) * d_out],
                "image {i}: single-request logits must match the batch row"
            );
        }
        // accounting: the batch was one request carrying n images
        assert_eq!(stats_a.requests.load(Ordering::Relaxed), 1);
        assert_eq!(stats_a.images.load(Ordering::Relaxed), n as u64);
        assert_eq!(stats_a.client_batch_requests.load(Ordering::Relaxed), 1);
        assert_eq!(stats_a.dispatch_batch_sizes.count(), 1);
        drop(client_a);
        drop(client_b);
        for h in handles_a.into_iter().chain(handles_b) {
            h.join().unwrap();
        }
    }

    #[test]
    fn batch_too_large_is_typed() {
        let dev = DeviceConfig::default();
        let w = vec![0.1f32; 4 * 2];
        let b = vec![0.0f32; 2];
        let model =
            Arc::new(NoisyModel::new(&[(w.as_slice(), b.as_slice(), 4, 2)], &dev).unwrap());
        let cfg = NativeServerConfig {
            max_client_batch: 2,
            device: dev,
            ..Default::default()
        };
        let (client, _stats, handles) = serve_native(model, cfg).unwrap();
        // 3 images > cap 2: typed BatchTooLarge from both flavours
        let images = vec![0.25f32; 3 * 4];
        let err = client.try_infer_batch(images.clone()).unwrap_err();
        assert!(err.is::<BatchTooLarge>(), "unexpected error: {err:?}");
        let err = client.infer_batch(images).unwrap_err();
        assert!(err.is::<BatchTooLarge>(), "unexpected error: {err:?}");
        // ragged / empty payloads are plain errors, not typed admission ones
        assert!(client.try_infer_batch(vec![0.0; 5]).is_err());
        assert!(client.try_infer_batch(Vec::new()).is_err());
        // within the cap works
        assert_eq!(client.infer_batch(vec![0.25f32; 2 * 4]).unwrap().len(), 2 * 2);
        drop(client);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn image_seed_is_content_addressed() {
        let a = [0.1f32, 0.2, 0.3];
        let b = [0.1f32, 0.2, 0.3];
        let c = [0.1f32, 0.2, 0.4];
        assert_eq!(image_seed(7, &a), image_seed(7, &b));
        assert_ne!(image_seed(7, &a), image_seed(8, &a), "lane seed must matter");
        assert_ne!(image_seed(7, &a), image_seed(7, &c), "pixels must matter");
        assert_ne!(
            image_seed(7, &a),
            image_seed(7, &a[..2]),
            "length must matter"
        );
    }

    #[test]
    fn latency_histogram_tracks_requests() {
        let dev = DeviceConfig::default();
        let w = vec![0.1f32; 8 * 4];
        let b = vec![0.0f32; 4];
        let model =
            Arc::new(NoisyModel::new(&[(w.as_slice(), b.as_slice(), 8, 4)], &dev).unwrap());
        let (client, stats, handles) =
            serve_native(model, NativeServerConfig::default()).unwrap();
        for i in 0..10u64 {
            let mut r = Rng::stream(7, i);
            let img: Vec<f32> = (0..8).map(|_| r.next_f32()).collect();
            client.infer(img).unwrap();
        }
        assert_eq!(stats.latency.count(), 10);
        let (p50, p95, p99) = (
            stats.latency.p50_us(),
            stats.latency.p95_us(),
            stats.latency.p99_us(),
        );
        assert!(p50 > 0.0);
        assert!(p50 <= p95 && p95 <= p99, "p50={p50} p95={p95} p99={p99}");
        drop(client);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn try_infer_sheds_load_when_queue_full() {
        // A deliberately slow model (two 192x192 layers) with queue_depth 1,
        // one worker, batch 1: a burst of concurrent try_infer calls can
        // park at most ~4 requests (in-flight + job queue + batcher +
        // request queue); the rest must fail fast with Overloaded.
        let dev = DeviceConfig::default();
        let d = 192usize;
        let mut rng = Rng::new(11);
        let w1: Vec<f32> = (0..d * d).map(|_| rng.normal() * 0.1).collect();
        let w2: Vec<f32> = (0..d * d).map(|_| rng.normal() * 0.1).collect();
        let b = vec![0.0f32; d];
        let model = Arc::new(
            NoisyModel::new(
                &[
                    (w1.as_slice(), b.as_slice(), d, d),
                    (w2.as_slice(), b.as_slice(), d, d),
                ],
                &dev,
            )
            .unwrap(),
        );
        let cfg = NativeServerConfig {
            batch: 1,
            workers: 1,
            queue_depth: 1,
            max_wait: Duration::from_millis(1),
            device: dev,
            ..Default::default()
        };
        let (client, stats, handles) = serve_native(model, cfg).unwrap();
        let n = 16u64;
        let clients: Vec<_> = (0..n)
            .map(|c| {
                let cl = client.clone();
                std::thread::spawn(move || {
                    let mut r = Rng::stream(400 + c, 0);
                    let img: Vec<f32> = (0..192).map(|_| r.next_f32()).collect();
                    match cl.try_infer(img) {
                        Ok(logits) => {
                            assert_eq!(logits.len(), 192);
                            (1u64, 0u64)
                        }
                        Err(e) => {
                            assert!(e.is::<Overloaded>(), "unexpected error: {e:?}");
                            (0u64, 1u64)
                        }
                    }
                })
            })
            .collect();
        let (mut ok, mut shed) = (0u64, 0u64);
        for h in clients {
            let (o, s) = h.join().unwrap();
            ok += o;
            shed += s;
        }
        assert_eq!(ok + shed, n);
        assert!(ok >= 1, "at least the first request must be admitted");
        assert!(shed >= 1, "burst of {n} at queue_depth 1 must shed load");
        assert_eq!(stats.requests.load(Ordering::Relaxed), ok);
        drop(client);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn client_rejects_wrong_input_len() {
        let dev = DeviceConfig::default();
        let w = vec![0.1f32; 4 * 2];
        let b = vec![0.0f32; 2];
        let model =
            Arc::new(NoisyModel::new(&[(w.as_slice(), b.as_slice(), 4, 2)], &dev).unwrap());
        let (client, _stats, handles) =
            serve_native(model, NativeServerConfig::default()).unwrap();
        assert!(client.infer(vec![0.0; 3]).is_err());
        drop(client);
        for h in handles {
            h.join().unwrap();
        }
    }
}
