//! Size-classed buffer pools for the zero-alloc serve path.
//!
//! Steady-state serving used to heap-allocate on every request: the
//! epoll front end built a fresh `Vec<u8>` per request body, the
//! scheduler packed pixels into a fresh `Vec<f32>` arena per batch and
//! cloned logits into fresh reply vectors, and the response writer
//! rendered into a fresh byte buffer.  [`BufferPool`] recycles all of
//! those through power-of-two size classes so a warmed server performs
//! no per-request heap allocation on the hot path.
//!
//! Correctness is by construction: a pooled buffer is only ever reused
//! for its *capacity* — every `get_*` returns an **empty** (len 0)
//! vector, so callers fill it exactly as they would a fresh
//! allocation and the produced bytes are identical with the pool on or
//! off.  `enabled == false` turns every `get_*` into a plain fresh
//! allocation and every `put_*` into a drop, without touching the
//! stats, so a `--no-alloc-pool` server is the literal pre-pool code
//! path (the byte-identity reference in CI).
//!
//! Class mapping keeps the invariant "any pooled buffer in the class I
//! pop from is big enough": `put` files a buffer under
//! `floor(log2(capacity))` (the class whose guarantee its capacity
//! meets), `get(min)` pops from `ceil(log2(min))` (the smallest class
//! whose members all have capacity >= min).  Each class retains at
//! most [`CLASS_CAP`] buffers per element type; overflow is dropped so
//! a burst cannot pin memory forever.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;

/// Number of power-of-two size classes: class `k` holds buffers with
/// `capacity in [2^k, 2^(k+1))`.  Class 31 covers anything up to 4 GiB
/// per buffer — far beyond any request this server admits.
const NUM_CLASSES: usize = 32;

/// Buffers retained per (class, element type); overflow is dropped.
const CLASS_CAP: usize = 32;

/// Shared counters behind `/metrics` (`emtopt_alloc_pool_*`).  Hits
/// and misses count `get_*` calls that were / were not served from a
/// free list; `bytes` gauges the capacity currently parked in the
/// free lists (grows on `put`, shrinks on a `get` hit).
#[derive(Debug, Default)]
pub struct PoolStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub bytes: AtomicU64,
}

impl PoolStats {
    /// Hit ratio over all `get_*` calls so far (0.0 before any call).
    pub fn hit_ratio(&self) -> f64 {
        let h = self.hits.load(Relaxed) as f64;
        let m = self.misses.load(Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

/// Size-classed recycling pool for the serve path's byte and f32
/// buffers.  One instance is shared by the epoll front end, the
/// dispatcher, and every scheduler worker; the per-class mutexes are
/// uncontended in practice (a lock is held only for a Vec push/pop).
pub struct BufferPool {
    enabled: bool,
    stats: PoolStats,
    bytes_classes: [Mutex<Vec<Vec<u8>>>; NUM_CLASSES],
    f32_classes: [Mutex<Vec<Vec<f32>>>; NUM_CLASSES],
}

/// Class a `get(min_capacity)` pops from: the smallest class whose
/// buffers are all guaranteed to have capacity >= min.
fn class_for_get(min_capacity: usize) -> usize {
    (usize::BITS - min_capacity.next_power_of_two().leading_zeros()) as usize - 1
}

/// Class a returned buffer files under: floor(log2(capacity)).
fn class_for_put(capacity: usize) -> usize {
    (usize::BITS - capacity.leading_zeros()) as usize - 1
}

impl BufferPool {
    pub fn new(enabled: bool) -> Self {
        Self {
            enabled,
            stats: PoolStats::default(),
            bytes_classes: std::array::from_fn(|_| Mutex::new(Vec::new())),
            f32_classes: std::array::from_fn(|_| Mutex::new(Vec::new())),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn stats(&self) -> &PoolStats {
        &self.stats
    }

    /// Fetch an empty `Vec<u8>` with capacity >= `min_capacity`.
    pub fn get_bytes(&self, min_capacity: usize) -> Vec<u8> {
        if !self.enabled {
            return Vec::with_capacity(min_capacity);
        }
        let class = class_for_get(min_capacity.max(1)).min(NUM_CLASSES - 1);
        if let Some(mut buf) = self.bytes_classes[class].lock().unwrap().pop() {
            self.stats.hits.fetch_add(1, Relaxed);
            self.stats.bytes.fetch_sub(buf.capacity() as u64, Relaxed);
            buf.clear();
            return buf;
        }
        self.stats.misses.fetch_add(1, Relaxed);
        Vec::with_capacity(min_capacity)
    }

    /// Return a byte buffer to its size class (dropped when the pool
    /// is disabled, the buffer has no capacity, or the class is full).
    pub fn put_bytes(&self, buf: Vec<u8>) {
        if !self.enabled || buf.capacity() == 0 {
            return;
        }
        let class = class_for_put(buf.capacity()).min(NUM_CLASSES - 1);
        let mut list = self.bytes_classes[class].lock().unwrap();
        if list.len() < CLASS_CAP {
            self.stats.bytes.fetch_add(buf.capacity() as u64, Relaxed);
            list.push(buf);
        }
    }

    /// Fetch an empty `Vec<f32>` with capacity >= `min_capacity`.
    pub fn get_f32(&self, min_capacity: usize) -> Vec<f32> {
        if !self.enabled {
            return Vec::with_capacity(min_capacity);
        }
        let class = class_for_get(min_capacity.max(1)).min(NUM_CLASSES - 1);
        if let Some(mut buf) = self.f32_classes[class].lock().unwrap().pop() {
            self.stats.hits.fetch_add(1, Relaxed);
            self.stats
                .bytes
                .fetch_sub((buf.capacity() * 4) as u64, Relaxed);
            buf.clear();
            return buf;
        }
        self.stats.misses.fetch_add(1, Relaxed);
        Vec::with_capacity(min_capacity)
    }

    /// Return an f32 buffer to its size class.
    pub fn put_f32(&self, buf: Vec<f32>) {
        if !self.enabled || buf.capacity() == 0 {
            return;
        }
        let class = class_for_put(buf.capacity()).min(NUM_CLASSES - 1);
        let mut list = self.f32_classes[class].lock().unwrap();
        if list.len() < CLASS_CAP {
            self.stats
                .bytes
                .fetch_add((buf.capacity() * 4) as u64, Relaxed);
            list.push(buf);
        }
    }
}

impl std::fmt::Debug for BufferPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BufferPool")
            .field("enabled", &self.enabled)
            .field("hits", &self.stats.hits.load(Relaxed))
            .field("misses", &self.stats.misses.load(Relaxed))
            .field("bytes", &self.stats.bytes.load(Relaxed))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_mapping_guarantees_capacity() {
        // put(class floor) / get(class ceil): any buffer filed in the
        // class a get pops from must satisfy the get's minimum.
        for min in [1usize, 2, 3, 7, 8, 9, 100, 784, 1 << 16] {
            let g = class_for_get(min);
            // every capacity that files into class g is >= 2^g >= min
            assert!(1usize << g >= min, "get class {g} too small for {min}");
        }
        for cap in [1usize, 2, 3, 8, 12, 784, 1000, 1 << 20] {
            let p = class_for_put(cap);
            assert!(cap >= 1 << p, "cap {cap} below its class floor");
            assert!(cap < 1 << (p + 1), "cap {cap} above its class ceiling");
        }
    }

    #[test]
    fn get_after_put_is_a_hit_with_enough_capacity() {
        let pool = BufferPool::new(true);
        let mut b = pool.get_bytes(100); // miss
        b.extend_from_slice(&[1, 2, 3]);
        let cap = b.capacity();
        pool.put_bytes(b);
        assert_eq!(pool.stats().bytes.load(Relaxed), cap as u64);

        let b2 = pool.get_bytes(50); // hit: class_for_get(50)=ceil -> same class region
        assert!(b2.is_empty(), "recycled buffer must come back empty");
        assert!(b2.capacity() >= 50);
        assert_eq!(pool.stats().hits.load(Relaxed), 1);
        assert_eq!(pool.stats().misses.load(Relaxed), 1);
        assert_eq!(pool.stats().bytes.load(Relaxed), 0);
        assert!((pool.stats().hit_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f32_pool_round_trips_and_tracks_bytes() {
        let pool = BufferPool::new(true);
        let mut v = pool.get_f32(784); // miss
        v.resize(784, 0.25);
        let cap = v.capacity();
        pool.put_f32(v);
        assert_eq!(pool.stats().bytes.load(Relaxed), (cap * 4) as u64);
        let v2 = pool.get_f32(784); // hit
        assert!(v2.is_empty());
        assert!(v2.capacity() >= 784);
        assert_eq!(pool.stats().bytes.load(Relaxed), 0);
    }

    #[test]
    fn smaller_put_never_serves_larger_get() {
        let pool = BufferPool::new(true);
        // a 12-cap buffer files under class 3 [8,16); a get(16) pops
        // from class 4, so it must MISS rather than return 12 < 16
        let mut b = Vec::with_capacity(12);
        b.push(0u8);
        let cap = b.capacity();
        pool.put_bytes(b);
        let g = pool.get_bytes(16.max(cap + 1));
        assert!(g.capacity() > cap || g.capacity() >= 16);
        assert_eq!(pool.stats().hits.load(Relaxed), 0);
    }

    #[test]
    fn disabled_pool_is_pure_passthrough() {
        let pool = BufferPool::new(false);
        let b = pool.get_bytes(64);
        assert!(b.capacity() >= 64);
        pool.put_bytes(b);
        let v = pool.get_f32(64);
        pool.put_f32(v);
        assert_eq!(pool.stats().hits.load(Relaxed), 0);
        assert_eq!(pool.stats().misses.load(Relaxed), 0);
        assert_eq!(pool.stats().bytes.load(Relaxed), 0);
        // nothing was parked: a fresh get still misses nothing (no stats)
        assert!(pool.get_bytes(64).is_empty());
    }

    #[test]
    fn class_retention_is_capped() {
        let pool = BufferPool::new(true);
        for _ in 0..(CLASS_CAP + 8) {
            pool.put_bytes(Vec::with_capacity(64));
        }
        // only CLASS_CAP buffers were parked; the rest were dropped
        let mut hits = 0;
        for _ in 0..(CLASS_CAP + 8) {
            let b = pool.get_bytes(64);
            if pool.stats().hits.load(Relaxed) > hits {
                hits = pool.stats().hits.load(Relaxed);
            }
            drop(b);
        }
        assert_eq!(hits as usize, CLASS_CAP);
        assert_eq!(pool.stats().bytes.load(Relaxed), 0);
    }
}
