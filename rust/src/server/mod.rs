//! HTTP serving front end over the native crossbar engine.
//!
//! The network surface of the coordinator: a dependency-free HTTP/1.1
//! server built around ONE raw-`epoll` readiness loop (no tokio — this
//! build is offline; see [`epoll`]) in front of ONE unified
//! [`scheduler::Engine`] with a lane per **energy tier** — a single
//! shared worker pool over per-tier bounded queues, all reading one
//! immutable `Arc<NoisyModel>`.
//!
//! ```text
//!   TCP clients ──> epoll event loop ──> route ──> tier queue
//!                      ▲        │                      │
//!                      │        │ submit_async  shared worker pool
//!                 wakeup fd     │              (work stealing + rebal.
//!                      │        ▼                 + energy governor)
//!                      └── completion queue ◄─── Reply push
//! ```
//!
//! The loop owns every socket as a nonblocking fd: it incrementally
//! assembles requests into per-connection parsers ([`http::RequestParser`]),
//! hands complete requests to the scheduler through the non-blocking
//! completion-queue path, and streams finished responses back out as
//! `EPOLLOUT` allows — a slow reader parks its bytes on the loop, never
//! a compute worker.  Concurrency is bounded by `--max-conns` (file
//! descriptors), not by a thread pool: the C10K regime the ROADMAP's
//! "millions of users" north star implies.
//!
//! Endpoints:
//!
//! * `POST /v1/infer`     `{"image": [f32; d_in], "tier": "low|normal|high"}`
//!   → `{"logits": [...], "tier": ..., "rho": ..., "mode": ...}`;
//!   or batch form `{"images": [[f32; d_in], ...], "tier": ...}`
//!   → `{"logits": [[...], ...], "count": n, ...}` — per-image logits
//!   bit-identical to the same images as sequential single requests
//!   (content-seeded noise; see `coordinator::router::image_seed`)
//! * `POST /v1/classify`  same bodies → adds `"class"` (argmax), or
//!   `"classes"` for the batch form
//! * `GET  /healthz`      liveness + build-info triple + deployed-model
//!   shape + batch cap + energy-plan advertisement (`plan_source`,
//!   per-tier rho vectors)
//! * `GET  /metrics`      Prometheus text (see [`prom`])
//! * `GET  /admin/trace`  flight-recorder dump: the last N complete
//!   request traces as Chrome trace-event JSON (Perfetto-loadable); a
//!   request body may also set `"trace": true` to get its own span
//!   breakdown echoed inline (see [`crate::trace`])
//! * `POST /admin/shutdown`  graceful drain
//!
//! **Energy tiers** surface the paper's energy–accuracy knob (eq. 7/8:
//! fluctuation sigma ∝ 1/sqrt(rho)) as an API parameter: each tier
//! resolves an energy budget to a full per-layer [`EnergyPlan`] through
//! [`tier_plans`] — a trained rho vector rescaled to the budget when
//! `--model-store` provides one ([`EnergyModel::plan_from_trained`]),
//! the closed-form analytic split otherwise
//! ([`EnergyModel::plan_for_budget`]) — and the low tier additionally
//! uses the decomposed (bit-serial, technique C) read mode.  A
//! request's tier picks the lane — and therefore the per-layer noise
//! level and the per-request device energy — it is served with; the
//! plan source and per-layer rho are advertised on `/healthz`,
//! `/v1/infer` responses, and `/metrics` (planned-vs-observed
//! uJ/inference).
//!
//! **Admission control:** requests enter a tier queue via
//! [`InferenceClient::try_infer`] (or `try_infer_batch` for multi-image
//! bodies, which dispatch as their own device batch but share the same
//! bounded queue); a full bounded queue returns the typed `Overloaded`
//! error, which this layer maps to `503` (carrying a `Retry-After` hint
//! derived from the lane's live queue depth x amortised infer time),
//! and a batch above the per-request image cap returns the typed
//! `BatchTooLarge`, mapped to `413`.  With `--energy-budget-uj-s` set,
//! the engine's governor additionally sheds the lowest tiers with a
//! typed `EnergyShed` (`503` + window-decay `Retry-After`) whenever the
//! rolling observed uJ/s runs over the fleet budget — the paper's
//! accuracy-per-joule contract as admission control.  The event loop
//! additionally sheds whole connections with `503` + `Retry-After` when
//! the global `max_conns` cap is reached (the live count and its
//! high-water mark are the `emtopt_http_open_conns{,_peak}` gauges on
//! `/metrics`), and answers `429 Too Many Requests` to a peer IP
//! holding more than `max_conns_per_peer` simultaneous connections.
//! Slow or stalled peers cost one fd and a parked buffer, never a
//! worker: a trickled request head is swept with `400` after
//! `request_timeout`, an idle keep-alive connection or a peer that
//! stopped reading its response after `idle_timeout`.  Overload never
//! grows memory without bound.

pub mod epoll;
pub mod http;
pub mod loadgen;
pub mod prom;

use std::collections::HashMap;
use std::io::{ErrorKind, Read as _, Write as _};
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cache::{CacheKey, CachedReply, ResultCache};
use crate::coordinator::router::{
    clients_for_engine, image_seed, BatchTooLarge, InferenceClient, NativeServerConfig,
    Overloaded, ServerStats,
};
use crate::device::DeviceConfig;
use crate::energy::{EnergyModel, EnergyPlan, LayerPlan, PlanSource, ReadMode};
use crate::inference::NoisyModel;
use crate::models::{LayerMeta, ModelDesc};
use crate::pool::BufferPool;
use crate::rng::hash2;
use crate::scheduler::{self, CompletionQueue, EnergyShed, EngineSnapshot, LaneSpec, Reply};
use crate::trace::{self, FlightRecorder, SpanRecord, Stage, TraceContext};
use crate::util::json::Json;
use crate::Result;

use self::epoll::{Poller, WakeFd, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP};
use self::http::{
    render_response, render_response_into, HttpRequest, PayloadTooLarge, RequestParser, Response,
};

// ---------------------------------------------------------------------------
// energy tiers
// ---------------------------------------------------------------------------

/// Per-request energy tier: the serving-time contract of the paper's
/// energy–accuracy tradeoff.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnergyTier {
    /// Half the reference energy budget, decomposed (bit-serial) reads.
    Low,
    /// The reference budget (device-default rho), original reads.
    Normal,
    /// Twice the reference budget: higher rho, lower fluctuation sigma.
    High,
}

impl EnergyTier {
    pub const ALL: [EnergyTier; 3] = [EnergyTier::Low, EnergyTier::Normal, EnergyTier::High];

    pub fn name(self) -> &'static str {
        match self {
            EnergyTier::Low => "low",
            EnergyTier::Normal => "normal",
            EnergyTier::High => "high",
        }
    }

    /// Lane index (also the RNG seed offset of the tier's engine).
    pub fn index(self) -> usize {
        match self {
            EnergyTier::Low => 0,
            EnergyTier::Normal => 1,
            EnergyTier::High => 2,
        }
    }

    /// Energy budget as a multiple of the reference (device-default rho)
    /// model energy.
    fn budget_scale(self) -> f64 {
        match self {
            EnergyTier::Low => 0.5,
            EnergyTier::Normal => 1.0,
            EnergyTier::High => 2.0,
        }
    }

    /// Low tier pays the B_a-cycle decomposed read (technique C) to keep
    /// fluctuation bounded at its reduced rho; the others read original.
    fn mode(self) -> ReadMode {
        match self {
            EnergyTier::Low => ReadMode::Decomposed,
            EnergyTier::Normal | EnergyTier::High => ReadMode::Original,
        }
    }
}

impl std::str::FromStr for EnergyTier {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "low" => Ok(EnergyTier::Low),
            "normal" => Ok(EnergyTier::Normal),
            "high" => Ok(EnergyTier::High),
            other => Err(format!("unknown tier {other:?} (want low|normal|high)")),
        }
    }
}

/// Parse a CLI `--tier` argument: a fixed tier, or `"mixed"` (`None`,
/// the loadgen cycles low/normal/high per request).
pub fn parse_tier_arg(s: &str) -> Result<Option<EnergyTier>> {
    if s == "mixed" {
        return Ok(None);
    }
    s.parse().map(Some).map_err(|e: String| anyhow::anyhow!(e))
}

/// Resolved serving plan of one tier: the full per-layer [`EnergyPlan`]
/// its lane reads with, plus summary scalars for reporting.
#[derive(Clone, Debug)]
pub struct TierPlan {
    pub tier: EnergyTier,
    /// Mean per-layer rho (the scalar summary; per-layer values live in
    /// [`TierPlan::plan`]).
    pub rho: f32,
    pub mode: ReadMode,
    /// Expected analytical energy per inference under the resolved plan
    /// — the tier's requested budget when achievable, or the closest
    /// achievable value after rho clamping / the peripheral floor, so
    /// the API never advertises a budget the lane cannot honour.
    pub budget_uj: f64,
    /// The per-layer allocation the lane's device reads actually use.
    pub plan: EnergyPlan,
}

impl TierPlan {
    /// Plan provenance (`trained` when a store rho vector shaped it).
    pub fn source(&self) -> PlanSource {
        self.plan.source
    }

    /// One-line human summary for CLI banners (shared by `serve-http`
    /// and the serving example so the two cannot drift).
    pub fn describe(&self) -> String {
        let rhos = self.plan.rhos();
        let (lo, hi) = rhos.iter().fold((f32::MAX, f32::MIN), |(lo, hi), &r| {
            (lo.min(r), hi.max(r))
        });
        format!(
            "tier {:<6}  rho {:>6.2} [{:.2}..{:.2}]  mode {:<10}  {:<8}  budget {:.2} uJ/inference",
            self.tier.name(),
            self.rho,
            lo,
            hi,
            self.mode.name(),
            self.source().name(),
            self.budget_uj
        )
    }
}

/// Describe a deployed [`NoisyModel`] as a dense-layer stack for the
/// analytical energy model (every native layer is one crossbar-mapped
/// dense layer, alpha == 1).
pub fn model_desc(model: &NoisyModel) -> ModelDesc {
    ModelDesc {
        name: "deployed".into(),
        layers: model
            .layers()
            .iter()
            .map(|l| LayerMeta::dense(l.d_in as u64, l.d_out as u64))
            .collect(),
    }
}

/// Rho range a tier lane may run a layer at (device-sane bounds; a plan
/// entry outside it is clamped and the advertised budget recomputed).
pub const TIER_RHO_MIN: f32 = 0.25;
pub const TIER_RHO_MAX: f32 = 64.0;

/// Load the trained per-layer rho vector of a stored model
/// (`store::save` format): the `--model-store` path of `serve-http`.
/// Returns the rho values (softplus-decoded from `rho_raw`), validated
/// finite/positive; layer-count validation happens in [`tier_plans`]
/// where the deployed model is known.
pub fn load_trained_rho(path: &std::path::Path) -> Result<Vec<f32>> {
    let trained = crate::coordinator::store::load(path)?;
    let rho = trained.rho();
    anyhow::ensure!(
        !rho.is_empty(),
        "{}: stored model carries no trained rho vector",
        path.display()
    );
    Ok(rho)
}

/// Resolve each tier to a full per-layer [`EnergyPlan`] for a deployed
/// model.  Tier budgets are multiples of the model's energy at the
/// device-default rho.  With a trained rho vector (`--model-store`) the
/// vector is rescaled onto each tier budget preserving its relative
/// layer allocation ([`EnergyModel::plan_from_trained`], plan source
/// `trained`); otherwise the analytic solver fills the budget uniformly
/// ([`EnergyModel::plan_for_budget`], source `analytic`).  Per-layer rho
/// is clamped to the device-sane range and the advertised budget is
/// recomputed from the clamped plan, so the API never advertises a
/// budget the lane cannot honour.
pub fn tier_plans(
    model: &NoisyModel,
    device: &DeviceConfig,
    trained_rho: Option<&[f32]>,
) -> Result<Vec<TierPlan>> {
    let desc = model_desc(model);
    let n_layers = desc.layers.len();
    if let Some(r) = trained_rho {
        anyhow::ensure!(
            r.len() == n_layers,
            "trained rho vector has {} layers, deployed model has {n_layers}",
            r.len()
        );
        anyhow::ensure!(
            r.iter().all(|v| v.is_finite() && *v > 0.0),
            "trained rho vector must be finite and positive: {r:?}"
        );
    }
    let em = EnergyModel::new(device.act_bits);
    let reference_uj = em.model_uj_uniform(&desc, device.rho as f64, ReadMode::Original);
    Ok(EnergyTier::ALL
        .iter()
        .map(|&tier| {
            let target_uj = reference_uj * tier.budget_scale();
            let mode = tier.mode();
            // A target below the mode's peripheral floor is unachievable
            // (solver -> None): fall back to the minimum-rho plan rather
            // than silently burning the device default.  The fallback
            // keeps the tier's plan source — a trained vector keeps its
            // shape at the minimum scale — so every tier of one engine
            // always advertises the same provenance (`/healthz` and the
            // CI smoke assert exactly that), and the recomputed budget
            // below reports what the lane will actually spend.
            let solved = match trained_rho {
                Some(r) => em.plan_from_trained(&desc, r, target_uj, mode).unwrap_or_else(|| {
                    let min = r.iter().cloned().fold(f32::MAX, f32::min);
                    EnergyPlan::new(
                        r.iter()
                            .map(|&v| LayerPlan::new(v * (TIER_RHO_MIN / min), mode))
                            .collect(),
                        PlanSource::Trained,
                    )
                }),
                None => em
                    .plan_for_budget(&desc, target_uj, mode, None)
                    .unwrap_or_else(|| EnergyPlan::uniform(n_layers, TIER_RHO_MIN, mode)),
            };
            let plan = EnergyPlan::new(
                solved
                    .layers()
                    .iter()
                    .map(|l| LayerPlan::new(l.rho.clamp(TIER_RHO_MIN, TIER_RHO_MAX), l.mode))
                    .collect(),
                solved.source,
            );
            // Advertise what the lane will actually spend (== target
            // whenever the target was achievable without clamping).
            let budget_uj = em.plan_uj(&desc, &plan);
            TierPlan {
                tier,
                rho: plan.mean_rho(),
                mode,
                budget_uj,
                plan,
            }
        })
        .collect())
}

// ---------------------------------------------------------------------------
// tiered engine: one scheduler lane per tier over a shared worker pool
// ---------------------------------------------------------------------------

/// The three energy tiers as lanes of ONE unified [`scheduler::Engine`]:
/// a single shared worker pool pulls from per-tier bounded queues
/// (deficit-weighted work stealing), a rebalancer loop follows load,
/// and — when configured — an energy governor enforces the fleet uJ/s
/// budget at admission.  Lane index == [`EnergyTier::index`], so `low`
/// is the lowest scheduling priority (shed first, drained last).
pub struct TieredEngine {
    engine: scheduler::Engine,
    plans: Vec<TierPlan>,
    /// One validating client handle per tier lane.
    clients: Vec<InferenceClient>,
}

impl TieredEngine {
    /// Spawn the engine; returns it plus all its thread handles (join
    /// them after dropping the engine).  `base.workers` is the size of
    /// the **shared** pool (not per tier).  `trained_rho` is the
    /// per-layer trained rho vector of a stored model
    /// ([`load_trained_rho`]), or `None` for the analytic plans.
    pub fn start(
        model: Arc<NoisyModel>,
        base: &NativeServerConfig,
        trained_rho: Option<&[f32]>,
    ) -> Result<(TieredEngine, Vec<std::thread::JoinHandle<()>>)> {
        anyhow::ensure!(base.max_client_batch > 0, "max_client_batch must be positive");
        let plans = tier_plans(&model, &base.device, trained_rho)?;
        let lanes: Vec<LaneSpec> = plans
            .iter()
            .map(|p| LaneSpec {
                plan: p.plan.clone(),
                seed: base.seed.wrapping_add(p.tier.index() as u64),
            })
            .collect();
        let (engine, handles) = scheduler::Engine::start(model, base, lanes)?;
        let clients = clients_for_engine(&engine, base.max_client_batch);
        Ok((
            TieredEngine {
                engine,
                plans,
                clients,
            },
            handles,
        ))
    }

    /// Plan provenance of the lanes (identical across tiers: one model,
    /// one source).
    pub fn plan_source(&self) -> PlanSource {
        self.plans[0].source()
    }

    pub fn plan(&self, tier: EnergyTier) -> &TierPlan {
        &self.plans[tier.index()]
    }

    pub fn stats(&self, tier: EnergyTier) -> &Arc<ServerStats> {
        self.engine.stats(tier.index())
    }

    /// `(plan, stats)` of every tier, in [`EnergyTier::ALL`] order.
    pub fn per_tier(&self) -> Vec<(&TierPlan, &ServerStats)> {
        self.plans
            .iter()
            .enumerate()
            .map(|(i, p)| (p, self.engine.stats(i).as_ref()))
            .collect()
    }

    /// Scheduler observability (per-tier queue length, effective
    /// workers, steals, governor state) for `/metrics`.
    pub fn snapshot(&self) -> EngineSnapshot {
        self.engine.snapshot()
    }

    /// One manual rebalance step (deterministic-clock tests; the
    /// background loop runs on `base.rebalance_interval` otherwise).
    pub fn rebalance_once(&self) -> usize {
        self.engine.rebalance_once()
    }

    /// Freeze rebalancing and drain highest-priority-first (graceful
    /// shutdown).
    pub fn begin_drain(&self) {
        self.engine.begin_drain()
    }

    /// The configured fleet energy budget, if the governor is armed.
    pub fn energy_budget_uj_s(&self) -> Option<f64> {
        self.engine.energy_budget_uj_s()
    }

    /// The engine's shared serve-path buffer pool: the HTTP front end
    /// recycles request bodies and reply logits through it, and its
    /// counters feed `emtopt_alloc_pool_*` on `/metrics` (see
    /// [`crate::pool`]).
    pub fn alloc_pool(&self) -> &Arc<BufferPool> {
        self.engine.alloc_pool()
    }

    pub fn input_len(&self) -> usize {
        self.clients[0].input_len
    }

    pub fn num_classes(&self) -> usize {
        self.clients[0].num_classes
    }

    /// Max images accepted in one multi-image request (identical across
    /// lanes — they share one engine config).
    pub fn max_client_batch(&self) -> usize {
        self.clients[0].max_client_batch
    }

    /// Non-blocking admission into the tier's queue (typed `Overloaded`
    /// when it is full, `EnergyShed` when the governor refuses the tier).
    pub fn try_infer(&self, tier: EnergyTier, image: Vec<f32>) -> Result<Vec<f32>> {
        self.clients[tier.index()].try_infer(image)
    }

    /// Non-blocking multi-image submit: the whole request runs as one
    /// device batch, skipping the dynamic-batching wait (typed
    /// `Overloaded` / `BatchTooLarge` / `EnergyShed` on admission
    /// failure).
    pub fn try_infer_batch(&self, tier: EnergyTier, images: Vec<f32>) -> Result<Vec<f32>> {
        self.clients[tier.index()].try_infer_batch(images)
    }

    /// Blocking submit (backpressure instead of load-shedding).
    pub fn infer(&self, tier: EnergyTier, image: Vec<f32>) -> Result<Vec<f32>> {
        self.clients[tier.index()].infer(image)
    }

    /// Blocking multi-image submit (backpressure flavour of
    /// [`TieredEngine::try_infer_batch`]).
    pub fn infer_batch(&self, tier: EnergyTier, images: Vec<f32>) -> Result<Vec<f32>> {
        self.clients[tier.index()].infer_batch(images)
    }

    /// Traced single-image submit (`block` picks backpressure vs
    /// load-shedding): returns the logits plus the span record the
    /// scheduler filled in for this request.
    pub fn infer_traced(
        &self,
        tier: EnergyTier,
        image: Vec<f32>,
        block: bool,
        tctx: &TraceContext,
    ) -> Result<Reply> {
        self.clients[tier.index()].infer_traced(image, block, tctx)
    }

    /// Traced multi-image submit (see [`TieredEngine::infer_traced`]).
    pub fn infer_batch_traced(
        &self,
        tier: EnergyTier,
        images: Vec<f32>,
        block: bool,
        tctx: &TraceContext,
    ) -> Result<Reply> {
        self.clients[tier.index()].infer_batch_traced(images, block, tctx)
    }

    /// Non-blocking submit whose `Reply` lands on `cq` tagged with `key`
    /// (the event loop's path: the caller never waits).  Admission
    /// errors (`Overloaded` / `EnergyShed`, or the parked-backpressure
    /// admit when `block`) are still returned synchronously — they need
    /// the live lane stats for their `Retry-After` hint.
    pub fn infer_completion(
        &self,
        tier: EnergyTier,
        image: Vec<f32>,
        block: bool,
        tctx: &TraceContext,
        cq: &Arc<CompletionQueue>,
        key: u64,
    ) -> Result<()> {
        self.clients[tier.index()].infer_completion(image, block, tctx, cq, key)
    }

    /// Multi-image flavour of [`TieredEngine::infer_completion`].
    pub fn infer_batch_completion(
        &self,
        tier: EnergyTier,
        images: Vec<f32>,
        block: bool,
        tctx: &TraceContext,
        cq: &Arc<CompletionQueue>,
        key: u64,
    ) -> Result<()> {
        self.clients[tier.index()].infer_batch_completion(images, block, tctx, cq, key)
    }
}

// ---------------------------------------------------------------------------
// HTTP front end
// ---------------------------------------------------------------------------

/// Configuration of the HTTP front end.
#[derive(Clone, Debug)]
pub struct HttpServerConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (port 0 picks an ephemeral
    /// port; read it back from [`ServerHandle::addr`]).
    pub addr: String,
    /// Global cap on simultaneously open connections.  Above it the
    /// event loop answers new connections with a typed `503` +
    /// `Retry-After` and closes them.  The live count and its
    /// high-water mark are the `emtopt_http_open_conns{,_peak}` gauges.
    pub max_conns: usize,
    /// Request body cap (`413` above it).
    pub max_body_bytes: usize,
    /// Sweep timeout for idle keep-alive connections and for peers that
    /// stopped reading their response (stalled writes).
    pub idle_timeout: Duration,
    /// Max age of a partially received request before the loop answers
    /// `400` and closes — the slowloris guard: a peer trickling header
    /// bytes costs one fd and a small buffer, never a worker.
    pub request_timeout: Duration,
    /// Max simultaneous connections accepted from one peer IP; above it
    /// the loop answers `429 Too Many Requests` and closes (typed
    /// rejection, counted on `/metrics`).  Keep-alive clients hold their
    /// connection between requests, so this bounds per-peer fd capture,
    /// not request rate.
    pub max_conns_per_peer: usize,
    /// Exact result-cache entry bound (`serve-http --cache-entries`).
    /// The cache is armed iff **both** this and [`cache_bytes`] are
    /// positive; the default (0) keeps every response byte-path
    /// identical to a cache-less build.  See [`crate::cache`] and
    /// DESIGN.md §13.
    ///
    /// [`cache_bytes`]: HttpServerConfig::cache_bytes
    pub cache_entries: usize,
    /// Exact result-cache payload byte bound (`serve-http --cache-mb`;
    /// 0 disables the cache).
    pub cache_bytes: usize,
    /// Per-layer trained rho vector for the tier plans
    /// ([`load_trained_rho`]; `serve-http --model-store`).  `None` uses
    /// the analytic plans.
    pub trained_rho: Option<Vec<f32>>,
    /// Engine config shared by the tier lanes (per-layer plan overridden
    /// per tier by [`tier_plans`]).
    pub engine: NativeServerConfig,
}

impl Default for HttpServerConfig {
    fn default() -> Self {
        HttpServerConfig {
            addr: "127.0.0.1:8080".into(),
            // C10K by default: a connection is one fd + parser/write
            // buffers on the loop, not a thread
            max_conns: 10_000,
            // Must fit the batches the engine default advertises on
            // /healthz: max_client_batch (64) CIFAR images are ~2 MiB of
            // JSON (~30 KiB per image), so 8 MiB leaves headroom —
            // a server must never 413 a batch it claims to accept.
            max_body_bytes: 8 << 20,
            idle_timeout: Duration::from_secs(60),
            request_timeout: Duration::from_secs(5),
            // generous: CI drives 8+ loadgen connections from localhost;
            // the cap is a hostile-peer guard, not a fairness scheduler
            max_conns_per_peer: 64,
            cache_entries: 0,
            cache_bytes: 0,
            trained_rho: None,
            engine: NativeServerConfig::default(),
        }
    }
}

/// HTTP-layer counters (responses by status, connections accepted).
#[derive(Debug, Default)]
pub struct HttpStats {
    pub connections: AtomicU64,
    pub ok_200: AtomicU64,
    pub bad_request_400: AtomicU64,
    pub not_found_404: AtomicU64,
    pub method_not_allowed_405: AtomicU64,
    pub payload_too_large_413: AtomicU64,
    /// Per-peer connection-cap rejections (whole connections, not
    /// requests: the peer was over [`HttpServerConfig::max_conns_per_peer`]).
    pub too_many_requests_429: AtomicU64,
    pub internal_500: AtomicU64,
    pub overloaded_503: AtomicU64,
    /// Connections currently open on the event loop (gauge).
    pub open_conns: AtomicU64,
    /// High-water mark of [`HttpStats::open_conns`] — a monotone peak,
    /// so a scrape after the burst still sees the achieved concurrency.
    pub open_conns_peak: AtomicU64,
}

impl HttpStats {
    /// One connection entered the loop: bump the gauge and fold it into
    /// the peak.
    pub fn conn_opened(&self) {
        let now = self.open_conns.fetch_add(1, Ordering::Relaxed) + 1;
        self.open_conns_peak.fetch_max(now, Ordering::Relaxed);
    }

    /// One connection left the loop.
    pub fn conn_closed(&self) {
        self.open_conns.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn record(&self, status: u16) {
        let cell = match status {
            200 => &self.ok_200,
            400 => &self.bad_request_400,
            404 => &self.not_found_404,
            405 => &self.method_not_allowed_405,
            413 => &self.payload_too_large_413,
            429 => &self.too_many_requests_429,
            503 => &self.overloaded_503,
            _ => &self.internal_500,
        };
        cell.fetch_add(1, Ordering::Relaxed);
    }

    /// `(status, count)` pairs in ascending status order (zeros included,
    /// so `/metrics` series are stable from the first scrape).
    pub fn by_code(&self) -> Vec<(u16, u64)> {
        vec![
            (200, self.ok_200.load(Ordering::Relaxed)),
            (400, self.bad_request_400.load(Ordering::Relaxed)),
            (404, self.not_found_404.load(Ordering::Relaxed)),
            (405, self.method_not_allowed_405.load(Ordering::Relaxed)),
            (413, self.payload_too_large_413.load(Ordering::Relaxed)),
            (429, self.too_many_requests_429.load(Ordering::Relaxed)),
            (500, self.internal_500.load(Ordering::Relaxed)),
            (503, self.overloaded_503.load(Ordering::Relaxed)),
        ]
    }

    /// Total responses written.
    pub fn total(&self) -> u64 {
        self.by_code().iter().map(|&(_, n)| n).sum()
    }
}

struct ServerCtx {
    engine: TieredEngine,
    http: HttpStats,
    shutdown: AtomicBool,
    started: Instant,
    addr: SocketAddr,
    /// Exact result cache (`--cache-entries`/`--cache-mb`; `None` = off).
    /// Consulted by the event loop *before* admission — a hit skips the
    /// scheduler entirely — and filled from the completion path.
    cache: Option<ResultCache>,
    /// Per-tier content-key salts, [`EnergyTier::index`]-ordered: the
    /// boot-time fold of (model fingerprint, tier plan hash, tier index)
    /// every request key derives under ([`CacheKey::tier_salt`]).
    cache_salts: [u64; 3],
    /// Ring of the last N complete request traces (`GET /admin/trace`).
    recorder: FlightRecorder,
    /// Event-loop wakeup: completion-queue pushes (from scheduler
    /// workers) and shutdown requests (from any thread) write here so
    /// the loop returns from `epoll_wait` immediately.
    wake: Arc<WakeFd>,
}

/// Handle to a running server: bound address, stats, graceful shutdown.
pub struct ServerHandle {
    ctx: Arc<ServerCtx>,
    event_loop: Option<std::thread::JoinHandle<()>>,
    engine_handles: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.ctx.addr
    }

    pub fn http_stats(&self) -> &HttpStats {
        &self.ctx.http
    }

    /// `(plan, stats)` of every engine tier.
    pub fn per_tier(&self) -> Vec<(&TierPlan, &ServerStats)> {
        self.ctx.engine.per_tier()
    }

    /// Per-tier serving summary (requests, tail latency, energy) for CLI
    /// reports; tiers that served no traffic are omitted.
    pub fn tier_summary(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (plan, stats) in self.per_tier() {
            let n = stats.requests.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            let _ = writeln!(
                out,
                "tier {:<6} {n:>6} requests | p50 {:.2} ms | p95 {:.2} ms | \
                 p99 {:.2} ms | {:.1} nJ/request",
                plan.tier.name(),
                stats.latency.p50_us() / 1000.0,
                stats.latency.p95_us() / 1000.0,
                stats.latency.p99_us() / 1000.0,
                stats.mean_energy_pj_per_request() / 1000.0
            );
        }
        out
    }

    /// True once a shutdown was requested (flag, `/admin/shutdown`, or
    /// [`ServerHandle::shutdown`]).
    pub fn shutdown_requested(&self) -> bool {
        self.ctx.shutdown.load(Ordering::SeqCst)
    }

    /// Request a shutdown without consuming the handle (the event loop
    /// is woken; call [`ServerHandle::shutdown`] to join everything).
    /// The engine enters drain mode immediately: rebalance moves freeze
    /// and queued work flushes highest-tier-first.
    pub fn request_shutdown(&self) {
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        self.ctx.engine.begin_drain();
        self.ctx.wake.wake();
    }

    /// Graceful shutdown: stop accepting, flush in-flight responses (the
    /// loop's bounded drain), stop the engine lanes, and join every
    /// thread.
    pub fn shutdown(mut self) -> Result<()> {
        self.request_shutdown();
        if let Some(h) = self.event_loop.take() {
            h.join().map_err(|_| anyhow::anyhow!("event loop panicked"))?;
        }
        // The loop is gone, so this is the last reference to the
        // context; dropping it drops the lane clients, which stops the
        // engine batchers and workers.
        drop(self.ctx);
        for h in self.engine_handles {
            h.join().map_err(|_| anyhow::anyhow!("engine worker panicked"))?;
        }
        Ok(())
    }
}

/// Bind, spawn the engine lanes + the epoll event loop, and return
/// immediately with a [`ServerHandle`].
pub fn serve_http(model: Arc<NoisyModel>, cfg: HttpServerConfig) -> Result<ServerHandle> {
    anyhow::ensure!(cfg.max_conns > 0, "max_conns must be positive");
    anyhow::ensure!(cfg.max_conns_per_peer > 0, "max_conns_per_peer must be positive");
    // One pass over the programmed weights before the model Arc moves
    // into the engine: the fingerprint half of the cache key salts.
    let fingerprint = model_fingerprint(&model);
    let (engine, engine_handles) =
        TieredEngine::start(model, &cfg.engine, cfg.trained_rho.as_deref())?;
    let cache = (cfg.cache_entries > 0 && cfg.cache_bytes > 0)
        .then(|| ResultCache::new(cfg.cache_entries, cfg.cache_bytes));
    let mut cache_salts = [0u64; 3];
    for tier in EnergyTier::ALL {
        cache_salts[tier.index()] =
            CacheKey::tier_salt(fingerprint, tier_plan_hash(engine.plan(tier)), tier.index());
    }

    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| anyhow::anyhow!("binding {}: {e}", cfg.addr))?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let wake = Arc::new(WakeFd::new().map_err(|e| anyhow::anyhow!("eventfd: {e}"))?);
    let ctx = Arc::new(ServerCtx {
        engine,
        http: HttpStats::default(),
        shutdown: AtomicBool::new(false),
        started: Instant::now(),
        addr,
        cache,
        cache_salts,
        recorder: FlightRecorder::new(trace::DEFAULT_FLIGHT_CAPACITY),
        wake,
    });

    // Construct (and register fds) here so an epoll failure surfaces as
    // a startup error, not a dead server.
    let el = EventLoop::new(
        ctx.clone(),
        listener,
        LoopConfig {
            max_conns: cfg.max_conns,
            max_conns_per_peer: cfg.max_conns_per_peer,
            max_body: cfg.max_body_bytes,
            idle_timeout: cfg.idle_timeout,
            request_timeout: cfg.request_timeout,
        },
    )?;
    let event_loop = std::thread::Builder::new()
        .name("emtopt-epoll".into())
        .spawn(move || el.run())?;

    Ok(ServerHandle {
        ctx,
        event_loop: Some(event_loop),
        engine_handles,
    })
}

// ---------------------------------------------------------------------------
// epoll event loop
// ---------------------------------------------------------------------------

/// `epoll_wait` token of the listening socket.
const TOKEN_LISTENER: u64 = 0;
/// `epoll_wait` token of the wakeup eventfd.
const TOKEN_WAKE: u64 = 1;
/// Connection tokens start here: token = slot index + `TOKEN_BASE`.
const TOKEN_BASE: u64 = 2;
/// `epoll_wait` timeout: bounds sweep latency and shutdown-flag checks
/// when no fd fires (wakes normally come through the eventfd).
const TICK_MS: i32 = 100;
/// How often the timeout sweep scans connections.
const SWEEP_EVERY: Duration = Duration::from_millis(250);
/// How long a graceful shutdown waits for in-flight compute + flushes.
const DRAIN_DEADLINE: Duration = Duration::from_secs(5);

/// Event-loop knobs (the connection-level subset of
/// [`HttpServerConfig`]).
struct LoopConfig {
    max_conns: usize,
    max_conns_per_peer: usize,
    max_body: usize,
    idle_timeout: Duration,
    request_timeout: Duration,
}

/// One admitted request in flight on the scheduler: everything needed
/// to render its response when the `Reply` lands on the completion
/// queue (the connection keeps no thread waiting).
struct Inflight {
    keep_alive: bool,
    classify: bool,
    trace_echo: bool,
    batch: bool,
    tier: EnergyTier,
    /// Monotonic anchor at request parse start (the `total_us` origin).
    t_start: Instant,
    /// Result-cache key of this request (cache armed, lookup missed):
    /// the completion path inserts the reply under it.  `None` when the
    /// cache is off — or on the synthetic hit-path `Inflight`, which
    /// must never re-insert what it just read.
    cache_key: Option<CacheKey>,
}

/// A traced response being flushed: `write_us` spans completion-enqueue
/// to last-byte-flushed — on a parked (EPOLLOUT) write-back that
/// includes the whole park, which is the point: the write stage
/// measures delivery, not a single syscall.
struct PendingWrite {
    span: SpanRecord,
    t_start: Instant,
    t_enqueue: Instant,
}

/// Per-connection state machine on the loop.  A connection is EITHER
/// reading a request, awaiting its completion, or flushing its response
/// — never more than one request in flight per connection (pipelined
/// bytes wait in the parser).
struct Conn {
    stream: TcpStream,
    peer_ip: Option<IpAddr>,
    /// Whether this connection was charged against its peer's cap
    /// (rejected connections are not).
    charged: bool,
    parser: RequestParser,
    out: Vec<u8>,
    out_pos: usize,
    awaiting: Option<Inflight>,
    pending_write: Option<PendingWrite>,
    close_after_flush: bool,
    /// Peer shut down its write half (EOF / RDHUP): serve what is
    /// already buffered, then close.
    read_closed: bool,
    /// Currently registered epoll interest mask.
    interest: u32,
    last_progress: Instant,
    /// When the currently-incomplete request's first byte arrived
    /// (slowloris sweep anchor); `None` between requests.
    partial_since: Option<Instant>,
}

struct Slot {
    conn: Option<Conn>,
    /// Bumped on close so a completion for a dead connection (stale
    /// key) can never reach the slot's next tenant.
    generation: u32,
}

enum SweepAction {
    Drop,
    Timeout400,
}

/// The readiness loop: owns every socket, the slab of connection
/// state, and the completion queue the scheduler posts `Reply`s to.
struct EventLoop {
    ctx: Arc<ServerCtx>,
    cfg: LoopConfig,
    poller: Poller,
    listener: TcpListener,
    cq: Arc<CompletionQueue>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    /// Live connection count per peer IP (entries removed at zero so
    /// the map stays bounded by distinct live peers).
    peers: HashMap<IpAddr, u32>,
    open: usize,
}

impl EventLoop {
    fn new(ctx: Arc<ServerCtx>, listener: TcpListener, cfg: LoopConfig) -> Result<EventLoop> {
        let poller = Poller::new().map_err(|e| anyhow::anyhow!("epoll_create1: {e}"))?;
        poller
            .add(listener.as_raw_fd(), EPOLLIN, TOKEN_LISTENER)
            .map_err(|e| anyhow::anyhow!("registering listener: {e}"))?;
        poller
            .add(ctx.wake.raw(), EPOLLIN, TOKEN_WAKE)
            .map_err(|e| anyhow::anyhow!("registering wakeup fd: {e}"))?;
        let wake = ctx.wake.clone();
        let cq = CompletionQueue::new(Box::new(move || wake.wake()));
        Ok(EventLoop {
            ctx,
            cfg,
            poller,
            listener,
            cq,
            slots: Vec::new(),
            free: Vec::new(),
            peers: HashMap::new(),
            open: 0,
        })
    }

    fn run(mut self) {
        let mut events = Poller::event_buf(1024);
        let mut last_sweep = Instant::now();
        let mut draining: Option<Instant> = None; // drain deadline
        loop {
            let n = match self.poller.wait(&mut events, TICK_MS) {
                Ok(n) => n,
                Err(_) => continue,
            };
            for ev in &events[..n] {
                match ev.key() {
                    TOKEN_LISTENER => {
                        if draining.is_none() {
                            self.accept_ready();
                        }
                    }
                    TOKEN_WAKE => self.ctx.wake.drain(),
                    token => self.conn_ready((token - TOKEN_BASE) as usize, ev.readiness()),
                }
            }
            self.drain_completions();

            let now = Instant::now();
            if now.duration_since(last_sweep) >= SWEEP_EVERY {
                self.sweep(now);
                last_sweep = now;
            }

            if draining.is_none() && self.ctx.shutdown.load(Ordering::SeqCst) {
                draining = Some(now + DRAIN_DEADLINE);
                // stop accepting; queued-but-unaccepted connections are
                // reset by the kernel when the listener drops
                let _ = self.poller.remove(self.listener.as_raw_fd());
            }
            if let Some(deadline) = draining {
                // close everything with nothing left to deliver; what
                // remains is in-flight compute or an unflushed response
                for idx in 0..self.slots.len() {
                    let done = matches!(
                        &self.slots[idx].conn,
                        Some(c) if c.awaiting.is_none() && c.out_pos >= c.out.len()
                    );
                    if done {
                        self.close(idx);
                    }
                }
                if self.open == 0 || Instant::now() >= deadline {
                    return;
                }
            }
        }
    }

    // -- accept path --------------------------------------------------

    fn accept_ready(&mut self) {
        loop {
            let (stream, peer) = match self.listener.accept() {
                Ok(x) => x,
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return,
            };
            self.ctx.http.connections.fetch_add(1, Ordering::Relaxed);
            let _ = stream.set_nonblocking(true);
            let _ = stream.set_nodelay(true);
            let ip = Some(peer.ip());

            // Per-peer cap first: a peer over its connection budget gets
            // a typed 429 before it can claim global capacity.  Unlike
            // 503 this is the peer's fault: it must close (or reuse)
            // existing connections, not retry with more.
            let mut reject: Option<Response> = None;
            let mut charged = false;
            let over_peer_cap = ip.map_or(false, |ip| {
                self.peers.get(&ip).map_or(0, |&n| n as usize) >= self.cfg.max_conns_per_peer
            });
            if over_peer_cap {
                self.ctx.http.record(429);
                reject = Some(
                    Response::error_json(
                        429,
                        &format!(
                            "too many connections from this peer (cap {})",
                            self.cfg.max_conns_per_peer
                        ),
                    )
                    .with_retry_after(1),
                );
            } else if self.open >= self.cfg.max_conns {
                // Global connection cap: typed 503 so well-behaved
                // clients back off instead of hammering the accept queue.
                self.ctx.http.record(503);
                reject = Some(
                    Response::error_json(
                        503,
                        &format!("server at connection capacity ({})", self.cfg.max_conns),
                    )
                    .with_retry_after(1),
                );
            } else if let Some(ip) = ip {
                *self.peers.entry(ip).or_insert(0) += 1;
                charged = true;
            }

            let mut conn = Conn {
                stream,
                peer_ip: ip,
                charged,
                // Pooled parser: request-body buffers come from (and
                // return to) the engine's shared pool, so a warmed
                // keep-alive connection frames bodies without
                // allocating.
                parser: RequestParser::with_pool(Some(self.ctx.engine.alloc_pool().clone())),
                out: Vec::new(),
                out_pos: 0,
                awaiting: None,
                pending_write: None,
                close_after_flush: reject.is_some(),
                read_closed: false,
                interest: 0,
                last_progress: Instant::now(),
                partial_since: None,
            };
            if let Some(resp) = reject {
                // rejected connections flush their error and close; the
                // loop never reads them
                conn.out = render_response(&resp, false);
            }
            let idx = self.insert(conn);
            self.advance(idx);
        }
    }

    /// Park a connection in a slab slot, register its fd, and bump the
    /// open-connection gauges.
    fn insert(&mut self, conn: Conn) -> usize {
        let idx = match self.free.pop() {
            Some(i) => {
                self.slots[i].conn = Some(conn);
                i
            }
            None => {
                self.slots.push(Slot {
                    conn: Some(conn),
                    generation: 0,
                });
                self.slots.len() - 1
            }
        };
        self.open += 1;
        self.ctx.http.conn_opened();
        let c = self.slots[idx].conn.as_mut().expect("just inserted");
        c.interest = desired_interest(c);
        let _ = self
            .poller
            .add(c.stream.as_raw_fd(), c.interest, TOKEN_BASE + idx as u64);
        idx
    }

    /// Completion-queue key of a slot: index + generation, so a reply
    /// outliving its connection is recognizably stale.
    fn completion_key(&self, idx: usize) -> u64 {
        ((self.slots[idx].generation as u64) << 32) | idx as u64
    }

    // -- readiness dispatch -------------------------------------------

    fn conn_ready(&mut self, idx: usize, readiness: u32) {
        if self
            .slots
            .get(idx)
            .map_or(true, |s| s.conn.is_none())
        {
            return; // closed earlier in this batch; spurious event
        }
        if readiness & (EPOLLERR | EPOLLHUP) != 0 {
            self.close(idx);
            return;
        }
        if readiness & (EPOLLIN | EPOLLRDHUP) != 0 && !self.read_some(idx) {
            return; // connection closed mid-read
        }
        self.advance(idx);
    }

    /// Pull whatever the kernel has buffered into the request parser.
    /// Returns false when the connection was closed.
    fn read_some(&mut self, idx: usize) -> bool {
        let mut buf = [0u8; 8192];
        loop {
            let c = match self.slots[idx].conn.as_mut() {
                Some(c) => c,
                None => return false,
            };
            let r = c.stream.read(&mut buf);
            match r {
                Ok(0) => {
                    c.read_closed = true;
                    return true;
                }
                Ok(n) => {
                    c.parser.feed(&buf[..n]);
                    c.last_progress = Instant::now();
                    if n < buf.len() {
                        return true; // kernel buffer drained (level-triggered: a refill re-fires)
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.close(idx);
                    return false;
                }
            }
        }
    }

    // -- the per-connection state machine -----------------------------

    /// Drive a connection as far as it can go right now: flush pending
    /// bytes, then (if idle) frame and dispatch the next request from
    /// the parser; repeat until it blocks, parks, or closes.  Ends by
    /// reconciling the fd's epoll interest with the new state.
    fn advance(&mut self, idx: usize) {
        enum Step {
            Parked,
            Close,
            Respond(Response),
            Request(HttpRequest),
            /// An interim `100 Continue` was queued: loop again so it
            /// flushes now, before the client's body arrives.
            Interim,
        }
        loop {
            if !self.flush(idx) {
                return; // closed
            }
            let max_body = self.cfg.max_body;
            let step = {
                let c = match self.slots[idx].conn.as_mut() {
                    Some(c) => c,
                    None => return,
                };
                if c.out_pos < c.out.len() {
                    Step::Parked // waiting for EPOLLOUT
                } else if c.close_after_flush {
                    Step::Close
                } else if c.awaiting.is_some() {
                    Step::Parked // response will land on the completion queue
                } else {
                    match c.parser.try_next(max_body) {
                        Err(e) => {
                            let status = if e.is::<PayloadTooLarge>() { 413 } else { 400 };
                            c.partial_since = None;
                            Step::Respond(Response::error_json(status, &format!("{e}")))
                        }
                        Ok(Some(req)) => {
                            c.partial_since = None;
                            c.last_progress = Instant::now();
                            Step::Request(req)
                        }
                        Ok(None) => {
                            c.partial_since = if c.parser.has_partial() {
                                c.partial_since.or(Some(Instant::now()))
                            } else {
                                None
                            };
                            if c.parser.take_expect_continue() {
                                // Head parsed clean under the body cap and
                                // the client asked `Expect: 100-continue`:
                                // tell it to ship the body.  (An over-cap
                                // head already answered a typed 413 above,
                                // before any body byte moved.)  Interim
                                // responses are not counted in http stats
                                // and carry no pending write-back span.
                                c.out.extend_from_slice(b"HTTP/1.1 100 Continue\r\n\r\n");
                                Step::Interim
                            } else if c.read_closed {
                                // EOF with no (complete) request pending
                                Step::Close
                            } else {
                                Step::Parked
                            }
                        }
                    }
                }
            };
            match step {
                Step::Parked => break,
                Step::Close => {
                    self.close(idx);
                    return;
                }
                Step::Respond(resp) => {
                    // protocol-level error: answer and close
                    self.respond(idx, resp, false, None);
                }
                Step::Request(req) => self.dispatch(idx, req),
                Step::Interim => {} // next flush writes it; the claim is one-shot
            }
        }
        self.update_interest(idx);
    }

    fn dispatch(&mut self, idx: usize, mut req: HttpRequest) {
        match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/v1/infer") => self.dispatch_infer(idx, &req, false),
            ("POST", "/v1/classify") => self.dispatch_infer(idx, &req, true),
            _ => {
                let resp = route_simple(&self.ctx, &req);
                self.respond(idx, resp, req.keep_alive, None);
            }
        }
        // The body's bytes were consumed above (parsed into pixels or
        // answered); the buffer itself re-enters the pool so the next
        // request on any connection frames into recycled capacity.
        self.ctx
            .engine
            .alloc_pool()
            .put_bytes(std::mem::take(&mut req.body));
    }

    /// Parse and submit an inference request.  On admission the
    /// connection parks with an [`Inflight`]; the scheduler's `Reply`
    /// arrives via the completion queue.  Parse and admission errors
    /// answer immediately — they need no compute.
    fn dispatch_infer(&mut self, idx: usize, req: &HttpRequest, classify: bool) {
        let t_start = Instant::now();
        let (payload, tier, blocking, trace_echo) =
            match parse_infer_body(&req.body, self.ctx.engine.input_len()) {
                Ok(p) => p,
                Err(e) => {
                    self.respond(
                        idx,
                        Response::error_json(400, &format!("{e}")),
                        req.keep_alive,
                        None,
                    );
                    return;
                }
            };
        // The pixel fold feeds both the trace id and (cache armed) the
        // content key — one pass over the body either way.
        let (pixels, count): (&[f32], usize) = match &payload {
            InferPayload::Single(image) => (image, 1),
            InferPayload::Batch { images, count } => (images, *count),
        };
        let trace_id = image_seed(TRACE_ID_SALT, pixels);
        let cache_key = self
            .ctx
            .cache
            .as_ref()
            .map(|_| CacheKey::derive(self.ctx.cache_salts[tier.index()], pixels, count));

        // Exact result cache, consulted BEFORE admission (DESIGN.md
        // §13): a hit needs no queue slot, no device reads, no energy —
        // the memoized reply enqueues for write-back immediately.  The
        // flush path then records a write-stage sample and pushes the
        // span (cache_hit, zero compute stages) exactly as it would for
        // a computed reply, so the response bytes cannot drift.
        if let Some(key) = cache_key {
            let hit = self.ctx.cache.as_ref().expect("key implies cache").lookup(key);
            if let Some(hit) = hit {
                let span = SpanRecord {
                    trace_id,
                    start_us: self.ctx.recorder.now_us(),
                    tier: tier.index(),
                    images: count,
                    cache_hit: true,
                    ..SpanRecord::default()
                };
                let inflight = Inflight {
                    keep_alive: req.keep_alive,
                    classify,
                    trace_echo,
                    batch: matches!(payload, InferPayload::Batch { .. }),
                    tier,
                    t_start,
                    cache_key: None,
                };
                let (resp, span) = render_completion(
                    &self.ctx,
                    &inflight,
                    Ok(Reply {
                        logits: hit.logits,
                        span,
                    }),
                );
                let pending = span.map(|span| PendingWrite {
                    span,
                    t_start,
                    t_enqueue: Instant::now(),
                });
                self.respond(idx, resp, inflight.keep_alive, pending);
                return;
            }
        }

        let key = self.completion_key(idx);
        let (submitted, batch) = match payload {
            InferPayload::Single(image) => {
                let tctx = TraceContext {
                    trace_id,
                    start_us: self.ctx.recorder.now_us(),
                    t_start,
                };
                // blocking = backpressure (park in the lane's wait set
                // until space frees), default = load-shedding (typed
                // Overloaded -> 503)
                (
                    self.ctx
                        .engine
                        .infer_completion(tier, image, blocking, &tctx, &self.cq, key),
                    false,
                )
            }
            InferPayload::Batch { images, .. } => {
                let tctx = TraceContext {
                    trace_id,
                    start_us: self.ctx.recorder.now_us(),
                    t_start,
                };
                (
                    self.ctx
                        .engine
                        .infer_batch_completion(tier, images, blocking, &tctx, &self.cq, key),
                    true,
                )
            }
        };
        match submitted {
            Ok(()) => {
                let c = self.slots[idx].conn.as_mut().expect("live conn");
                c.awaiting = Some(Inflight {
                    keep_alive: req.keep_alive,
                    classify,
                    trace_echo,
                    batch,
                    tier,
                    t_start,
                    cache_key,
                });
            }
            Err(e) => {
                let resp = engine_error_response(&e, self.ctx.engine.stats(tier));
                self.respond(idx, resp, req.keep_alive, None);
            }
        }
    }

    /// Render finished compute back onto connections: the streaming
    /// write-back half of the loop.
    fn drain_completions(&mut self) {
        for (key, result) in self.cq.drain() {
            let idx = (key & 0xffff_ffff) as usize;
            let generation = (key >> 32) as u32;
            let live = self.slots.get(idx).map_or(false, |s| {
                s.generation == generation
                    && s.conn.as_ref().map_or(false, |c| c.awaiting.is_some())
            });
            if !live {
                // The connection died while its request computed.  The
                // reply has nowhere to go, but the work happened: keep
                // the span for the flight recorder (write_us stays 0 —
                // nothing was delivered, and the write-stage histogram
                // only ever samples delivered responses).
                if let Ok(reply) = result {
                    self.ctx.engine.alloc_pool().put_f32(reply.logits);
                    self.ctx.recorder.push(reply.span);
                }
                continue;
            }
            let inflight = self.slots[idx]
                .conn
                .as_mut()
                .and_then(|c| c.awaiting.take())
                .expect("checked live above");
            // Memoize the computed reply under the key the miss derived:
            // span.energy_uj is the compute energy a future hit saves.
            // Error replies are never cached — they are load state, not
            // content.
            if let (Some(cache), Some(ck)) = (self.ctx.cache.as_ref(), inflight.cache_key) {
                if let Ok(reply) = &result {
                    cache.insert(
                        ck,
                        CachedReply {
                            logits: reply.logits.clone(),
                            count: reply.span.images,
                            energy_uj: reply.span.energy_uj,
                        },
                    );
                }
            }
            let (resp, span) = render_completion(&self.ctx, &inflight, result);
            let pending = span.map(|span| PendingWrite {
                span,
                t_start: inflight.t_start,
                t_enqueue: Instant::now(),
            });
            self.respond(idx, resp, inflight.keep_alive, pending);
            self.advance(idx);
        }
    }

    /// Record + render a response into the connection's write buffer.
    /// Actual socket writes happen in [`EventLoop::flush`] (via
    /// [`EventLoop::advance`]) as the socket allows.
    fn respond(
        &mut self,
        idx: usize,
        resp: Response,
        keep_alive: bool,
        pending: Option<PendingWrite>,
    ) {
        self.ctx.http.record(resp.status);
        let c = self.slots[idx].conn.as_mut().expect("live conn");
        let keep = keep_alive && !c.read_closed && !c.close_after_flush;
        // Render straight into the connection's persistent out-buffer
        // (bytes identical to `render_response`); the response's own
        // body buffer then re-enters the pool.
        render_response_into(&resp, keep, &mut c.out);
        if !keep {
            c.close_after_flush = true;
        }
        debug_assert!(c.pending_write.is_none(), "one traced response at a time");
        c.pending_write = pending;
        self.ctx.engine.alloc_pool().put_bytes(resp.body);
    }

    /// Write as much of the out-buffer as the socket accepts; on the
    /// last byte, complete the deferred write-back span.  Returns false
    /// when the connection was closed.
    fn flush(&mut self, idx: usize) -> bool {
        loop {
            let c = match self.slots[idx].conn.as_mut() {
                Some(c) => c,
                None => return false,
            };
            if c.out_pos >= c.out.len() {
                if !c.out.is_empty() {
                    c.out.clear();
                    c.out_pos = 0;
                }
                if let Some(pw) = c.pending_write.take() {
                    let mut span = pw.span;
                    // enqueue-to-last-byte-flushed: a parked EPOLLOUT
                    // write-back bills its park time to the write stage
                    span.write_us = pw.t_enqueue.elapsed().as_micros() as u64;
                    span.total_us = pw.t_start.elapsed().as_micros() as u64;
                    if let Some(&tier) = EnergyTier::ALL.get(span.tier) {
                        self.ctx
                            .engine
                            .stats(tier)
                            .stages
                            .record(Stage::Write, span.write_us);
                    }
                    self.ctx.recorder.push(span);
                }
                return true;
            }
            let r = {
                let (stream, out, pos) = (&mut c.stream, &c.out, c.out_pos);
                let mut s = stream;
                s.write(&out[pos..])
            };
            match r {
                Ok(0) => {
                    self.close(idx);
                    return false;
                }
                Ok(n) => {
                    let c = self.slots[idx].conn.as_mut().expect("live conn");
                    c.out_pos += n;
                    c.last_progress = Instant::now();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return true, // park on EPOLLOUT
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.close(idx);
                    return false;
                }
            }
        }
    }

    // -- sweep + close -------------------------------------------------

    /// Reap connections that stopped making progress: idle keep-alive
    /// past `idle_timeout` (quiet close), a trickled partial request
    /// past `request_timeout` (`400` — the slowloris answer), a peer
    /// that stopped reading its response past `idle_timeout` (drop).
    fn sweep(&mut self, now: Instant) {
        for idx in 0..self.slots.len() {
            let action = {
                let c = match &self.slots[idx].conn {
                    Some(c) => c,
                    None => continue,
                };
                if c.awaiting.is_some() {
                    None // compute in flight; completion restarts the clock
                } else if c.out_pos < c.out.len() {
                    (now.duration_since(c.last_progress) > self.cfg.idle_timeout)
                        .then_some(SweepAction::Drop)
                } else if let Some(since) = c.partial_since {
                    (now.duration_since(since) > self.cfg.request_timeout)
                        .then_some(SweepAction::Timeout400)
                } else {
                    (now.duration_since(c.last_progress) > self.cfg.idle_timeout)
                        .then_some(SweepAction::Drop)
                }
            };
            match action {
                None => {}
                Some(SweepAction::Drop) => self.close(idx),
                Some(SweepAction::Timeout400) => {
                    self.respond(
                        idx,
                        Response::error_json(400, "request timed out (incomplete)"),
                        false,
                        None,
                    );
                    self.advance(idx);
                }
            }
        }
    }

    fn close(&mut self, idx: usize) {
        let Some(c) = self.slots[idx].conn.take() else {
            return;
        };
        let _ = self.poller.remove(c.stream.as_raw_fd());
        if c.charged {
            if let Some(ip) = c.peer_ip {
                if let Some(n) = self.peers.get_mut(&ip) {
                    *n -= 1;
                    if *n == 0 {
                        self.peers.remove(&ip);
                    }
                }
            }
        }
        // a completion racing this close sees a stale generation
        self.slots[idx].generation = self.slots[idx].generation.wrapping_add(1);
        self.free.push(idx);
        self.open -= 1;
        self.ctx.http.conn_closed();
        // c.stream drops here -> fd closed (after epoll deregistration)
    }

    /// Reconcile the fd's registered epoll interest with the
    /// connection's state (EPOLLIN only while willing to read, EPOLLOUT
    /// only while bytes are parked).
    fn update_interest(&mut self, idx: usize) {
        let Some(c) = self.slots[idx].conn.as_mut() else {
            return;
        };
        let want = desired_interest(c);
        if want != c.interest {
            c.interest = want;
            let _ = self
                .poller
                .modify(c.stream.as_raw_fd(), want, TOKEN_BASE + idx as u64);
        }
    }
}

/// Epoll interest a connection's state implies.  Reading stops while a
/// request is in flight or a response is unflushed — backpressure rides
/// the TCP window, and pipelined bytes wait in the kernel buffer.
fn desired_interest(c: &Conn) -> u32 {
    let mut mask = EPOLLRDHUP;
    let flushed = c.out_pos >= c.out.len();
    if !c.read_closed && c.awaiting.is_none() && flushed && !c.close_after_flush {
        mask |= EPOLLIN;
    }
    if !flushed {
        mask |= EPOLLOUT;
    }
    mask
}

/// Route everything that answers without compute (the infer endpoints
/// are dispatched asynchronously by [`EventLoop::dispatch_infer`]).
fn route_simple(ctx: &ServerCtx, req: &HttpRequest) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let tiers: Vec<Json> = ctx
                .engine
                .per_tier()
                .iter()
                .map(|(plan, _)| {
                    Json::obj(vec![
                        ("tier", Json::Str(plan.tier.name().into())),
                        ("mode", Json::Str(plan.mode.name().into())),
                        ("source", Json::Str(plan.source().name().into())),
                        ("planned_uj", Json::Num(plan.budget_uj)),
                        ("rho", Json::f32_arr(&plan.plan.rhos())),
                    ])
                })
                .collect();
            let bi = trace::build_info();
            Response::json(
                200,
                &Json::obj(vec![
                    ("status", Json::Str("ok".into())),
                    ("version", Json::Str(bi.version.into())),
                    ("rustc", Json::Str(bi.rustc.into())),
                    ("git_sha", Json::Str(bi.git_sha.into())),
                    ("input_len", Json::Num(ctx.engine.input_len() as f64)),
                    ("num_classes", Json::Num(ctx.engine.num_classes() as f64)),
                    (
                        "max_batch",
                        Json::Num(ctx.engine.max_client_batch() as f64),
                    ),
                    (
                        "plan_source",
                        Json::Str(ctx.engine.plan_source().name().into()),
                    ),
                    (
                        "energy_budget_uj_s",
                        match ctx.engine.energy_budget_uj_s() {
                            Some(b) => Json::Num(b),
                            None => Json::Null,
                        },
                    ),
                    ("tiers", Json::Arr(tiers)),
                    (
                        "uptime_s",
                        Json::Num(ctx.started.elapsed().as_secs_f64()),
                    ),
                ]),
            )
        }
        ("GET", "/metrics") => {
            let body = prom::render(
                &ctx.http,
                &ctx.engine.per_tier(),
                &ctx.engine.snapshot(),
                ctx.cache.as_ref().map(|c| c.stats()),
                Some(ctx.engine.alloc_pool().stats()),
                ctx.started.elapsed().as_secs_f64(),
            );
            Response {
                status: 200,
                content_type: "text/plain; version=0.0.4; charset=utf-8",
                body: body.into_bytes(),
                headers: Vec::new(),
            }
        }
        ("GET", "/admin/trace") => {
            // the last N complete request traces as Chrome trace-event
            // JSON (Perfetto / chrome://tracing / about:tracing)
            let records = ctx.recorder.snapshot();
            let names: Vec<&str> = EnergyTier::ALL.iter().map(|t| t.name()).collect();
            Response::json(200, &trace::to_chrome_json(&records, &names))
        }
        ("POST", "/admin/shutdown") => {
            ctx.shutdown.store(true, Ordering::SeqCst);
            // drain order: freeze rebalancing, flush high tiers first;
            // the loop observes the flag at the end of this iteration
            // (the response still flushes during the bounded drain)
            ctx.engine.begin_drain();
            ctx.wake.wake();
            Response::json(200, &Json::obj(vec![("status", Json::Str("shutting down".into()))]))
        }
        (
            _,
            "/healthz" | "/metrics" | "/v1/infer" | "/v1/classify" | "/admin/shutdown"
            | "/admin/trace",
        ) => Response::error_json(405, &format!("method {} not allowed here", req.method)),
        (_, path) => Response::error_json(404, &format!("no route for {path}")),
    }
}

/// Parsed inference request body: one image, or a client-batched set.
enum InferPayload {
    Single(Vec<f32>),
    /// `count * input_len` row-major images from an `"images"` body.
    Batch { images: Vec<f32>, count: usize },
}

/// Map an engine admission error to its HTTP status: `EnergyShed` and
/// `Overloaded` are the server's problem (`503`, retryable — the former
/// carries the governor's window-decay `Retry-After`, the latter an
/// honest hint derived from the lane's live queue depth x amortised
/// infer time), `BatchTooLarge` the client's (`413`, never retryable
/// unchanged), anything else a `500`.
fn engine_error_response(e: &anyhow::Error, lane_stats: &ServerStats) -> Response {
    if let Some(shed) = e.downcast_ref::<EnergyShed>() {
        return Response::error_json(503, &format!("{e}")).with_retry_after(shed.retry_after_s);
    }
    if e.is::<Overloaded>() {
        return Response::error_json(503, &format!("{e}"))
            .with_retry_after(lane_stats.retry_after_s());
    }
    let status = if e.is::<BatchTooLarge>() { 413 } else { 500 };
    Response::error_json(status, &format!("{e}"))
}

/// Content fingerprint of the deployed model for the result-cache key
/// salts: a [`hash2`] fold over every layer's shape, quantization
/// scale, exact programmed tile weights (normalized cell values, bit
/// patterns — two models fingerprint equal iff their crossbars read
/// identically), and bias bits.  Computed once at boot; two servers
/// deploying the same store therefore derive interchangeable keys.
fn model_fingerprint(model: &NoisyModel) -> u64 {
    let mut h = hash2(0x6d6f_6465_6c5f_6670, model.layers().len() as u64); // "model_fp"
    for l in model.layers() {
        h = hash2(h, l.d_in as u64);
        h = hash2(h, l.d_out as u64);
        h = hash2(h, u64::from(l.array.w_scale().to_bits()));
        h = hash2(h, l.array.weight_bits() as u64);
        for t in l.array.tiles() {
            for &w in t.w_norm() {
                h = hash2(h, u64::from(w.to_bits()));
            }
        }
        for &b in &l.bias {
            h = hash2(h, u64::from(b.to_bits()));
        }
    }
    h
}

/// Hash of everything in a resolved [`TierPlan`] that shapes the logits
/// a lane computes: per-layer rho bit patterns and read modes.  A
/// rescaled budget, a different plan source shape, or a flipped read
/// mode all change the noise sigma (and decomposition) a request sees,
/// so they must key distinct cache namespaces.
fn tier_plan_hash(plan: &TierPlan) -> u64 {
    let mode_bit = |m: ReadMode| match m {
        ReadMode::Original => 0u64,
        ReadMode::Decomposed => 1,
    };
    let mut h = hash2(0x7469_6572_5f70_6c6e, mode_bit(plan.mode)); // "tier_pln"
    h = hash2(h, plan.budget_uj.to_bits());
    for l in plan.plan.layers() {
        h = hash2(h, u64::from(l.rho.to_bits()));
        h = hash2(h, mode_bit(l.mode));
    }
    h
}

/// Salt folding request pixels into a trace id ([`image_seed`] under a
/// fixed lane-independent seed).  The id is content-derived like the
/// noise seeds but from a *different* fold, and tracing only ever reads
/// it — the RNG streams never see it.
const TRACE_ID_SALT: u64 = 0x7472_6163_655f_6964; // "trace_id"

/// Render a completed (or failed) scheduler reply into the response
/// the submit side promised, plus the span record whose `write_us` /
/// `total_us` the flush path still owes (see [`PendingWrite`]).
/// Response bytes are identical to the old synchronous path: same
/// field order, same error taxonomy.
fn render_completion(
    ctx: &ServerCtx,
    inflight: &Inflight,
    result: Result<Reply>,
) -> (Response, Option<SpanRecord>) {
    let Reply { logits, span } = match result {
        Ok(r) => r,
        Err(e) => return (engine_error_response(&e, ctx.engine.stats(inflight.tier)), None),
    };
    let plan = ctx.engine.plan(inflight.tier);
    let mut fields = vec![
        ("tier", Json::Str(inflight.tier.name().into())),
        ("rho", Json::Num(plan.rho as f64)),
        ("rho_per_layer", Json::f32_arr(&plan.plan.rhos())),
        ("plan_source", Json::Str(plan.source().name().into())),
        ("mode", Json::Str(plan.mode.name().into())),
    ];
    let nc = ctx.engine.num_classes();
    if inflight.batch {
        fields.push(("count", Json::Num(span.images as f64)));
        fields.push((
            "logits",
            Json::Arr(logits.chunks(nc).map(Json::f32_arr).collect()),
        ));
        if inflight.classify {
            fields.push((
                "classes",
                Json::Arr(
                    logits
                        .chunks(nc)
                        .map(|row| Json::Num(crate::inference::argmax(row) as f64))
                        .collect(),
                ),
            ));
        }
    } else {
        fields.push(("logits", Json::f32_arr(&logits)));
        if inflight.classify {
            let class = crate::inference::argmax(&logits);
            fields.push(("class", Json::Num(class as f64)));
        }
    }
    if inflight.trace_echo {
        fields.push(("trace", span.to_inline_json(inflight.tier.name())));
    }
    // The logits were copied into the JSON fields above; the reply's
    // buffer re-enters the pool (a scheduler worker's next reply
    // fan-out reclaims it).
    let resp = Response::json(200, &Json::obj(fields));
    ctx.engine.alloc_pool().put_f32(logits);
    (resp, Some(span))
}

/// Validate one image row: expected width, all-finite pixels.
/// Non-finite pixels (e.g. 1e39 saturating to f32 infinity) would
/// propagate into the logits and render as invalid JSON downstream.
fn check_image(image: &[f32], input_len: usize, what: &str) -> Result<()> {
    anyhow::ensure!(
        image.len() == input_len,
        "{what} must be {input_len} floats, got {}",
        image.len()
    );
    anyhow::ensure!(
        image.iter().all(|v| v.is_finite()),
        "{what} values must be finite"
    );
    Ok(())
}

fn parse_infer_body(
    body: &[u8],
    input_len: usize,
) -> Result<(InferPayload, EnergyTier, bool, bool)> {
    let text =
        std::str::from_utf8(body).map_err(|_| anyhow::anyhow!("body is not UTF-8"))?;
    let v = Json::parse(text)?;
    let payload = match (v.opt("image"), v.opt("images")) {
        (Some(_), Some(_)) => {
            anyhow::bail!("body must carry either \"image\" or \"images\", not both")
        }
        (Some(img), None) => {
            let image = img.as_f32s()?;
            check_image(&image, input_len, "image")?;
            InferPayload::Single(image)
        }
        (None, Some(arr)) => {
            let rows = arr.as_arr()?;
            anyhow::ensure!(!rows.is_empty(), "\"images\" must contain at least one image");
            let mut images = Vec::with_capacity(rows.len() * input_len);
            for (i, row) in rows.iter().enumerate() {
                let r = row.as_f32s()?;
                check_image(&r, input_len, &format!("images[{i}]"))?;
                images.extend_from_slice(&r);
            }
            InferPayload::Batch {
                images,
                count: rows.len(),
            }
        }
        (None, None) => anyhow::bail!("missing key \"image\" (or batch key \"images\")"),
    };
    let tier = match v.opt("tier") {
        None => EnergyTier::Normal,
        Some(t) => t
            .as_str()?
            .parse()
            .map_err(|e: String| anyhow::anyhow!(e))?,
    };
    // `"blocking": true` opts this request into the backpressure path:
    // a full queue makes the handler wait for space instead of shedding
    // with 503 (default stays load-shedding — the ladder compares both).
    let blocking = match v.opt("blocking") {
        None => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => anyhow::bail!("\"blocking\" must be a boolean"),
    };
    // `"trace": true` echoes this request's span breakdown inline in the
    // response (the flight recorder records every request regardless).
    let trace_echo = match v.opt("trace") {
        None => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => anyhow::bail!("\"trace\" must be a boolean"),
    };
    Ok((payload, tier, blocking, trace_echo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tiny_model(dev: &DeviceConfig) -> Arc<NoisyModel> {
        let mut rng = Rng::new(21);
        let (d_in, d_out) = (6usize, 3usize);
        let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() * 0.4).collect();
        let b = vec![0.0f32; d_out];
        Arc::new(NoisyModel::new(&[(w.as_slice(), b.as_slice(), d_in, d_out)], dev).unwrap())
    }

    #[test]
    fn tier_parsing() {
        assert_eq!("low".parse::<EnergyTier>().unwrap(), EnergyTier::Low);
        assert_eq!("normal".parse::<EnergyTier>().unwrap(), EnergyTier::Normal);
        assert_eq!("high".parse::<EnergyTier>().unwrap(), EnergyTier::High);
        assert!("turbo".parse::<EnergyTier>().is_err());
        for t in EnergyTier::ALL {
            assert_eq!(t.name().parse::<EnergyTier>().unwrap(), t);
        }
        assert_eq!(parse_tier_arg("mixed").unwrap(), None);
        assert_eq!(parse_tier_arg("high").unwrap(), Some(EnergyTier::High));
        assert!(parse_tier_arg("nope").is_err());
    }

    #[test]
    fn tier_plans_track_budgets() {
        let dev = DeviceConfig::default();
        let model = tiny_model(&dev);
        let plans = tier_plans(&model, &dev, None).unwrap();
        assert_eq!(plans.len(), 3);
        // normal tier at the reference budget must recover the device rho
        let normal = &plans[EnergyTier::Normal.index()];
        assert_eq!(normal.mode, ReadMode::Original);
        assert!(
            (normal.rho - dev.rho).abs() < 1e-3,
            "normal rho {} vs device {}",
            normal.rho,
            dev.rho
        );
        // budgets are ordered low < normal < high
        let low = &plans[EnergyTier::Low.index()];
        let high = &plans[EnergyTier::High.index()];
        assert!(low.budget_uj < normal.budget_uj && normal.budget_uj < high.budget_uj);
        // high tier buys a larger rho (lower fluctuation) than normal
        assert!(high.rho > normal.rho);
        assert_eq!(low.mode, ReadMode::Decomposed);
        // all rhos clamped to the sane device range
        for p in &plans {
            assert!((0.25..=64.0).contains(&p.rho), "rho {}", p.rho);
            assert_eq!(p.source(), PlanSource::Analytic);
            assert_eq!(p.plan.len(), 1);
        }
    }

    #[test]
    fn tier_plans_trained_preserve_layer_ratios() {
        // a two-layer model + a trained rho vector: every tier's plan
        // must keep the trained 1:3 allocation (rescaled to its budget)
        // and advertise the trained source
        let dev = DeviceConfig::default();
        let mut rng = Rng::new(31);
        let dims = [(8usize, 6usize), (6, 3)];
        let data: Vec<(Vec<f32>, Vec<f32>)> = dims
            .iter()
            .map(|&(i, o)| {
                let w: Vec<f32> = (0..i * o).map(|_| rng.normal() * 0.4).collect();
                (w, vec![0.0f32; o])
            })
            .collect();
        let specs: Vec<(&[f32], &[f32], usize, usize)> = data
            .iter()
            .zip(dims.iter())
            .map(|((w, b), &(i, o))| (w.as_slice(), b.as_slice(), i, o))
            .collect();
        let model = NoisyModel::new(&specs, &dev).unwrap();
        let trained = [2.0f32, 6.0];
        let plans = tier_plans(&model, &dev, Some(&trained)).unwrap();
        for p in &plans {
            assert_eq!(p.source(), PlanSource::Trained);
            let r = p.plan.rhos();
            assert_eq!(r.len(), 2);
            assert!(
                (r[1] / r[0] - 3.0).abs() < 1e-3,
                "tier {}: trained ratio lost, got {r:?}",
                p.tier.name()
            );
        }
        // budgets still ordered
        assert!(plans[0].budget_uj < plans[1].budget_uj);
        assert!(plans[1].budget_uj < plans[2].budget_uj);
        // validation: wrong layer count and non-finite vectors are typed errors
        assert!(tier_plans(&model, &dev, Some(&[1.0])).is_err());
        assert!(tier_plans(&model, &dev, Some(&[1.0, f32::NAN])).is_err());
        assert!(tier_plans(&model, &dev, Some(&[1.0, -2.0])).is_err());
    }

    #[test]
    fn model_desc_mirrors_layers() {
        let dev = DeviceConfig::default();
        let model = tiny_model(&dev);
        let desc = model_desc(&model);
        assert_eq!(desc.layers.len(), 1);
        assert_eq!(desc.layers[0].cells, 18);
        assert_eq!(desc.layers[0].fan_in, 6);
        assert_eq!(desc.layers[0].out_features, 3);
    }

    #[test]
    fn tiered_engine_serves_all_tiers() {
        let dev = DeviceConfig::default();
        let model = tiny_model(&dev);
        let base = NativeServerConfig {
            batch: 4,
            workers: 1,
            max_wait: Duration::from_millis(1),
            // manual stepping only: keeps the single worker's home pinned
            // so the steal accounting below is deterministic
            rebalance_interval: Duration::ZERO,
            device: dev,
            ..Default::default()
        };
        let (engine, handles) = TieredEngine::start(model, &base, None).unwrap();
        assert_eq!(engine.input_len(), 6);
        assert_eq!(engine.num_classes(), 3);
        assert_eq!(engine.energy_budget_uj_s(), None);
        for tier in EnergyTier::ALL {
            let mut r = Rng::stream(55, tier.index() as u64);
            let img: Vec<f32> = (0..6).map(|_| r.next_f32()).collect();
            let logits = engine.try_infer(tier, img).unwrap();
            assert_eq!(logits.len(), 3);
            assert!(logits.iter().all(|v| v.is_finite()));
            assert_eq!(engine.stats(tier).requests.load(Ordering::Relaxed), 1);
        }
        // the decomposed low lane burns more cycles per request
        let low_cycles = engine.stats(EnergyTier::Low).energy().cycles;
        let normal_cycles = engine.stats(EnergyTier::Normal).energy().cycles;
        assert!(low_cycles > normal_cycles);
        // scheduler observability: one snapshot lane per tier, the whole
        // (single-worker) pool accounted for, queues drained, no governor
        let snap = engine.snapshot();
        assert_eq!(snap.lanes.len(), 3);
        assert_eq!(
            snap.lanes.iter().map(|l| l.effective_workers).sum::<usize>(),
            1
        );
        assert!(snap.lanes.iter().all(|l| l.queue_len == 0));
        assert!(snap.lanes.iter().all(|l| l.governor_shed == 0));
        assert!(snap.energy.is_none());
        assert!(!snap.draining);
        // one worker homed on one lane served all three tiers: the other
        // two lanes' batches were (counted) steals
        let steals: u64 = snap.lanes.iter().map(|l| l.steals).sum();
        assert!(steals >= 2, "expected cross-lane steals, got {snap:?}");
        // drain mode flips the snapshot flag and freezes rebalancing
        engine.begin_drain();
        assert!(engine.snapshot().draining);
        assert_eq!(engine.rebalance_once(), 0, "rebalance frozen during drain");
        drop(engine);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn http_stats_record_and_total() {
        let s = HttpStats::default();
        for code in [200, 200, 400, 404, 405, 413, 503, 500, 502] {
            s.record(code);
        }
        let by = s.by_code();
        assert_eq!(by.iter().find(|&&(c, _)| c == 200).unwrap().1, 2);
        assert_eq!(by.iter().find(|&&(c, _)| c == 503).unwrap().1, 1);
        // unknown codes land in the 500 bucket
        assert_eq!(by.iter().find(|&&(c, _)| c == 500).unwrap().1, 2);
        assert_eq!(s.total(), 9);
    }

    #[test]
    fn parse_infer_body_validates() {
        assert!(parse_infer_body(b"{\"image\":[1,2,3]}", 3).is_ok());
        let (payload, tier, blocking, trace_echo) =
            parse_infer_body(b"{\"image\":[1,2,3],\"tier\":\"high\"}", 3).unwrap();
        match payload {
            InferPayload::Single(img) => assert_eq!(img, vec![1.0, 2.0, 3.0]),
            InferPayload::Batch { .. } => panic!("expected a single-image payload"),
        }
        assert_eq!(tier, EnergyTier::High);
        assert!(!blocking, "blocking must default off (load-shedding)");
        assert!(!trace_echo, "trace echo must default off");
        // defaults to normal
        let (_, tier, _, _) = parse_infer_body(b"{\"image\":[0,0,0]}", 3).unwrap();
        assert_eq!(tier, EnergyTier::Normal);
        // explicit blocking flag, both values
        let (_, _, b, _) =
            parse_infer_body(b"{\"image\":[0,0,0],\"blocking\":true}", 3).unwrap();
        assert!(b);
        let (_, _, b, _) =
            parse_infer_body(b"{\"image\":[0,0,0],\"blocking\":false}", 3).unwrap();
        assert!(!b);
        // explicit trace flag, both values; non-boolean is a 400
        let (_, _, _, t) = parse_infer_body(b"{\"image\":[0,0,0],\"trace\":true}", 3).unwrap();
        assert!(t);
        let (_, _, _, t) = parse_infer_body(b"{\"image\":[0,0,0],\"trace\":false}", 3).unwrap();
        assert!(!t);
        assert!(parse_infer_body(b"{\"image\":[0,0,0],\"trace\":\"yes\"}", 3).is_err());
        // non-boolean blocking is a 400
        assert!(parse_infer_body(b"{\"image\":[0,0,0],\"blocking\":1}", 3).is_err());
        // shape mismatch, bad tier, bad json, missing key, non-finite pixel
        assert!(parse_infer_body(b"{\"image\":[1,2]}", 3).is_err());
        assert!(parse_infer_body(b"{\"image\":[1,2,3],\"tier\":\"x\"}", 3).is_err());
        assert!(parse_infer_body(b"not json", 3).is_err());
        assert!(parse_infer_body(b"{}", 3).is_err());
        assert!(parse_infer_body(b"{\"image\":[1e39,0,0]}", 3).is_err());
    }

    #[test]
    fn parse_infer_body_batch_form() {
        // well-formed batch: 2 images of width 3, flattened row-major
        let (payload, tier, _, _) =
            parse_infer_body(b"{\"images\":[[1,2,3],[4,5,6]],\"tier\":\"low\"}", 3).unwrap();
        match payload {
            InferPayload::Batch { images, count } => {
                assert_eq!(count, 2);
                assert_eq!(images, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
            }
            InferPayload::Single(_) => panic!("expected a batch payload"),
        }
        assert_eq!(tier, EnergyTier::Low);
        // ragged rows, empty batch, both keys, non-finite row, non-array row
        assert!(parse_infer_body(b"{\"images\":[[1,2,3],[4,5]]}", 3).is_err());
        assert!(parse_infer_body(b"{\"images\":[]}", 3).is_err());
        assert!(parse_infer_body(b"{\"image\":[1,2,3],\"images\":[[1,2,3]]}", 3).is_err());
        assert!(parse_infer_body(b"{\"images\":[[1e39,0,0]]}", 3).is_err());
        assert!(parse_infer_body(b"{\"images\":[1,2,3]}", 3).is_err());
    }
}
