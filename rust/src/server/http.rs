//! Minimal HTTP/1.1 framing over blocking byte streams (std::net only —
//! this build is offline, no hyper/tokio; see the serde note in
//! `util::json`).
//!
//! Covers exactly what the serving front end and the load generator
//! need: request/response lines, headers, `Content-Length` bodies, and
//! keep-alive.  No chunked transfer encoding, no HTTP/2 — clients that
//! send anything else get a clean `400`.
//!
//! [`HttpConn`] owns the stream plus a carry-over buffer, so pipelined
//! or coalesced bytes from a keep-alive peer are never lost between
//! requests.  It is generic over `Read + Write` so the unit tests can
//! drive it with in-memory streams.
//!
//! [`RequestParser`] / [`ResponseParser`] are the sans-io counterparts:
//! the epoll event loop (and its load-generator client) feed them
//! whatever bytes the socket had and ask for complete messages, so a
//! peer that trickles one byte per second never blocks anything — it
//! just stays "partial" until the idle sweep reaps it.

use std::io::{ErrorKind, Read, Write};
use std::sync::Arc;

use crate::pool::BufferPool;
use crate::Result;

/// Maximum accepted request/response head (request line + headers).
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Socket-timeout retries tolerated *inside* one request/response before
/// giving up.  The socket read timeout is tuned short so idle keep-alive
/// connections notice shutdowns quickly (see `HttpServerConfig`); a slow
/// peer mid-message gets this many grace periods (e.g. 20 x 250ms = 5s)
/// instead of an instant `400`.
const MID_MESSAGE_TIMEOUT_RETRIES: u32 = 20;

/// Typed marker error: declared `Content-Length` exceeds the configured
/// body cap.  The server maps it to `413 Payload Too Large`; the limit is
/// carried so the error response tells clients (e.g. batch senders) how
/// much the deployment actually accepts (`--max-body-mb` on `serve-http`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PayloadTooLarge {
    /// The configured body cap in bytes.
    pub limit: usize,
}

impl std::fmt::Display for PayloadTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "request body exceeds the configured limit of {} bytes",
            self.limit
        )
    }
}

impl std::error::Error for PayloadTooLarge {}

/// One parsed HTTP request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Header names lower-cased, values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the connection should stay open after the response.
    pub keep_alive: bool,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Outcome of waiting for one request on a keep-alive connection.
#[derive(Debug)]
pub enum RequestOutcome {
    Request(HttpRequest),
    /// Peer closed cleanly between requests.
    Closed,
    /// Socket read timeout fired while idle (no partial request buffered);
    /// the caller re-checks its shutdown flag and retries.
    TimedOut,
}

/// An HTTP response to be written.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra response headers (e.g. `retry-after` on `503`/`429`).
    pub headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn json(status: u16, v: &crate::util::json::Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: v.render().into_bytes(),
            headers: Vec::new(),
        }
    }

    /// `{"error": msg}` with the given status.
    pub fn error_json(status: u16, msg: &str) -> Response {
        Response::json(
            status,
            &crate::util::json::Json::obj(vec![(
                "error",
                crate::util::json::Json::Str(msg.to_string()),
            )]),
        )
    }

    /// Attach an extra response header.
    pub fn with_header(mut self, name: &'static str, value: String) -> Response {
        self.headers.push((name, value));
        self
    }

    /// Attach `retry-after: <seconds>` — the honest back-off hint shed
    /// (`503`) and peer-capped (`429`) clients should honour.
    pub fn with_retry_after(self, seconds: u64) -> Response {
        self.with_header("retry-after", seconds.to_string())
    }
}

/// Reason phrase for the status codes this stack emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

enum Fill {
    Data,
    Eof,
    Timeout,
}

enum HeadOutcome {
    Head(Vec<u8>),
    Closed,
    TimedOut,
}

/// A buffered HTTP/1.1 connection (server or client side).
pub struct HttpConn<S: Read + Write> {
    stream: S,
    buf: Vec<u8>,
}

impl<S: Read + Write> HttpConn<S> {
    pub fn new(stream: S) -> Self {
        HttpConn {
            stream,
            buf: Vec::new(),
        }
    }

    pub fn into_inner(self) -> S {
        self.stream
    }

    fn fill(&mut self) -> std::io::Result<Fill> {
        let mut tmp = [0u8; 4096];
        match self.stream.read(&mut tmp) {
            Ok(0) => Ok(Fill::Eof),
            Ok(n) => {
                self.buf.extend_from_slice(&tmp[..n]);
                Ok(Fill::Data)
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                Ok(Fill::Timeout)
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::ConnectionReset
                        | ErrorKind::ConnectionAborted
                        | ErrorKind::BrokenPipe
                        | ErrorKind::UnexpectedEof
                ) =>
            {
                Ok(Fill::Eof)
            }
            Err(e) => Err(e),
        }
    }

    /// Drain one head (through the blank line) out of the buffer, if
    /// complete.
    fn take_head(&mut self) -> Option<Vec<u8>> {
        let pos = self.buf.windows(4).position(|w| w == b"\r\n\r\n")?;
        let head: Vec<u8> = self.buf[..pos].to_vec();
        self.buf.drain(..pos + 4);
        Some(head)
    }

    /// Read until a full head is buffered (or the peer goes away).
    fn read_head(&mut self) -> Result<HeadOutcome> {
        let mut timeouts = 0u32;
        loop {
            if let Some(h) = self.take_head() {
                return Ok(HeadOutcome::Head(h));
            }
            anyhow::ensure!(self.buf.len() <= MAX_HEAD_BYTES, "head too large");
            match self.fill()? {
                Fill::Data => {}
                Fill::Eof => {
                    if self.buf.is_empty() {
                        return Ok(HeadOutcome::Closed);
                    }
                    anyhow::bail!("connection closed mid-head");
                }
                Fill::Timeout => {
                    if self.buf.is_empty() {
                        return Ok(HeadOutcome::TimedOut);
                    }
                    timeouts += 1;
                    anyhow::ensure!(
                        timeouts < MID_MESSAGE_TIMEOUT_RETRIES,
                        "timed out mid-head"
                    );
                }
            }
        }
    }

    /// Read exactly `len` body bytes (the head is already consumed).
    fn read_body(&mut self, len: usize) -> Result<Vec<u8>> {
        let mut timeouts = 0u32;
        while self.buf.len() < len {
            match self.fill()? {
                Fill::Data => {}
                Fill::Eof => anyhow::bail!("connection closed mid-body"),
                Fill::Timeout => {
                    timeouts += 1;
                    anyhow::ensure!(
                        timeouts < MID_MESSAGE_TIMEOUT_RETRIES,
                        "timed out reading body"
                    );
                }
            }
        }
        Ok(self.buf.drain(..len).collect())
    }

    /// Wait for one request (server side).
    pub fn read_request(&mut self, max_body: usize) -> Result<RequestOutcome> {
        let head = match self.read_head()? {
            HeadOutcome::Head(h) => h,
            HeadOutcome::Closed => return Ok(RequestOutcome::Closed),
            HeadOutcome::TimedOut => return Ok(RequestOutcome::TimedOut),
        };
        let parsed = parse_request_head(&head)?;
        if parsed.content_length > max_body {
            return Err(anyhow::Error::new(PayloadTooLarge { limit: max_body }));
        }
        let body = self.read_body(parsed.content_length)?;
        Ok(RequestOutcome::Request(parsed.into_request(body)))
    }

    /// Write a response (server side).
    pub fn write_response(&mut self, resp: &Response, keep_alive: bool) -> std::io::Result<()> {
        let bytes = render_response(resp, keep_alive);
        self.stream.write_all(&bytes)?;
        self.stream.flush()
    }

    /// Write a request (client side / load generator).
    pub fn write_request(&mut self, method: &str, path: &str, body: &[u8]) -> std::io::Result<()> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: emtopt\r\ncontent-type: application/json\r\n\
             content-length: {}\r\nconnection: keep-alive\r\n\r\n",
            body.len(),
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body)?;
        self.stream.flush()
    }

    /// Read one response (client side); returns `(status, body)`.
    pub fn read_response(&mut self, max_body: usize) -> Result<(u16, Vec<u8>)> {
        let (status, _headers, body) = self.read_response_parts(max_body)?;
        Ok((status, body))
    }

    /// Read one response including its headers (client side); returns
    /// `(status, headers, body)` — header names lower-cased.  Used by
    /// clients that honour `retry-after` back-off hints.
    pub fn read_response_parts(
        &mut self,
        max_body: usize,
    ) -> Result<(u16, Vec<(String, String)>, Vec<u8>)> {
        loop {
            let head = match self.read_head()? {
                HeadOutcome::Head(h) => h,
                HeadOutcome::Closed => anyhow::bail!("server closed the connection"),
                HeadOutcome::TimedOut => anyhow::bail!("timed out waiting for response"),
            };
            let (status, headers, content_length) = parse_response_head(&head)?;
            if (100..200).contains(&status) {
                continue; // 1xx interim (e.g. 100 Continue): bodiless, not final
            }
            anyhow::ensure!(content_length <= max_body, "response body too large");
            let body = self.read_body(content_length)?;
            return Ok((status, headers, body));
        }
    }
}

/// Serialize a response (status line + headers + body) into one byte
/// buffer.  The event loop appends this to a connection's write buffer
/// and flushes it as `EPOLLOUT` allows; `HttpConn::write_response` uses
/// it too, so both paths emit byte-identical responses.
pub fn render_response(resp: &Response, keep_alive: bool) -> Vec<u8> {
    let mut out = Vec::with_capacity(128 + resp.body.len());
    render_response_into(resp, keep_alive, &mut out);
    out
}

/// Serialize a response by appending to an existing buffer — the
/// zero-alloc flavour of [`render_response`] the event loop uses to
/// render straight into a connection's (pooled, reused) write buffer.
/// Appends byte-for-byte what [`render_response`] returns.
pub fn render_response_into(resp: &Response, keep_alive: bool, out: &mut Vec<u8>) {
    let _ = write!(
        out,
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (name, value) in &resp.headers {
        let _ = write!(out, "{name}: {value}\r\n");
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(&resp.body);
}

/// A fully parsed request head (everything above the blank line).
struct RequestHead {
    method: String,
    path: String,
    headers: Vec<(String, String)>,
    keep_alive: bool,
    content_length: usize,
    /// The client declared `Expect: 100-continue` and is waiting for an
    /// interim response before shipping its body (RFC 9110 §10.1.1).
    expect_continue: bool,
}

impl RequestHead {
    fn into_request(self, body: Vec<u8>) -> HttpRequest {
        HttpRequest {
            method: self.method,
            path: self.path,
            headers: self.headers,
            body,
            keep_alive: self.keep_alive,
        }
    }
}

fn parse_request_head(head: &[u8]) -> Result<RequestHead> {
    let text =
        std::str::from_utf8(head).map_err(|_| anyhow::anyhow!("request head is not UTF-8"))?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request line has no path"))?
        .to_string();
    let version = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request line has no version"))?;
    anyhow::ensure!(
        version == "HTTP/1.1" || version == "HTTP/1.0",
        "unsupported protocol {version:?}"
    );
    let headers = parse_headers(lines)?;
    let content_length = content_length(&headers)?;
    let keep_alive = match headers
        .iter()
        .find(|(k, _)| k == "connection")
        .map(|(_, v)| v.as_str())
    {
        Some(v) if v.eq_ignore_ascii_case("close") => false,
        Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
        _ => version == "HTTP/1.1",
    };
    let expect_continue = headers
        .iter()
        .find(|(k, _)| k == "expect")
        .map_or(false, |(_, v)| v.eq_ignore_ascii_case("100-continue"));
    Ok(RequestHead {
        method,
        path,
        headers,
        keep_alive,
        content_length,
        expect_continue,
    })
}

fn parse_response_head(head: &[u8]) -> Result<(u16, Vec<(String, String)>, usize)> {
    let text =
        std::str::from_utf8(head).map_err(|_| anyhow::anyhow!("response head is not UTF-8"))?;
    let mut lines = text.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let mut parts = status_line.split_whitespace();
    let version = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty status line"))?;
    anyhow::ensure!(
        version.starts_with("HTTP/1."),
        "bad status line {status_line:?}"
    );
    let status: u16 = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("status line has no code"))?
        .parse()
        .map_err(|_| anyhow::anyhow!("bad status code in {status_line:?}"))?;
    let headers = parse_headers(lines)?;
    let content_length = content_length(&headers)?;
    Ok((status, headers, content_length))
}

/// Shared sans-io framing buffer: accumulate fed bytes, split one head
/// off at `\r\n\r\n`, then drain the declared body length.
struct FrameBuf {
    buf: Vec<u8>,
    /// `\r\n\r\n` search resume point, so a byte-at-a-time slowloris
    /// feed stays O(bytes) instead of rescanning the whole head.
    scanned: usize,
}

impl FrameBuf {
    fn new() -> Self {
        FrameBuf {
            buf: Vec::new(),
            scanned: 0,
        }
    }

    fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Drain one complete head (through the blank line) if buffered.
    /// `Err` once the partial head exceeds [`MAX_HEAD_BYTES`].
    fn take_head(&mut self) -> Result<Option<Vec<u8>>> {
        let start = self.scanned.saturating_sub(3);
        match self.buf[start..].windows(4).position(|w| w == b"\r\n\r\n") {
            Some(rel) => {
                let pos = start + rel;
                let head = self.buf[..pos].to_vec();
                self.buf.drain(..pos + 4);
                self.scanned = 0;
                Ok(Some(head))
            }
            None => {
                self.scanned = self.buf.len();
                anyhow::ensure!(self.buf.len() <= MAX_HEAD_BYTES, "head too large");
                Ok(None)
            }
        }
    }

    /// Drain exactly `len` body bytes if buffered.
    fn take_body(&mut self, len: usize) -> Option<Vec<u8>> {
        self.take_body_pooled(len, None)
    }

    /// [`FrameBuf::take_body`], but the body vector's capacity comes
    /// from `pool` when one is armed (byte content is identical either
    /// way — a recycled buffer starts empty).
    fn take_body_pooled(&mut self, len: usize, pool: Option<&BufferPool>) -> Option<Vec<u8>> {
        if self.buf.len() < len {
            return None;
        }
        let body = match pool {
            Some(p) => {
                let mut b = p.get_bytes(len);
                b.extend(self.buf.drain(..len));
                b
            }
            None => self.buf.drain(..len).collect(),
        };
        self.scanned = 0;
        Some(body)
    }
}

/// Incremental (sans-io) HTTP/1.1 request parser for the event loop.
///
/// Feed it whatever bytes the nonblocking socket had; `try_next`
/// returns complete requests as they frame up.  Malformed heads and
/// over-cap bodies surface as errors the loop maps to `400`/`413`.
pub struct RequestParser {
    frame: FrameBuf,
    /// Head parsed, waiting for `content_length` body bytes.
    pending: Option<RequestHead>,
    /// The pending head's `Expect: 100-continue` was already claimed by
    /// [`RequestParser::take_expect_continue`] (one interim response per
    /// request).
    continue_claimed: bool,
    /// Request bodies draw their capacity from this pool when armed
    /// (the event loop shares the engine's pool); `None` keeps plain
    /// per-request allocations.
    pool: Option<Arc<BufferPool>>,
}

impl Default for RequestParser {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestParser {
    pub fn new() -> Self {
        Self::with_pool(None)
    }

    /// A parser whose request bodies draw pooled capacity (see
    /// [`BufferPool`]); the server recycles each body after dispatch.
    pub fn with_pool(pool: Option<Arc<BufferPool>>) -> Self {
        RequestParser {
            frame: FrameBuf::new(),
            pending: None,
            continue_claimed: false,
            pool,
        }
    }

    /// Buffer freshly read socket bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.frame.feed(bytes);
    }

    /// True when a request is partially buffered (bytes or a parsed
    /// head waiting for its body) — the slowloris sweep signal.
    pub fn has_partial(&self) -> bool {
        self.pending.is_some() || !self.frame.is_empty()
    }

    /// Next complete request, if the buffer holds one.
    ///
    /// `Ok(None)` means "need more bytes"; errors are fatal for the
    /// connection (garbage head, head too large, or a declared
    /// `Content-Length` above `max_body` → [`PayloadTooLarge`]).
    pub fn try_next(&mut self, max_body: usize) -> Result<Option<HttpRequest>> {
        if self.pending.is_none() {
            let head = match self.frame.take_head()? {
                Some(h) => h,
                None => return Ok(None),
            };
            let parsed = parse_request_head(&head)?;
            if parsed.content_length > max_body {
                // Declared length over the cap: typed 413 at head time —
                // an `Expect: 100-continue` client learns its body is
                // rejected before shipping a single body byte.
                return Err(anyhow::Error::new(PayloadTooLarge { limit: max_body }));
            }
            self.pending = Some(parsed);
            self.continue_claimed = false;
        }
        let need = self.pending.as_ref().map(|h| h.content_length).unwrap_or(0);
        match self.frame.take_body_pooled(need, self.pool.as_deref()) {
            Some(body) => {
                let head = self.pending.take().expect("pending head");
                Ok(Some(head.into_request(body)))
            }
            None => Ok(None),
        }
    }

    /// True at most once per request: the pending (head-parsed, body
    /// acceptable but not yet buffered) request declared
    /// `Expect: 100-continue` and still owes the client its interim
    /// `100 Continue` line.  The event loop writes it on `true`; a head
    /// over the body cap never reaches this point — it surfaced as a
    /// typed [`PayloadTooLarge`] from [`RequestParser::try_next`]
    /// instead, so the rejection beats the body onto the wire.
    pub fn take_expect_continue(&mut self) -> bool {
        match &self.pending {
            Some(h) if h.expect_continue && !self.continue_claimed => {
                self.continue_claimed = true;
                true
            }
            _ => false,
        }
    }
}

/// Incremental (sans-io) HTTP/1.1 response parser for the epoll load
/// generator client.  Mirrors [`RequestParser`].
pub struct ResponseParser {
    frame: FrameBuf,
    pending: Option<(u16, Vec<(String, String)>, usize)>,
}

impl Default for ResponseParser {
    fn default() -> Self {
        Self::new()
    }
}

impl ResponseParser {
    pub fn new() -> Self {
        ResponseParser {
            frame: FrameBuf::new(),
            pending: None,
        }
    }

    pub fn feed(&mut self, bytes: &[u8]) {
        self.frame.feed(bytes);
    }

    /// Next complete response as `(status, headers, body)`.
    pub fn try_next(
        &mut self,
        max_body: usize,
    ) -> Result<Option<(u16, Vec<(String, String)>, Vec<u8>)>> {
        if self.pending.is_none() {
            let head = match self.frame.take_head()? {
                Some(h) => h,
                None => return Ok(None),
            };
            let parsed = parse_response_head(&head)?;
            anyhow::ensure!(parsed.2 <= max_body, "response body too large");
            self.pending = Some(parsed);
        }
        let need = self.pending.as_ref().map(|p| p.2).unwrap_or(0);
        match self.frame.take_body(need) {
            Some(body) => {
                let (status, headers, _) = self.pending.take().expect("pending head");
                Ok(Some((status, headers, body)))
            }
            None => Ok(None),
        }
    }
}

fn parse_headers<'a, I: Iterator<Item = &'a str>>(lines: I) -> Result<Vec<(String, String)>> {
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("malformed header line {line:?}"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    Ok(headers)
}

fn content_length(headers: &[(String, String)]) -> Result<usize> {
    match headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.as_str())
    {
        None => Ok(0),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad content-length {v:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn conn(bytes: &[u8]) -> HttpConn<Cursor<Vec<u8>>> {
        HttpConn::new(Cursor::new(bytes.to_vec()))
    }

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello";
        let mut c = conn(raw);
        match c.read_request(1024).unwrap() {
            RequestOutcome::Request(r) => {
                assert_eq!(r.method, "POST");
                assert_eq!(r.path, "/v1/infer");
                assert_eq!(r.body, b"hello");
                assert!(r.keep_alive); // HTTP/1.1 default
                assert_eq!(r.header("host"), Some("x"));
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }

    #[test]
    fn keep_alive_rules() {
        let close = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut c = conn(close);
        match c.read_request(1024).unwrap() {
            RequestOutcome::Request(r) => assert!(!r.keep_alive),
            other => panic!("unexpected outcome {other:?}"),
        }
        let old = b"GET / HTTP/1.0\r\n\r\n";
        let mut c = conn(old);
        match c.read_request(1024).unwrap() {
            RequestOutcome::Request(r) => assert!(!r.keep_alive),
            other => panic!("unexpected outcome {other:?}"),
        }
        let old_ka = b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n";
        let mut c = conn(old_ka);
        match c.read_request(1024).unwrap() {
            RequestOutcome::Request(r) => assert!(r.keep_alive),
            other => panic!("unexpected outcome {other:?}"),
        }
    }

    #[test]
    fn two_pipelined_requests_survive_buffering() {
        let raw =
            b"GET /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nxyGET /b HTTP/1.1\r\n\r\n".to_vec();
        let mut c = HttpConn::new(Cursor::new(raw));
        match c.read_request(1024).unwrap() {
            RequestOutcome::Request(r) => {
                assert_eq!(r.path, "/a");
                assert_eq!(r.body, b"xy");
            }
            other => panic!("unexpected outcome {other:?}"),
        }
        match c.read_request(1024).unwrap() {
            RequestOutcome::Request(r) => {
                assert_eq!(r.path, "/b");
                assert!(r.body.is_empty());
            }
            other => panic!("unexpected outcome {other:?}"),
        }
        assert!(matches!(c.read_request(1024).unwrap(), RequestOutcome::Closed));
    }

    #[test]
    fn rejects_garbage_and_caps_body() {
        let mut c = conn(b"NOT-HTTP\r\n\r\n");
        assert!(c.read_request(1024).is_err());

        let mut c = conn(b"POST / HTTP/1.1\r\nContent-Length: beef\r\n\r\n");
        assert!(c.read_request(1024).is_err());

        let mut c = conn(b"POST / HTTP/1.1\r\nContent-Length: 99\r\n\r\n");
        let err = c.read_request(10).unwrap_err();
        assert!(err.is::<PayloadTooLarge>());

        let mut c = conn(b"POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\nshort");
        assert!(c.read_request(1024).is_err()); // body truncated by EOF
    }

    #[test]
    fn response_roundtrip() {
        // write a response into a buffer, then parse it back client-side
        let resp = Response::json(
            200,
            &crate::util::json::Json::obj(vec![(
                "ok",
                crate::util::json::Json::Bool(true),
            )]),
        );
        let mut server = HttpConn::new(Cursor::new(Vec::new()));
        server.write_response(&resp, true).unwrap();
        let written = server.stream.into_inner();

        let mut client = HttpConn::new(Cursor::new(written));
        let (status, body) = client.read_response(1024).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\":true}");
    }

    #[test]
    fn request_write_parses_back() {
        let mut client = HttpConn::new(Cursor::new(Vec::new()));
        client
            .write_request("POST", "/v1/classify", b"{\"image\":[1]}")
            .unwrap();
        let written = client.stream.into_inner();

        let mut server = HttpConn::new(Cursor::new(written));
        match server.read_request(1024).unwrap() {
            RequestOutcome::Request(r) => {
                assert_eq!(r.method, "POST");
                assert_eq!(r.path, "/v1/classify");
                assert_eq!(r.body, b"{\"image\":[1]}");
                assert!(r.keep_alive);
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }

    #[test]
    fn extra_headers_roundtrip() {
        let resp = Response::error_json(503, "overloaded").with_retry_after(7);
        let mut server = HttpConn::new(Cursor::new(Vec::new()));
        server.write_response(&resp, false).unwrap();
        let written = server.stream.into_inner();
        let mut client = HttpConn::new(Cursor::new(written));
        let (status, headers, body) = client.read_response_parts(1024).unwrap();
        assert_eq!(status, 503);
        assert!(!body.is_empty());
        let ra = headers
            .iter()
            .find(|(k, _)| k == "retry-after")
            .map(|(_, v)| v.as_str());
        assert_eq!(ra, Some("7"));
        assert_eq!(status_text(429), "Too Many Requests");
    }

    #[test]
    fn error_json_shape() {
        let r = Response::error_json(503, "overloaded");
        assert_eq!(r.status, 503);
        let v = crate::util::json::Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "overloaded");
    }

    #[test]
    fn request_parser_assembles_byte_by_byte() {
        let raw = b"POST /v1/infer HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        let mut p = RequestParser::new();
        assert!(!p.has_partial());
        for (i, b) in raw.iter().enumerate() {
            p.feed(std::slice::from_ref(b));
            let got = p.try_next(1024).unwrap();
            if i + 1 < raw.len() {
                assert!(got.is_none(), "complete too early at byte {i}");
                assert!(p.has_partial());
            } else {
                let r = got.expect("complete at last byte");
                assert_eq!(r.method, "POST");
                assert_eq!(r.path, "/v1/infer");
                assert_eq!(r.body, b"hello");
                assert!(r.keep_alive);
            }
        }
        assert!(!p.has_partial());
    }

    #[test]
    fn request_parser_handles_pipelined_and_errors() {
        let mut p = RequestParser::new();
        p.feed(b"GET /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nxyGET /b HTTP/1.1\r\n\r\n");
        let a = p.try_next(1024).unwrap().unwrap();
        assert_eq!(a.path, "/a");
        assert_eq!(a.body, b"xy");
        let b = p.try_next(1024).unwrap().unwrap();
        assert_eq!(b.path, "/b");
        assert!(p.try_next(1024).unwrap().is_none());

        // over-cap body is a typed PayloadTooLarge before any body bytes
        let mut p = RequestParser::new();
        p.feed(b"POST / HTTP/1.1\r\nContent-Length: 99\r\n\r\n");
        let err = p.try_next(10).unwrap_err();
        assert!(err.is::<PayloadTooLarge>());

        // garbage head is a plain error (mapped to 400 by the loop)
        let mut p = RequestParser::new();
        p.feed(b"NOT-HTTP\r\n\r\n");
        assert!(p.try_next(1024).is_err());

        // an endless head trips the cap without a blank line
        let mut p = RequestParser::new();
        p.feed(b"GET / HTTP/1.1\r\n");
        let junk = vec![b'a'; MAX_HEAD_BYTES + 16];
        p.feed(&junk);
        assert!(p.try_next(1024).is_err());
    }

    #[test]
    fn expect_continue_is_surfaced_once_per_request() {
        let mut p = RequestParser::new();
        p.feed(b"POST /v1/infer HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 5\r\n\r\n");
        assert!(p.try_next(1024).unwrap().is_none()); // head parsed, body pending
        assert!(p.take_expect_continue(), "pending Expect head fires once");
        assert!(!p.take_expect_continue(), "second claim must not fire");
        p.feed(b"hello");
        let r = p.try_next(1024).unwrap().expect("complete after body");
        assert_eq!(r.body, b"hello");
        // a follow-up request without the header never fires
        p.feed(b"POST / HTTP/1.1\r\nContent-Length: 2\r\n\r\n");
        assert!(p.try_next(1024).unwrap().is_none());
        assert!(!p.take_expect_continue());
        p.feed(b"ab");
        assert!(p.try_next(1024).unwrap().is_some());
        // a fresh Expect head on the same parser fires again
        // (case-insensitive value per RFC 9110)
        p.feed(b"POST / HTTP/1.1\r\nexpect: 100-CONTINUE\r\nContent-Length: 1\r\n\r\n");
        assert!(p.try_next(1024).unwrap().is_none());
        assert!(p.take_expect_continue());
    }

    #[test]
    fn expect_continue_over_cap_is_typed_413_with_no_interim() {
        // the declared length is over the cap: the parser surfaces the
        // typed 413 at head time and never offers the interim response,
        // so the rejection reaches the client before any body byte
        let mut p = RequestParser::new();
        p.feed(b"POST / HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 999\r\n\r\n");
        let err = p.try_next(10).unwrap_err();
        assert!(err.is::<PayloadTooLarge>());
        assert!(!p.take_expect_continue());
    }

    #[test]
    fn client_skips_interim_100_before_final_response() {
        let resp = Response::json(
            200,
            &crate::util::json::Json::obj(vec![("ok", crate::util::json::Json::Bool(true))]),
        );
        let mut bytes = b"HTTP/1.1 100 Continue\r\n\r\n".to_vec();
        bytes.extend_from_slice(&render_response(&resp, true));
        let mut c = HttpConn::new(Cursor::new(bytes));
        let (status, body) = c.read_response(1024).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, resp.body);
    }

    #[test]
    fn response_parser_roundtrips_rendered_bytes() {
        let resp = Response::error_json(503, "overloaded").with_retry_after(7);
        let bytes = render_response(&resp, true);
        let mut p = ResponseParser::new();
        // split the feed mid-head and mid-body
        p.feed(&bytes[..10]);
        assert!(p.try_next(1024).unwrap().is_none());
        p.feed(&bytes[10..bytes.len() - 3]);
        assert!(p.try_next(1024).unwrap().is_none());
        p.feed(&bytes[bytes.len() - 3..]);
        let (status, headers, body) = p.try_next(1024).unwrap().unwrap();
        assert_eq!(status, 503);
        assert_eq!(body, resp.body);
        let ra = headers
            .iter()
            .find(|(k, _)| k == "retry-after")
            .map(|(_, v)| v.as_str());
        assert_eq!(ra, Some("7"));
    }

    #[test]
    fn pooled_parser_bodies_are_identical_and_recycle() {
        let pool = Arc::new(BufferPool::new(true));
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        let mut p = RequestParser::with_pool(Some(pool.clone()));
        p.feed(raw);
        let r = p.try_next(1024).unwrap().unwrap();
        assert_eq!(r.body, b"hello");
        pool.put_bytes(r.body); // the server recycles after dispatch
        p.feed(raw);
        let r2 = p.try_next(1024).unwrap().unwrap();
        assert_eq!(r2.body, b"hello", "pooled body must carry identical bytes");
        assert_eq!(
            pool.stats().hits.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "second body must be served from the pool"
        );
    }

    #[test]
    fn render_response_into_appends_identical_bytes() {
        let resp = Response::error_json(503, "overloaded").with_retry_after(7);
        let mut out = b"prefix".to_vec();
        render_response_into(&resp, true, &mut out);
        assert_eq!(&out[..6], b"prefix");
        assert_eq!(&out[6..], render_response(&resp, true).as_slice());
    }

    #[test]
    fn render_response_matches_write_response() {
        let resp = Response::json(
            200,
            &crate::util::json::Json::obj(vec![("ok", crate::util::json::Json::Bool(true))]),
        );
        let mut server = HttpConn::new(Cursor::new(Vec::new()));
        server.write_response(&resp, true).unwrap();
        assert_eq!(server.stream.into_inner(), render_response(&resp, true));
    }
}
