//! Minimal raw-`epoll` bindings for the event-loop front end.
//!
//! The build is offline and dependency-free (no `libc`, no tokio), so
//! the three syscall wrappers the readiness loop needs — `epoll_create1`
//! / `epoll_ctl` / `epoll_wait` plus an `eventfd` wakeup — are declared
//! here as `extern "C"` against the platform libc the binary already
//! links. Linux-only, like the rest of the serving stack's CI.
//!
//! Two safe handles are exported:
//!
//! * [`Poller`] — owns the epoll fd; level-triggered interest
//!   registration keyed by a caller-chosen `u64` token.
//! * [`WakeFd`] — an `eventfd` the scheduler's completion queue writes
//!   to from worker threads so the loop returns from `epoll_wait`
//!   immediately when a `Reply` lands.

use std::io;
use std::os::fd::RawFd;

// ---------------------------------------------------------------------
// FFI surface
// ---------------------------------------------------------------------

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn close(fd: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
}

/// Readiness: data to read (or pending accepts on a listener).
pub const EPOLLIN: u32 = 0x1;
/// Readiness: socket writable again after a short write.
pub const EPOLLOUT: u32 = 0x4;
/// Error condition (always reported; no need to register).
pub const EPOLLERR: u32 = 0x8;
/// Hangup (always reported; no need to register).
pub const EPOLLHUP: u32 = 0x10;
/// Peer shut down its write half (half-closed keep-alive sockets).
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;
const EPOLL_CLOEXEC: i32 = 0x80000;
const EFD_NONBLOCK: i32 = 0x800;
const EFD_CLOEXEC: i32 = 0x80000;

/// `struct epoll_event`. On x86-64 the kernel ABI packs the struct
/// (12 bytes); other architectures use natural alignment.
#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub token: u64,
}

#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub token: u64,
}

impl EpollEvent {
    fn zeroed() -> Self {
        EpollEvent { events: 0, token: 0 }
    }

    /// Copy out the (possibly unaligned) readiness mask.
    pub fn readiness(&self) -> u32 {
        let e = self.events;
        e
    }

    /// Copy out the (possibly unaligned) caller token.
    pub fn key(&self) -> u64 {
        let t = self.token;
        t
    }
}

// ---------------------------------------------------------------------
// Poller
// ---------------------------------------------------------------------

/// Owned epoll instance with level-triggered registration.
pub struct Poller {
    epfd: RawFd,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller { epfd })
    }

    fn ctl(&self, op: i32, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest, token };
        let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Register `fd` with the given interest mask and token.
    pub fn add(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, interest, token)
    }

    /// Change the interest mask of an already-registered fd.
    pub fn modify(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, interest, token)
    }

    /// Deregister `fd`. Safe to call right before closing it.
    pub fn remove(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block up to `timeout_ms` (`-1` = forever) and fill `events`.
    /// Returns the number of ready entries; `EINTR` is retried.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    events.as_mut_ptr(),
                    events.len() as i32,
                    timeout_ms,
                )
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }

    /// A zeroed event buffer of the given capacity.
    pub fn event_buf(cap: usize) -> Vec<EpollEvent> {
        vec![EpollEvent::zeroed(); cap]
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        unsafe {
            close(self.epfd);
        }
    }
}

// ---------------------------------------------------------------------
// WakeFd
// ---------------------------------------------------------------------

/// Nonblocking `eventfd` used to interrupt `epoll_wait` from another
/// thread (completion-queue pushes, shutdown requests).
pub struct WakeFd {
    fd: RawFd,
}

impl WakeFd {
    pub fn new() -> io::Result<WakeFd> {
        let fd = unsafe { eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(WakeFd { fd })
    }

    pub fn raw(&self) -> RawFd {
        self.fd
    }

    /// Nudge the loop. Saturation (`EAGAIN` at u64::MAX - 1 pending
    /// wakes) still leaves the fd readable, so a lost increment cannot
    /// lose the wakeup itself.
    pub fn wake(&self) {
        let one: u64 = 1;
        unsafe {
            write(self.fd, &one as *const u64 as *const u8, 8);
        }
    }

    /// Reset the counter after the loop woke up (reads until clear).
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        loop {
            let n = unsafe { read(self.fd, buf.as_mut_ptr(), 8) };
            if n <= 0 {
                return;
            }
        }
    }
}

impl Drop for WakeFd {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

// WakeFd is written from scheduler worker threads and drained on the
// event loop; eventfd reads/writes are atomic at the kernel boundary.
unsafe impl Send for WakeFd {}
unsafe impl Sync for WakeFd {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;

    #[test]
    fn eventfd_wake_drain_roundtrip() {
        let poller = Poller::new().unwrap();
        let wake = WakeFd::new().unwrap();
        poller.add(wake.raw(), EPOLLIN, 7).unwrap();

        let mut events = Poller::event_buf(4);
        // nothing pending: zero-timeout wait returns no events
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0);

        wake.wake();
        wake.wake();
        let n = poller.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].key(), 7);
        assert_ne!(events[0].readiness() & EPOLLIN, 0);

        // drain clears the counter; the level-triggered fd goes quiet
        wake.drain();
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn listener_and_stream_readiness() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();

        let poller = Poller::new().unwrap();
        poller
            .add(listener.as_raw_fd(), EPOLLIN, 1)
            .unwrap();

        let mut client = TcpStream::connect(addr).unwrap();
        let mut events = Poller::event_buf(8);
        let n = poller.wait(&mut events, 2000).unwrap();
        assert!(n >= 1);
        assert_eq!(events[0].key(), 1);

        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();
        poller
            .add(server_side.as_raw_fd(), EPOLLIN | EPOLLRDHUP, 2)
            .unwrap();

        client.write_all(b"ping").unwrap();
        let n = poller.wait(&mut events, 2000).unwrap();
        assert!(n >= 1);
        assert!(events[..n].iter().any(|e| e.key() == 2));

        // interest can be switched to write-side readiness
        poller
            .modify(server_side.as_raw_fd(), EPOLLOUT, 2)
            .unwrap();
        let n = poller.wait(&mut events, 2000).unwrap();
        assert!(events[..n].iter().any(|e| {
            e.key() == 2 && e.readiness() & EPOLLOUT != 0
        }));

        poller.remove(server_side.as_raw_fd()).unwrap();
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0);
    }
}
