//! Prometheus text-format rendering of the serving stats
//! (`GET /metrics`).
//!
//! Exposes the HTTP-layer response counters, per-tier engine counters
//! (requests, batches, queue/infer time, device energy and read cycles),
//! the per-tier latency histogram with `p50/p95/p99` summary gauges, the
//! resolved tier plans (rho, energy budget), and the unified scheduler's
//! state (true per-tier queue length, effective workers, steal and
//! rebalance counters, governor shed counts and budget headroom) so a
//! scrape shows the paper's energy–accuracy knob — and where the shared
//! capacity currently sits — directly.

use std::fmt::Write as _;

use crate::cache::CacheStats;
use crate::coordinator::router::ServerStats;
use crate::metrics::{BATCH_SIZE_BUCKET_BOUNDS, LATENCY_BUCKET_BOUNDS_US};
use crate::pool::PoolStats;
use crate::scheduler::EngineSnapshot;
use crate::trace::{self, Stage};

use super::{HttpStats, TierPlan};

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

/// Render the full `/metrics` payload.  `sched.lanes` must align with
/// `tiers` (both are in [`super::EnergyTier::ALL`] order).  `cache` is
/// the result-cache counters when `--cache-entries` armed one; the
/// `emtopt_cache_*` families render as zeros otherwise, so the series
/// exist from the first scrape either way.  `pool` is the serve-path
/// buffer-pool counters ([`crate::pool::BufferPool::stats`]); the
/// `emtopt_alloc_pool_*` families follow the same zeros-when-absent
/// convention (and stay zero on a `--no-alloc-pool` server, whose pool
/// is a pure passthrough).
pub fn render(
    http: &HttpStats,
    tiers: &[(&TierPlan, &ServerStats)],
    sched: &EngineSnapshot,
    cache: Option<&CacheStats>,
    pool: Option<&PoolStats>,
    uptime_s: f64,
) -> String {
    use std::sync::atomic::Ordering::Relaxed;

    let mut out = String::with_capacity(4096);

    header(
        &mut out,
        "emtopt_build_info",
        "gauge",
        "Build provenance (constant 1; version/rustc/git_sha labels carry the values).",
    );
    let bi = trace::build_info();
    let _ = writeln!(
        out,
        "emtopt_build_info{{version=\"{}\",rustc=\"{}\",git_sha=\"{}\"}} 1",
        bi.version, bi.rustc, bi.git_sha
    );

    header(
        &mut out,
        "emtopt_http_requests_total",
        "counter",
        "HTTP responses written, by status code.",
    );
    for (code, n) in http.by_code() {
        let _ = writeln!(out, "emtopt_http_requests_total{{code=\"{code}\"}} {n}");
    }

    header(
        &mut out,
        "emtopt_http_connections_total",
        "counter",
        "TCP connections accepted.",
    );
    let _ = writeln!(
        out,
        "emtopt_http_connections_total {}",
        http.connections.load(Relaxed)
    );

    header(
        &mut out,
        "emtopt_http_open_conns",
        "gauge",
        "Connections currently open on the event loop.",
    );
    let _ = writeln!(
        out,
        "emtopt_http_open_conns {}",
        http.open_conns.load(Relaxed)
    );

    header(
        &mut out,
        "emtopt_http_open_conns_peak",
        "gauge",
        "High-water mark of simultaneously open connections (monotone, \
         so a scrape after a burst still sees the achieved concurrency).",
    );
    let _ = writeln!(
        out,
        "emtopt_http_open_conns_peak {}",
        http.open_conns_peak.load(Relaxed)
    );

    header(
        &mut out,
        "emtopt_requests_total",
        "counter",
        "Requests served by the inference engine, by energy tier.",
    );
    for (plan, stats) in tiers {
        let _ = writeln!(
            out,
            "emtopt_requests_total{{tier=\"{}\"}} {}",
            plan.tier.name(),
            stats.requests.load(Relaxed)
        );
    }

    header(
        &mut out,
        "emtopt_images_total",
        "counter",
        "Images served by the inference engine, by energy tier (>= requests \
         once multi-image bodies arrive).",
    );
    for (plan, stats) in tiers {
        let _ = writeln!(
            out,
            "emtopt_images_total{{tier=\"{}\"}} {}",
            plan.tier.name(),
            stats.images.load(Relaxed)
        );
    }

    header(
        &mut out,
        "emtopt_client_batch_requests_total",
        "counter",
        "Multi-image client requests served via the direct batch path, by tier.",
    );
    for (plan, stats) in tiers {
        let _ = writeln!(
            out,
            "emtopt_client_batch_requests_total{{tier=\"{}\"}} {}",
            plan.tier.name(),
            stats.client_batch_requests.load(Relaxed)
        );
    }

    header(
        &mut out,
        "emtopt_batches_total",
        "counter",
        "Device batches dispatched, by energy tier.",
    );
    for (plan, stats) in tiers {
        let _ = writeln!(
            out,
            "emtopt_batches_total{{tier=\"{}\"}} {}",
            plan.tier.name(),
            stats.batches.load(Relaxed)
        );
    }

    header(
        &mut out,
        "emtopt_dispatch_batch_size",
        "histogram",
        "Images per dispatched engine batch, by energy tier (batch-amortisation signal).",
    );
    for (plan, stats) in tiers {
        let tier = plan.tier.name();
        let counts = stats.dispatch_batch_sizes.snapshot();
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if i < BATCH_SIZE_BUCKET_BOUNDS.len() {
                let _ = writeln!(
                    out,
                    "emtopt_dispatch_batch_size_bucket{{tier=\"{tier}\",le=\"{}\"}} {cum}",
                    BATCH_SIZE_BUCKET_BOUNDS[i]
                );
            } else {
                let _ = writeln!(
                    out,
                    "emtopt_dispatch_batch_size_bucket{{tier=\"{tier}\",le=\"+Inf\"}} {cum}"
                );
            }
        }
        let _ = writeln!(out, "emtopt_dispatch_batch_size_count{{tier=\"{tier}\"}} {cum}");
        // _sum = total images; the images counter is written by the same
        // worker immediately after the histogram record, so a scrape can
        // be at most one batch out of step
        let _ = writeln!(
            out,
            "emtopt_dispatch_batch_size_sum{{tier=\"{tier}\"}} {}",
            stats.images.load(Relaxed)
        );
    }

    header(
        &mut out,
        "emtopt_queue_us_total",
        "counter",
        "Cumulative enqueue-to-reply time in microseconds, by tier.",
    );
    for (plan, stats) in tiers {
        let _ = writeln!(
            out,
            "emtopt_queue_us_total{{tier=\"{}\"}} {}",
            plan.tier.name(),
            stats.queue_us.load(Relaxed)
        );
    }

    header(
        &mut out,
        "emtopt_infer_us_total",
        "counter",
        "Cumulative model-execution time in microseconds, by tier.",
    );
    for (plan, stats) in tiers {
        let _ = writeln!(
            out,
            "emtopt_infer_us_total{{tier=\"{}\"}} {}",
            plan.tier.name(),
            stats.infer_us.load(Relaxed)
        );
    }

    header(
        &mut out,
        "emtopt_read_cycles_total",
        "counter",
        "Device read cycles, by tier (decomposed mode pays B_a cycles).",
    );
    for (plan, stats) in tiers {
        let _ = writeln!(
            out,
            "emtopt_read_cycles_total{{tier=\"{}\"}} {}",
            plan.tier.name(),
            stats.read_cycles.load(Relaxed)
        );
    }

    header(
        &mut out,
        "emtopt_energy_cell_pj_total",
        "counter",
        "Cumulative analog cell read energy in picojoules, by tier.",
    );
    for (plan, stats) in tiers {
        let _ = writeln!(
            out,
            "emtopt_energy_cell_pj_total{{tier=\"{}\"}} {}",
            plan.tier.name(),
            stats.energy().cell_pj
        );
    }

    header(
        &mut out,
        "emtopt_energy_peripheral_pj_total",
        "counter",
        "Cumulative DAC/ADC peripheral energy in picojoules, by tier.",
    );
    for (plan, stats) in tiers {
        let _ = writeln!(
            out,
            "emtopt_energy_peripheral_pj_total{{tier=\"{}\"}} {}",
            plan.tier.name(),
            stats.energy().peripheral_pj
        );
    }

    header(
        &mut out,
        "emtopt_http_peer_rejected_total",
        "counter",
        "Connections rejected with 429 by the per-peer connection cap.",
    );
    let _ = writeln!(
        out,
        "emtopt_http_peer_rejected_total {}",
        http.too_many_requests_429.load(Relaxed)
    );

    // The pre-scheduler gauge derived queue depth as submitted-minus-
    // replied per tier; it stays as an AGGREGATE for dashboard
    // continuity, while emtopt_tier_queue_len below reports the true
    // per-tier queue length straight from the scheduler's queues.
    header(
        &mut out,
        "emtopt_queue_depth",
        "gauge",
        "Requests admitted but not yet replied, all tiers (aggregate; \
         see emtopt_tier_queue_len for true per-tier queue lengths).",
    );
    let in_flight: u64 = tiers.iter().map(|(_, stats)| stats.queued_requests()).sum();
    let _ = writeln!(out, "emtopt_queue_depth {in_flight}");

    header(
        &mut out,
        "emtopt_tier_queue_len",
        "gauge",
        "Requests waiting in the tier's bounded scheduler queue (true \
         per-tier queue length, excluding work already in flight).",
    );
    for ((plan, _), lane) in tiers.iter().zip(sched.lanes.iter()) {
        let _ = writeln!(
            out,
            "emtopt_tier_queue_len{{tier=\"{}\"}} {}",
            plan.tier.name(),
            lane.queue_len
        );
    }

    header(
        &mut out,
        "emtopt_tier_effective_workers",
        "gauge",
        "Workers of the shared pool currently homed on the tier \
         (effective capacity share set by the rebalancer).",
    );
    for ((plan, _), lane) in tiers.iter().zip(sched.lanes.iter()) {
        let _ = writeln!(
            out,
            "emtopt_tier_effective_workers{{tier=\"{}\"}} {}",
            plan.tier.name(),
            lane.effective_workers
        );
    }

    header(
        &mut out,
        "emtopt_steals_total",
        "counter",
        "Batches of the tier executed by a worker homed on another tier \
         (work-stealing activity).",
    );
    for ((plan, _), lane) in tiers.iter().zip(sched.lanes.iter()) {
        let _ = writeln!(
            out,
            "emtopt_steals_total{{tier=\"{}\"}} {}",
            plan.tier.name(),
            lane.steals
        );
    }

    header(
        &mut out,
        "emtopt_rebalance_moves_total",
        "counter",
        "Workers moved between tier homes by the capacity rebalancer.",
    );
    let _ = writeln!(out, "emtopt_rebalance_moves_total {}", sched.rebalance_moves);

    header(
        &mut out,
        "emtopt_governor_shed_total",
        "counter",
        "Requests refused by the energy governor (503 EnergyShed), by tier.",
    );
    for ((plan, _), lane) in tiers.iter().zip(sched.lanes.iter()) {
        let _ = writeln!(
            out,
            "emtopt_governor_shed_total{{tier=\"{}\"}} {}",
            plan.tier.name(),
            lane.governor_shed
        );
    }

    if let Some((rate, budget)) = sched.energy {
        header(
            &mut out,
            "emtopt_energy_rate_uj_s",
            "gauge",
            "Rolling observed device energy rate in uJ/s (governor window).",
        );
        let _ = writeln!(out, "emtopt_energy_rate_uj_s {rate}");
        header(
            &mut out,
            "emtopt_energy_budget_uj_s",
            "gauge",
            "Configured fleet energy budget in uJ/s.",
        );
        let _ = writeln!(out, "emtopt_energy_budget_uj_s {budget}");
        header(
            &mut out,
            "emtopt_energy_budget_headroom_uj_s",
            "gauge",
            "Budget minus rolling observed rate (negative while shedding).",
        );
        let _ = writeln!(out, "emtopt_energy_budget_headroom_uj_s {}", budget - rate);
    }

    header(
        &mut out,
        "emtopt_tier_rho",
        "gauge",
        "Mean per-layer energy coefficient rho of each tier's lane (eq. 7/8).",
    );
    for (plan, _) in tiers {
        let _ = writeln!(
            out,
            "emtopt_tier_rho{{tier=\"{}\"}} {}",
            plan.tier.name(),
            plan.rho
        );
    }

    header(
        &mut out,
        "emtopt_tier_layer_rho",
        "gauge",
        "Per-layer energy coefficient rho of each tier's plan (technique B shaping).",
    );
    for (plan, _) in tiers {
        for (i, r) in plan.plan.rhos().iter().enumerate() {
            let _ = writeln!(
                out,
                "emtopt_tier_layer_rho{{tier=\"{}\",layer=\"{i}\"}} {r}",
                plan.tier.name()
            );
        }
    }

    header(
        &mut out,
        "emtopt_tier_plan_info",
        "gauge",
        "Plan provenance of each tier's lane (constant 1; source label carries the value).",
    );
    for (plan, _) in tiers {
        let _ = writeln!(
            out,
            "emtopt_tier_plan_info{{tier=\"{}\",source=\"{}\"}} 1",
            plan.tier.name(),
            plan.source().name()
        );
    }

    header(
        &mut out,
        "emtopt_tier_budget_uj",
        "gauge",
        "Per-inference energy budget of each tier in microjoules.",
    );
    for (plan, _) in tiers {
        let _ = writeln!(
            out,
            "emtopt_tier_budget_uj{{tier=\"{}\"}} {}",
            plan.tier.name(),
            plan.budget_uj
        );
    }

    header(
        &mut out,
        "emtopt_tier_planned_uj_per_inference",
        "gauge",
        "Planned (analytical) energy per inference of each tier's plan in microjoules.",
    );
    for (plan, _) in tiers {
        let _ = writeln!(
            out,
            "emtopt_tier_planned_uj_per_inference{{tier=\"{}\"}} {}",
            plan.tier.name(),
            plan.budget_uj
        );
    }

    header(
        &mut out,
        "emtopt_tier_observed_uj_per_inference",
        "gauge",
        "Observed device energy per served image in microjoules (planned-vs-observed pair).",
    );
    for (plan, stats) in tiers {
        let _ = writeln!(
            out,
            "emtopt_tier_observed_uj_per_inference{{tier=\"{}\"}} {}",
            plan.tier.name(),
            stats.mean_energy_uj_per_image()
        );
    }

    header(
        &mut out,
        "emtopt_request_latency_us",
        "histogram",
        "End-to-end engine latency per request in microseconds, by tier.",
    );
    for (plan, stats) in tiers {
        let tier = plan.tier.name();
        let counts = stats.latency.snapshot();
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if i < LATENCY_BUCKET_BOUNDS_US.len() {
                let _ = writeln!(
                    out,
                    "emtopt_request_latency_us_bucket{{tier=\"{tier}\",le=\"{}\"}} {cum}",
                    LATENCY_BUCKET_BOUNDS_US[i]
                );
            } else {
                let _ = writeln!(
                    out,
                    "emtopt_request_latency_us_bucket{{tier=\"{tier}\",le=\"+Inf\"}} {cum}"
                );
            }
        }
        // _count comes from the same snapshot as the buckets, so the
        // histogram invariant (count == +Inf bucket) holds per scrape
        // even while workers record concurrently.
        let _ = writeln!(
            out,
            "emtopt_request_latency_us_count{{tier=\"{tier}\"}} {cum}"
        );
        let _ = writeln!(
            out,
            "emtopt_request_latency_us_sum{{tier=\"{tier}\"}} {}",
            stats.queue_us.load(Relaxed)
        );
    }

    // Precomputed tail quantiles live in their own gauge family — a
    // histogram family may only carry _bucket/_sum/_count series.
    header(
        &mut out,
        "emtopt_request_latency_quantile_us",
        "gauge",
        "Interpolated engine latency quantiles in microseconds, by tier.",
    );
    for (plan, stats) in tiers {
        let tier = plan.tier.name();
        for (q, v) in [
            ("0.5", stats.latency.p50_us()),
            ("0.95", stats.latency.p95_us()),
            ("0.99", stats.latency.p99_us()),
        ] {
            let _ = writeln!(
                out,
                "emtopt_request_latency_quantile_us{{tier=\"{tier}\",quantile=\"{q}\"}} {v:.1}"
            );
        }
    }

    header(
        &mut out,
        "emtopt_stage_latency_us",
        "histogram",
        "Per-stage request-path latency in microseconds, by tier and stage \
         (queue_wait | batch_wait | compute | write), fed by the span tracer.",
    );
    for (plan, stats) in tiers {
        let tier = plan.tier.name();
        for stage in Stage::ALL {
            let h = stats.stages.hist(stage);
            let counts = h.snapshot();
            let mut cum = 0u64;
            for (i, &c) in counts.iter().enumerate() {
                cum += c;
                if i < LATENCY_BUCKET_BOUNDS_US.len() {
                    let _ = writeln!(
                        out,
                        "emtopt_stage_latency_us_bucket{{tier=\"{tier}\",stage=\"{}\",le=\"{}\"}} {cum}",
                        stage.name(),
                        LATENCY_BUCKET_BOUNDS_US[i]
                    );
                } else {
                    let _ = writeln!(
                        out,
                        "emtopt_stage_latency_us_bucket{{tier=\"{tier}\",stage=\"{}\",le=\"+Inf\"}} {cum}",
                        stage.name()
                    );
                }
            }
            let _ = writeln!(
                out,
                "emtopt_stage_latency_us_count{{tier=\"{tier}\",stage=\"{}\"}} {cum}",
                stage.name()
            );
            let _ = writeln!(
                out,
                "emtopt_stage_latency_us_sum{{tier=\"{tier}\",stage=\"{}\"}} {}",
                stage.name(),
                h.sum_us()
            );
        }
    }

    // Exact result cache (DESIGN.md §13): counters readable without the
    // shard locks; all zero while the cache is off so dashboards keep
    // stable series across deployments that toggle it.
    let (hits, misses, evictions, entries, bytes, saved_uj) = match cache {
        Some(c) => (
            c.hits.load(Relaxed),
            c.misses.load(Relaxed),
            c.evictions.load(Relaxed),
            c.entries.load(Relaxed),
            c.bytes.load(Relaxed),
            c.saved_uj(),
        ),
        None => (0, 0, 0, 0, 0, 0.0),
    };
    header(
        &mut out,
        "emtopt_cache_hits_total",
        "counter",
        "Requests served verbatim from the exact result cache (zero device reads).",
    );
    let _ = writeln!(out, "emtopt_cache_hits_total {hits}");
    header(
        &mut out,
        "emtopt_cache_misses_total",
        "counter",
        "Result-cache lookups that fell through to the scheduler.",
    );
    let _ = writeln!(out, "emtopt_cache_misses_total {misses}");
    header(
        &mut out,
        "emtopt_cache_evictions_total",
        "counter",
        "Result-cache entries evicted by the per-shard LRU bounds.",
    );
    let _ = writeln!(out, "emtopt_cache_evictions_total {evictions}");
    header(
        &mut out,
        "emtopt_cache_entries",
        "gauge",
        "Live result-cache entries across all shards.",
    );
    let _ = writeln!(out, "emtopt_cache_entries {entries}");
    header(
        &mut out,
        "emtopt_cache_bytes",
        "gauge",
        "Live result-cache payload bytes across all shards.",
    );
    let _ = writeln!(out, "emtopt_cache_bytes {bytes}");
    header(
        &mut out,
        "emtopt_cache_saved_uj_total",
        "counter",
        "Device energy in microjoules that cache hits did not spend \
         (each hit credits its entry's recorded compute energy).",
    );
    let _ = writeln!(out, "emtopt_cache_saved_uj_total {saved_uj}");

    // Serve-path buffer pool (zero-alloc serving): hit/miss counters
    // over every pooled get, plus the capacity currently parked in the
    // free lists.  Zeros when no pool was provided (or the pool is the
    // `--no-alloc-pool` passthrough, which never touches its stats).
    let (pool_hits, pool_misses, pool_bytes) = match pool {
        Some(p) => (
            p.hits.load(Relaxed),
            p.misses.load(Relaxed),
            p.bytes.load(Relaxed),
        ),
        None => (0, 0, 0),
    };
    header(
        &mut out,
        "emtopt_alloc_pool_hits_total",
        "counter",
        "Serve-path buffer fetches recycled from the pool's free lists.",
    );
    let _ = writeln!(out, "emtopt_alloc_pool_hits_total {pool_hits}");
    header(
        &mut out,
        "emtopt_alloc_pool_misses_total",
        "counter",
        "Serve-path buffer fetches that fell through to a fresh heap allocation.",
    );
    let _ = writeln!(out, "emtopt_alloc_pool_misses_total {pool_misses}");
    header(
        &mut out,
        "emtopt_alloc_pool_bytes",
        "gauge",
        "Buffer capacity currently parked in the pool's size-classed free lists.",
    );
    let _ = writeln!(out, "emtopt_alloc_pool_bytes {pool_bytes}");

    header(
        &mut out,
        "emtopt_uptime_seconds",
        "gauge",
        "Seconds since the server started.",
    );
    let _ = writeln!(out, "emtopt_uptime_seconds {uptime_s:.3}");

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::{EnergyPlan, ReadMode};
    use crate::scheduler::LaneSnapshot;
    use crate::server::EnergyTier;
    use std::sync::atomic::Ordering;

    fn snapshot_with(lanes: usize, energy: Option<(f64, f64)>) -> EngineSnapshot {
        EngineSnapshot {
            lanes: (0..lanes)
                .map(|i| LaneSnapshot {
                    queue_len: 3 + i,
                    effective_workers: 2,
                    steals: 7,
                    governor_shed: 4,
                })
                .collect(),
            rebalance_moves: 9,
            energy,
            draining: false,
        }
    }

    #[test]
    fn renders_expected_series() {
        let http = HttpStats::default();
        http.record(200);
        http.record(200);
        http.record(503);
        let stats = ServerStats::default();
        stats.requests.store(2, Ordering::Relaxed);
        stats.submitted.store(3, Ordering::Relaxed);
        stats.images.store(5, Ordering::Relaxed);
        stats.client_batch_requests.store(1, Ordering::Relaxed);
        stats.batches.store(1, Ordering::Relaxed);
        stats.dispatch_batch_sizes.record(5);
        stats.latency.record_us(120);
        stats.latency.record_us(380);
        stats.stages.record(Stage::Compute, 120);
        stats.stages.record(Stage::QueueWait, 8);
        let plan = TierPlan {
            tier: EnergyTier::Normal,
            rho: 4.0,
            mode: ReadMode::Original,
            budget_uj: 1.5,
            plan: EnergyPlan::uniform(2, 4.0, ReadMode::Original),
        };
        let sched = snapshot_with(1, Some((12.0, 10.0)));
        let text = render(&http, &[(&plan, &stats)], &sched, None, None, 12.5);

        assert!(text.contains("emtopt_http_requests_total{code=\"200\"} 2"));
        assert!(text.contains("emtopt_http_requests_total{code=\"503\"} 1"));
        // open-connection gauges render even before any connection
        http.conn_opened();
        http.conn_opened();
        http.conn_closed();
        let text2 = render(&http, &[(&plan, &stats)], &sched, None, None, 12.5);
        assert!(text.contains("emtopt_http_open_conns 0"));
        assert!(text.contains("emtopt_http_open_conns_peak 0"));
        assert!(text2.contains("emtopt_http_open_conns 1"));
        // the peak is monotone: it remembers the burst of two
        assert!(text2.contains("emtopt_http_open_conns_peak 2"));
        assert!(text.contains("emtopt_requests_total{tier=\"normal\"} 2"));
        assert!(text.contains("emtopt_images_total{tier=\"normal\"} 5"));
        assert!(text.contains("emtopt_client_batch_requests_total{tier=\"normal\"} 1"));
        assert!(text.contains("emtopt_batches_total{tier=\"normal\"} 1"));
        // 5 images landed in the (4, 8] bucket; count/sum close the family
        assert!(text.contains("emtopt_dispatch_batch_size_bucket{tier=\"normal\",le=\"4\"} 0"));
        assert!(text.contains("emtopt_dispatch_batch_size_bucket{tier=\"normal\",le=\"8\"} 1"));
        assert!(text.contains("emtopt_dispatch_batch_size_count{tier=\"normal\"} 1"));
        assert!(text.contains("emtopt_dispatch_batch_size_sum{tier=\"normal\"} 5"));
        assert!(text.contains("emtopt_tier_rho{tier=\"normal\"} 4"));
        assert!(text.contains("emtopt_tier_layer_rho{tier=\"normal\",layer=\"1\"} 4"));
        assert!(text.contains("emtopt_tier_plan_info{tier=\"normal\",source=\"analytic\"} 1"));
        assert!(text.contains("emtopt_tier_planned_uj_per_inference{tier=\"normal\"} 1.5"));
        assert!(text.contains("emtopt_tier_observed_uj_per_inference{tier=\"normal\"} 0"));
        assert!(text.contains("emtopt_http_peer_rejected_total 0"));
        // the legacy gauge is now the submitted-minus-replied AGGREGATE...
        assert!(text.contains("emtopt_queue_depth 1"));
        // ...next to the scheduler's true per-tier state
        assert!(text.contains("emtopt_tier_queue_len{tier=\"normal\"} 3"));
        assert!(text.contains("emtopt_tier_effective_workers{tier=\"normal\"} 2"));
        assert!(text.contains("emtopt_steals_total{tier=\"normal\"} 7"));
        assert!(text.contains("emtopt_rebalance_moves_total 9"));
        assert!(text.contains("emtopt_governor_shed_total{tier=\"normal\"} 4"));
        // governor armed: rate, budget, and (negative) headroom gauges
        assert!(text.contains("emtopt_energy_rate_uj_s 12"));
        assert!(text.contains("emtopt_energy_budget_uj_s 10"));
        assert!(text.contains("emtopt_energy_budget_headroom_uj_s -2"));
        assert!(text.contains("emtopt_request_latency_us_count{tier=\"normal\"} 2"));
        assert!(text.contains("le=\"+Inf\"} 2"));
        assert!(text.contains("quantile=\"0.99\""));
        // stage histograms: one compute sample in (100, 200], one
        // queue_wait sample in (5, 10]; exact _sum from the histogram
        assert!(text.contains(
            "emtopt_stage_latency_us_bucket{tier=\"normal\",stage=\"compute\",le=\"200\"} 1"
        ));
        assert!(text.contains(
            "emtopt_stage_latency_us_bucket{tier=\"normal\",stage=\"compute\",le=\"100\"} 0"
        ));
        assert!(text
            .contains("emtopt_stage_latency_us_count{tier=\"normal\",stage=\"compute\"} 1"));
        assert!(
            text.contains("emtopt_stage_latency_us_sum{tier=\"normal\",stage=\"compute\"} 120")
        );
        assert!(text
            .contains("emtopt_stage_latency_us_count{tier=\"normal\",stage=\"queue_wait\"} 1"));
        // untouched stages still render a stable (all-zero) series
        assert!(
            text.contains("emtopt_stage_latency_us_count{tier=\"normal\",stage=\"write\"} 0")
        );
        // pool families render stable zeros when no pool was provided
        assert!(text.contains("emtopt_alloc_pool_hits_total 0"));
        assert!(text.contains("emtopt_alloc_pool_misses_total 0"));
        assert!(text.contains("emtopt_alloc_pool_bytes 0"));
        // cache families render stable zeros while the cache is off
        assert!(text.contains("emtopt_cache_hits_total 0"));
        assert!(text.contains("emtopt_cache_misses_total 0"));
        assert!(text.contains("emtopt_cache_evictions_total 0"));
        assert!(text.contains("emtopt_cache_entries 0"));
        assert!(text.contains("emtopt_cache_bytes 0"));
        assert!(text.contains("emtopt_cache_saved_uj_total 0"));
        // build provenance gauge is always present with all three labels
        assert!(text.contains("emtopt_build_info{version=\""));
        assert!(text.contains(",rustc=\""));
        assert!(text.contains(",git_sha=\""));
        assert!(text.contains("emtopt_uptime_seconds 12.5"));
        // every non-comment line is "name{labels} value" or "name value"
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            assert!(
                line.rsplit_once(' ').is_some(),
                "malformed metrics line {line:?}"
            );
        }
    }

    #[test]
    fn governor_gauges_absent_without_budget() {
        let http = HttpStats::default();
        let stats = ServerStats::default();
        let plan = TierPlan {
            tier: EnergyTier::Normal,
            rho: 4.0,
            mode: ReadMode::Original,
            budget_uj: 1.5,
            plan: EnergyPlan::uniform(1, 4.0, ReadMode::Original),
        };
        let sched = snapshot_with(1, None);
        let text = render(&http, &[(&plan, &stats)], &sched, None, None, 0.0);
        // shed counters always render (zeros keep the series stable)...
        assert!(text.contains("emtopt_governor_shed_total{tier=\"normal\"} 4"));
        // ...but the budget gauges only exist when a budget is armed
        assert!(!text.contains("emtopt_energy_budget_uj_s"));
        assert!(!text.contains("emtopt_energy_rate_uj_s"));
    }

    #[test]
    fn cache_families_render_live_counters() {
        use crate::cache::{CacheKey, CachedReply, ResultCache};
        let http = HttpStats::default();
        let stats = ServerStats::default();
        let plan = TierPlan {
            tier: EnergyTier::Normal,
            rho: 4.0,
            mode: ReadMode::Original,
            budget_uj: 1.5,
            plan: EnergyPlan::uniform(1, 4.0, ReadMode::Original),
        };
        let cache = ResultCache::new(16, 1 << 20);
        let k = CacheKey::derive(1, &[0.5], 1);
        assert!(cache.lookup(k).is_none()); // one miss
        cache.insert(
            k,
            CachedReply {
                logits: vec![1.0, 2.0],
                count: 1,
                energy_uj: 2.5,
            },
        );
        cache.lookup(k).unwrap(); // one hit, credits 2.5 uJ
        let text = render(
            &http,
            &[(&plan, &stats)],
            &snapshot_with(1, None),
            Some(cache.stats()),
            None,
            0.0,
        );
        assert!(text.contains("emtopt_cache_hits_total 1"));
        assert!(text.contains("emtopt_cache_misses_total 1"));
        assert!(text.contains("emtopt_cache_evictions_total 0"));
        assert!(text.contains("emtopt_cache_entries 1"));
        assert!(text.contains("emtopt_cache_saved_uj_total 2.5"));
        // the byte gauge carries the entry's payload + overhead cost
        let bytes_line = text
            .lines()
            .find(|l| l.starts_with("emtopt_cache_bytes "))
            .expect("bytes gauge rendered");
        let v: u64 = bytes_line.rsplit_once(' ').unwrap().1.parse().unwrap();
        assert!(v > 8, "cache bytes gauge should exceed the payload, got {v}");
    }

    #[test]
    fn pool_families_render_live_counters() {
        use crate::pool::BufferPool;
        let http = HttpStats::default();
        let stats = ServerStats::default();
        let plan = TierPlan {
            tier: EnergyTier::Normal,
            rho: 4.0,
            mode: ReadMode::Original,
            budget_uj: 1.5,
            plan: EnergyPlan::uniform(1, 4.0, ReadMode::Original),
        };
        let pool = BufferPool::new(true);
        let b = pool.get_bytes(100); // miss
        pool.put_bytes(b); // parks capacity
        let b2 = pool.get_bytes(100); // hit (drains the gauge)
        pool.put_bytes(b2);
        let parked = pool.stats().bytes.load(Ordering::Relaxed);
        assert!(parked >= 100);
        let text = render(
            &http,
            &[(&plan, &stats)],
            &snapshot_with(1, None),
            None,
            Some(pool.stats()),
            0.0,
        );
        assert!(text.contains("emtopt_alloc_pool_hits_total 1"));
        assert!(text.contains("emtopt_alloc_pool_misses_total 1"));
        assert!(text.contains(&format!("emtopt_alloc_pool_bytes {parked}")));
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let http = HttpStats::default();
        let stats = ServerStats::default();
        stats.latency.record_us(3); // (2, 5] bucket
        stats.latency.record_us(40); // (20, 50]
        let plan = TierPlan {
            tier: EnergyTier::Low,
            rho: 1.0,
            mode: ReadMode::Decomposed,
            budget_uj: 0.5,
            plan: EnergyPlan::uniform(1, 1.0, ReadMode::Decomposed),
        };
        let text =
            render(&http, &[(&plan, &stats)], &snapshot_with(1, None), None, None, 0.0);
        assert!(text.contains("emtopt_request_latency_us_bucket{tier=\"low\",le=\"5\"} 1"));
        assert!(text.contains("emtopt_request_latency_us_bucket{tier=\"low\",le=\"50\"} 2"));
        assert!(text.contains("emtopt_request_latency_us_bucket{tier=\"low\",le=\"+Inf\"} 2"));
    }
}
