//! Open-loop HTTP load generator for the serving front end.
//!
//! Drives `POST /v1/infer` / `POST /v1/classify` over N keep-alive
//! connections at a target aggregate QPS (0 = closed-loop, as fast as
//! the connections allow).  Requests are deterministic dataset samples,
//! so on `/v1/classify` the generator also scores served accuracy.
//!
//! Latency is measured from the request's **scheduled** send time when
//! pacing (coordinated-omission-corrected: a stalled server inflates the
//! tail instead of silently thinning the arrival rate), or from the
//! actual send when running closed-loop.  The report carries
//! p50/p95/p99/max, throughput, per-status counts, and is written as
//! `BENCH_serve.json` for the perf trajectory.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::data::{Dataset, Split, Suite, DATA_SEED, IMG_LEN};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::Result;

use super::http::HttpConn;
use super::EnergyTier;

/// Load-generator configuration.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Target server, e.g. `127.0.0.1:8080`.
    pub addr: String,
    /// Concurrent keep-alive connections.
    pub connections: usize,
    /// Total requests across all connections.
    pub requests: u64,
    /// Aggregate target rate; 0.0 = closed loop (no pacing).
    pub target_qps: f64,
    /// Fixed tier, or `None` to cycle low/normal/high per request.
    pub tier: Option<EnergyTier>,
    /// Hit `/v1/classify` (and score accuracy) instead of `/v1/infer`.
    pub classify: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:8080".into(),
            connections: 8,
            requests: 1000,
            target_qps: 0.0,
            tier: Some(EnergyTier::Normal),
            classify: true,
        }
    }
}

/// Aggregated result of one load-generation run.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    pub sent: u64,
    pub ok: u64,
    /// `503` responses (admission control sheds load under overload).
    pub overloaded: u64,
    /// Non-200, non-503 HTTP responses.
    pub http_errors: u64,
    /// Connect / socket / framing failures.
    pub transport_errors: u64,
    /// Correct classifications out of `labeled` (classify mode on the
    /// native dataset only).
    pub correct: u64,
    pub labeled: u64,
    pub elapsed_s: f64,
    /// Completed-OK requests per second of wall clock.
    pub throughput_rps: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
    pub max_us: u64,
    pub connections: usize,
    pub target_qps: f64,
}

impl LoadgenReport {
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "loadgen: {} sent over {} connections in {:.2}s -> {:.0} req/s\n",
            self.sent, self.connections, self.elapsed_s, self.throughput_rps
        ));
        s.push_str(&format!(
            "  ok {} | overloaded(503) {} | http errors {} | transport errors {}\n",
            self.ok, self.overloaded, self.http_errors, self.transport_errors
        ));
        if self.labeled > 0 {
            s.push_str(&format!(
                "  served accuracy {:.1}% ({}/{})\n",
                100.0 * self.correct as f64 / self.labeled as f64,
                self.correct,
                self.labeled
            ));
        }
        s.push_str(&format!(
            "  latency p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms | mean {:.2} ms | max {:.2} ms",
            self.p50_us as f64 / 1000.0,
            self.p95_us as f64 / 1000.0,
            self.p99_us as f64 / 1000.0,
            self.mean_us / 1000.0,
            self.max_us as f64 / 1000.0
        ));
        s
    }

    /// Machine-readable record (`BENCH_serve.json` schema).
    pub fn to_json(&self) -> Json {
        let latency = Json::obj(vec![
            ("p50_us", Json::Num(self.p50_us as f64)),
            ("p95_us", Json::Num(self.p95_us as f64)),
            ("p99_us", Json::Num(self.p99_us as f64)),
            ("mean_us", Json::Num(self.mean_us)),
            ("max_us", Json::Num(self.max_us as f64)),
        ]);
        let unix_time = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        Json::obj(vec![
            ("bench", Json::Str("serve".into())),
            ("unix_time", Json::Num(unix_time as f64)),
            ("connections", Json::Num(self.connections as f64)),
            ("target_qps", Json::Num(self.target_qps)),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("overloaded", Json::Num(self.overloaded as f64)),
            ("http_errors", Json::Num(self.http_errors as f64)),
            ("transport_errors", Json::Num(self.transport_errors as f64)),
            ("correct", Json::Num(self.correct as f64)),
            ("labeled", Json::Num(self.labeled as f64)),
            ("elapsed_s", Json::Num(self.elapsed_s)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("latency_us", latency),
        ])
    }
}

/// Write the report to `path` (pretty enough for a CI artifact).
pub fn write_bench(report: &LoadgenReport, path: &str) -> Result<()> {
    std::fs::write(path, report.to_json().render() + "\n")?;
    Ok(())
}

/// Exact percentile over a sorted sample (nearest-rank).
pub fn percentile(sorted_us: &[u64], q: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let rank = (q * sorted_us.len() as f64).ceil() as usize;
    sorted_us[rank.clamp(1, sorted_us.len()) - 1]
}

#[derive(Clone, Copy, Debug, Default)]
struct Counts {
    sent: u64,
    ok: u64,
    overloaded: u64,
    http_errors: u64,
    transport_errors: u64,
    correct: u64,
    labeled: u64,
}

/// Open a keep-alive connection to the server, or `None` on failure.
fn connect_http(addr: &str) -> Option<HttpConn<TcpStream>> {
    let stream = TcpStream::connect(addr).ok()?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_nodelay(true);
    Some(HttpConn::new(stream))
}

/// Probe `/healthz` for the deployed model's shape.
fn probe(addr: &str) -> Result<(usize, usize)> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let mut conn = HttpConn::new(stream);
    conn.write_request("GET", "/healthz", b"")?;
    let (status, body) = conn.read_response(64 * 1024)?;
    anyhow::ensure!(status == 200, "healthz returned {status}");
    let v = Json::parse(std::str::from_utf8(&body)?)?;
    Ok((
        v.get("input_len")?.as_usize()?,
        v.get("num_classes")?.as_usize()?,
    ))
}

/// JSON body for one request (manual rendering keeps the hot loop free
/// of intermediate `Json` trees).
fn body_for(image: &[f32], tier: EnergyTier) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(image.len() * 10 + 32);
    s.push_str("{\"image\":[");
    for (i, v) in image.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{v}");
    }
    let _ = write!(s, "],\"tier\":\"{}\"}}", tier.name());
    s
}

/// Run the load generator; blocks until every connection finished.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    anyhow::ensure!(cfg.connections > 0, "need at least one connection");
    anyhow::ensure!(cfg.requests > 0, "need at least one request");
    let (input_len, num_classes) = probe(&cfg.addr)?;
    // Native dataset when the deployed shape identifies a suite (gives
    // labels for accuracy scoring); deterministic synthetic vectors
    // otherwise — scoring a mismatched suite would report noise.
    let suite = [Suite::Cifar, Suite::ImageNet]
        .into_iter()
        .find(|s| s.num_classes() == num_classes);
    let dataset = match suite {
        Some(s) if input_len == IMG_LEN => Some(Dataset::new(s, DATA_SEED)),
        _ => None,
    };
    let interval = if cfg.target_qps > 0.0 {
        Duration::from_secs_f64(1.0 / cfg.target_qps)
    } else {
        Duration::ZERO
    };
    let path = if cfg.classify { "/v1/classify" } else { "/v1/infer" };
    let conns = cfg.connections as u64;
    let base = cfg.requests / conns;
    let extra = cfg.requests % conns;

    let t0 = Instant::now();
    let threads: Vec<_> = (0..conns)
        .map(|c| {
            let my_count = base + u64::from(c < extra);
            let addr = cfg.addr.clone();
            let dataset = dataset.clone();
            let fixed_tier = cfg.tier;
            let classify = cfg.classify;
            std::thread::spawn(move || -> (Counts, Vec<u64>) {
                let mut counts = Counts::default();
                let mut latencies = Vec::with_capacity(my_count as usize);
                let mut conn = connect_http(&addr);
                let mut img = vec![0.0f32; input_len];
                for k in 0..my_count {
                    // striped global index -> evenly interleaved schedule
                    let global = c + k * conns;
                    let tier =
                        fixed_tier.unwrap_or(EnergyTier::ALL[(global % 3) as usize]);
                    let label = match &dataset {
                        Some(ds) => Some(ds.sample_into(Split::Test, global, &mut img)),
                        None => {
                            let mut r = Rng::stream(0x10ad, global);
                            for v in img.iter_mut() {
                                *v = r.next_f32();
                            }
                            None
                        }
                    };
                    // render the body before the latency clock starts, so
                    // p50/p95/p99 measure network + server, not client-side
                    // JSON formatting
                    let body = body_for(&img, tier);
                    let start = if interval.is_zero() {
                        Instant::now()
                    } else {
                        let due = t0 + interval.mul_f64(global as f64);
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        due
                    };
                    counts.sent += 1;
                    // At-most-once submission with one reconnect: a failed
                    // WRITE (nothing reached the server) is retried on a
                    // fresh socket, so a connection the server closed costs
                    // one reconnect, not the remaining schedule.  A lost
                    // RESPONSE is never retried — the server may already
                    // have executed the request, and a resend would break
                    // the loadgen-report == /metrics reconciliation.
                    let mut exchange = None;
                    for _attempt in 0..2 {
                        if conn.is_none() {
                            conn = connect_http(&addr);
                        }
                        let Some(cn) = conn.as_mut() else { break };
                        if cn.write_request("POST", path, body.as_bytes()).is_err() {
                            conn = None; // dead socket, nothing submitted
                            continue;
                        }
                        match cn.read_response(1 << 20) {
                            Ok(r) => exchange = Some(r),
                            Err(_) => conn = None,
                        }
                        break;
                    }
                    let (status, resp_body) = match exchange {
                        Some(r) => r,
                        None => {
                            counts.transport_errors += 1;
                            continue;
                        }
                    };
                    let us = Instant::now()
                        .saturating_duration_since(start)
                        .as_micros() as u64;
                    match status {
                        200 => {
                            counts.ok += 1;
                            latencies.push(us);
                            if classify {
                                if let Some(label) = label {
                                    counts.labeled += 1;
                                    let pred = std::str::from_utf8(&resp_body)
                                        .ok()
                                        .and_then(|t| Json::parse(t).ok())
                                        .and_then(|v| {
                                            v.get("class").ok().and_then(|c| c.as_usize().ok())
                                        });
                                    if pred == Some(label as usize) {
                                        counts.correct += 1;
                                    }
                                }
                            }
                        }
                        503 => counts.overloaded += 1,
                        _ => counts.http_errors += 1,
                    }
                }
                (counts, latencies)
            })
        })
        .collect();

    let mut total = Counts::default();
    let mut latencies: Vec<u64> = Vec::with_capacity(cfg.requests as usize);
    for t in threads {
        let (c, mut l) = t.join().map_err(|_| anyhow::anyhow!("loadgen thread panicked"))?;
        total.sent += c.sent;
        total.ok += c.ok;
        total.overloaded += c.overloaded;
        total.http_errors += c.http_errors;
        total.transport_errors += c.transport_errors;
        total.correct += c.correct;
        total.labeled += c.labeled;
        latencies.append(&mut l);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    latencies.sort_unstable();
    let mean_us = if latencies.is_empty() {
        0.0
    } else {
        latencies.iter().sum::<u64>() as f64 / latencies.len() as f64
    };
    Ok(LoadgenReport {
        sent: total.sent,
        ok: total.ok,
        overloaded: total.overloaded,
        http_errors: total.http_errors,
        transport_errors: total.transport_errors,
        correct: total.correct,
        labeled: total.labeled,
        elapsed_s,
        throughput_rps: if elapsed_s > 0.0 {
            total.ok as f64 / elapsed_s
        } else {
            0.0
        },
        p50_us: percentile(&latencies, 0.50),
        p95_us: percentile(&latencies, 0.95),
        p99_us: percentile(&latencies, 0.99),
        mean_us,
        max_us: latencies.last().copied().unwrap_or(0),
        connections: cfg.connections,
        target_qps: cfg.target_qps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&xs, 0.50), 50);
        assert_eq!(percentile(&xs, 0.95), 95);
        assert_eq!(percentile(&xs, 0.99), 99);
        assert_eq!(percentile(&xs, 1.0), 100);
        assert_eq!(percentile(&xs, 0.0), 1);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.99), 7);
    }

    #[test]
    fn body_renders_valid_json() {
        let body = body_for(&[0.5, -1.25, 3.0], EnergyTier::High);
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.get("tier").unwrap().as_str().unwrap(), "high");
        assert_eq!(
            v.get("image").unwrap().as_f32s().unwrap(),
            vec![0.5, -1.25, 3.0]
        );
    }

    #[test]
    fn report_json_roundtrips() {
        let r = LoadgenReport {
            sent: 100,
            ok: 98,
            overloaded: 2,
            elapsed_s: 1.5,
            throughput_rps: 65.3,
            p50_us: 800,
            p95_us: 2000,
            p99_us: 5000,
            mean_us: 950.0,
            max_us: 8000,
            connections: 8,
            ..Default::default()
        };
        let j = r.to_json();
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(), "serve");
        assert_eq!(back.get("sent").unwrap().as_u64().unwrap(), 100);
        assert_eq!(
            back.get("latency_us")
                .unwrap()
                .get("p99_us")
                .unwrap()
                .as_u64()
                .unwrap(),
            5000
        );
        assert!(r.render().contains("p99 5.00 ms"));
    }
}
