//! Open-loop HTTP load generator for the serving front end.
//!
//! Drives `POST /v1/infer` / `POST /v1/classify` over N keep-alive
//! connections at a target aggregate QPS (0 = closed-loop, as fast as
//! the connections allow).  Requests are deterministic dataset samples,
//! so on `/v1/classify` the generator also scores served accuracy.
//! With `batch > 1` each request carries a multi-image `{"images": ...}`
//! body through the server's direct batch path.
//!
//! Latency is measured from the request's **scheduled** send time when
//! pacing (coordinated-omission-corrected: a stalled server inflates the
//! tail instead of silently thinning the arrival rate), or from the
//! actual send when running closed-loop.  The report carries
//! p50/p95/p99/max, throughput, per-status counts, and is written as
//! `BENCH_serve.json` for the perf trajectory.
//!
//! [`run_ladder`] turns single operating points into a latency–throughput
//! **curve**: it first measures closed-loop capacity per energy tier,
//! then replays the schedule at a ladder of offered-load fractions
//! (default 0.25x..2x of measured capacity), recording one report per
//! rung — the `BENCH_serve.json` "ladder" schema CI asserts against.
//!
//! Every run additionally scrapes the server's `emtopt_stage_latency_us`
//! histograms before and after, so each report (and therefore each
//! ladder rung) carries a per-(tier, stage) `stage_breakdown` delta
//! covering exactly its own requests.  `--trace-sample N` marks every
//! Nth request with `"trace": true` and summarizes the echoed inline
//! span breakdowns; default bodies stay byte-identical.
//!
//! Two connection drivers share one schedule and one accounting path:
//! the default spawns a thread per connection (simple, fine up to a few
//! hundred sockets), while `--event-loop` drives **all** connections
//! from a single epoll readiness loop — the C10K client that can hold
//! ten thousand keep-alive sockets open against the server's own event
//! loop without ten thousand OS threads.  Reports carry the driver used
//! plus the server's `emtopt_http_open_conns_peak` high-water mark, so
//! a concurrency claim in `BENCH_serve.json` is backed by the server's
//! own gauge rather than the client's bookkeeping.

use std::collections::{BTreeMap, HashSet};
use std::io::{ErrorKind, Read as _, Write as _};
use std::net::TcpStream;
use std::os::fd::AsRawFd;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::data::{Dataset, Split, Suite, DATA_SEED, IMG_LEN};
use crate::metrics::{
    latency_quantile_from_counts, LATENCY_BUCKET_BOUNDS_US, LATENCY_NUM_BUCKETS,
};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::Result;

use super::epoll::{Poller, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP};
use super::http::{HttpConn, ResponseParser};
use super::EnergyTier;

/// Load-generator configuration.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Target server, e.g. `127.0.0.1:8080`.
    pub addr: String,
    /// Concurrent keep-alive connections.
    pub connections: usize,
    /// Total requests across all connections.
    pub requests: u64,
    /// Aggregate target rate; 0.0 = closed loop (no pacing).
    pub target_qps: f64,
    /// Fixed tier, or `None` to cycle low/normal/high per request.
    pub tier: Option<EnergyTier>,
    /// Hit `/v1/classify` (and score accuracy) instead of `/v1/infer`.
    pub classify: bool,
    /// Images per request body: 1 sends `{"image": ...}`, more sends a
    /// multi-image `{"images": ...}` body through the batch path.
    pub batch: usize,
    /// Send `"blocking": true` on every request, driving the server's
    /// backpressure `infer` path (wait for queue space) instead of the
    /// default load-shedding path (503 under overload).  Lets one
    /// `BENCH_serve.json` compare backpressure vs shedding tails.
    pub blocking: bool,
    /// Mark every Nth request (by global index) with `"trace": true`
    /// and collect the echoed inline span breakdowns.  0 disables
    /// sampling and keeps request bodies byte-identical to older
    /// generators.
    pub trace_sample: usize,
    /// Drive all connections from one epoll event loop instead of a
    /// thread per connection.  Same schedule, same at-most-once
    /// semantics; this is the only driver that scales to 10k+
    /// concurrent sockets.
    pub event_loop: bool,
    /// `--key-reuse zipf:S,N`: draw each request's image content from
    /// `N` distinct contents under a Zipf(`S`) popularity law instead
    /// of the dense never-repeating default.  Deterministic (request
    /// `global` always draws the same content, on either driver), so a
    /// server-side exact result cache sees repeats and the report can
    /// predict which requests were repeat-content.  `None` keeps the
    /// legacy dense schedule byte-identical.
    pub key_reuse: Option<KeyReuse>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:8080".into(),
            connections: 8,
            requests: 1000,
            target_qps: 0.0,
            tier: Some(EnergyTier::Normal),
            classify: true,
            batch: 1,
            blocking: false,
            trace_sample: 0,
            event_loop: false,
            key_reuse: None,
        }
    }
}

/// Parsed `--key-reuse zipf:S,N` spec: `n` distinct request contents
/// drawn under a Zipf(`s`) popularity law.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KeyReuse {
    /// Zipf exponent (popularity skew); rank k gets weight `1/k^s`.
    pub s: f64,
    /// Distinct request contents in the pool.
    pub n: usize,
}

impl std::str::FromStr for KeyReuse {
    type Err = String;
    fn from_str(spec: &str) -> std::result::Result<Self, Self::Err> {
        let err = || format!("bad --key-reuse {spec:?} (want zipf:S,N, e.g. zipf:1.1,32)");
        let body = spec.strip_prefix("zipf:").ok_or_else(err)?;
        let (s, n) = body.split_once(',').ok_or_else(err)?;
        let s: f64 = s.trim().parse().map_err(|_| err())?;
        let n: usize = n.trim().parse().map_err(|_| err())?;
        if !s.is_finite() || s <= 0.0 {
            return Err(format!("--key-reuse exponent must be finite and positive, got {s}"));
        }
        if n == 0 {
            return Err("--key-reuse needs at least one distinct content".into());
        }
        Ok(KeyReuse { s, n })
    }
}

/// Salt of the per-request popularity draw ("zipf"): a dedicated
/// counter-RNG stream, so reuse sampling can never perturb the image
/// content streams.
const ZIPF_SALT: u64 = 0x7a69_7066;

/// Deterministic Zipf sampler over content ranks `[0, n)`: request
/// `global` always draws the same rank, on any driver, in any process.
#[derive(Clone, Debug)]
pub struct ZipfSampler {
    /// Normalized cumulative weights of ranks `1..=n`.
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(s: f64, n: usize) -> ZipfSampler {
        assert!(n > 0, "zipf sampler needs at least one rank");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += (k as f64).powf(s).recip();
            cdf.push(acc);
        }
        for v in &mut cdf {
            *v /= acc;
        }
        ZipfSampler { cdf }
    }

    /// Content rank of request `global` (0 = most popular).
    pub fn rank(&self, global: u64) -> usize {
        let u = f64::from(Rng::stream(ZIPF_SALT, global).next_f32());
        self.cdf
            .partition_point(|&c| c < u)
            .min(self.cdf.len() - 1)
    }
}

/// Predict, per scheduled request, whether its `(tier, content)` pair
/// repeats an earlier one — the requests an armed server-side result
/// cache serves without compute.  Concurrency can turn a predicted hit
/// into a real miss (the first occurrence may still be in flight), so
/// the exact ratio comes from the server's own counters; this split
/// buckets client latencies.
fn predict_repeats(requests: u64, fixed_tier: Option<EnergyTier>, z: &ZipfSampler) -> Vec<bool> {
    let mut seen = HashSet::new();
    (0..requests)
        .map(|g| {
            let tier = fixed_tier.map_or((g % 3) as usize, EnergyTier::index);
            !seen.insert((tier, z.rank(g)))
        })
        .collect()
}

/// Aggregated result of one load-generation run.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    pub sent: u64,
    pub ok: u64,
    /// `503` responses (admission control sheds load under overload).
    pub overloaded: u64,
    /// Non-200, non-503 HTTP responses.
    pub http_errors: u64,
    /// Connect / socket / framing failures.
    pub transport_errors: u64,
    /// Correct classifications out of `labeled` (classify mode on the
    /// native dataset only).
    pub correct: u64,
    pub labeled: u64,
    pub elapsed_s: f64,
    /// Completed-OK requests per second of wall clock.
    pub throughput_rps: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
    pub max_us: u64,
    pub connections: usize,
    pub target_qps: f64,
    /// Images per request body (1 = single-image requests).
    pub batch: usize,
    /// Whether requests opted into the backpressure path
    /// (`"blocking": true`) instead of the default load-shedding path.
    pub blocking: bool,
    /// Energy-plan provenance the server advertised on `/healthz`
    /// (`trained`/`analytic`; empty when probing an older server).
    pub plan_source: String,
    /// Fleet energy budget (uJ/s) the server advertised on `/healthz`
    /// (`None` when no governor is armed or the server predates it).
    pub energy_budget_uj_s: Option<f64>,
    /// Per-(tier, stage) latency breakdown from the server's
    /// `emtopt_stage_latency_us` histograms — the before/after scrape
    /// delta covering exactly this run's requests.  Empty when the
    /// server predates the family or the scrape failed.
    pub stage_breakdown: Vec<StageStat>,
    /// `"trace": true` sampling period used (0 = off).
    pub trace_sample: usize,
    /// OK responses that echoed an inline span breakdown.
    pub trace_sampled: u64,
    /// Mean stage times across the sampled inline echoes, microseconds:
    /// `[queue_wait, batch_wait, compute]` (the echo omits write/total).
    pub trace_inline_mean_us: [f64; 3],
    /// Whether the run used the single-threaded epoll driver
    /// (`--event-loop`) instead of a thread per connection.
    pub event_loop: bool,
    /// `emtopt_http_open_conns_peak` scraped from the server after the
    /// run: the server-side high-water mark of concurrently open
    /// sockets (0 when the server predates the gauge or the scrape
    /// failed).  This is the number a C10K claim rests on.
    pub server_open_conns_peak: u64,
    /// The `--key-reuse` spec driven (reports without one omit the
    /// cache block entirely — legacy schema).
    pub key_reuse: Option<KeyReuse>,
    /// Result-cache observation over exactly this run (`--key-reuse`
    /// set): server-side counter deltas plus the client's predicted
    /// hit/miss latency split.
    pub cache: Option<CacheObs>,
    /// Serve-path buffer-pool observation: `emtopt_alloc_pool_*`
    /// counter deltas bracketing the run.  `None` when the server
    /// predates the family (legacy schema) or the scrape failed.
    pub alloc_pool: Option<PoolObs>,
}

/// What one run observed of the server's serve-path buffer pool
/// ([`crate::pool::BufferPool`]): hit/miss counter deltas bracketing
/// the run, plus the free-list byte gauge after it.  A warmed pooled
/// server should report `hit_ratio` near 1.0; a `--no-alloc-pool`
/// server reports all zeros.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PoolObs {
    /// Server-side `hits / (hits + misses)` over the run's delta.
    pub hit_ratio: f64,
    /// Pooled-buffer fetches served from a free list during the run.
    pub hits: u64,
    /// Fetches that fell through to a fresh allocation during the run.
    pub misses: u64,
    /// `emtopt_alloc_pool_bytes` after the run (parked capacity).
    pub bytes: u64,
}

impl PoolObs {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hit_ratio", Json::Num(self.hit_ratio)),
            ("hits", Json::Num(self.hits as f64)),
            ("misses", Json::Num(self.misses as f64)),
            ("bytes", Json::Num(self.bytes as f64)),
        ])
    }
}

/// What one `--key-reuse` run observed of the server's exact result
/// cache: `hit_ratio`/`saved_uj` are the server's own
/// `emtopt_cache_*` counter deltas bracketing the run (exact, 0 when
/// the cache is off or the scrape failed); the p50s split client
/// latencies by the schedule's repeat-content prediction.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheObs {
    /// Server-side `hits / (hits + misses)` over the run's delta.
    pub hit_ratio: f64,
    /// Compute energy the server's hits skipped over the run (uJ).
    pub saved_uj: f64,
    /// Client p50 over predicted repeat-content requests (us).
    pub hit_p50_us: u64,
    /// Client p50 over predicted first-occurrence requests (us).
    pub miss_p50_us: u64,
    /// Scheduled requests predicted as repeats / first occurrences.
    pub predicted_hits: u64,
    pub predicted_misses: u64,
}

impl CacheObs {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hit_ratio", Json::Num(self.hit_ratio)),
            ("saved_uj", Json::Num(self.saved_uj)),
            ("hit_p50_us", Json::Num(self.hit_p50_us as f64)),
            ("miss_p50_us", Json::Num(self.miss_p50_us as f64)),
            ("predicted_hits", Json::Num(self.predicted_hits as f64)),
            ("predicted_misses", Json::Num(self.predicted_misses as f64)),
        ])
    }
}

/// Summary of one (tier, stage) cell of the server's stage-latency
/// histograms over a loadgen run (quantiles interpolated from the
/// bucket-count delta, mean from the exact `_sum` delta).
#[derive(Clone, Debug, Default)]
pub struct StageStat {
    pub tier: String,
    pub stage: String,
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

impl StageStat {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tier", Json::Str(self.tier.clone())),
            ("stage", Json::Str(self.stage.clone())),
            ("count", Json::Num(self.count as f64)),
            ("mean_us", Json::Num(self.mean_us)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p95_us", Json::Num(self.p95_us)),
            ("p99_us", Json::Num(self.p99_us)),
        ])
    }
}

impl LoadgenReport {
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "loadgen: {} sent over {} connections{} in {:.2}s -> {:.0} req/s{}\n",
            self.sent,
            self.connections,
            if self.event_loop { " (event loop)" } else { "" },
            self.elapsed_s,
            self.throughput_rps,
            if self.batch > 1 {
                format!(" ({} images/request)", self.batch)
            } else {
                String::new()
            }
        ));
        if self.server_open_conns_peak > 0 {
            s.push_str(&format!(
                "  server open-connection peak: {}\n",
                self.server_open_conns_peak
            ));
        }
        if self.blocking {
            s.push_str("  mode: blocking (backpressure infer path)\n");
        }
        s.push_str(&format!(
            "  ok {} | overloaded(503) {} | http errors {} | transport errors {}\n",
            self.ok, self.overloaded, self.http_errors, self.transport_errors
        ));
        if self.labeled > 0 {
            s.push_str(&format!(
                "  served accuracy {:.1}% ({}/{})\n",
                100.0 * self.correct as f64 / self.labeled as f64,
                self.correct,
                self.labeled
            ));
        }
        s.push_str(&format!(
            "  latency p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms | mean {:.2} ms | max {:.2} ms",
            self.p50_us as f64 / 1000.0,
            self.p95_us as f64 / 1000.0,
            self.p99_us as f64 / 1000.0,
            self.mean_us / 1000.0,
            self.max_us as f64 / 1000.0
        ));
        for st in &self.stage_breakdown {
            s.push_str(&format!(
                "\n  stage {:<6} {:<10} n {:>6} | mean {:>8.1} us | p50 {:>8.1} | \
                 p95 {:>8.1} | p99 {:>8.1}",
                st.tier, st.stage, st.count, st.mean_us, st.p50_us, st.p95_us, st.p99_us
            ));
        }
        if let (Some(kr), Some(c)) = (self.key_reuse, &self.cache) {
            s.push_str(&format!(
                "\n  key reuse zipf:{},{}: server hit ratio {:.1}% | saved {:.1} uJ | \
                 hit p50 {:.2} ms | miss p50 {:.2} ms",
                kr.s,
                kr.n,
                100.0 * c.hit_ratio,
                c.saved_uj,
                c.hit_p50_us as f64 / 1000.0,
                c.miss_p50_us as f64 / 1000.0
            ));
        }
        if let Some(p) = &self.alloc_pool {
            s.push_str(&format!(
                "\n  alloc pool: hit ratio {:.1}% ({} hits / {} misses) | {} bytes parked",
                100.0 * p.hit_ratio,
                p.hits,
                p.misses,
                p.bytes
            ));
        }
        if self.trace_sample > 0 {
            s.push_str(&format!(
                "\n  traced 1/{}: {} echoes | inline mean queue_wait {:.1} us | \
                 batch_wait {:.1} us | compute {:.1} us",
                self.trace_sample,
                self.trace_sampled,
                self.trace_inline_mean_us[0],
                self.trace_inline_mean_us[1],
                self.trace_inline_mean_us[2]
            ));
        }
        s
    }

    /// Machine-readable record (`BENCH_serve.json` schema).
    pub fn to_json(&self) -> Json {
        let latency = Json::obj(vec![
            ("p50_us", Json::Num(self.p50_us as f64)),
            ("p95_us", Json::Num(self.p95_us as f64)),
            ("p99_us", Json::Num(self.p99_us as f64)),
            ("mean_us", Json::Num(self.mean_us)),
            ("max_us", Json::Num(self.max_us as f64)),
        ]);
        let mut fields = vec![
            ("bench", Json::Str("serve".into())),
            ("unix_time", Json::Num(unix_time() as f64)),
            ("connections", Json::Num(self.connections as f64)),
            ("event_loop", Json::Bool(self.event_loop)),
            (
                "server_open_conns_peak",
                Json::Num(self.server_open_conns_peak as f64),
            ),
            ("batch", Json::Num(self.batch as f64)),
            ("blocking", Json::Bool(self.blocking)),
            ("plan_source", Json::Str(self.plan_source.clone())),
            (
                "energy_budget",
                match self.energy_budget_uj_s {
                    Some(b) => Json::Num(b),
                    None => Json::Null,
                },
            ),
            ("target_qps", Json::Num(self.target_qps)),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("overloaded", Json::Num(self.overloaded as f64)),
            ("http_errors", Json::Num(self.http_errors as f64)),
            ("transport_errors", Json::Num(self.transport_errors as f64)),
            ("correct", Json::Num(self.correct as f64)),
            ("labeled", Json::Num(self.labeled as f64)),
            ("elapsed_s", Json::Num(self.elapsed_s)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("latency_us", latency),
            (
                "stage_breakdown",
                Json::Arr(self.stage_breakdown.iter().map(|s| s.to_json()).collect()),
            ),
        ];
        if let Some(kr) = self.key_reuse {
            fields.push((
                "key_reuse",
                Json::obj(vec![
                    ("dist", Json::Str("zipf".into())),
                    ("s", Json::Num(kr.s)),
                    ("n", Json::Num(kr.n as f64)),
                ]),
            ));
        }
        if let Some(c) = &self.cache {
            fields.push(("cache", c.to_json()));
        }
        if let Some(p) = &self.alloc_pool {
            fields.push(("alloc_pool", p.to_json()));
        }
        if self.trace_sample > 0 {
            fields.push(("trace_sample", Json::Num(self.trace_sample as f64)));
            fields.push(("trace_sampled", Json::Num(self.trace_sampled as f64)));
            fields.push((
                "trace_inline_mean_us",
                Json::obj(vec![
                    ("queue_wait", Json::Num(self.trace_inline_mean_us[0])),
                    ("batch_wait", Json::Num(self.trace_inline_mean_us[1])),
                    ("compute", Json::Num(self.trace_inline_mean_us[2])),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

fn unix_time() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Write the report to `path` (pretty enough for a CI artifact).
pub fn write_bench(report: &LoadgenReport, path: &str) -> Result<()> {
    std::fs::write(path, report.to_json().render() + "\n")?;
    Ok(())
}

/// Exact percentile over a sorted sample (nearest-rank).
pub fn percentile(sorted_us: &[u64], q: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let rank = (q * sorted_us.len() as f64).ceil() as usize;
    sorted_us[rank.clamp(1, sorted_us.len()) - 1]
}

#[derive(Clone, Copy, Debug, Default)]
struct Counts {
    sent: u64,
    ok: u64,
    overloaded: u64,
    http_errors: u64,
    transport_errors: u64,
    correct: u64,
    labeled: u64,
    /// OK responses that echoed an inline `"trace"` breakdown.
    trace_sampled: u64,
}

/// OK-response latencies bucketed by the schedule's repeat-content
/// prediction (`--key-reuse` runs only; empty otherwise).
#[derive(Clone, Debug, Default)]
struct HitMissSplit {
    hit_us: Vec<u64>,
    miss_us: Vec<u64>,
}

impl HitMissSplit {
    fn merge(&mut self, mut other: HitMissSplit) {
        self.hit_us.append(&mut other.hit_us);
        self.miss_us.append(&mut other.miss_us);
    }
}

/// Open a keep-alive connection to the server, or `None` on failure.
fn connect_http(addr: &str) -> Option<HttpConn<TcpStream>> {
    let stream = TcpStream::connect(addr).ok()?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_nodelay(true);
    Some(HttpConn::new(stream))
}

/// What a `/healthz` probe learned about the deployed server.
struct ProbeInfo {
    input_len: usize,
    num_classes: usize,
    /// Per-request image cap (`usize::MAX` when the server predates the
    /// `max_batch` field).
    max_batch: usize,
    /// Energy-plan provenance (`trained`/`analytic`; empty on servers
    /// that predate the field).
    plan_source: String,
    /// Fleet energy budget in uJ/s (`None` when no governor is armed).
    energy_budget_uj_s: Option<f64>,
}

/// Probe `/healthz` for the deployed model's shape, the server's
/// per-request image cap, the energy-plan source it serves with, and
/// its fleet energy budget (if any).
fn probe(addr: &str) -> Result<ProbeInfo> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let mut conn = HttpConn::new(stream);
    conn.write_request("GET", "/healthz", b"")?;
    let (status, body) = conn.read_response(64 * 1024)?;
    anyhow::ensure!(status == 200, "healthz returned {status}");
    let v = Json::parse(std::str::from_utf8(&body)?)?;
    let max_batch = match v.opt("max_batch") {
        Some(m) => m.as_usize()?,
        None => usize::MAX,
    };
    let plan_source = match v.opt("plan_source") {
        Some(ps) => ps.as_str()?.to_string(),
        None => String::new(),
    };
    // Json::Null (governor disarmed) and a missing key both map to None
    let energy_budget_uj_s = v
        .opt("energy_budget_uj_s")
        .and_then(|b| b.as_f64().ok());
    Ok(ProbeInfo {
        input_len: v.get("input_len")?.as_usize()?,
        num_classes: v.get("num_classes")?.as_usize()?,
        max_batch,
        plan_source,
        energy_budget_uj_s,
    })
}

/// One (tier, stage) cell of a scraped `emtopt_stage_latency_us`
/// exposition: cumulative bucket counts (as exposed, `le`-ordered),
/// `_count`, and the exact `_sum`.
#[derive(Clone, Copy, Debug, Default)]
struct StageCell {
    cum: [u64; LATENCY_NUM_BUCKETS],
    count: u64,
    sum_us: u64,
}

/// Scraped stage histograms keyed by (tier, stage); `BTreeMap` keeps the
/// derived breakdown deterministically ordered.
type StageScrape = BTreeMap<(String, String), StageCell>;

/// Parse `emtopt_stage_latency_us_{bucket,count,sum}` lines out of a
/// Prometheus text exposition; everything else is skipped.  Unknown `le`
/// bounds are ignored rather than misfiled, so a server with a different
/// bucket table degrades to count/sum-only stats.
fn parse_stage_scrape(text: &str) -> StageScrape {
    let mut map = StageScrape::new();
    for line in text.lines() {
        let Some(rest) = line.strip_prefix("emtopt_stage_latency_us_") else {
            continue;
        };
        let (kind, rest) = match rest.split_once('{') {
            Some(kv) => kv,
            None => continue,
        };
        let Some((labels, value)) = rest.split_once('}') else {
            continue;
        };
        let Ok(value) = value.trim().parse::<u64>() else {
            continue;
        };
        let (mut tier, mut stage, mut le) = (None, None, None);
        for kv in labels.split(',') {
            let Some((k, v)) = kv.split_once('=') else {
                continue;
            };
            let v = v.trim_matches('"');
            match k {
                "tier" => tier = Some(v),
                "stage" => stage = Some(v),
                "le" => le = Some(v),
                _ => {}
            }
        }
        let (Some(tier), Some(stage)) = (tier, stage) else {
            continue;
        };
        let cell = map
            .entry((tier.to_string(), stage.to_string()))
            .or_default();
        match kind {
            "bucket" => {
                let idx = match le {
                    Some("+Inf") => Some(LATENCY_NUM_BUCKETS - 1),
                    Some(b) => b
                        .parse::<u64>()
                        .ok()
                        .and_then(|b| LATENCY_BUCKET_BOUNDS_US.iter().position(|&x| x == b)),
                    None => None,
                };
                if let Some(idx) = idx {
                    cell.cum[idx] = value;
                }
            }
            "count" => cell.count = value,
            "sum" => cell.sum_us = value,
            _ => {}
        }
    }
    map
}

/// Fetch the raw `/metrics` exposition text.
fn scrape_metrics_text(addr: &str) -> Result<String> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let mut conn = HttpConn::new(stream);
    conn.write_request("GET", "/metrics", b"")?;
    let (status, body) = conn.read_response(4 << 20)?;
    anyhow::ensure!(status == 200, "metrics returned {status}");
    Ok(String::from_utf8(body)?)
}

/// Extract one unlabelled gauge/counter value from an exposition.  The
/// name must be followed by a space, so `emtopt_http_open_conns` never
/// matches the `..._peak` line (or `# HELP` comments).
fn parse_gauge(text: &str, name: &str) -> Option<u64> {
    text.lines().find_map(|line| {
        line.strip_prefix(name)
            .and_then(|rest| rest.strip_prefix(' '))
            .and_then(|v| v.trim().parse().ok())
    })
}

/// Float flavour of [`parse_gauge`] for families rendered with a
/// fractional part (`emtopt_cache_saved_uj_total 2.5`).
fn parse_gauge_f64(text: &str, name: &str) -> Option<f64> {
    text.lines().find_map(|line| {
        line.strip_prefix(name)
            .and_then(|rest| rest.strip_prefix(' '))
            .and_then(|v| v.trim().parse().ok())
    })
}

/// Per-(tier, stage) breakdown of the samples recorded **between** two
/// scrapes: per-bucket deltas feed the shared quantile kernel, the
/// `_sum` delta gives the exact mean.  Cells with no new samples are
/// dropped (an idle tier produces no rows, not zero rows).
fn stage_breakdown(before: &StageScrape, after: &StageScrape) -> Vec<StageStat> {
    let zero = StageCell::default();
    let mut out = Vec::new();
    for (key, a) in after {
        let b = before.get(key).unwrap_or(&zero);
        let count = a.count.saturating_sub(b.count);
        if count == 0 {
            continue;
        }
        // de-cumulate each exposition, then diff per bucket
        let mut counts = [0u64; LATENCY_NUM_BUCKETS];
        for i in 0..LATENCY_NUM_BUCKETS {
            let ai = a.cum[i].saturating_sub(if i > 0 { a.cum[i - 1] } else { 0 });
            let bi = b.cum[i].saturating_sub(if i > 0 { b.cum[i - 1] } else { 0 });
            counts[i] = ai.saturating_sub(bi);
        }
        out.push(StageStat {
            tier: key.0.clone(),
            stage: key.1.clone(),
            count,
            mean_us: a.sum_us.saturating_sub(b.sum_us) as f64 / count as f64,
            p50_us: latency_quantile_from_counts(&counts, 0.50),
            p95_us: latency_quantile_from_counts(&counts, 0.95),
            p99_us: latency_quantile_from_counts(&counts, 0.99),
        });
    }
    out
}

/// Clamp a sample to a JSON-renderable value: `{}` formats non-finite
/// `f32`s as `NaN`/`inf`, which is not JSON — the server would answer an
/// opaque `400` for every affected request.  Mirrors the server-side
/// non-finite pixel rejection in `server/mod.rs`: neither end lets a
/// non-finite value onto the wire.
fn finite_or_zero(v: f32) -> f32 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// Render one `[p0,p1,...]` pixel row (manual rendering keeps the hot
/// loop free of intermediate `Json` trees).
fn push_image(s: &mut String, image: &[f32]) {
    use std::fmt::Write as _;
    s.push('[');
    for (i, v) in image.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{}", finite_or_zero(*v));
    }
    s.push(']');
}

/// JSON body for one single-image request.  `blocking` and `trace` are
/// only rendered when set, so default runs keep byte-identical bodies
/// with older generators (and exercise servers that predate the flags).
fn body_for(image: &[f32], tier: EnergyTier, blocking: bool, trace: bool) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(image.len() * 10 + 48);
    s.push_str("{\"image\":");
    push_image(&mut s, image);
    if blocking {
        s.push_str(",\"blocking\":true");
    }
    if trace {
        s.push_str(",\"trace\":true");
    }
    let _ = write!(s, ",\"tier\":\"{}\"}}", tier.name());
    s
}

/// JSON body for one multi-image request: `images` is `count * input_len`
/// row-major, rendered as `{"images":[[...],...],"tier":...}`.
fn body_for_batch(
    images: &[f32],
    input_len: usize,
    tier: EnergyTier,
    blocking: bool,
    trace: bool,
) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(images.len() * 10 + 64);
    s.push_str("{\"images\":[");
    for (i, row) in images.chunks(input_len).enumerate() {
        if i > 0 {
            s.push(',');
        }
        push_image(&mut s, row);
    }
    s.push(']');
    if blocking {
        s.push_str(",\"blocking\":true");
    }
    if trace {
        s.push_str(",\"trace\":true");
    }
    let _ = write!(s, ",\"tier\":\"{}\"}}", tier.name());
    s
}

/// Build the JSON body (and per-image labels) for request `global`.
/// Shared by both connection drivers so the thread-per-connection and
/// event-loop modes send byte-identical schedules.
#[allow(clippy::too_many_arguments)]
fn build_request(
    global: u64,
    batch: usize,
    input_len: usize,
    dataset: Option<&Dataset>,
    fixed_tier: Option<EnergyTier>,
    blocking: bool,
    trace_sample: u64,
    sampler: Option<&ZipfSampler>,
    img: &mut [f32],
    labels: &mut Vec<usize>,
) -> (String, bool) {
    let tier = fixed_tier.unwrap_or(EnergyTier::ALL[(global % 3) as usize]);
    // content index: dense (never repeats) by default; a --key-reuse
    // run draws it from the Zipf popularity pool, so two requests with
    // the same rank carry byte-identical pixels
    let content = match sampler {
        Some(z) => z.rank(global) as u64,
        None => global,
    };
    labels.clear();
    for j in 0..batch {
        // image index space is dense across contents: content `c`
        // carries images [c*batch, (c+1)*batch)
        let sample = content * batch as u64 + j as u64;
        let row = &mut img[j * input_len..(j + 1) * input_len];
        match dataset {
            Some(ds) => labels.push(ds.sample_into(Split::Test, sample, row) as usize),
            None => {
                let mut r = Rng::stream(0x10ad, sample);
                for v in row.iter_mut() {
                    *v = r.next_f32();
                }
            }
        }
    }
    let traced = trace_sample > 0 && global % trace_sample == 0;
    let body = if batch == 1 {
        body_for(img, tier, blocking, traced)
    } else {
        body_for_batch(img, input_len, tier, blocking, traced)
    };
    (body, traced)
}

/// Account one completed HTTP exchange into the run's counters.  Shared
/// by both connection drivers, so a status means the same thing in a
/// thread-per-connection report and an event-loop report.
#[allow(clippy::too_many_arguments)]
fn score_response(
    status: u16,
    resp_body: &[u8],
    us: u64,
    classify: bool,
    labels: &[usize],
    traced: bool,
    predicted_repeat: Option<bool>,
    batch: usize,
    counts: &mut Counts,
    latencies: &mut Vec<u64>,
    split: &mut HitMissSplit,
    spans: &mut Vec<[u64; 3]>,
) {
    match status {
        200 => {
            counts.ok += 1;
            latencies.push(us);
            match predicted_repeat {
                Some(true) => split.hit_us.push(us),
                Some(false) => split.miss_us.push(us),
                None => {}
            }
            let parsed = if (classify && !labels.is_empty()) || traced {
                std::str::from_utf8(resp_body)
                    .ok()
                    .and_then(|t| Json::parse(t).ok())
            } else {
                None
            };
            if let Some(v) = &parsed {
                if classify && !labels.is_empty() {
                    if batch == 1 {
                        counts.labeled += 1;
                        let pred = v.get("class").ok().and_then(|c| c.as_usize().ok());
                        if pred == Some(labels[0]) {
                            counts.correct += 1;
                        }
                    } else if let Ok(classes) = v.get("classes").and_then(|c| c.as_arr()) {
                        counts.labeled += labels.len() as u64;
                        for (j, cls) in classes.iter().enumerate().take(labels.len()) {
                            if cls.as_usize().ok() == Some(labels[j]) {
                                counts.correct += 1;
                            }
                        }
                    }
                }
                if traced {
                    if let Some(t) = v.opt("trace") {
                        let g = |k: &str| {
                            t.get(k).ok().and_then(|x| x.as_u64().ok()).unwrap_or(0)
                        };
                        counts.trace_sampled += 1;
                        spans.push([
                            g("queue_wait_us"),
                            g("batch_wait_us"),
                            g("compute_us"),
                        ]);
                    }
                }
            }
        }
        503 => counts.overloaded += 1,
        _ => counts.http_errors += 1,
    }
}

/// Run the load generator; blocks until every connection finished.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    anyhow::ensure!(cfg.connections > 0, "need at least one connection");
    anyhow::ensure!(cfg.requests > 0, "need at least one request");
    anyhow::ensure!(cfg.batch > 0, "need at least one image per request");
    let batch = cfg.batch;
    let info = probe(&cfg.addr)?;
    let (input_len, num_classes, max_batch) =
        (info.input_len, info.num_classes, info.max_batch);
    // Fail fast with the real cause instead of a run of opaque 413s: the
    // server advertises its per-request image cap on /healthz.
    anyhow::ensure!(
        batch <= max_batch,
        "--batch {batch} exceeds the server's max_batch {max_batch} (see /healthz)"
    );
    // Native dataset when the deployed shape identifies a suite (gives
    // labels for accuracy scoring); deterministic synthetic vectors
    // otherwise — scoring a mismatched suite would report noise.
    let suite = [Suite::Cifar, Suite::ImageNet]
        .into_iter()
        .find(|s| s.num_classes() == num_classes);
    let dataset = match suite {
        Some(s) if input_len == IMG_LEN => Some(Dataset::new(s, DATA_SEED)),
        _ => None,
    };
    let interval = if cfg.target_qps > 0.0 {
        Duration::from_secs_f64(1.0 / cfg.target_qps)
    } else {
        Duration::ZERO
    };
    let path = if cfg.classify { "/v1/classify" } else { "/v1/infer" };

    // Key-reuse machinery: the Zipf content sampler plus the schedule's
    // repeat-content prediction (first occurrence of a (tier, rank)
    // pair = the request that computes; repeats = the ones an armed
    // server cache serves without compute).
    let sampler = cfg.key_reuse.map(|kr| ZipfSampler::new(kr.s, kr.n));
    let predicted: Option<Arc<Vec<bool>>> = sampler
        .as_ref()
        .map(|z| Arc::new(predict_repeats(cfg.requests, cfg.tier, z)));

    // Scrapes bracketing the run: the deltas attribute exactly this
    // run's requests — stage histograms and (key-reuse runs) the
    // result-cache counters.  Tolerated to fail (older server, scrape
    // race) — the derived stats are then empty/zero, never wrong.
    let before_text = scrape_metrics_text(&cfg.addr).unwrap_or_default();
    let scrape_before = parse_stage_scrape(&before_text);

    let t0 = Instant::now();
    let (total, mut latencies, split, spans) = if cfg.event_loop {
        run_event_loop(
            cfg,
            input_len,
            dataset.as_ref(),
            interval,
            path,
            sampler.as_ref(),
            predicted.clone(),
            t0,
        )?
    } else {
        run_threaded(
            cfg,
            input_len,
            dataset,
            interval,
            path,
            sampler.clone(),
            predicted.clone(),
            t0,
        )?
    };
    let elapsed_s = t0.elapsed().as_secs_f64();
    let after_text = scrape_metrics_text(&cfg.addr).unwrap_or_default();
    let scrape_after = parse_stage_scrape(&after_text);
    let server_open_conns_peak =
        parse_gauge(&after_text, "emtopt_http_open_conns_peak").unwrap_or(0);
    let breakdown = stage_breakdown(&scrape_before, &scrape_after);
    let cache = cfg.key_reuse.map(|_| {
        let delta = |name: &str| {
            (parse_gauge_f64(&after_text, name).unwrap_or(0.0)
                - parse_gauge_f64(&before_text, name).unwrap_or(0.0))
            .max(0.0)
        };
        let hits = delta("emtopt_cache_hits_total");
        let misses = delta("emtopt_cache_misses_total");
        let mut hit_us = split.hit_us;
        let mut miss_us = split.miss_us;
        hit_us.sort_unstable();
        miss_us.sort_unstable();
        CacheObs {
            hit_ratio: if hits + misses > 0.0 {
                hits / (hits + misses)
            } else {
                0.0
            },
            saved_uj: delta("emtopt_cache_saved_uj_total"),
            hit_p50_us: percentile(&hit_us, 0.50),
            miss_p50_us: percentile(&miss_us, 0.50),
            predicted_hits: predicted
                .as_ref()
                .map_or(0, |p| p.iter().filter(|&&h| h).count() as u64),
            predicted_misses: predicted
                .as_ref()
                .map_or(0, |p| p.iter().filter(|&&h| !h).count() as u64),
        }
    });
    // Pool observation: present iff the server renders the family at
    // all (absent against an older server — legacy schema preserved).
    let alloc_pool = parse_gauge_f64(&after_text, "emtopt_alloc_pool_hits_total").map(|_| {
        let delta = |name: &str| {
            (parse_gauge_f64(&after_text, name).unwrap_or(0.0)
                - parse_gauge_f64(&before_text, name).unwrap_or(0.0))
            .max(0.0)
        };
        let hits = delta("emtopt_alloc_pool_hits_total");
        let misses = delta("emtopt_alloc_pool_misses_total");
        PoolObs {
            hit_ratio: if hits + misses > 0.0 {
                hits / (hits + misses)
            } else {
                0.0
            },
            hits: hits as u64,
            misses: misses as u64,
            bytes: parse_gauge_f64(&after_text, "emtopt_alloc_pool_bytes").unwrap_or(0.0)
                as u64,
        }
    });
    let trace_inline_mean_us = if spans.is_empty() {
        [0.0; 3]
    } else {
        let n = spans.len() as f64;
        let mut m = [0.0; 3];
        for s in &spans {
            for (acc, &v) in m.iter_mut().zip(s.iter()) {
                *acc += v as f64 / n;
            }
        }
        m
    };
    latencies.sort_unstable();
    let mean_us = if latencies.is_empty() {
        0.0
    } else {
        latencies.iter().sum::<u64>() as f64 / latencies.len() as f64
    };
    Ok(LoadgenReport {
        sent: total.sent,
        ok: total.ok,
        overloaded: total.overloaded,
        http_errors: total.http_errors,
        transport_errors: total.transport_errors,
        correct: total.correct,
        labeled: total.labeled,
        elapsed_s,
        throughput_rps: if elapsed_s > 0.0 {
            total.ok as f64 / elapsed_s
        } else {
            0.0
        },
        p50_us: percentile(&latencies, 0.50),
        p95_us: percentile(&latencies, 0.95),
        p99_us: percentile(&latencies, 0.99),
        mean_us,
        max_us: latencies.last().copied().unwrap_or(0),
        connections: cfg.connections,
        target_qps: cfg.target_qps,
        batch: cfg.batch,
        blocking: cfg.blocking,
        plan_source: info.plan_source,
        energy_budget_uj_s: info.energy_budget_uj_s,
        stage_breakdown: breakdown,
        trace_sample: cfg.trace_sample,
        trace_sampled: total.trace_sampled,
        trace_inline_mean_us,
        event_loop: cfg.event_loop,
        server_open_conns_peak,
        key_reuse: cfg.key_reuse,
        cache,
        alloc_pool,
    })
}

/// Thread-per-connection driver: each connection gets an OS thread that
/// walks its striped slice of the schedule with blocking I/O.  Simple
/// and accurate up to a few hundred connections; beyond that, use the
/// epoll driver.
#[allow(clippy::too_many_arguments)]
fn run_threaded(
    cfg: &LoadgenConfig,
    input_len: usize,
    dataset: Option<Dataset>,
    interval: Duration,
    path: &'static str,
    sampler: Option<ZipfSampler>,
    predicted: Option<Arc<Vec<bool>>>,
    t0: Instant,
) -> Result<(Counts, Vec<u64>, HitMissSplit, Vec<[u64; 3]>)> {
    let batch = cfg.batch;
    let conns = cfg.connections as u64;
    let base = cfg.requests / conns;
    let extra = cfg.requests % conns;
    let threads: Vec<_> = (0..conns)
        .map(|c| {
            let my_count = base + u64::from(c < extra);
            let addr = cfg.addr.clone();
            let dataset = dataset.clone();
            let fixed_tier = cfg.tier;
            let classify = cfg.classify;
            let blocking = cfg.blocking;
            let trace_sample = cfg.trace_sample as u64;
            let sampler = sampler.clone();
            let predicted = predicted.clone();
            std::thread::spawn(move || -> (Counts, Vec<u64>, HitMissSplit, Vec<[u64; 3]>) {
                let mut counts = Counts::default();
                let mut latencies = Vec::with_capacity(my_count as usize);
                let mut split = HitMissSplit::default();
                let mut spans: Vec<[u64; 3]> = Vec::new();
                let mut conn = connect_http(&addr);
                let mut img = vec![0.0f32; input_len * batch];
                let mut labels: Vec<usize> = Vec::with_capacity(batch);
                for k in 0..my_count {
                    // striped global index -> evenly interleaved schedule;
                    // the body renders before the latency clock starts, so
                    // p50/p95/p99 measure network + server, not client-side
                    // JSON formatting
                    let global = c + k * conns;
                    let (body, traced) = build_request(
                        global,
                        batch,
                        input_len,
                        dataset.as_ref(),
                        fixed_tier,
                        blocking,
                        trace_sample,
                        sampler.as_ref(),
                        &mut img,
                        &mut labels,
                    );
                    let predicted_repeat =
                        predicted.as_ref().map(|p| p[global as usize]);
                    let start = if interval.is_zero() {
                        Instant::now()
                    } else {
                        let due = t0 + interval.mul_f64(global as f64);
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        due
                    };
                    counts.sent += 1;
                    // At-most-once submission with one reconnect: a failed
                    // WRITE (nothing reached the server) is retried on a
                    // fresh socket, so a connection the server closed costs
                    // one reconnect, not the remaining schedule.  A lost
                    // RESPONSE is never retried — the server may already
                    // have executed the request, and a resend would break
                    // the loadgen-report == /metrics reconciliation.
                    let mut exchange = None;
                    for _attempt in 0..2 {
                        if conn.is_none() {
                            conn = connect_http(&addr);
                        }
                        let Some(cn) = conn.as_mut() else { break };
                        if cn.write_request("POST", path, body.as_bytes()).is_err() {
                            conn = None; // dead socket, nothing submitted
                            continue;
                        }
                        match cn.read_response(1 << 20) {
                            Ok(r) => exchange = Some(r),
                            Err(_) => conn = None,
                        }
                        break;
                    }
                    let (status, resp_body) = match exchange {
                        Some(r) => r,
                        None => {
                            counts.transport_errors += 1;
                            continue;
                        }
                    };
                    let us = Instant::now()
                        .saturating_duration_since(start)
                        .as_micros() as u64;
                    score_response(
                        status,
                        &resp_body,
                        us,
                        classify,
                        &labels,
                        traced,
                        predicted_repeat,
                        batch,
                        &mut counts,
                        &mut latencies,
                        &mut split,
                        &mut spans,
                    );
                }
                (counts, latencies, split, spans)
            })
        })
        .collect();

    let mut total = Counts::default();
    let mut latencies: Vec<u64> = Vec::with_capacity(cfg.requests as usize);
    let mut split = HitMissSplit::default();
    let mut spans: Vec<[u64; 3]> = Vec::new();
    for t in threads {
        let (c, mut l, hm, mut s) =
            t.join().map_err(|_| anyhow::anyhow!("loadgen thread panicked"))?;
        total.sent += c.sent;
        total.ok += c.ok;
        total.overloaded += c.overloaded;
        total.http_errors += c.http_errors;
        total.transport_errors += c.transport_errors;
        total.correct += c.correct;
        total.labeled += c.labeled;
        total.trace_sampled += c.trace_sampled;
        latencies.append(&mut l);
        split.merge(hm);
        spans.append(&mut s);
    }
    Ok((total, latencies, split, spans))
}

// ---------------------------------------------------------------------------
// epoll driver: the C10K client
// ---------------------------------------------------------------------------

/// Response-body cap for the epoll driver (matches the blocking
/// driver's `read_response` limit).
const CLIENT_MAX_BODY: usize = 1 << 20;

/// Blocking connect (the schedule has not started, so connect time is
/// on no latency path), then nonblocking for the readiness loop.
fn connect_nonblocking(addr: &str) -> Option<TcpStream> {
    let stream = TcpStream::connect(addr).ok()?;
    let _ = stream.set_nodelay(true);
    stream.set_nonblocking(true).ok()?;
    Some(stream)
}

/// Metadata of a submitted, not-yet-answered request.
struct Pending {
    start: Instant,
    traced: bool,
    /// `--key-reuse` schedule prediction: `Some(true)` if this request's
    /// (tier, rank) pair appeared earlier in the schedule (expected
    /// cache hit).  `None` when key reuse is off.
    predicted: Option<bool>,
    labels: Vec<usize>,
}

/// One nonblocking connection on the epoll driver.
struct ClientConn {
    /// `None` between a socket error and the reconnect, or for good
    /// once the connection is retired without a socket.
    stream: Option<TcpStream>,
    parser: ResponseParser,
    /// Unsent tail of the current request (head + body).
    out: Vec<u8>,
    out_pos: usize,
    /// Requests completed or abandoned on this connection so far.
    k: u64,
    /// This connection's slice of the striped schedule.
    my_count: u64,
    inflight: Option<Pending>,
    /// Reconnects since the last completed exchange — bounds the retry
    /// spin against a server that keeps closing us (per-peer 429s).
    attempts: u32,
    interest: u32,
    done: bool,
}

enum FlushOutcome {
    Done,
    Blocked,
    Error,
}

/// The epoll client: every connection, one thread, one readiness loop.
/// Mirrors the server's own event loop — level-triggered interest, a
/// state-driven `pump` that is safe to run on spurious wakeups, and
/// at-most-once request semantics identical to the threaded driver.
struct ClientLoop<'a> {
    addr: String,
    path: &'static str,
    dataset: Option<&'a Dataset>,
    input_len: usize,
    batch: usize,
    conns: u64,
    fixed_tier: Option<EnergyTier>,
    classify: bool,
    blocking: bool,
    trace_sample: u64,
    /// `--key-reuse` popularity sampler (None = dense, never-repeating
    /// content indices).
    sampler: Option<&'a ZipfSampler>,
    /// Per-request repeat predictions for the whole schedule, indexed by
    /// `global`.  Present iff `sampler` is.
    predicted: Option<Arc<Vec<bool>>>,
    interval: Duration,
    t0: Instant,
    poller: Poller,
    table: Vec<ClientConn>,
    /// Connections still working their schedule.
    active: usize,
    counts: Counts,
    latencies: Vec<u64>,
    split: HitMissSplit,
    spans: Vec<[u64; 3]>,
    /// Scratch image/label buffers (single thread, reused per build).
    img: Vec<f32>,
    labels: Vec<usize>,
}

impl ClientLoop<'_> {
    fn run(&mut self) -> Result<()> {
        let mut events = Poller::event_buf(1024);
        while self.active > 0 {
            // kick idle connections whose scheduled send time arrived,
            // and find the earliest future send for the wait timeout
            let mut next_due: Option<Instant> = None;
            for idx in 0..self.table.len() {
                if self.table[idx].done {
                    continue;
                }
                if let Some(due) = self.pump(idx) {
                    next_due = Some(match next_due {
                        Some(d) if d < due => d,
                        _ => due,
                    });
                }
            }
            if self.active == 0 {
                break;
            }
            let timeout_ms = match next_due {
                Some(due) => {
                    let now = Instant::now();
                    if due <= now {
                        0
                    } else {
                        (due - now).as_millis().clamp(1, 100) as i32
                    }
                }
                None => 100,
            };
            let n = self
                .poller
                .wait(&mut events, timeout_ms)
                .map_err(|e| anyhow::anyhow!("epoll_wait: {e}"))?;
            for ev in events.iter().take(n) {
                let idx = ev.key() as usize;
                let readiness = ev.readiness();
                if idx >= self.table.len() || self.table[idx].done {
                    continue;
                }
                if readiness & (EPOLLERR | EPOLLHUP) != 0 {
                    self.conn_error(idx);
                } else if readiness & (EPOLLIN | EPOLLRDHUP) != 0 {
                    self.read_ready(idx);
                }
                if !self.table[idx].done {
                    // EPOLLOUT and post-read progress both land here
                    let _ = self.pump(idx);
                }
            }
        }
        Ok(())
    }

    /// Drive one connection's state machine until it blocks on the
    /// socket, exhausts its schedule, or (paced mode) is not due yet —
    /// then the due time is returned for the wait timeout.
    fn pump(&mut self, idx: usize) -> Option<Instant> {
        loop {
            if self.table[idx].done {
                return None;
            }
            if !self.table[idx].out.is_empty() {
                match self.flush(idx) {
                    FlushOutcome::Done => {}
                    FlushOutcome::Blocked => {
                        self.update_interest(idx);
                        return None;
                    }
                    FlushOutcome::Error => {
                        self.conn_error(idx);
                        continue;
                    }
                }
            }
            if self.table[idx].inflight.is_some() {
                // request fully written: progress now rides on EPOLLIN
                self.update_interest(idx);
                return None;
            }
            let (k, my_count) = {
                let c = &self.table[idx];
                (c.k, c.my_count)
            };
            if k >= my_count {
                self.finish(idx);
                return None;
            }
            if self.table[idx].stream.is_none() {
                return None;
            }
            let global = idx as u64 + k * self.conns;
            if !self.interval.is_zero() {
                let due = self.t0 + self.interval.mul_f64(global as f64);
                if due > Instant::now() {
                    self.update_interest(idx);
                    return Some(due);
                }
            }
            self.submit(idx, global);
            // loop: flush the fresh request right away
        }
    }

    /// Build and enqueue request `global` on connection `idx`.
    fn submit(&mut self, idx: usize, global: u64) {
        let (body, traced) = build_request(
            global,
            self.batch,
            self.input_len,
            self.dataset,
            self.fixed_tier,
            self.blocking,
            self.trace_sample,
            self.sampler,
            &mut self.img,
            &mut self.labels,
        );
        let predicted = self.predicted.as_ref().map(|p| p[global as usize]);
        // latency clock: scheduled send time when pacing (coordinated-
        // omission-corrected), actual send when closed-loop
        let start = if self.interval.is_zero() {
            Instant::now()
        } else {
            self.t0 + self.interval.mul_f64(global as f64)
        };
        self.counts.sent += 1;
        let labels = self.labels.clone();
        // byte-identical to HttpConn::write_request
        let head = format!(
            "POST {} HTTP/1.1\r\nhost: emtopt\r\ncontent-type: application/json\r\n\
             content-length: {}\r\nconnection: keep-alive\r\n\r\n",
            self.path,
            body.len(),
        );
        let mut out = head.into_bytes();
        out.extend_from_slice(body.as_bytes());
        let c = &mut self.table[idx];
        c.out = out;
        c.out_pos = 0;
        c.inflight = Some(Pending { start, traced, predicted, labels });
    }

    /// Write as much of the pending request as the socket accepts.
    fn flush(&mut self, idx: usize) -> FlushOutcome {
        let ClientConn {
            stream,
            out,
            out_pos,
            ..
        } = &mut self.table[idx];
        let Some(stream) = stream.as_mut() else {
            return FlushOutcome::Error;
        };
        while *out_pos < out.len() {
            match stream.write(&out[*out_pos..]) {
                Ok(0) => return FlushOutcome::Error,
                Ok(n) => *out_pos += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => return FlushOutcome::Blocked,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => return FlushOutcome::Error,
            }
        }
        out.clear();
        *out_pos = 0;
        FlushOutcome::Done
    }

    /// Drain readable bytes and score any completed responses.
    fn read_ready(&mut self, idx: usize) {
        let mut buf = [0u8; 64 * 1024];
        let mut dead = false;
        loop {
            let n = {
                let c = &mut self.table[idx];
                let Some(stream) = c.stream.as_mut() else { return };
                stream.read(&mut buf)
            };
            match n {
                Ok(0) => {
                    dead = true;
                    break;
                }
                Ok(n) => {
                    self.table[idx].parser.feed(&buf[..n]);
                    if n < buf.len() {
                        break; // short read: socket drained
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    dead = true;
                    break;
                }
            }
        }
        loop {
            match self.table[idx].parser.try_next(CLIENT_MAX_BODY) {
                Ok(Some((status, _headers, body))) => {
                    let Some(p) = self.table[idx].inflight.take() else {
                        // unsolicited response — e.g. the pre-rendered 429
                        // a per-peer-capped accept sends before any
                        // request.  Nothing of ours to score; the close
                        // that follows lands in conn_error.
                        continue;
                    };
                    let us = Instant::now()
                        .saturating_duration_since(p.start)
                        .as_micros() as u64;
                    score_response(
                        status,
                        &body,
                        us,
                        self.classify,
                        &p.labels,
                        p.traced,
                        p.predicted,
                        self.batch,
                        &mut self.counts,
                        &mut self.latencies,
                        &mut self.split,
                        &mut self.spans,
                    );
                    let c = &mut self.table[idx];
                    c.k += 1;
                    c.attempts = 0;
                }
                Ok(None) => break,
                Err(_) => {
                    dead = true;
                    break;
                }
            }
        }
        if dead {
            self.conn_error(idx);
        }
    }

    /// Handle a broken socket: settle the in-flight request under
    /// at-most-once semantics, then reconnect or retire.
    fn conn_error(&mut self, idx: usize) {
        let (had_inflight, retry_same) = {
            let c = &self.table[idx];
            let unsent = c.out_pos == 0 && !c.out.is_empty();
            (
                c.inflight.is_some(),
                c.inflight.is_some() && unsent && c.attempts == 0,
            )
        };
        if had_inflight && !retry_same {
            // bytes (or the whole request) reached the wire: charge it
            // and move on — a resend could double-execute
            self.counts.transport_errors += 1;
            let c = &mut self.table[idx];
            c.inflight = None;
            c.out.clear();
            c.out_pos = 0;
            c.k += 1;
        }
        {
            let c = &mut self.table[idx];
            if let Some(s) = c.stream.take() {
                let _ = self.poller.remove(s.as_raw_fd());
            }
            c.parser = ResponseParser::new();
            c.attempts += 1;
        }
        // a server that keeps closing us without progress must not
        // spin: past the first idle reconnect, each further one
        // forfeits a request
        if !had_inflight {
            let charge = {
                let c = &mut self.table[idx];
                if c.attempts > 1 && c.k < c.my_count {
                    c.k += 1;
                    true
                } else {
                    false
                }
            };
            if charge {
                self.counts.sent += 1;
                self.counts.transport_errors += 1;
            }
        }
        if self.table[idx].inflight.is_none()
            && self.table[idx].k >= self.table[idx].my_count
        {
            self.finish(idx);
            return;
        }
        match connect_nonblocking(&self.addr) {
            Some(stream) => {
                let fd = stream.as_raw_fd();
                if self
                    .poller
                    .add(fd, EPOLLIN | EPOLLRDHUP, idx as u64)
                    .is_ok()
                {
                    let c = &mut self.table[idx];
                    c.stream = Some(stream);
                    c.interest = EPOLLIN | EPOLLRDHUP;
                    // a kept retry (out intact, out_pos == 0) flushes on
                    // the caller's next pump pass
                } else {
                    self.retire_failed(idx);
                }
            }
            None => self.retire_failed(idx),
        }
    }

    /// Reconnect failed: charge everything left on this connection's
    /// schedule and retire it.
    fn retire_failed(&mut self, idx: usize) {
        if self.table[idx].inflight.take().is_some() {
            // the kept retry has nowhere to go now
            self.counts.transport_errors += 1;
            let c = &mut self.table[idx];
            c.out.clear();
            c.out_pos = 0;
            c.k += 1;
        }
        let left = {
            let c = &mut self.table[idx];
            let left = c.my_count.saturating_sub(c.k);
            c.k = c.my_count;
            left
        };
        self.counts.sent += left;
        self.counts.transport_errors += left;
        self.finish(idx);
    }

    /// Retire a connection whose schedule is exhausted.  The socket (if
    /// still open) is deregistered but held open until the run returns,
    /// so "N concurrent connections" holds for the whole run — the
    /// server's open-conns gauge sees the full fleet.
    fn finish(&mut self, idx: usize) {
        let c = &mut self.table[idx];
        if c.done {
            return;
        }
        c.done = true;
        c.out.clear();
        c.out_pos = 0;
        c.inflight = None;
        if let Some(s) = &c.stream {
            let _ = self.poller.remove(s.as_raw_fd());
        }
        self.active -= 1;
    }

    /// Level-triggered interest: always read (responses, server close),
    /// write only while a request tail is pending.
    fn update_interest(&mut self, idx: usize) {
        let c = &mut self.table[idx];
        let Some(stream) = &c.stream else { return };
        let want = EPOLLIN | EPOLLRDHUP | if c.out.is_empty() { 0 } else { EPOLLOUT };
        if want != c.interest
            && self
                .poller
                .modify(stream.as_raw_fd(), want, idx as u64)
                .is_ok()
        {
            c.interest = want;
        }
    }
}

/// Epoll driver entry point: connect the whole fleet up front (the
/// server's open-connection gauge peaks at the full count before the
/// first request is sent), then run the readiness loop to completion.
#[allow(clippy::too_many_arguments)]
fn run_event_loop(
    cfg: &LoadgenConfig,
    input_len: usize,
    dataset: Option<&Dataset>,
    interval: Duration,
    path: &'static str,
    sampler: Option<&ZipfSampler>,
    predicted: Option<Arc<Vec<bool>>>,
    t0: Instant,
) -> Result<(Counts, Vec<u64>, HitMissSplit, Vec<[u64; 3]>)> {
    let conns = cfg.connections as u64;
    let base = cfg.requests / conns;
    let extra = cfg.requests % conns;
    let mut lp = ClientLoop {
        addr: cfg.addr.clone(),
        path,
        dataset,
        input_len,
        batch: cfg.batch,
        conns,
        fixed_tier: cfg.tier,
        classify: cfg.classify,
        blocking: cfg.blocking,
        trace_sample: cfg.trace_sample as u64,
        sampler,
        predicted,
        interval,
        t0,
        poller: Poller::new().map_err(|e| anyhow::anyhow!("epoll_create1: {e}"))?,
        table: Vec::with_capacity(cfg.connections),
        active: 0,
        counts: Counts::default(),
        latencies: Vec::with_capacity(cfg.requests as usize),
        split: HitMissSplit::default(),
        spans: Vec::new(),
        img: vec![0.0f32; input_len * cfg.batch],
        labels: Vec::with_capacity(cfg.batch),
    };
    for c in 0..conns {
        let my_count = base + u64::from(c < extra);
        let mut conn = ClientConn {
            stream: None,
            parser: ResponseParser::new(),
            out: Vec::new(),
            out_pos: 0,
            k: my_count, // overwritten to 0 on a live connect
            my_count,
            inflight: None,
            attempts: 0,
            interest: EPOLLIN | EPOLLRDHUP,
            done: true,
        };
        match connect_nonblocking(&cfg.addr) {
            Some(stream) if my_count > 0 => {
                lp.poller
                    .add(stream.as_raw_fd(), EPOLLIN | EPOLLRDHUP, c)
                    .map_err(|e| anyhow::anyhow!("epoll_ctl add: {e}"))?;
                conn.stream = Some(stream);
                conn.k = 0;
                conn.done = false;
                lp.active += 1;
            }
            Some(stream) => {
                // zero-request connection (connections > requests): it
                // still holds a socket open for the concurrency claim
                conn.stream = Some(stream);
            }
            None => {
                // never connected: its whole slice is transport errors
                lp.counts.sent += my_count;
                lp.counts.transport_errors += my_count;
            }
        }
        lp.table.push(conn);
    }
    lp.run()?;
    Ok((lp.counts, lp.latencies, lp.split, lp.spans))
}

// ---------------------------------------------------------------------------
// qps ladder: latency–throughput curves per energy tier
// ---------------------------------------------------------------------------

/// Ladder-sweep configuration: measure closed-loop capacity, then replay
/// the schedule at `fractions` of it.
#[derive(Clone, Debug)]
pub struct LadderConfig {
    /// Per-rung loadgen settings (`target_qps` is overridden per rung).
    /// `tier: Some(t)` sweeps one curve for that tier; `None` (mixed)
    /// sweeps one curve per energy tier.
    pub base: LoadgenConfig,
    /// Offered-load fractions of the measured capacity, strictly
    /// ascending (see [`ladder_fractions`]).
    pub fractions: Vec<f64>,
    /// Requests of the closed-loop calibration run (0 = `base.requests`).
    pub calib_requests: u64,
    /// Images-per-request sizes to sweep (`--batch-sweep 1,4,16`): each
    /// tier gets one calibrated curve per batch size, mapping the
    /// batch-amortisation surface.  Empty = just `base.batch`.
    pub batch_sweep: Vec<usize>,
}

/// Evenly spaced offered-load fractions from 0.25x to 2x of measured
/// capacity — below the knee, at it, and past saturation.
pub fn ladder_fractions(points: usize) -> Vec<f64> {
    let n = points.max(2);
    (0..n)
        .map(|i| 0.25 + (2.0 - 0.25) * i as f64 / (n - 1) as f64)
        .collect()
}

/// One rung of a ladder sweep.
#[derive(Clone, Debug)]
pub struct LadderPoint {
    /// Offered load as a fraction of the tier's measured capacity.
    pub frac: f64,
    pub report: LoadgenReport,
}

/// The latency–throughput curve of one (energy tier, batch size) pair.
#[derive(Clone, Debug)]
pub struct TierCurve {
    /// Tier name (`low`/`normal`/`high`).
    pub tier: String,
    /// Images per request body on this curve (a `--batch-sweep` run
    /// emits one curve per swept size; otherwise the base batch).
    pub batch: usize,
    /// Closed-loop capacity measured by the calibration run, req/s.
    pub capacity_rps: f64,
    /// Rungs in ascending offered-load order.
    pub points: Vec<LadderPoint>,
}

/// Result of a full ladder sweep (`BENCH_serve.json` "ladder" schema).
#[derive(Clone, Debug)]
pub struct LadderReport {
    pub batch: usize,
    pub connections: usize,
    pub requests_per_point: u64,
    /// Whether the sweep drove the backpressure path (`--blocking`): a
    /// blocking ladder's past-saturation rungs trade 503s for queueing
    /// tail latency, so the two modes' curves are only comparable when
    /// the record says which one was measured.
    pub blocking: bool,
    /// Energy-plan provenance the server advertised during the sweep.
    pub plan_source: String,
    /// Fleet energy budget the server advertised (`None` = no governor).
    pub energy_budget_uj_s: Option<f64>,
    /// Batch sizes swept per tier (empty when not sweeping).
    pub batch_sweep: Vec<usize>,
    pub tiers: Vec<TierCurve>,
}

impl LadderReport {
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        for t in &self.tiers {
            let _ = writeln!(
                s,
                "ladder tier {:<6} capacity {:.0} req/s ({} images/request)",
                t.tier, t.capacity_rps, t.batch
            );
            for p in &t.points {
                let r = &p.report;
                let _ = writeln!(
                    s,
                    "  {:>5.2}x  offered {:>7.1} qps -> {:>7.1} req/s | p50 {:.2} ms | \
                     p99 {:.2} ms | ok {} | 503 {}",
                    p.frac,
                    r.target_qps,
                    r.throughput_rps,
                    r.p50_us as f64 / 1000.0,
                    r.p99_us as f64 / 1000.0,
                    r.ok,
                    r.overloaded
                );
            }
        }
        s
    }

    /// Machine-readable record: one `{tier, capacity_rps, curve: [...]}`
    /// entry per swept tier, each curve point a full [`LoadgenReport`]
    /// plus its `qps_frac`.
    pub fn to_json(&self) -> Json {
        let tiers: Vec<Json> = self
            .tiers
            .iter()
            .map(|t| {
                let curve: Vec<Json> = t
                    .points
                    .iter()
                    .map(|p| match p.report.to_json() {
                        Json::Obj(mut m) => {
                            m.insert("qps_frac".into(), Json::Num(p.frac));
                            Json::Obj(m)
                        }
                        other => other,
                    })
                    .collect();
                Json::obj(vec![
                    ("tier", Json::Str(t.tier.clone())),
                    ("batch", Json::Num(t.batch as f64)),
                    ("capacity_rps", Json::Num(t.capacity_rps)),
                    ("curve", Json::Arr(curve)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("bench", Json::Str("serve".into())),
            ("mode", Json::Str("ladder".into())),
            ("unix_time", Json::Num(unix_time() as f64)),
            ("plan_source", Json::Str(self.plan_source.clone())),
            (
                "energy_budget",
                match self.energy_budget_uj_s {
                    Some(b) => Json::Num(b),
                    None => Json::Null,
                },
            ),
            ("batch", Json::Num(self.batch as f64)),
            ("blocking", Json::Bool(self.blocking)),
            (
                "batch_sweep",
                Json::Arr(
                    self.batch_sweep
                        .iter()
                        .map(|&b| Json::Num(b as f64))
                        .collect(),
                ),
            ),
            ("connections", Json::Num(self.connections as f64)),
            ("requests_per_point", Json::Num(self.requests_per_point as f64)),
            ("tiers", Json::Arr(tiers)),
        ])
    }
}

/// Write a ladder report to `path` (the CI artifact).
pub fn write_bench_ladder(report: &LadderReport, path: &str) -> Result<()> {
    std::fs::write(path, report.to_json().render() + "\n")?;
    Ok(())
}

/// Run the full ladder sweep; blocks until every rung of every tier
/// finished.  Each swept (tier, batch size) pair gets its own
/// closed-loop calibration run (capacities differ — the low tier pays
/// decomposed reads, and bigger batches amortise dispatch), then one
/// paced run per fraction, ascending, so every curve's offered qps is
/// monotone by construction.
pub fn run_ladder(cfg: &LadderConfig) -> Result<LadderReport> {
    anyhow::ensure!(!cfg.fractions.is_empty(), "ladder needs at least one rung");
    anyhow::ensure!(
        cfg.fractions.windows(2).all(|w| w[0] < w[1]),
        "ladder fractions must be strictly ascending"
    );
    anyhow::ensure!(
        cfg.fractions.iter().all(|&f| f > 0.0),
        "ladder fractions must be positive"
    );
    let batches: Vec<usize> = if cfg.batch_sweep.is_empty() {
        vec![cfg.base.batch]
    } else {
        anyhow::ensure!(
            cfg.batch_sweep.iter().all(|&b| b > 0),
            "batch sweep entries must be positive"
        );
        let mut b = cfg.batch_sweep.clone();
        b.sort_unstable();
        b.dedup();
        b
    };
    let tiers: Vec<EnergyTier> = match cfg.base.tier {
        Some(t) => vec![t],
        None => EnergyTier::ALL.to_vec(),
    };
    let mut curves = Vec::with_capacity(tiers.len() * batches.len());
    for tier in tiers {
        for &batch in &batches {
            let calib = run(&LoadgenConfig {
                tier: Some(tier),
                target_qps: 0.0,
                batch,
                requests: if cfg.calib_requests > 0 {
                    cfg.calib_requests
                } else {
                    cfg.base.requests
                },
                ..cfg.base.clone()
            })?;
            anyhow::ensure!(
                calib.ok > 0,
                "tier {} batch {batch}: calibration run served no requests",
                tier.name()
            );
            // floor at 1 rps so a pathological calibration cannot produce
            // a zero/negative pacing interval
            let capacity_rps = calib.throughput_rps.max(1.0);
            let mut points = Vec::with_capacity(cfg.fractions.len());
            for &frac in &cfg.fractions {
                let report = run(&LoadgenConfig {
                    tier: Some(tier),
                    target_qps: capacity_rps * frac,
                    batch,
                    ..cfg.base.clone()
                })?;
                points.push(LadderPoint { frac, report });
            }
            curves.push(TierCurve {
                tier: tier.name().to_string(),
                batch,
                capacity_rps,
                points,
            });
        }
    }
    let first = curves.first().and_then(|c| c.points.first());
    Ok(LadderReport {
        batch: cfg.base.batch,
        connections: cfg.base.connections,
        requests_per_point: cfg.base.requests,
        blocking: cfg.base.blocking,
        plan_source: first
            .map(|p| p.report.plan_source.clone())
            .unwrap_or_default(),
        energy_budget_uj_s: first.and_then(|p| p.report.energy_budget_uj_s),
        batch_sweep: if cfg.batch_sweep.is_empty() {
            Vec::new()
        } else {
            batches
        },
        tiers: curves,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&xs, 0.50), 50);
        assert_eq!(percentile(&xs, 0.95), 95);
        assert_eq!(percentile(&xs, 0.99), 99);
        assert_eq!(percentile(&xs, 1.0), 100);
        assert_eq!(percentile(&xs, 0.0), 1);
        assert_eq!(percentile(&[], 0.5), 0);
        // single-element input: every quantile is that element
        assert_eq!(percentile(&[7], 0.0), 7);
        assert_eq!(percentile(&[7], 0.5), 7);
        assert_eq!(percentile(&[7], 0.99), 7);
        assert_eq!(percentile(&[7], 1.0), 7);
        // two elements: nearest-rank splits at q = 0.5
        assert_eq!(percentile(&[3, 9], 0.5), 3);
        assert_eq!(percentile(&[3, 9], 0.51), 9);
    }

    #[test]
    fn body_renders_valid_json() {
        let body = body_for(&[0.5, -1.25, 3.0], EnergyTier::High, false, false);
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.get("tier").unwrap().as_str().unwrap(), "high");
        assert_eq!(
            v.get("image").unwrap().as_f32s().unwrap(),
            vec![0.5, -1.25, 3.0]
        );
        // the shedding default omits the flags entirely (byte-compatible
        // with servers that predate them)
        assert!(v.opt("blocking").is_none());
        assert!(v.opt("trace").is_none());
    }

    #[test]
    fn blocking_flag_renders_into_both_body_forms() {
        let single = body_for(&[1.0, 2.0], EnergyTier::Low, true, false);
        let v = Json::parse(&single).unwrap();
        assert_eq!(*v.get("blocking").unwrap(), Json::Bool(true));
        assert_eq!(v.get("tier").unwrap().as_str().unwrap(), "low");
        let batch = body_for_batch(&[1.0, 2.0, 3.0, 4.0], 2, EnergyTier::Normal, true, false);
        let v = Json::parse(&batch).unwrap();
        assert_eq!(*v.get("blocking").unwrap(), Json::Bool(true));
        assert_eq!(v.get("images").unwrap().as_arr().unwrap().len(), 2);
        // and stays absent from batch bodies by default
        let batch = body_for_batch(&[1.0, 2.0], 2, EnergyTier::Normal, false, false);
        assert!(Json::parse(&batch).unwrap().opt("blocking").is_none());
    }

    #[test]
    fn trace_flag_renders_into_both_body_forms() {
        let single = body_for(&[1.0], EnergyTier::Normal, false, true);
        let v = Json::parse(&single).unwrap();
        assert_eq!(*v.get("trace").unwrap(), Json::Bool(true));
        let batch = body_for_batch(&[1.0, 2.0], 2, EnergyTier::Normal, true, true);
        let v = Json::parse(&batch).unwrap();
        assert_eq!(*v.get("trace").unwrap(), Json::Bool(true));
        assert_eq!(*v.get("blocking").unwrap(), Json::Bool(true));
        // untraced bodies are byte-identical with pre-trace generators
        assert_eq!(
            body_for(&[1.0], EnergyTier::Normal, false, false),
            "{\"image\":[1],\"tier\":\"normal\"}"
        );
    }

    #[test]
    fn parse_gauge_matches_exact_name_only() {
        let text = "# HELP emtopt_http_open_conns Connections currently open.\n\
                    emtopt_http_open_conns 3\n\
                    emtopt_http_open_conns_peak 1207\n";
        // the un-suffixed name must not swallow the `_peak` line
        assert_eq!(parse_gauge(text, "emtopt_http_open_conns"), Some(3));
        assert_eq!(parse_gauge(text, "emtopt_http_open_conns_peak"), Some(1207));
        assert_eq!(parse_gauge(text, "emtopt_http_requests_total"), None);
    }

    #[test]
    fn report_carries_concurrency_fields() {
        let r = LoadgenReport {
            connections: 10_000,
            event_loop: true,
            server_open_conns_peak: 10_002,
            ..Default::default()
        };
        let back = Json::parse(&r.to_json().render()).unwrap();
        assert_eq!(*back.get("event_loop").unwrap(), Json::Bool(true));
        assert_eq!(
            back.get("server_open_conns_peak").unwrap().as_u64().unwrap(),
            10_002
        );
        assert!(r.render().contains("(event loop)"));
        assert!(r.render().contains("server open-connection peak: 10002"));
        // the threaded default keeps both fields but flags the driver
        let plain = LoadgenReport::default();
        let back = Json::parse(&plain.to_json().render()).unwrap();
        assert_eq!(*back.get("event_loop").unwrap(), Json::Bool(false));
        assert_eq!(
            back.get("server_open_conns_peak").unwrap().as_u64().unwrap(),
            0
        );
        assert!(!plain.render().contains("(event loop)"));
    }

    #[test]
    fn stage_scrape_parses_and_diffs() {
        // two scrapes of one (tier, stage) cell: 1 sample in (100, 200]
        // before; 2 more samples land in (100, 200] and (500, 1000]
        let before = parse_stage_scrape(
            "# HELP emtopt_stage_latency_us x\n\
             emtopt_stage_latency_us_bucket{tier=\"normal\",stage=\"compute\",le=\"100\"} 0\n\
             emtopt_stage_latency_us_bucket{tier=\"normal\",stage=\"compute\",le=\"200\"} 1\n\
             emtopt_stage_latency_us_bucket{tier=\"normal\",stage=\"compute\",le=\"+Inf\"} 1\n\
             emtopt_stage_latency_us_count{tier=\"normal\",stage=\"compute\"} 1\n\
             emtopt_stage_latency_us_sum{tier=\"normal\",stage=\"compute\"} 150\n",
        );
        let after = parse_stage_scrape(
            "emtopt_stage_latency_us_bucket{tier=\"normal\",stage=\"compute\",le=\"100\"} 0\n\
             emtopt_stage_latency_us_bucket{tier=\"normal\",stage=\"compute\",le=\"200\"} 2\n\
             emtopt_stage_latency_us_bucket{tier=\"normal\",stage=\"compute\",le=\"1000\"} 3\n\
             emtopt_stage_latency_us_bucket{tier=\"normal\",stage=\"compute\",le=\"+Inf\"} 3\n\
             emtopt_stage_latency_us_count{tier=\"normal\",stage=\"compute\"} 3\n\
             emtopt_stage_latency_us_sum{tier=\"normal\",stage=\"compute\"} 1050\n\
             emtopt_stage_latency_us_count{tier=\"low\",stage=\"write\"} 0\n\
             unrelated_metric 7\n",
        );
        let stats = stage_breakdown(&before, &after);
        // the idle (low, write) cell produces no row
        assert_eq!(stats.len(), 1);
        let st = &stats[0];
        assert_eq!((st.tier.as_str(), st.stage.as_str()), ("normal", "compute"));
        assert_eq!(st.count, 2);
        // exact mean from the _sum delta: (1050 - 150) / 2
        assert!((st.mean_us - 450.0).abs() < 1e-9, "mean {}", st.mean_us);
        // delta samples: one in (100, 200], one in (500, 1000]
        assert!(st.p50_us > 100.0 && st.p50_us <= 200.0, "p50 {}", st.p50_us);
        assert!(st.p99_us > 500.0 && st.p99_us <= 1000.0, "p99 {}", st.p99_us);
    }

    #[test]
    fn stage_breakdown_handles_fresh_server() {
        // no `before` entry at all (server restarted or first scrape
        // failed): the whole `after` state is attributed to the run
        let after = parse_stage_scrape(
            "emtopt_stage_latency_us_bucket{tier=\"low\",stage=\"queue_wait\",le=\"10\"} 4\n\
             emtopt_stage_latency_us_bucket{tier=\"low\",stage=\"queue_wait\",le=\"+Inf\"} 4\n\
             emtopt_stage_latency_us_count{tier=\"low\",stage=\"queue_wait\"} 4\n\
             emtopt_stage_latency_us_sum{tier=\"low\",stage=\"queue_wait\"} 32\n",
        );
        let stats = stage_breakdown(&StageScrape::new(), &after);
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].count, 4);
        assert!((stats[0].mean_us - 8.0).abs() < 1e-9);
    }

    #[test]
    fn body_clamps_non_finite_samples() {
        // NaN/inf render as `NaN`/`inf` under `{}`, which is not JSON —
        // the generator must clamp before rendering
        let body = body_for(
            &[f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -1.5],
            EnergyTier::Low,
            false,
            false,
        );
        let v = Json::parse(&body).expect("clamped body must parse as JSON");
        assert_eq!(
            v.get("image").unwrap().as_f32s().unwrap(),
            vec![0.0, 0.0, 0.0, -1.5]
        );
    }

    #[test]
    fn batch_body_renders_rows() {
        let images = [0.5f32, 1.0, f32::NAN, 2.0, 3.0, 4.0];
        let body = body_for_batch(&images, 3, EnergyTier::Normal, false, false);
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.get("tier").unwrap().as_str().unwrap(), "normal");
        let rows = v.get("images").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].as_f32s().unwrap(), vec![0.5, 1.0, 0.0]);
        assert_eq!(rows[1].as_f32s().unwrap(), vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn ladder_fractions_span_quarter_to_double() {
        let fs = ladder_fractions(5);
        assert_eq!(fs.len(), 5);
        assert!((fs[0] - 0.25).abs() < 1e-12);
        assert!((fs[4] - 2.0).abs() < 1e-12);
        assert!(fs.windows(2).all(|w| w[0] < w[1]), "{fs:?}");
        // degenerate request collapses to the 2-point minimum
        assert_eq!(ladder_fractions(0).len(), 2);
        let three = ladder_fractions(3);
        assert!((three[1] - 1.125).abs() < 1e-12, "{three:?}");
    }

    #[test]
    fn ladder_report_json_schema() {
        let point = |frac: f64, qps: f64| LadderPoint {
            frac,
            report: LoadgenReport {
                sent: 10,
                ok: 10,
                target_qps: qps,
                throughput_rps: qps * 0.9,
                batch: 4,
                connections: 2,
                ..Default::default()
            },
        };
        let r = LadderReport {
            batch: 4,
            connections: 2,
            requests_per_point: 10,
            blocking: true,
            plan_source: "analytic".into(),
            energy_budget_uj_s: Some(25.0),
            batch_sweep: vec![1, 4],
            tiers: vec![TierCurve {
                tier: "normal".into(),
                batch: 4,
                capacity_rps: 100.0,
                points: vec![point(0.25, 25.0), point(2.0, 200.0)],
            }],
        };
        let j = Json::parse(&r.to_json().render()).unwrap();
        assert_eq!(j.get("mode").unwrap().as_str().unwrap(), "ladder");
        assert_eq!(j.get("plan_source").unwrap().as_str().unwrap(), "analytic");
        assert_eq!(j.get("batch").unwrap().as_usize().unwrap(), 4);
        assert_eq!(*j.get("blocking").unwrap(), Json::Bool(true));
        // the energy budget and swept batch sizes are part of the record
        assert_eq!(j.get("energy_budget").unwrap().as_f64().unwrap(), 25.0);
        let sweep = j.get("batch_sweep").unwrap().as_arr().unwrap();
        assert_eq!(sweep.len(), 2);
        assert_eq!(sweep[1].as_usize().unwrap(), 4);
        let tiers = j.get("tiers").unwrap().as_arr().unwrap();
        assert_eq!(tiers.len(), 1);
        assert_eq!(tiers[0].get("tier").unwrap().as_str().unwrap(), "normal");
        assert_eq!(tiers[0].get("batch").unwrap().as_usize().unwrap(), 4);
        let curve = tiers[0].get("curve").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), 2);
        assert!(
            curve[0].get("target_qps").unwrap().as_f64().unwrap()
                < curve[1].get("target_qps").unwrap().as_f64().unwrap()
        );
        assert_eq!(curve[0].get("qps_frac").unwrap().as_f64().unwrap(), 0.25);
        assert!(r.render().contains("ladder tier normal"));
        // a governor-less report records an explicit null budget
        let no_budget = LadderReport {
            energy_budget_uj_s: None,
            ..r
        };
        let j = Json::parse(&no_budget.to_json().render()).unwrap();
        assert_eq!(*j.get("energy_budget").unwrap(), Json::Null);
    }

    #[test]
    fn report_json_roundtrips() {
        let r = LoadgenReport {
            sent: 100,
            ok: 98,
            overloaded: 2,
            elapsed_s: 1.5,
            throughput_rps: 65.3,
            p50_us: 800,
            p95_us: 2000,
            p99_us: 5000,
            mean_us: 950.0,
            max_us: 8000,
            connections: 8,
            batch: 4,
            stage_breakdown: vec![StageStat {
                tier: "normal".into(),
                stage: "compute".into(),
                count: 98,
                mean_us: 420.0,
                p50_us: 400.0,
                p95_us: 800.0,
                p99_us: 950.0,
            }],
            trace_sample: 4,
            trace_sampled: 25,
            trace_inline_mean_us: [5.0, 10.0, 400.0],
            ..Default::default()
        };
        let j = r.to_json();
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(), "serve");
        assert_eq!(back.get("sent").unwrap().as_u64().unwrap(), 100);
        assert_eq!(back.get("batch").unwrap().as_u64().unwrap(), 4);
        assert_eq!(
            back.get("latency_us")
                .unwrap()
                .get("p99_us")
                .unwrap()
                .as_u64()
                .unwrap(),
            5000
        );
        let breakdown = back.get("stage_breakdown").unwrap().as_arr().unwrap();
        assert_eq!(breakdown.len(), 1);
        assert_eq!(breakdown[0].get("stage").unwrap().as_str().unwrap(), "compute");
        assert_eq!(breakdown[0].get("count").unwrap().as_u64().unwrap(), 98);
        assert_eq!(back.get("trace_sample").unwrap().as_u64().unwrap(), 4);
        assert_eq!(
            back.get("trace_inline_mean_us")
                .unwrap()
                .get("compute")
                .unwrap()
                .as_f64()
                .unwrap(),
            400.0
        );
        assert!(r.render().contains("p99 5.00 ms"));
        assert!(r.render().contains("stage normal compute"));
        assert!(r.render().contains("traced 1/4"));
        // an untraced report keeps the legacy schema: breakdown is
        // present (empty), the trace_* fields are absent entirely
        let plain = LoadgenReport::default();
        let back = Json::parse(&plain.to_json().render()).unwrap();
        assert!(back.get("stage_breakdown").unwrap().as_arr().unwrap().is_empty());
        assert!(back.opt("trace_sample").is_none());
        assert!(back.opt("trace_inline_mean_us").is_none());
    }

    #[test]
    fn key_reuse_spec_parses() {
        let kr: KeyReuse = "zipf:1.1,32".parse().unwrap();
        assert_eq!(kr, KeyReuse { s: 1.1, n: 32 });
        let kr: KeyReuse = "zipf: 0.8 , 4".parse().unwrap();
        assert_eq!(kr, KeyReuse { s: 0.8, n: 4 });
        for bad in [
            "uniform:1,32", // unknown distribution
            "zipf:1.1",     // missing pool size
            "zipf:x,32",    // non-numeric exponent
            "zipf:1.1,0",   // empty pool
            "zipf:0,32",    // non-positive exponent
            "zipf:inf,32",  // non-finite exponent
            "",
        ] {
            assert!(bad.parse::<KeyReuse>().is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn zipf_sampler_is_deterministic_and_skewed() {
        let z = ZipfSampler::new(1.1, 32);
        // same global -> same rank, always (the property the server's
        // exact result cache keys on)
        for g in 0..200u64 {
            assert_eq!(z.rank(g), z.rank(g));
        }
        // every rank is in range, and rank 0 is drawn strictly more
        // often than the tail half combined (Zipf head dominance)
        let mut counts = vec![0u64; 32];
        for g in 0..2000u64 {
            let r = z.rank(g);
            assert!(r < 32);
            counts[r] += 1;
        }
        let tail: u64 = counts[16..].iter().sum();
        assert!(
            counts[0] > tail,
            "rank 0 drawn {} times, tail half {}",
            counts[0],
            tail
        );
    }

    #[test]
    fn predict_repeats_marks_first_occurrences() {
        let z = ZipfSampler::new(1.1, 4);
        let fixed = predict_repeats(100, Some(EnergyTier::Normal), &z);
        assert_eq!(fixed.len(), 100);
        // first request can never be a repeat; with 4 contents and 100
        // requests, most of the schedule is
        assert!(!fixed[0]);
        assert!(fixed.iter().filter(|&&h| h).count() >= 90);
        // the prediction recomputes the same ranks the request builder
        // draws: a rank's first occurrence is the one false entry
        let mut seen = std::collections::HashSet::new();
        for (g, &hit) in fixed.iter().enumerate() {
            assert_eq!(hit, !seen.insert(z.rank(g as u64)), "request {g}");
        }
        // mixed-tier schedules namespace contents per tier: the same
        // rank on a different tier is a distinct cache key, so the
        // mixed schedule predicts no more hits than the fixed one
        let mixed = predict_repeats(100, None, &z);
        assert!(
            mixed.iter().filter(|&&h| h).count()
                <= fixed.iter().filter(|&&h| h).count()
        );
    }

    #[test]
    fn report_json_carries_cache_block() {
        let r = LoadgenReport {
            key_reuse: Some(KeyReuse { s: 1.1, n: 32 }),
            cache: Some(CacheObs {
                hit_ratio: 0.75,
                saved_uj: 12.5,
                hit_p50_us: 300,
                miss_p50_us: 900,
                predicted_hits: 75,
                predicted_misses: 25,
            }),
            ..Default::default()
        };
        let back = Json::parse(&r.to_json().render()).unwrap();
        let kr = back.get("key_reuse").unwrap();
        assert_eq!(kr.get("dist").unwrap().as_str().unwrap(), "zipf");
        assert_eq!(kr.get("n").unwrap().as_usize().unwrap(), 32);
        let c = back.get("cache").unwrap();
        assert_eq!(c.get("hit_ratio").unwrap().as_f64().unwrap(), 0.75);
        assert_eq!(c.get("saved_uj").unwrap().as_f64().unwrap(), 12.5);
        assert_eq!(c.get("hit_p50_us").unwrap().as_u64().unwrap(), 300);
        assert_eq!(c.get("miss_p50_us").unwrap().as_u64().unwrap(), 900);
        assert_eq!(c.get("predicted_hits").unwrap().as_u64().unwrap(), 75);
        assert!(r.render().contains("key reuse zipf:1.1,32"));
        assert!(r.render().contains("hit ratio 75.0%"));
        // a run without --key-reuse keeps the legacy schema: neither
        // block appears
        let plain = LoadgenReport::default();
        let back = Json::parse(&plain.to_json().render()).unwrap();
        assert!(back.opt("key_reuse").is_none());
        assert!(back.opt("cache").is_none());
        assert!(!plain.render().contains("key reuse"));
    }

    #[test]
    fn report_json_carries_alloc_pool_block() {
        let r = LoadgenReport {
            alloc_pool: Some(PoolObs {
                hit_ratio: 0.96,
                hits: 960,
                misses: 40,
                bytes: 131072,
            }),
            ..Default::default()
        };
        let back = Json::parse(&r.to_json().render()).unwrap();
        let p = back.get("alloc_pool").unwrap();
        assert_eq!(p.get("hit_ratio").unwrap().as_f64().unwrap(), 0.96);
        assert_eq!(p.get("hits").unwrap().as_u64().unwrap(), 960);
        assert_eq!(p.get("misses").unwrap().as_u64().unwrap(), 40);
        assert_eq!(p.get("bytes").unwrap().as_u64().unwrap(), 131072);
        assert!(r.render().contains("alloc pool: hit ratio 96.0%"));
        // against a server that predates the family (or with the scrape
        // missing) the block is absent entirely — legacy schema
        let plain = LoadgenReport::default();
        let back = Json::parse(&plain.to_json().render()).unwrap();
        assert!(back.opt("alloc_pool").is_none());
        assert!(!plain.render().contains("alloc pool"));
    }
}
