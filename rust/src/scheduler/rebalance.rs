//! Pure capacity-rebalancing policy of the unified scheduler.
//!
//! The engine keeps a *home* lane per worker (a soft preference — a
//! worker whose home queue is empty steals from any non-empty lane, see
//! `scheduler::pick_lane`).  The rebalancer periodically recomputes the
//! home assignment from live per-lane pressure (queue depth + tail
//! latency), so a tier burst pulls effective capacity toward itself
//! instead of queueing behind idle workers pinned to quiet tiers.
//!
//! Everything here is a pure function of its inputs — no clocks, no
//! atomics — so the policy is unit-testable with a deterministic clock
//! by construction: one [`assign`] call *is* one rebalance interval.

/// Live pressure observation of one lane at rebalance time.
#[derive(Clone, Copy, Debug, Default)]
pub struct LaneLoad {
    /// Requests waiting in the lane's bounded queue.
    pub queue_len: usize,
    /// The lane's p99 enqueue-to-reply latency over the *current
    /// rebalance interval*, microseconds (a
    /// [`crate::metrics::LatencyWindow`] delta over the lane histogram —
    /// the cumulative p99 never forgets, so one slow cold start would
    /// bias this lane's pressure for the process lifetime).  0 when the
    /// lane completed nothing in the interval: no completions means no
    /// tail pressure; a backlog still registers through `queue_len`.
    pub p99_us: f64,
}

/// Pressure score of one lane: every queued request counts 1, and every
/// 10 ms of p99 tail counts like one queued request.  The `1.0` floor
/// keeps an idle lane from being starved to weight zero (it still wins
/// steals occasionally and re-earns capacity the moment traffic lands).
pub fn lane_score(load: &LaneLoad) -> f64 {
    1.0 + load.queue_len as f64 + load.p99_us / 10_000.0
}

/// One rebalance step: recompute per-lane worker targets proportional to
/// pressure (largest-remainder rounding, ties to the higher-priority
/// lane), then move the minimum number of workers from over- to
/// under-provisioned lanes.  Deterministic: identical inputs give
/// identical assignments, and a second step on an unchanged load is a
/// no-op.  Returns `(new homes, new steal weights, workers moved)`.
pub fn assign(prev: &[usize], loads: &[LaneLoad]) -> (Vec<usize>, Vec<f64>, usize) {
    let n_lanes = loads.len();
    let workers = prev.len();
    let scores: Vec<f64> = loads.iter().map(lane_score).collect();
    let total: f64 = scores.iter().sum();
    let raw: Vec<f64> = scores.iter().map(|s| s / total * workers as f64).collect();
    let mut target: Vec<usize> = raw.iter().map(|r| r.floor() as usize).collect();
    let mut rem: Vec<(usize, f64)> = raw
        .iter()
        .enumerate()
        .map(|(i, r)| (i, r - r.floor()))
        .collect();
    // biggest remainder first; ties break toward the higher lane index
    // (higher priority), so the premium tier wins the odd worker out
    rem.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b.0.cmp(&a.0))
    });
    let mut left = workers - target.iter().sum::<usize>();
    for (i, _) in rem {
        if left == 0 {
            break;
        }
        target[i] += 1;
        left -= 1;
    }

    let mut have = vec![0usize; n_lanes];
    for &h in prev {
        have[h] += 1;
    }
    let mut homes = prev.to_vec();
    let mut moves = 0usize;
    for home in homes.iter_mut() {
        let from = *home;
        if have[from] <= target[from] {
            continue;
        }
        if let Some(to) = (0..n_lanes).find(|&l| have[l] < target[l]) {
            have[from] -= 1;
            have[to] += 1;
            *home = to;
            moves += 1;
        }
    }
    (homes, scores, moves)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(queue_len: usize) -> LaneLoad {
        LaneLoad {
            queue_len,
            p99_us: 0.0,
        }
    }

    #[test]
    fn saturated_lane_takes_workers_in_one_step() {
        // ISSUE 5 satellite: a saturated `high` queue (lane 2) steals the
        // workers of idle `low`/`normal` lanes within ONE rebalance
        // interval — one assign() call is one interval, no clock needed.
        let prev = vec![0, 1, 2];
        let (homes, weights, moves) = assign(&prev, &[q(0), q(0), q(12)]);
        assert_eq!(homes, vec![2, 2, 2], "all capacity must move to the hot lane");
        assert_eq!(moves, 2);
        assert!(weights[2] > weights[0], "steal weights must favour the hot lane");
    }

    #[test]
    fn balanced_load_reaches_a_stable_fixpoint() {
        // equal pressure: one step lands on the canonical split, and a
        // second step on the same load moves nothing (no churn)
        let prev = vec![0, 1, 2, 0];
        let loads = [q(0), q(0), q(0)];
        let (homes, _, _) = assign(&prev, &loads);
        let (homes2, _, moves2) = assign(&homes, &loads);
        assert_eq!(homes, homes2);
        assert_eq!(moves2, 0, "unchanged load must not reshuffle workers");
        // every lane keeps at least one home at this worker count
        for lane in 0..3 {
            assert!(homes.iter().any(|&h| h == lane), "lane {lane} starved: {homes:?}");
        }
    }

    #[test]
    fn p99_pressure_attracts_capacity() {
        // identical queues, but one lane carries a 100 ms p99 tail: the
        // tail alone (worth ~10 queued requests) pulls workers over
        let prev = vec![0, 1, 2];
        let slow = LaneLoad {
            queue_len: 0,
            p99_us: 100_000.0,
        };
        let (homes, _, moves) = assign(&prev, &[q(0), slow, q(0)]);
        assert!(moves >= 1);
        let on_slow = homes.iter().filter(|&&h| h == 1).count();
        assert!(on_slow >= 2, "tail-heavy lane must gain workers: {homes:?}");
    }

    #[test]
    fn priority_wins_remainder_ties() {
        // all idle, 2 workers over 3 lanes: the odd split favours the
        // higher-priority lanes (1 and 2), never strands both on low
        let (homes, _, _) = assign(&[0, 1], &[q(0), q(0), q(0)]);
        let mut counts = [0usize; 3];
        for &h in &homes {
            counts[h] += 1;
        }
        assert_eq!(counts, [0, 1, 1], "{homes:?}");
    }
}
