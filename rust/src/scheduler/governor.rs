//! Energy-SLO admission: the governor that closes the loop on the
//! paper's accuracy-per-joule contract at serving time.
//!
//! Batch workers report their observed device energy into a rolling
//! [`EnergyMeter`]; every admission consults the meter's uJ/s rate
//! against the configured [`EnergyBudget`].  Over budget, the governor
//! refuses the lowest-priority lanes first (escalating with the
//! overshoot; the top lane is never refused) with the typed
//! [`EnergyShed`] error the HTTP front end maps to `503` + an honest
//! `Retry-After` derived from the window-decay time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::energy::{EnergyBudget, EnergyMeter};

/// Rolling window the governor averages observed energy over.  Short
/// enough to react to a burst within a couple of seconds, long enough
/// that one expensive batch cannot flap the shed decision.
pub const GOVERNOR_WINDOW: Duration = Duration::from_secs(2);

/// Typed energy-SLO load-shedding error: the rolling observed energy
/// rate exceeds the fleet budget and this request's tier is inside the
/// shed band.  The HTTP front end maps it to `503 Service Unavailable`
/// with `Retry-After: retry_after_s` — unlike `Overloaded` (a queue
/// problem that drains in milliseconds), this clears only when the
/// energy window decays, so the hint comes from the budget math.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyShed {
    /// Rolling observed rate at shed time, uJ/s.
    pub rate_uj_s: f64,
    /// The configured budget, uJ/s.
    pub budget_uj_s: f64,
    /// Window-decay back-off hint, seconds (clamped to [1, 30]).
    pub retry_after_s: u64,
}

impl std::fmt::Display for EnergyShed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "energy budget exceeded: observed {:.3} uJ/s against a budget of {:.3} uJ/s",
            self.rate_uj_s, self.budget_uj_s
        )
    }
}

impl std::error::Error for EnergyShed {}

/// The engine's energy governor: rolling meter + budget + per-lane shed
/// counters.  All methods are `&self` (atomics + a mutexed ring), so
/// admission and worker threads share it without coordination.
#[derive(Debug)]
pub struct EnergyGovernor {
    meter: EnergyMeter,
    budget: EnergyBudget,
    started: Instant,
    /// Requests refused per lane (surfaced as
    /// `emtopt_governor_shed_total` on `/metrics`).
    shed_total: Vec<AtomicU64>,
}

impl EnergyGovernor {
    pub fn new(budget_uj_s: f64, n_lanes: usize) -> Self {
        EnergyGovernor {
            meter: EnergyMeter::new(GOVERNOR_WINDOW),
            budget: EnergyBudget { budget_uj_s },
            started: Instant::now(),
            shed_total: (0..n_lanes).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    fn now_us(&self) -> u64 {
        self.started.elapsed().as_micros() as u64
    }

    /// Record one dispatched batch's device energy (microjoules).
    pub fn record_uj(&self, uj: f64) {
        self.meter.record(self.now_us(), uj);
    }

    /// Rolling observed energy rate, uJ/s.
    pub fn rate_uj_s(&self) -> f64 {
        self.meter.rate_uj_s(self.now_us())
    }

    pub fn budget_uj_s(&self) -> f64 {
        self.budget.budget_uj_s
    }

    /// Requests this governor refused on `lane` so far.
    pub fn shed_count(&self, lane: usize) -> u64 {
        self.shed_total[lane].load(Ordering::Relaxed)
    }

    /// Admission check for a request on `lane` (0 = lowest priority):
    /// `Err(EnergyShed)` when the lane falls inside the current shed
    /// band, `Ok` otherwise.
    pub fn admit(&self, lane: usize) -> crate::Result<()> {
        let rate = self.rate_uj_s();
        let shed = self.budget.shed_lanes(rate, self.shed_total.len());
        if lane < shed {
            self.shed_total[lane].fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(EnergyShed {
                rate_uj_s: rate,
                budget_uj_s: self.budget.budget_uj_s,
                retry_after_s: self.budget.retry_after_s(rate, self.meter.window_s()),
            }));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn governor_sheds_lowest_lanes_when_over_budget() {
        // budget 1 uJ/s; record 100 uJ -> rolling rate ~50 uJ/s, far
        // over budget: lanes 0 and 1 shed, the top lane never does.
        // (Deterministic as long as the test finishes inside the 2 s
        // window, which it does by orders of magnitude.)
        let gov = EnergyGovernor::new(1.0, 3);
        assert!(gov.admit(0).is_ok(), "within budget nothing is shed");
        gov.record_uj(100.0);
        assert!(gov.rate_uj_s() > 10.0);
        let err = gov.admit(0).unwrap_err();
        let shed = err.downcast_ref::<EnergyShed>().expect("typed EnergyShed");
        assert!(shed.rate_uj_s > shed.budget_uj_s);
        assert!((1..=30).contains(&shed.retry_after_s));
        assert!(gov.admit(1).is_err(), "escalated shed covers the mid lane");
        assert!(gov.admit(2).is_ok(), "top lane is never energy-shed");
        assert_eq!(gov.shed_count(0), 1);
        assert_eq!(gov.shed_count(1), 1);
        assert_eq!(gov.shed_count(2), 0);
    }
}
