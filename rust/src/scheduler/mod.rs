//! Unified adaptive scheduler: ONE shared worker pool over per-lane
//! bounded queues, replacing the three statically-partitioned
//! `serve_native` engines the tiered HTTP front end used to spawn.
//!
//! ```text
//!              queue "low"   queue "normal"   queue "high"
//!  submit ──►  [bounded]     [bounded]        [bounded]
//!                   \             |              /
//!                    └──── shared worker pool ──┘
//!                     (home lanes + deficit-weighted stealing)
//!                          rebalancer  ·  EnergyGovernor
//! ```
//!
//! * **Work stealing.**  Every free worker picks the next lane by
//!   deficit-weighted round-robin over the non-empty queues
//!   ([`pick_lane`]): each eligible lane earns its rebalancer-set
//!   pressure weight as credit per pick and the winner pays the whole
//!   round, so pull frequency tracks load exactly, a burst on one tier
//!   is served by the whole pool, and — because every weight is
//!   floored at 1 — no backlogged lane can starve.  Ties favour the
//!   worker's *home* lane (the rebalancer's capacity assignment);
//!   serving a foreign lane is counted as a steal.
//! * **Rebalancer.**  A background loop (interval
//!   `NativeServerConfig::rebalance_interval`; [`Engine::rebalance_once`]
//!   steps it manually for deterministic tests) recomputes home
//!   assignments from live queue depth and the *windowed* p99 per lane
//!   — the tail of the current interval only, via
//!   [`crate::metrics::LatencyWindow`], so a slow cold start cannot skew
//!   pressure forever ([`rebalance::assign`]) — effective capacity
//!   follows load.
//! * **Energy governor.**  With `NativeServerConfig::energy_budget_uj_s`
//!   set, admission consults an [`EnergyGovernor`]: when the rolling
//!   observed uJ/s exceeds the budget, the lowest-priority lanes shed
//!   with the typed [`EnergyShed`] error (HTTP `503` + `Retry-After`).
//! * **Drain.**  [`Engine::begin_drain`] freezes the rebalancer and
//!   switches the pool to strict highest-priority-first pulls, so a
//!   graceful shutdown flushes premium work before cheap work.
//!
//! **Determinism.**  Work stealing cannot change results: every served
//! image draws its noise from the content-derived seed
//! `image_seed(lane_seed, pixels)` (`coordinator::router`), which
//! depends only on the image bytes and its lane — never on which worker
//! ran it, how the pool batched it, or what the rebalancer did in
//! between.  The batch-parity suites pin this end to end.

pub mod governor;
pub mod rebalance;

pub use governor::{EnergyGovernor, EnergyShed};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::router::{image_seed, NativeServerConfig, Overloaded, ServerStats};
use crate::crossbar::ReadCounters;
use crate::device::DeviceConfig;
use crate::energy::EnergyPlan;
use crate::inference::{NoisyModel, SlabPool};
use crate::metrics::LatencyWindow;
use crate::pool::BufferPool;
use crate::trace::{SpanRecord, Stage, TraceContext};
use crate::Result;

/// One reply off the engine: the request's concatenated per-image logits
/// plus its span record so far.  The scheduler fills queue/batch/compute
/// spans, worker/steal attribution and per-request energy; the HTTP
/// layer completes `write_us`/`total_us` and feeds the flight recorder.
///
/// The result cache (`server::cache`) memoizes successful replies off
/// the completion path: `logits` becomes the cached value verbatim, and
/// `span.images`/`span.energy_uj` become the entry's image count and
/// saved-energy credit.  Errors never produce a `Reply`, so they can
/// never be cached.
pub struct Reply {
    pub logits: Vec<f32>,
    pub span: SpanRecord,
}

/// Where a finished [`Reply`] goes.
///
/// * [`ReplySink::Rendezvous`] — the classic blocking path
///   (`serve_native`, CLI, tests): the submitter parks on an mpsc
///   receiver until its reply lands.
/// * [`ReplySink::Completion`] — the event-loop path: workers push the
///   keyed result onto a shared [`CompletionQueue`] and fire its wakeup
///   hook (an `eventfd` write).  Nothing ever blocks a compute worker
///   on a slow HTTP reader.
pub(crate) enum ReplySink {
    Rendezvous(mpsc::Sender<Result<Reply>>),
    Completion { cq: Arc<CompletionQueue>, key: u64 },
}

impl ReplySink {
    fn deliver(&self, result: Result<Reply>) {
        match self {
            ReplySink::Rendezvous(tx) => {
                let _ = tx.send(result);
            }
            ReplySink::Completion { cq, key } => cq.push(*key, result),
        }
    }
}

/// Non-blocking reply mailbox between the worker pool and the event
/// loop.  Workers [`push`](CompletionQueue::push) keyed results and call
/// the notify hook; the loop [`drain`](CompletionQueue::drain)s after
/// each wakeup.  Keys are loop-chosen (connection slot + generation) so
/// a completion for a since-closed connection is detectable and cheap
/// to drop.
pub struct CompletionQueue {
    items: Mutex<Vec<(u64, Result<Reply>)>>,
    notify: Box<dyn Fn() + Send + Sync>,
}

impl CompletionQueue {
    pub fn new(notify: Box<dyn Fn() + Send + Sync>) -> Arc<CompletionQueue> {
        Arc::new(CompletionQueue {
            items: Mutex::new(Vec::new()),
            notify,
        })
    }

    pub fn push(&self, key: u64, result: Result<Reply>) {
        {
            let mut items = self.items.lock().expect("completion queue poisoned");
            items.push((key, result));
        }
        (self.notify)();
    }

    /// Take everything delivered since the last drain.
    pub fn drain(&self) -> Vec<(u64, Result<Reply>)> {
        let mut items = self.items.lock().expect("completion queue poisoned");
        std::mem::take(&mut *items)
    }
}

/// One scheduling lane: the per-layer energy plan its reads use and the
/// RNG lane seed its images derive their noise streams from.  Lane
/// index doubles as drain/shed priority — index 0 is the lowest
/// priority (shed first, drained last).
#[derive(Clone, Debug)]
pub struct LaneSpec {
    pub plan: EnergyPlan,
    pub seed: u64,
}

/// One queued request: one or more images plus the reply slot for the
/// concatenated per-image logits.
struct WorkItem {
    /// `count * d_in` row-major pixels.
    images: Vec<f32>,
    count: usize,
    reply: ReplySink,
    enqueued: Instant,
    /// Trace identity minted at HTTP parse time (id + recorder-epoch
    /// start timestamp); internal for non-HTTP callers.
    trace_id: u64,
    start_us: u64,
    /// When a worker pulled this item off its lane queue (queue_wait
    /// ends here; batch_wait runs from here to dispatch).
    picked: Option<Instant>,
}

/// Per-lane engine state outside the scheduler mutex.
struct Lane {
    plan: EnergyPlan,
    seed: u64,
    stats: Arc<ServerStats>,
    /// Batches of this lane executed by a worker homed elsewhere.
    steals: AtomicU64,
    /// Lock-free mirror of the lane's queue length (the true per-lane
    /// depth gauge on `/metrics`; updated on every push and pull).
    queue_len: AtomicU64,
    /// Rebalancer-owned delta window over `stats.latency`: pressure uses
    /// the p99 of the *current rebalance interval*, not the cumulative
    /// histogram (which never forgets — one slow cold start would skew
    /// this lane's pressure score forever).  Only `rebalance_shared`
    /// advances it.
    p99_window: Mutex<LatencyWindow>,
}

/// Mutable scheduling state (one mutex: queues are popped in batches and
/// the real work — crossbar reads — happens outside the lock).
struct Sched {
    queues: Vec<VecDeque<WorkItem>>,
    /// Blocking-mode submissions from the event loop that found their
    /// lane queue full.  The loop must never block, so instead of
    /// waiting on `space_cv` the item parks here and a worker promotes
    /// it into the bounded queue as space frees (FIFO per lane).
    /// Bounded implicitly by the front end's `--max-conns` — each
    /// connection has at most one request in flight.
    parked: Vec<VecDeque<WorkItem>>,
    /// Worker index -> home lane.
    homes: Vec<usize>,
    /// Per-lane steal weights (rebalancer-set pressure scores).
    weights: Vec<f64>,
    /// Deficit-round-robin credits for the steal pick.
    deficits: Vec<f64>,
    stopped: bool,
}

struct Shared {
    model: Arc<NoisyModel>,
    device: DeviceConfig,
    batch: usize,
    max_wait: Duration,
    queue_depth: usize,
    lanes: Vec<Lane>,
    sched: Mutex<Sched>,
    /// Signalled on push, drain and stop (workers wait here).
    work_cv: Condvar,
    /// Signalled on pull (blocking submitters wait here for queue space).
    space_cv: Condvar,
    /// Signalled on stop only: the rebalancer sleeps here, so per-submit
    /// `work_cv` notifications never wake it on the hot path.
    rebalance_cv: Condvar,
    draining: AtomicBool,
    rebalance_moves: AtomicU64,
    governor: Option<EnergyGovernor>,
    /// Size-classed buffer pool of the zero-alloc serve path (pixel
    /// arenas, reply logits; the HTTP front end shares it for bodies
    /// and rendered responses).  Disabled (`--no-alloc-pool`) it is a
    /// pure passthrough to fresh allocations.
    pool: Arc<BufferPool>,
    /// Recycled [`BatchSlab`](crate::inference::BatchSlab) arenas for
    /// the layer-major forward (activation ping-pong, RNG/counter
    /// slabs, MAC scratch).  Only consulted while `pool` is enabled.
    slabs: SlabPool,
}

/// Stops the engine when the last clone drops: workers finish the
/// queued work, then exit (mirrors the old channel-disconnect shutdown).
struct StopToken {
    shared: Arc<Shared>,
}

impl Drop for StopToken {
    fn drop(&mut self) {
        let parked: Vec<WorkItem> = match self.shared.sched.lock() {
            Ok(mut s) => {
                s.stopped = true;
                // queued work still drains (workers finish the queues
                // before exiting), but parked items will never be
                // promoted once stopped — fail them now
                s.parked.iter_mut().flat_map(|q| q.drain(..)).collect()
            }
            Err(_) => Vec::new(),
        };
        for item in &parked {
            item.reply.deliver(Err(anyhow::anyhow!("server stopped")));
        }
        self.shared.work_cv.notify_all();
        self.shared.space_cv.notify_all();
        self.shared.rebalance_cv.notify_all();
    }
}

/// Handle to a running engine (clonable; the engine stops when the last
/// clone — including every client built over it — is dropped).
#[derive(Clone)]
pub struct Engine {
    shared: Arc<Shared>,
    _stop: Arc<StopToken>,
}

/// Point-in-time scheduler observability, rendered on `/metrics`.
#[derive(Clone, Debug)]
pub struct EngineSnapshot {
    /// Per-lane state, in lane (priority) order.
    pub lanes: Vec<LaneSnapshot>,
    /// Cumulative workers moved between homes by the rebalancer.
    pub rebalance_moves: u64,
    /// `(rolling observed uJ/s, budget uJ/s)` when the governor is armed.
    pub energy: Option<(f64, f64)>,
    pub draining: bool,
}

/// One lane's slice of an [`EngineSnapshot`].
#[derive(Clone, Debug)]
pub struct LaneSnapshot {
    /// Requests currently waiting in the lane's bounded queue (the true
    /// per-lane depth, not the submitted-minus-replied derivation).
    pub queue_len: usize,
    /// Workers currently homed on this lane (effective capacity share).
    pub effective_workers: usize,
    /// Batches served for this lane by workers homed elsewhere.
    pub steals: u64,
    /// Requests the energy governor refused on this lane.
    pub governor_shed: u64,
}

impl Engine {
    /// Spawn the shared pool (plus the rebalancer when there is more
    /// than one lane and `cfg.rebalance_interval` is non-zero) over one
    /// immutable model.  `cfg.plan`/`cfg.seed` are ignored in favour of
    /// the per-lane specs.  Returns the engine handle and every thread
    /// handle (join them after dropping the engine and its clients).
    pub fn start(
        model: Arc<NoisyModel>,
        cfg: &NativeServerConfig,
        lanes: Vec<LaneSpec>,
    ) -> Result<(Engine, Vec<std::thread::JoinHandle<()>>)> {
        anyhow::ensure!(!lanes.is_empty(), "engine needs at least one lane");
        anyhow::ensure!(cfg.batch > 0, "batch must be positive");
        anyhow::ensure!(cfg.workers > 0, "need at least one worker");
        anyhow::ensure!(cfg.queue_depth > 0, "queue_depth must be positive");
        for (i, l) in lanes.iter().enumerate() {
            l.plan
                .validate(model.layers().len())
                .map_err(|e| anyhow::anyhow!("lane {i}: {e}"))?;
        }
        if let Some(b) = cfg.energy_budget_uj_s {
            anyhow::ensure!(
                b.is_finite() && b > 0.0,
                "energy budget must be a positive uJ/s value, got {b}"
            );
        }
        let n = lanes.len();
        let governor = cfg.energy_budget_uj_s.map(|b| EnergyGovernor::new(b, n));
        let shared = Arc::new(Shared {
            model,
            device: cfg.device.clone(),
            batch: cfg.batch,
            max_wait: cfg.max_wait,
            queue_depth: cfg.queue_depth,
            lanes: lanes
                .into_iter()
                .map(|l| Lane {
                    plan: l.plan,
                    seed: l.seed,
                    stats: Arc::new(ServerStats::default()),
                    steals: AtomicU64::new(0),
                    queue_len: AtomicU64::new(0),
                    p99_window: Mutex::new(LatencyWindow::new()),
                })
                .collect(),
            sched: Mutex::new(Sched {
                queues: (0..n).map(|_| VecDeque::new()).collect(),
                parked: (0..n).map(|_| VecDeque::new()).collect(),
                homes: (0..cfg.workers).map(|w| w % n).collect(),
                weights: vec![1.0; n],
                deficits: vec![0.0; n],
                stopped: false,
            }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            rebalance_cv: Condvar::new(),
            draining: AtomicBool::new(false),
            rebalance_moves: AtomicU64::new(0),
            governor,
            pool: Arc::new(BufferPool::new(cfg.alloc_pool)),
            slabs: SlabPool::new(),
        });
        let mut handles = Vec::with_capacity(cfg.workers + 1);
        for w in 0..cfg.workers {
            let sh = shared.clone();
            handles.push(std::thread::spawn(move || worker_loop(&sh, w)));
        }
        if n > 1 && !cfg.rebalance_interval.is_zero() {
            let sh = shared.clone();
            let interval = cfg.rebalance_interval;
            handles.push(std::thread::spawn(move || rebalancer_loop(&sh, interval)));
        }
        let engine = Engine {
            _stop: Arc::new(StopToken {
                shared: shared.clone(),
            }),
            shared,
        };
        Ok((engine, handles))
    }

    pub fn n_lanes(&self) -> usize {
        self.shared.lanes.len()
    }

    pub fn d_in(&self) -> usize {
        self.shared.model.d_in()
    }

    pub fn d_out(&self) -> usize {
        self.shared.model.d_out()
    }

    /// The lane's stats handle (same [`ServerStats`] contract the old
    /// per-tier engines exposed).
    pub fn stats(&self, lane: usize) -> &Arc<ServerStats> {
        &self.shared.lanes[lane].stats
    }

    pub fn plan(&self, lane: usize) -> &EnergyPlan {
        &self.shared.lanes[lane].plan
    }

    pub fn energy_budget_uj_s(&self) -> Option<f64> {
        self.shared.governor.as_ref().map(|g| g.budget_uj_s())
    }

    /// The engine's shared serve-path buffer pool (the HTTP front end
    /// recycles request bodies and rendered responses through it; its
    /// counters feed `emtopt_alloc_pool_*` on `/metrics`).
    pub fn alloc_pool(&self) -> &Arc<BufferPool> {
        &self.shared.pool
    }

    /// Freeze rebalancing and switch the pool to strict
    /// highest-priority-first pulls (graceful-shutdown drain order).
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        // wake accumulating workers so partial batches flush immediately
        self.shared.work_cv.notify_all();
    }

    /// One rebalance step (the background loop calls this on its
    /// interval; tests call it directly for a deterministic clock).
    /// Returns the number of workers moved; a no-op while draining.
    pub fn rebalance_once(&self) -> usize {
        rebalance_shared(&self.shared)
    }

    /// Scheduler observability for `/metrics`.
    pub fn snapshot(&self) -> EngineSnapshot {
        let homes = {
            let s = self.shared.sched.lock().expect("scheduler poisoned");
            s.homes.clone()
        };
        let mut eff = vec![0usize; self.shared.lanes.len()];
        for &h in &homes {
            eff[h] += 1;
        }
        EngineSnapshot {
            lanes: self
                .shared
                .lanes
                .iter()
                .enumerate()
                .map(|(i, lane)| LaneSnapshot {
                    queue_len: lane.queue_len.load(Ordering::Relaxed) as usize,
                    effective_workers: eff[i],
                    steals: lane.steals.load(Ordering::Relaxed),
                    governor_shed: self
                        .shared
                        .governor
                        .as_ref()
                        .map_or(0, |g| g.shed_count(i)),
                })
                .collect(),
            rebalance_moves: self.shared.rebalance_moves.load(Ordering::Relaxed),
            energy: self
                .shared
                .governor
                .as_ref()
                .map(|g| (g.rate_uj_s(), g.budget_uj_s())),
            draining: self.shared.draining.load(Ordering::SeqCst),
        }
    }

    /// Submit `count` images to `lane`; returns the reply receiver.
    /// Admission order: governor (typed [`EnergyShed`]) first, then the
    /// lane's bounded queue — full means a typed [`Overloaded`] error
    /// (`block == false`) or waiting for space (`block == true`).
    /// `tctx` is the request's trace identity (use
    /// [`TraceContext::internal`] for non-HTTP callers).
    pub(crate) fn submit(
        &self,
        lane: usize,
        images: Vec<f32>,
        count: usize,
        block: bool,
        tctx: &TraceContext,
    ) -> Result<mpsc::Receiver<Result<Reply>>> {
        let shared = &self.shared;
        if let Some(gov) = &shared.governor {
            gov.admit(lane)?;
        }
        let (reply, rx) = mpsc::channel();
        let item = WorkItem {
            images,
            count,
            reply: ReplySink::Rendezvous(reply),
            enqueued: Instant::now(),
            trace_id: tctx.trace_id,
            start_us: tctx.start_us,
            picked: None,
        };
        let mut s = shared.sched.lock().expect("scheduler poisoned");
        loop {
            anyhow::ensure!(!s.stopped, "server stopped");
            if s.queues[lane].len() < shared.queue_depth {
                break;
            }
            if !block {
                return Err(anyhow::Error::new(Overloaded));
            }
            s = shared.space_cv.wait(s).expect("scheduler poisoned");
        }
        s.queues[lane].push_back(item);
        shared.lanes[lane]
            .queue_len
            .store(s.queues[lane].len() as u64, Ordering::Relaxed);
        shared.lanes[lane]
            .stats
            .submitted
            .fetch_add(1, Ordering::Relaxed);
        drop(s);
        shared.work_cv.notify_all();
        Ok(rx)
    }

    /// Event-loop submission: the reply lands on `cq` under `key`
    /// instead of a rendezvous channel, and this call NEVER blocks.
    /// Admission mirrors [`Engine::submit`] — governor first, then the
    /// bounded queue — except that `block == true` with a full queue
    /// *parks* the item (FIFO per lane) rather than waiting; a worker
    /// promotes parked items as space frees.  `block == false` with a
    /// full queue is still a typed [`Overloaded`] error, answered
    /// synchronously so the 503 carries live `Retry-After` stats.
    pub(crate) fn submit_async(
        &self,
        lane: usize,
        images: Vec<f32>,
        count: usize,
        block: bool,
        tctx: &TraceContext,
        cq: &Arc<CompletionQueue>,
        key: u64,
    ) -> Result<()> {
        let shared = &self.shared;
        if let Some(gov) = &shared.governor {
            gov.admit(lane)?;
        }
        let item = WorkItem {
            images,
            count,
            reply: ReplySink::Completion {
                cq: cq.clone(),
                key,
            },
            enqueued: Instant::now(),
            trace_id: tctx.trace_id,
            start_us: tctx.start_us,
            picked: None,
        };
        let mut s = shared.sched.lock().expect("scheduler poisoned");
        anyhow::ensure!(!s.stopped, "server stopped");
        if s.queues[lane].len() < shared.queue_depth {
            s.queues[lane].push_back(item);
            shared.lanes[lane]
                .queue_len
                .store(s.queues[lane].len() as u64, Ordering::Relaxed);
            shared.lanes[lane]
                .stats
                .submitted
                .fetch_add(1, Ordering::Relaxed);
            drop(s);
            shared.work_cv.notify_all();
        } else if block {
            s.parked[lane].push_back(item);
            shared.lanes[lane]
                .stats
                .submitted
                .fetch_add(1, Ordering::Relaxed);
        } else {
            return Err(anyhow::Error::new(Overloaded));
        }
        Ok(())
    }
}

/// Choose the lane a free worker should serve, or `None` when every
/// queue is empty.  Draining: strictly highest-priority-first (highest
/// lane index), so a graceful shutdown flushes premium work before
/// cheap work.  Normal operation: deficit-weighted round-robin across
/// the non-empty lanes — every eligible lane earns its weight as
/// credit, the winner pays the whole round — so pull frequency tracks
/// the rebalancer's pressure weights, and since every weight is
/// floored at 1 a backlogged lane always wins within a bounded number
/// of rounds (no starvation, unlike a naive home-queue-first pick).
/// Credit ties favour the worker's home lane.  Returns the lane and
/// whether the pick was a steal (a lane other than the worker's home).
fn pick_lane(s: &mut Sched, worker: usize, draining: bool) -> Option<(usize, bool)> {
    if draining {
        // a drain flush is priority policy, not work stealing: never
        // counted as a steal, whatever the worker's home is
        return (0..s.queues.len())
            .rev()
            .find(|&l| !s.queues[l].is_empty())
            .map(|l| (l, false));
    }
    let eligible: Vec<usize> = (0..s.queues.len())
        .filter(|&l| !s.queues[l].is_empty())
        .collect();
    if eligible.is_empty() {
        return None;
    }
    let home = s.homes[worker];
    let round: f64 = eligible.iter().map(|&l| s.weights[l]).sum();
    let mut best = eligible[0];
    for &l in &eligible {
        s.deficits[l] += s.weights[l];
        if l != best
            && (s.deficits[l] > s.deficits[best]
                || (s.deficits[l] == s.deficits[best] && l == home))
        {
            best = l;
        }
    }
    s.deficits[best] -= round;
    Some((best, best != home))
}

/// One worker of the shared pool: pick a lane, collect one device batch
/// from its queue, run it against the shared model.
fn worker_loop(shared: &Shared, worker: usize) {
    loop {
        let mut s = shared.sched.lock().expect("scheduler poisoned");
        // wait for work anywhere (or the stop flag + drained queues)
        let (lane, stolen) = loop {
            let draining = shared.draining.load(Ordering::SeqCst);
            if let Some((lane, stolen)) = pick_lane(&mut s, worker, draining) {
                if stolen {
                    shared.lanes[lane].steals.fetch_add(1, Ordering::Relaxed);
                }
                break (lane, stolen);
            }
            if s.stopped {
                return;
            }
            s = shared.work_cv.wait(s).expect("scheduler poisoned");
        };
        // Collect one device batch: a multi-image request always runs
        // alone (the express path — it already is a batch); singles
        // accumulate up to `batch`, waiting out `max_wait` for
        // stragglers (classic dynamic batching) unless the engine is
        // draining or stopping.  Arrival order within a lane is
        // preserved: singles queued ahead of a multi dispatch first.
        let mut items: Vec<WorkItem> = Vec::new();
        if s.queues[lane].front().is_some_and(|r| r.count > 1) {
            let mut it = s.queues[lane].pop_front().expect("checked non-empty");
            it.picked = Some(Instant::now());
            items.push(it);
        } else {
            let deadline = Instant::now() + shared.max_wait;
            loop {
                while items.len() < shared.batch {
                    match s.queues[lane].front() {
                        Some(r) if r.count == 1 => {
                            let mut it = s.queues[lane].pop_front().expect("checked front");
                            it.picked = Some(Instant::now());
                            items.push(it);
                        }
                        _ => break, // empty, or a multi that must run alone
                    }
                }
                if items.len() >= shared.batch
                    || s.stopped
                    || shared.draining.load(Ordering::SeqCst)
                    || s.queues[lane].front().is_some_and(|r| r.count > 1)
                {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _timeout) = shared
                    .work_cv
                    .wait_timeout(s, deadline - now)
                    .expect("scheduler poisoned");
                s = guard;
            }
        }
        // the pulls above freed queue space: promote parked event-loop
        // submissions (blocking mode) into their bounded queues, FIFO
        let promoted = promote_parked(shared, &mut s);
        shared.lanes[lane]
            .queue_len
            .store(s.queues[lane].len() as u64, Ordering::Relaxed);
        drop(s);
        shared.space_cv.notify_all();
        if promoted {
            shared.work_cv.notify_all();
        }
        run_batch(shared, lane, worker, stolen, items);
    }
}

/// Move parked (blocking, event-loop) submissions into their lane's
/// bounded queue while space allows.  Caller holds the scheduler lock.
fn promote_parked(shared: &Shared, s: &mut Sched) -> bool {
    let mut promoted = false;
    for l in 0..shared.lanes.len() {
        if s.parked[l].is_empty() {
            continue;
        }
        while s.queues[l].len() < shared.queue_depth {
            match s.parked[l].pop_front() {
                Some(item) => {
                    s.queues[l].push_back(item);
                    promoted = true;
                }
                None => break,
            }
        }
        shared.lanes[l]
            .queue_len
            .store(s.queues[l].len() as u64, Ordering::Relaxed);
    }
    promoted
}

/// Execute one collected batch on the shared model and fan the per-image
/// logits back to the callers (identical accounting to the old per-lane
/// engines; per-image noise seeds stay content-derived, so results are
/// independent of which worker ran the batch).  Each reply carries the
/// request's span record: queue_wait (enqueue→pick), batch_wait
/// (pick→dispatch), compute (whole-batch forward wall time — what the
/// rider actually waited on), plus the request's own samples' observed
/// energy and per-layer breakdown from the traced forward.
fn run_batch(
    shared: &Shared,
    lane_idx: usize,
    worker: usize,
    stolen: bool,
    mut items: Vec<WorkItem>,
) {
    let lane = &shared.lanes[lane_idx];
    let model = &shared.model;
    let d_in = model.d_in();
    let nc = model.d_out();
    let n_images: usize = items.iter().map(|r| r.count).sum();
    // pixel arena: pooled capacity, zero-filled to the packed length
    // (a recycled buffer comes back empty, so resize refills every slot)
    let mut x = shared.pool.get_f32(n_images * d_in);
    x.resize(n_images * d_in, 0.0);
    let mut seeds = Vec::with_capacity(n_images);
    let mut off = 0usize;
    for r in &items {
        x[off * d_in..off * d_in + r.images.len()].copy_from_slice(&r.images);
        for i in 0..r.count {
            seeds.push(image_seed(lane.seed, &r.images[i * d_in..(i + 1) * d_in]));
        }
        off += r.count;
    }
    // the parsed pixel vecs are dead once packed: recycle them so the
    // HTTP parser's next get_f32 is a pool hit
    for r in &mut items {
        shared.pool.put_f32(std::mem::take(&mut r.images));
    }
    let t0 = Instant::now();
    let mut counters = ReadCounters::default();
    let (logits, traces) = if shared.pool.enabled() {
        model.forward_batch_seeds_traced_pooled(
            &x,
            &lane.plan,
            &shared.device,
            &seeds,
            &mut counters,
            &shared.slabs,
        )
    } else {
        model.forward_batch_seeds_traced(&x, &lane.plan, &shared.device, &seeds, &mut counters)
    };
    let infer_us = t0.elapsed().as_micros() as u64;

    let stats = &lane.stats;
    stats.requests.fetch_add(items.len() as u64, Ordering::Relaxed);
    stats.images.fetch_add(n_images as u64, Ordering::Relaxed);
    stats.batches.fetch_add(1, Ordering::Relaxed);
    stats
        .padded_slots
        .fetch_add(shared.batch.saturating_sub(n_images) as u64, Ordering::Relaxed);
    stats.infer_us.fetch_add(infer_us, Ordering::Relaxed);
    stats.dispatch_batch_sizes.record(n_images as u64);
    stats.add_counters(&counters);
    if let Some(gov) = &shared.governor {
        gov.record_uj(counters.total_pj() * 1e-6);
    }

    let mut off = 0usize;
    for r in &items {
        if r.count > 1 {
            stats.client_batch_requests.fetch_add(1, Ordering::Relaxed);
        }
        let total_us = r.enqueued.elapsed().as_micros() as u64;
        stats.queue_us.fetch_add(total_us, Ordering::Relaxed);
        stats.latency.record_us(total_us);

        let queue_wait_us = r
            .picked
            .map_or(0, |p| p.duration_since(r.enqueued).as_micros() as u64);
        let batch_wait_us = r
            .picked
            .map_or(0, |p| t0.duration_since(p).as_micros() as u64);
        let mut span = SpanRecord {
            trace_id: r.trace_id,
            start_us: r.start_us,
            tier: lane_idx,
            worker,
            stolen,
            batch_images: n_images,
            images: r.count,
            queue_wait_us,
            batch_wait_us,
            compute_us: infer_us,
            ..SpanRecord::default()
        };
        for t in &traces[off..off + r.count] {
            span.energy_uj += t.counters.total_pj() * 1e-6;
            span.layers.merge(&t.layers);
        }
        stats.stages.record(Stage::QueueWait, queue_wait_us);
        stats.stages.record(Stage::BatchWait, batch_wait_us);
        stats.stages.record(Stage::Compute, infer_us);

        // per-reply logits: pooled capacity instead of a fresh clone
        let mut out = shared.pool.get_f32(r.count * nc);
        out.extend_from_slice(&logits[off * nc..(off + r.count) * nc]);
        r.reply.deliver(Ok(Reply { logits: out, span }));
        off += r.count;
    }
    shared.pool.put_f32(x);
    shared.pool.put_f32(logits);
}

/// One rebalance step over the live queue depths and per-lane *windowed*
/// p99s (the tail of requests completed since the previous step — see
/// `Lane::p99_window`).
fn rebalance_shared(shared: &Shared) -> usize {
    if shared.draining.load(Ordering::SeqCst) {
        return 0; // capacity is frozen during a drain
    }
    let mut s = shared.sched.lock().expect("scheduler poisoned");
    if s.stopped {
        return 0;
    }
    let loads: Vec<rebalance::LaneLoad> = shared
        .lanes
        .iter()
        .enumerate()
        .map(|(i, lane)| rebalance::LaneLoad {
            queue_len: s.queues[i].len(),
            p99_us: lane
                .p99_window
                .lock()
                .expect("p99 window poisoned")
                .advance_quantile_us(&lane.stats.latency, 0.99),
        })
        .collect();
    let (homes, weights, moves) = rebalance::assign(&s.homes, &loads);
    s.homes = homes;
    s.weights = weights;
    drop(s);
    if moves > 0 {
        shared.rebalance_moves.fetch_add(moves as u64, Ordering::Relaxed);
    }
    moves
}

/// Background rebalancer: one [`rebalance_shared`] step per interval,
/// waking early only for the stop flag (its own condvar — per-request
/// `work_cv` traffic never touches this thread).
fn rebalancer_loop(shared: &Shared, interval: Duration) {
    loop {
        let deadline = Instant::now() + interval;
        let mut s = shared.sched.lock().expect("scheduler poisoned");
        loop {
            if s.stopped {
                return;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _timeout) = shared
                .rebalance_cv
                .wait_timeout(s, deadline - now)
                .expect("scheduler poisoned");
            s = guard;
        }
        drop(s);
        rebalance_shared(shared);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_item(count: usize) -> WorkItem {
        let (reply, _rx) = mpsc::channel();
        WorkItem {
            images: vec![0.0; count],
            count,
            reply: ReplySink::Rendezvous(reply),
            enqueued: Instant::now(),
            trace_id: 0,
            start_us: 0,
            picked: None,
        }
    }

    fn sched_with(queued: &[usize]) -> Sched {
        Sched {
            queues: queued
                .iter()
                .map(|&n| (0..n).map(|_| dummy_item(1)).collect())
                .collect(),
            parked: queued.iter().map(|_| VecDeque::new()).collect(),
            homes: vec![0],
            weights: vec![1.0; queued.len()],
            deficits: vec![0.0; queued.len()],
            stopped: false,
        }
    }

    #[test]
    fn completion_queue_push_notifies_and_drains() {
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        let cq = CompletionQueue::new(Box::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        }));
        let sink = ReplySink::Completion {
            cq: cq.clone(),
            key: 42,
        };
        sink.deliver(Err(anyhow::anyhow!("boom")));
        assert_eq!(hits.load(Ordering::Relaxed), 1, "push fires the wakeup hook");
        let items = cq.drain();
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].0, 42);
        assert!(items[0].1.is_err());
        assert!(cq.drain().is_empty(), "drain takes everything");
    }

    #[test]
    fn drain_prefers_highest_priority_lane() {
        // ISSUE 5 satellite: drain order is highest-priority-first, not
        // lane-creation order — lane 2 flushes before lane 0.  Drain
        // flushes are priority policy, never counted as steals.
        let mut s = sched_with(&[2, 0, 1]);
        assert_eq!(pick_lane(&mut s, 0, true), Some((2, false)));
        s.queues[2].clear();
        assert_eq!(pick_lane(&mut s, 0, true), Some((0, false)));
        s.queues[0].clear();
        assert_eq!(pick_lane(&mut s, 0, true), None);
    }

    #[test]
    fn home_lane_wins_credit_ties() {
        // equal weights and credits: the worker's home lane takes the
        // pick (capacity bias without starving anyone)
        let mut s = sched_with(&[1, 1, 1]);
        s.homes = vec![1];
        assert_eq!(pick_lane(&mut s, 0, false), Some((1, false)));
    }

    #[test]
    fn saturated_home_cannot_starve_other_lanes() {
        // the regression the DRR pick exists for: a worker homed on a
        // lane whose queue never empties must still serve the others
        // within a bounded number of rounds
        let mut s = sched_with(&[8, 0, 1]);
        s.weights = vec![9.0, 1.0, 1.0]; // rebalancer marked lane 0 hot
        let mut served_high = false;
        for _ in 0..32 {
            let (lane, _) = pick_lane(&mut s, 0, false).unwrap();
            if lane == 2 {
                served_high = true;
                break;
            }
        }
        assert!(served_high, "lane 2 starved behind the saturated home lane");
    }

    #[test]
    fn steal_pick_follows_weights() {
        // home (lane 0) empty; lanes 1 and 2 non-empty with weights 1:3
        // -> over 8 picks the deficit round-robin serves them 2:6
        let mut s = sched_with(&[0, 4, 4]);
        s.weights = vec![1.0, 1.0, 3.0];
        let mut picks = [0usize; 3];
        for _ in 0..8 {
            let (lane, stolen) = pick_lane(&mut s, 0, false).unwrap();
            assert!(stolen, "home is empty: every pick is a steal");
            picks[lane] += 1;
        }
        assert_eq!(picks, [0, 2, 6], "weighted round-robin must hold exactly");
    }
}
