//! Result reporting: aligned table printing + experiment records.

/// A printable results table (paper-style).
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row.iter()) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, (c, w)) in cells.iter().zip(widths.iter()).enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{c:<w$}"));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Human-readable energy (uJ with magnitude-aware precision, paper style:
/// "4.1", "36", "23k").
pub fn fmt_energy_uj(uj: f64) -> String {
    if uj >= 10_000.0 {
        format!("{:.0}k", uj / 1000.0)
    } else if uj >= 100.0 {
        format!("{uj:.0}")
    } else if uj >= 10.0 {
        format!("{uj:.0}")
    } else {
        format!("{uj:.1}")
    }
}

/// Cell count, paper style ("15M", "3.2M").
pub fn fmt_cells(cells: f64) -> String {
    let m = cells / 1e6;
    if m >= 10.0 {
        format!("{m:.0}M")
    } else {
        format!("{m:.1}M")
    }
}

/// Latency in us, paper style ("2.8", "14", "151").
pub fn fmt_delay_us(us: f64) -> String {
    if us >= 100.0 {
        format!("{us:.0}")
    } else if us >= 10.0 {
        format!("{us:.0}")
    } else {
        format!("{us:.1}")
    }
}

/// Percentage with one decimal.
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "Energy (uJ)"]);
        t.row(vec!["Ours (A+B)".into(), "36".into()]);
        t.row(vec!["Binarized".into(), "378".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("Ours (A+B)"));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_energy_uj(4.1234), "4.1");
        assert_eq!(fmt_energy_uj(36.2), "36");
        assert_eq!(fmt_energy_uj(23_000.0), "23k");
        assert_eq!(fmt_cells(15_000_000.0), "15M");
        assert_eq!(fmt_cells(3_200_000.0), "3.2M");
        assert_eq!(fmt_delay_us(2.8), "2.8");
        assert_eq!(fmt_delay_us(151.0), "151");
        assert_eq!(fmt_pct(0.936), "93.6%");
    }
}
