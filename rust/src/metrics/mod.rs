//! Result reporting: aligned table printing, experiment records, and the
//! fixed-bucket atomic latency histogram used by the serving stack.

use std::sync::atomic::{AtomicU64, Ordering};

/// Upper bounds (inclusive, microseconds) of the fixed latency buckets.
/// A final implicit overflow bucket catches everything above the last
/// bound.  Strict 1-2-5 log spacing from 1 us to 50 s covers both the
/// native engine (tens of us) and a heavily queued server (seconds);
/// `bounds_follow_1_2_5_progression` pins the spacing so a skipped bound
/// (the table once jumped 10 s -> 50 s) cannot silently coarsen the
/// quantiles again.
pub const LATENCY_BUCKET_BOUNDS_US: [u64; 24] = [
    1,
    2,
    5,
    10,
    20,
    50,
    100,
    200,
    500,
    1_000,
    2_000,
    5_000,
    10_000,
    20_000,
    50_000,
    100_000,
    200_000,
    500_000,
    1_000_000,
    2_000_000,
    5_000_000,
    10_000_000,
    20_000_000,
    50_000_000,
];

/// Bucket count including the overflow bucket.
pub const LATENCY_NUM_BUCKETS: usize = LATENCY_BUCKET_BOUNDS_US.len() + 1;

/// Quantile reported for observations that landed in the overflow bucket
/// (above the last bound): the last finite bound, with no interpolation.
/// The histogram cannot know how far past 50 s an observation went, so it
/// reports this documented sentinel instead of fabricating a value.
pub const LATENCY_OVERFLOW_REPORT_US: f64 =
    LATENCY_BUCKET_BOUNDS_US[LATENCY_BUCKET_BOUNDS_US.len() - 1] as f64;

/// Lock-free fixed-bucket latency histogram.
///
/// `record_us` is a single relaxed `fetch_add`, so any number of worker
/// threads can record concurrently; quantiles are read from a snapshot
/// with linear interpolation inside the winning bucket.  Bucket bounds
/// are static ([`LATENCY_BUCKET_BOUNDS_US`]), which keeps the type
/// allocation-free and `Default`-constructible inside `ServerStats`.
#[derive(Debug)]
pub struct LatencyHistogram {
    counts: [AtomicU64; LATENCY_NUM_BUCKETS],
    sum_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    /// Record one latency observation (microseconds).
    pub fn record_us(&self, us: u64) {
        let idx = LATENCY_BUCKET_BOUNDS_US
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(LATENCY_NUM_BUCKETS - 1);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Total number of recorded observations.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Sum of all recorded observations (microseconds) — the exact
    /// `_sum` a Prometheus histogram exposition needs, which bucket
    /// counts alone cannot reconstruct.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Per-bucket counts; the last entry is the overflow bucket.
    pub fn snapshot(&self) -> [u64; LATENCY_NUM_BUCKETS] {
        std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed))
    }

    /// Latency quantile in microseconds (`q` in [0, 1]), linearly
    /// interpolated inside the winning bucket.  Returns 0.0 when empty.
    /// A quantile that lands in the overflow bucket (observations above
    /// the last bound) reports [`LATENCY_OVERFLOW_REPORT_US`] — the last
    /// finite bound, explicitly uninterpolated, since the bucket has no
    /// upper edge to interpolate toward.
    pub fn quantile_us(&self, q: f64) -> f64 {
        latency_quantile_from_counts(&self.snapshot(), q)
    }

    pub fn p50_us(&self) -> f64 {
        self.quantile_us(0.50)
    }

    pub fn p95_us(&self) -> f64 {
        self.quantile_us(0.95)
    }

    pub fn p99_us(&self) -> f64 {
        self.quantile_us(0.99)
    }
}

/// Quantile over an explicit bucket-count array (the shared kernel of
/// [`LatencyHistogram::quantile_us`] and [`LatencyWindow`]).  Semantics
/// match `quantile_us`: 0.0 when empty, linear interpolation inside the
/// winning bucket, [`LATENCY_OVERFLOW_REPORT_US`] for the overflow
/// bucket.
pub fn latency_quantile_from_counts(counts: &[u64; LATENCY_NUM_BUCKETS], q: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = q.clamp(0.0, 1.0) * total as f64;
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let next = cum + c;
        if next as f64 >= target {
            if i == LATENCY_BUCKET_BOUNDS_US.len() {
                return LATENCY_OVERFLOW_REPORT_US;
            }
            let lower = if i == 0 {
                0
            } else {
                LATENCY_BUCKET_BOUNDS_US[i - 1]
            };
            let upper = LATENCY_BUCKET_BOUNDS_US[i];
            let frac = ((target - cum as f64) / c as f64).clamp(0.0, 1.0);
            return lower as f64 + frac * (upper - lower) as f64;
        }
        cum = next;
    }
    LATENCY_OVERFLOW_REPORT_US
}

/// Delta-window view over a [`LatencyHistogram`]: remembers the bucket
/// counts at the previous observation and computes quantiles over only
/// the samples recorded *since* — the signal the scheduler's rebalancer
/// wants.  The cumulative histogram never forgets, so one slow cold
/// start would otherwise skew a lane's p99 (and therefore its pressure
/// score) for the rest of the process lifetime.
///
/// An empty window reports 0.0: a lane that completed nothing in the
/// interval exerts no *tail* pressure (its backlog still shows up via
/// queue depth).  Cumulative fallback is deliberately avoided — it would
/// resurrect the cold-start skew for every idle interval.
#[derive(Clone, Debug, Default)]
pub struct LatencyWindow {
    prev: [u64; LATENCY_NUM_BUCKETS],
}

impl LatencyWindow {
    /// A fresh window: the first `advance_quantile_us` call covers every
    /// observation recorded so far.
    pub fn new() -> Self {
        Self::default()
    }

    /// Quantile over the observations recorded in `hist` since the last
    /// call, then advance the window to now.  Returns 0.0 for an empty
    /// window (see type docs).
    pub fn advance_quantile_us(&mut self, hist: &LatencyHistogram, q: f64) -> f64 {
        let now = hist.snapshot();
        let mut delta = [0u64; LATENCY_NUM_BUCKETS];
        for (d, (&n, &p)) in delta.iter_mut().zip(now.iter().zip(self.prev.iter())) {
            *d = n.saturating_sub(p);
        }
        self.prev = now;
        latency_quantile_from_counts(&delta, q)
    }
}

/// Upper bounds (inclusive, images) of the fixed batch-size buckets; a
/// final implicit overflow bucket catches anything larger.  Powers of two
/// up to the default device batch (16) and the default client batch cap
/// region beyond it.
pub const BATCH_SIZE_BUCKET_BOUNDS: [u64; 6] = [1, 2, 4, 8, 16, 32];

/// Bucket count including the overflow bucket.
pub const BATCH_SIZE_NUM_BUCKETS: usize = BATCH_SIZE_BUCKET_BOUNDS.len() + 1;

/// Lock-free fixed-bucket histogram of images per dispatched engine
/// batch — the serving stack's batch-amortisation signal (`/metrics`
/// shows whether traffic actually fills device batches or trickles
/// through one image at a time).
#[derive(Debug)]
pub struct BatchSizeHistogram {
    counts: [AtomicU64; BATCH_SIZE_NUM_BUCKETS],
}

impl Default for BatchSizeHistogram {
    fn default() -> Self {
        BatchSizeHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl BatchSizeHistogram {
    /// Record one dispatched batch of `n` images.
    pub fn record(&self, n: u64) {
        let idx = BATCH_SIZE_BUCKET_BOUNDS
            .iter()
            .position(|&b| n <= b)
            .unwrap_or(BATCH_SIZE_NUM_BUCKETS - 1);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Total number of recorded batches.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Per-bucket counts; the last entry is the overflow bucket.
    pub fn snapshot(&self) -> [u64; BATCH_SIZE_NUM_BUCKETS] {
        std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed))
    }
}

/// A printable results table (paper-style).
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row.iter()) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, (c, w)) in cells.iter().zip(widths.iter()).enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{c:<w$}"));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Human-readable energy (uJ with magnitude-aware precision, paper style:
/// "4.1", "36", "23k").
pub fn fmt_energy_uj(uj: f64) -> String {
    if uj >= 10_000.0 {
        format!("{:.0}k", uj / 1000.0)
    } else if uj >= 100.0 {
        format!("{uj:.0}")
    } else if uj >= 10.0 {
        format!("{uj:.0}")
    } else {
        format!("{uj:.1}")
    }
}

/// Cell count, paper style ("15M", "3.2M").
pub fn fmt_cells(cells: f64) -> String {
    let m = cells / 1e6;
    if m >= 10.0 {
        format!("{m:.0}M")
    } else {
        format!("{m:.1}M")
    }
}

/// Latency in us, paper style ("2.8", "14", "151").
pub fn fmt_delay_us(us: f64) -> String {
    if us >= 100.0 {
        format!("{us:.0}")
    } else if us >= 10.0 {
        format!("{us:.0}")
    } else {
        format!("{us:.1}")
    }
}

/// Percentage with one decimal.
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "Energy (uJ)"]);
        t.row(vec!["Ours (A+B)".into(), "36".into()]);
        t.row(vec!["Binarized".into(), "378".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("Ours (A+B)"));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn histogram_empty_safe() {
        let h = LatencyHistogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_us(0.5), 0.0);
        assert_eq!(h.p99_us(), 0.0);
    }

    #[test]
    fn histogram_tracks_exact_sum() {
        let h = LatencyHistogram::default();
        assert_eq!(h.sum_us(), 0);
        for us in [3u64, 8, 900, 90_000] {
            h.record_us(us);
        }
        assert_eq!(h.sum_us(), 3 + 8 + 900 + 90_000);
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn histogram_quantiles_interpolate() {
        let h = LatencyHistogram::default();
        // 1000 observations all in the (5, 10] bucket
        for _ in 0..1000 {
            h.record_us(8);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.p50_us();
        assert!(p50 > 5.0 && p50 <= 10.0, "p50={p50}");
        let p99 = h.p99_us();
        assert!(p99 > p50 && p99 <= 10.0, "p99={p99}");
    }

    #[test]
    fn histogram_spread_orders_quantiles() {
        let h = LatencyHistogram::default();
        // 90% fast (~10us), 5% medium (~1ms), 5% slow (~90ms)
        for _ in 0..900 {
            h.record_us(9);
        }
        for _ in 0..50 {
            h.record_us(900);
        }
        for _ in 0..50 {
            h.record_us(90_000);
        }
        let (p50, p95, p99) = (h.p50_us(), h.p95_us(), h.p99_us());
        assert!(p50 <= 10.0, "p50={p50}");
        assert!(p95 > 100.0 && p95 <= 1000.0, "p95={p95}");
        assert!(p99 > 10_000.0 && p99 <= 100_000.0, "p99={p99}");
    }

    #[test]
    fn bounds_follow_1_2_5_progression() {
        // strict 1-2-5 log spacing: consecutive ratios alternate 2x and
        // 2.5x, and every bound's leading digit is 1, 2, or 5
        for w in LATENCY_BUCKET_BOUNDS_US.windows(2) {
            let (a, b) = (w[0], w[1]);
            assert!(
                b == 2 * a || 2 * b == 5 * a,
                "bounds {a} -> {b} break the 1-2-5 progression"
            );
        }
        for &b in &LATENCY_BUCKET_BOUNDS_US {
            let mut m = b;
            while m % 10 == 0 {
                m /= 10;
            }
            assert!(matches!(m, 1 | 2 | 5), "bound {b} is not a 1-2-5 value");
        }
        // the once-missing 20 s bound is present, and the table spans
        // 1 us .. 50 s
        assert!(LATENCY_BUCKET_BOUNDS_US.contains(&20_000_000));
        assert_eq!(LATENCY_BUCKET_BOUNDS_US[0], 1);
        assert_eq!(*LATENCY_BUCKET_BOUNDS_US.last().unwrap(), 50_000_000);
    }

    #[test]
    fn histogram_overflow_bucket() {
        let h = LatencyHistogram::default();
        h.record_us(u64::MAX);
        assert_eq!(h.count(), 1);
        let last = *LATENCY_BUCKET_BOUNDS_US.last().unwrap() as f64;
        assert_eq!(LATENCY_OVERFLOW_REPORT_US, last);
        assert_eq!(h.quantile_us(0.5), LATENCY_OVERFLOW_REPORT_US);
        let snap = h.snapshot();
        assert_eq!(snap[LATENCY_NUM_BUCKETS - 1], 1);
    }

    #[test]
    fn histogram_all_overflow_reports_sentinel_at_any_q() {
        // all observations above the last bound: every quantile reports
        // the documented sentinel, never a zero-width interpolation below
        // or above it
        let h = LatencyHistogram::default();
        for _ in 0..100 {
            h.record_us(60_000_000);
        }
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_us(q), LATENCY_OVERFLOW_REPORT_US, "q={q}");
        }
    }

    #[test]
    fn histogram_quantile_extremes() {
        // a single observation in the (5, 10] bucket: q=0 pins the lower
        // edge, q=1 the upper edge, and everything between stays inside
        let h = LatencyHistogram::default();
        h.record_us(8);
        assert_eq!(h.quantile_us(0.0), 5.0);
        assert_eq!(h.quantile_us(1.0), 10.0);
        let mid = h.quantile_us(0.5);
        assert!((5.0..=10.0).contains(&mid), "mid={mid}");
        // out-of-range q clamps rather than panicking
        assert_eq!(h.quantile_us(-1.0), h.quantile_us(0.0));
        assert_eq!(h.quantile_us(2.0), h.quantile_us(1.0));
    }

    #[test]
    fn latency_window_forgets_cold_start() {
        // a slow cold start (50 x ~90 ms) permanently dominates the
        // cumulative p99, but the window sees only the current interval
        let h = LatencyHistogram::default();
        let mut w = LatencyWindow::new();
        for _ in 0..50 {
            h.record_us(90_000);
        }
        let cold = w.advance_quantile_us(&h, 0.99);
        assert!(cold > 10_000.0, "cold-start window p99 {cold}");
        // steady state: 1000 fast requests in the next interval
        for _ in 0..1000 {
            h.record_us(9);
        }
        let steady = w.advance_quantile_us(&h, 0.99);
        assert!(steady <= 10.0, "windowed p99 {steady} still skewed");
        // ... while the cumulative histogram never forgets
        assert!(h.p99_us() > 10_000.0, "cumulative p99 {}", h.p99_us());
    }

    #[test]
    fn latency_window_empty_interval_reports_zero() {
        let h = LatencyHistogram::default();
        let mut w = LatencyWindow::new();
        h.record_us(90_000);
        assert!(w.advance_quantile_us(&h, 0.99) > 0.0);
        // no new samples: no tail pressure, NOT the cumulative fallback
        assert_eq!(w.advance_quantile_us(&h, 0.99), 0.0);
    }

    #[test]
    fn latency_window_first_advance_matches_cumulative() {
        let h = LatencyHistogram::default();
        for _ in 0..900 {
            h.record_us(9);
        }
        for _ in 0..100 {
            h.record_us(900);
        }
        let mut w = LatencyWindow::new();
        assert_eq!(w.advance_quantile_us(&h, 0.99), h.p99_us());
    }

    #[test]
    fn batch_size_histogram_buckets() {
        let h = BatchSizeHistogram::default();
        for n in [1u64, 1, 2, 3, 8, 16, 17, 64] {
            h.record(n);
        }
        assert_eq!(h.count(), 8);
        let snap = h.snapshot();
        assert_eq!(snap[0], 2); // <= 1
        assert_eq!(snap[1], 1); // (1, 2]
        assert_eq!(snap[2], 1); // (2, 4]
        assert_eq!(snap[3], 1); // (4, 8]
        assert_eq!(snap[4], 1); // (8, 16]
        assert_eq!(snap[5], 1); // (16, 32]
        assert_eq!(snap[BATCH_SIZE_NUM_BUCKETS - 1], 1); // overflow
    }

    #[test]
    fn histogram_concurrent_recording() {
        use std::sync::Arc;
        let h = Arc::new(LatencyHistogram::default());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record_us(t * 113 + i % 97);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_energy_uj(4.1234), "4.1");
        assert_eq!(fmt_energy_uj(36.2), "36");
        assert_eq!(fmt_energy_uj(23_000.0), "23k");
        assert_eq!(fmt_cells(15_000_000.0), "15M");
        assert_eq!(fmt_cells(3_200_000.0), "3.2M");
        assert_eq!(fmt_delay_us(2.8), "2.8");
        assert_eq!(fmt_delay_us(151.0), "151");
        assert_eq!(fmt_pct(0.936), "93.6%");
    }
}
